//! Merging & composition demo: build a multitask model from the GLUE
//! experts with Task Arithmetic and TIES (original vs ComPEFT inputs),
//! then adapt to an unseen compositional task with LoraHub-style
//! gradient-free composition of compressed experts.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example merge_and_compose [scale]

use anyhow::Result;
use compeft::bench_support as bs;
use compeft::compeft::compress::{compress_params, CompressConfig, Granularity};
use compeft::compeft::engine::par_merge;
use compeft::coordinator::registry::ExpertMethod;
use compeft::eval::fewshot_loss;
use compeft::merging::es::EsConfig;
use compeft::merging::lorahub::learn_composition;
use compeft::merging::{task_arithmetic, ties::ties_merge, ties::TiesConfig, MergeMethod};
use compeft::runtime::AdapterKind;
use compeft::tensor::ParamSet;
use compeft::util::pool::ThreadPool;
use compeft::util::rng::Pcg;

const GLUE: [&str; 7] = ["mnli", "rte", "qnli", "wnli", "sst2", "mrpc", "qqp"];

fn main() -> Result<()> {
    let scale = std::env::args().nth(1).unwrap_or_else(|| "s".into());
    let artifacts = bs::require_artifacts();
    let (_rt, bundle) = bs::load_bundle(&artifacts, &scale)?;

    // ---- Part 1: merge the 7 GLUE experts into one multitask model.
    let experts: Vec<_> = GLUE
        .iter()
        .filter_map(|t| bs::load_expert(&artifacts, &scale, t, "lora", None).ok())
        .collect();
    anyhow::ensure!(experts.len() == 7, "need all 7 GLUE experts (make artifacts)");
    let tvs: Vec<ParamSet> = experts.iter().map(|e| e.tv.clone()).collect();
    let ctvs: Vec<ParamSet> =
        experts.iter().map(|e| bs::compress_tv(&e.tv, 0.2, 1.0)).collect();

    let tests: Vec<_> = GLUE
        .iter()
        .map(|t| bs::load_eval(&artifacts, &format!("glue_{t}")))
        .collect::<Result<_>>()?;
    let eval_avg = |tv: &ParamSet| -> Result<f64> {
        let mut s = 0.0;
        for set in &tests {
            s += bs::eval_tv(&bundle, ExpertMethod::Lora, tv, set)?;
        }
        Ok(s / tests.len() as f64)
    };

    println!("== merging 7 GLUE-analog experts (scale {scale}) ==");
    for (name, merged) in [
        ("task-arithmetic (orig)", task_arithmetic(&tvs, 0.3)?),
        ("task-arithmetic (ComPEFT)", task_arithmetic(&ctvs, 0.3)?),
        ("TIES (orig)", ties_merge(&tvs, &TiesConfig::default())?),
        ("TIES (ComPEFT)", ties_merge(&ctvs, &TiesConfig::default())?),
    ] {
        println!("  {name:28} avg accuracy {:.3}", eval_avg(&merged)?);
    }

    // Ternary-domain merging: the same ComPEFT TIES result computed
    // directly on the compressed payloads — no per-expert dense
    // materialization — chunk-parallel, and bit-identical by contract.
    let ccfg = CompressConfig {
        density: 0.2,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let comps: Vec<_> = experts.iter().map(|e| compress_params(&e.tv, &ccfg)).collect();
    let refs: Vec<&_> = comps.iter().collect();
    let pool = ThreadPool::new(4);
    let t0 = std::time::Instant::now();
    let tern = par_merge(&refs, &MergeMethod::Ties { density: 0.2, lambda: 1.0 }, &pool)?;
    let dt = t0.elapsed();
    assert_eq!(tern, ties_merge(&ctvs, &TiesConfig::default())?);
    println!("  TIES (ternary-domain)        bit-identical, merged in {dt:?}");

    // ---- Part 2: LoraHub composition for an unseen compositional task.
    let mut pool = Vec::new();
    for i in 0..12 {
        if let Ok(e) =
            bs::load_expert(&artifacts, &scale, &format!("pre{i:02}"), "lora", None)
        {
            pool.push(bs::compress_tv(&e.tv, 0.2, 1.0)); // compressed pool
        }
    }
    if pool.is_empty() {
        println!("(no pretrain-rule pool at scale {scale}; skipping LoraHub demo)");
        return Ok(());
    }
    let task = "bbh00";
    let test = bs::load_eval(&artifacts, &format!("bbh_{task}"))?;
    let fewshot = bs::load_eval(&artifacts, &format!("bbh_{task}_fewshot"))?;
    let zs = compeft::eval::evaluate(
        &bundle,
        AdapterKind::Base,
        bs::EVAL_BATCH,
        None,
        None,
        &test,
    )?;
    println!("\n== LoraHub composition on unseen task {task} ==");
    println!("  zero-shot: {zs:.3}");

    let mut rng = Pcg::seed(11);
    let result = learn_composition(
        &pool,
        &EsConfig { budget: 80, restarts: 2, l1: 0.05, ..Default::default() },
        &mut rng,
        |tv| {
            let mut adapter = (*bundle.lora_init).clone();
            adapter.add_assign(tv).unwrap();
            fewshot_loss(&bundle, AdapterKind::Lora, bs::EVAL_BATCH, &adapter, &fewshot)
                .unwrap_or(f64::INFINITY)
        },
    )?;
    let acc = bs::eval_tv(&bundle, ExpertMethod::Lora, &result.composed, &test)?;
    println!(
        "  LoraHub over {} ComPEFT experts: {:.3} (few-shot loss {:.3}, {} evals)",
        pool.len(),
        acc,
        result.best_loss,
        result.evals
    );
    println!(
        "  learned weights: {:?}",
        result.weights.iter().map(|w| (w * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    Ok(())
}
