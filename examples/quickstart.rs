//! Quickstart: compress a task vector with ComPEFT, inspect the sizes,
//! round-trip both wire encodings, and use the fast bit-level ops.
//!
//! Works without artifacts (synthesizes a realistic task vector).
//!
//! Run: `cargo run --release --example quickstart`

use compeft::compeft::bitmask::MaskPair;
use compeft::compeft::compress::{
    compress_vector, decompress_vector, CompressConfig,
};
use compeft::compeft::entropy::{
    compeft_entropy_bits, entropy_compression_ratio, human_bytes,
};
use compeft::compeft::golomb;
use compeft::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // A LoRA-sized task vector: near-zero-mean gaussian with heavy tail
    // (the structure the paper's Table 7 reports).
    let d = 1 << 21; // 2M params
    let mut rng = Pcg::seed(42);
    let tau: Vec<f32> = (0..d)
        .map(|_| {
            let v = rng.normal_ms(0.0, 7e-4) as f32;
            if rng.next_f32() < 0.01 { v * 20.0 } else { v }
        })
        .collect();
    println!("task vector: {} params = {} at fp16", d, human_bytes(d as u64 * 2));

    // Algorithm 1: keep top-5% magnitudes as signs, scale by α·σ.
    let cfg = CompressConfig { density: 0.05, alpha: 1.0, ..Default::default() };
    let tern = compress_vector(&tau, &cfg);
    println!(
        "compressed: {} nonzeros (density {:.1}%), shared scale {:+.2e}",
        tern.nnz(),
        tern.density() * 100.0,
        tern.scale
    );

    // Wire encoding 1: Golomb (storage-optimal).
    let bytes = golomb::encode(&tern);
    println!(
        "golomb coded: {} ({:.1}x smaller than fp16; entropy bound {} → ratio {:.1}x)",
        human_bytes(bytes.len() as u64),
        (d as f64 * 2.0) / bytes.len() as f64,
        human_bytes((compeft_entropy_bits(d, 0.05) / 8.0) as u64),
        entropy_compression_ratio(d, 0.05),
    );
    let decoded = golomb::decode(&bytes)?;
    assert_eq!(decoded, tern);

    // Wire encoding 2: two binary masks (compute-optimal).
    let masks = MaskPair::from_ternary(&tern);
    println!(
        "mask pair: {} (2 bits/param), XOR+POPCNT distance & AND-dot ready",
        human_bytes(masks.wire_bytes())
    );

    // Fast ops on compressed experts: similarity without decompression.
    let tern2 = compress_vector(
        &tau.iter().map(|v| v * 0.5 + 1e-4).collect::<Vec<_>>(),
        &cfg,
    );
    let masks2 = MaskPair::from_ternary(&tern2);
    println!(
        "sign cosine to a perturbed expert: {:.3} (dot {:+.3e}, l1 dist {})",
        masks.sign_cosine(&masks2)?,
        masks.dot(&masks2)?,
        masks.ternary_l1_distance(&masks2)?
    );

    // Reconstruction: how close is τ̃ to τ on the kept coordinates?
    let dense = decompress_vector(&tern);
    let kept: Vec<usize> = (0..d).filter(|&i| dense[i] != 0.0).collect();
    let sign_match = kept
        .iter()
        .filter(|&&i| dense[i].signum() == tau[i].signum())
        .count();
    println!(
        "reconstruction: {}/{} kept coordinates have the original sign",
        sign_match,
        kept.len()
    );
    println!("quickstart OK");
    Ok(())
}
