//! END-TO-END DRIVER (recorded in EXPERIMENTS.md): serve a pool of
//! fine-tuned experts through the full three-layer stack —
//!
//!   Zipf request trace → router/batcher (Rust) → tiered cache with
//!   simulated internet/PCIe links → Golomb decode → PJRT execution of
//!   the AOT-lowered µT forward (JAX/Pallas lowered at build time) →
//!   rank-classified answers.
//!
//! Runs the SAME trace twice — original fp16 experts vs ComPEFT
//! `.cpeft` experts — and reports throughput, latency percentiles, swap
//! counts, cache hit-rates, and bytes moved, demonstrating the paper's
//! serving claim end to end with real accuracy preserved.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example serve_experts [scale] [n_requests] \
//!       [--store-nodes N] [--replication R] [--rebalance]
//!
//! With `--store-nodes` the coordinator fetches experts from the
//! sharded, replicated store (striped multi-replica transfers with
//! CRC-verified failover) instead of the flat single link — the served
//! predictions are bit-identical either way. `--rebalance` adds
//! popularity-driven adaptive replication on top: hot experts widen,
//! cold ones narrow back to base, under a per-round migration budget.

use anyhow::{Context, Result};
use compeft::bench_support as bs;
use compeft::compeft::compress::{CompressConfig, Granularity};
use compeft::compeft::entropy::human_bytes;
use compeft::coordinator::batcher::BatchPolicy;
use compeft::coordinator::registry::scan_expert_npz;
use compeft::coordinator::{
    Coordinator, CoordinatorConfig, ExpertMethod, LinkSpec, Registry,
};
use compeft::eval::EvalSet;
use compeft::util::rng::{Pcg, Zipf};
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = compeft::util::cli::ArgSpec::new(
        "serve_experts",
        "serve the expert pool over original vs ComPEFT checkpoints; \
         positionals: [scale] [n_requests]",
    )
    .flag("store-nodes", "0", "sharded store nodes (0 = flat single link)")
    .flag("replication", "1", "replicas per expert in the sharded store")
    .boolean(
        "rebalance",
        "popularity-driven adaptive replication (needs --store-nodes > 0)",
    )
    .flag("rebalance-every", "8", "batches between rebalance rounds")
    .flag(
        "archive",
        "",
        "local .cpar archive (see `compeft archive build`) served as \
         zero-copy views; applies to the compeft leg only",
    );
    let a = spec.parse(&argv)?;
    // Malformed values error out loudly instead of silently falling
    // back to the flat store.
    let store_nodes = a.get_usize("store-nodes")?;
    let replication = a.get_usize("replication")?;
    let rebalance = a.get_bool("rebalance");
    let rebalance_every = a.get_u64("rebalance-every")?;
    anyhow::ensure!(
        !rebalance || store_nodes > 0,
        "--rebalance needs a sharded store (--store-nodes > 0)"
    );
    let archive = a.get("archive").to_string();
    let scale = a
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "s".into());
    let n_req: usize = a
        .positional()
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let artifacts = bs::require_artifacts();

    // Expert pool: every instruct-task LoRA expert of this scale.
    let found = scan_expert_npz(&artifacts, &scale)?;
    let pool: Vec<(String, std::path::PathBuf)> = found
        .iter()
        .filter(|(task, m, _)| {
            *m == ExpertMethod::Lora
                && artifacts.join("eval").join(format!("task_{task}.npz")).exists()
        })
        .map(|(task, _, path)| (task.clone(), path.clone()))
        .collect();
    anyhow::ensure!(!pool.is_empty(), "no experts for scale {scale}; run `make artifacts`");
    println!("scale {scale}: serving {} experts, {} requests\n", pool.len(), n_req);

    let mut summary = Vec::new();
    for format in ["original", "compeft"] {
        let mut registry = Registry::new();
        let mut ids = Vec::new();
        for (task, path) in &pool {
            let id = format!("{task}.lora");
            if format == "compeft" {
                registry.register_compeft(
                    &id,
                    task,
                    &scale,
                    ExpertMethod::Lora,
                    path,
                    &CompressConfig {
                        density: 0.2,
                        alpha: 1.0,
                        granularity: Granularity::Global,
                    },
                )?;
            } else {
                registry.register_original(&id, task, &scale, ExpertMethod::Lora, path)?;
            }
            ids.push((id, task.clone()));
        }
        let expert_bytes = registry.get(&ids[0].0).unwrap().encoded_bytes;

        // GPU tier sized for ~2 original experts: ComPEFT fits the whole
        // pool, originals thrash — the paper's §1 scenario.
        let orig_bytes = {
            let mut r = Registry::new();
            r.register_original("x", "x", &scale, ExpertMethod::Lora, &pool[0].1)?;
            r.get("x").unwrap().encoded_bytes
        };
        let mut cfg = CoordinatorConfig::new(artifacts.clone(), &scale);
        cfg.gpu_capacity_bytes = orig_bytes * 2 + orig_bytes / 2;
        cfg.policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
        cfg.net = LinkSpec::internet();
        cfg.pcie = LinkSpec::pcie();
        cfg.store_nodes = store_nodes;
        cfg.replication = replication;
        cfg.rebalance = rebalance;
        cfg.rebalance_every = rebalance_every;
        // The archive holds `.cpeft` members; the original-fp16 leg
        // must not view ComPEFT bytes for its npz-format experts.
        if format == "compeft" && !archive.is_empty() {
            cfg.archive = Some(std::path::PathBuf::from(&archive));
        }
        let coord = Coordinator::start(cfg, registry)?;

        // Identical Zipf trace for both formats.
        let mut rng = Pcg::seed(7);
        let zipf = Zipf::new(ids.len(), 1.1);
        let sets: Vec<EvalSet> = ids
            .iter()
            .map(|(_, t)| bs::load_eval(&artifacts, &format!("task_{t}")))
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n_req);
        for _ in 0..n_req {
            let e = zipf.sample(&mut rng);
            let set = &sets[e];
            let i = rng.range(0, set.n);
            pending.push((
                coord.submit(
                    &ids[e].0,
                    set.tokens[i * set.seq..(i + 1) * set.seq].to_vec(),
                    set.n_classes[i] as usize,
                ),
                set.labels[i],
            ));
        }
        let mut correct = 0usize;
        for (rx, label) in pending {
            let p = rx.recv().context("reply")?;
            if p.class as i64 == label {
                correct += 1;
            }
        }
        let wall = t0.elapsed();
        let m = coord.metrics();
        let report = coord.shutdown()?;

        println!("=== {format} (expert = {}) ===", human_bytes(expert_bytes));
        println!(
            "  accuracy {:.3}   throughput {:.1} req/s   wall {:.2?}",
            correct as f64 / n_req as f64,
            n_req as f64 / wall.as_secs_f64(),
            wall
        );
        println!(
            "  latency mean {:.2}ms  p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms",
            m.total_mean_us / 1e3,
            m.total_p50_us / 1e3,
            m.total_p95_us / 1e3,
            m.total_p99_us / 1e3
        );
        println!(
            "  swaps {} / {} batches (gpu hit-rate {:.2}), swap mean {:.2}ms",
            m.swaps,
            m.batches,
            report.gpu.hit_rate(),
            m.swap_mean_us / 1e3
        );
        println!(
            "  bytes moved: net {}  pcie {}  gpu residents {}",
            human_bytes(report.net_bytes),
            human_bytes(report.pcie_bytes),
            report.gpu.entries
        );
        println!(
            "  prefetch: {} hits / {} waits / {} misses, overlap saved {:.2?}",
            report.prefetch_hits,
            report.prefetch_waits,
            report.prefetch_misses,
            report.overlap_saved
        );
        if store_nodes > 0 {
            println!(
                "  store: {} nodes x{} replication, {} stripe retries / {} failovers \
                 / {} corrupt\n",
                store_nodes,
                replication,
                report.stripe_retries,
                report.failovers,
                report.corrupt_payloads
            );
        } else {
            println!();
        }
        if rebalance {
            println!(
                "  rebalance: {} rounds, +{} / -{} replicas, {} migrated\n",
                report.rebalances,
                report.replicas_added,
                report.replicas_dropped,
                human_bytes(report.migrated_bytes)
            );
        }
        if report.archive_hits > 0 {
            println!(
                "  archive: {} hits, {} viewed in place, {} payload copies\n",
                report.archive_hits,
                human_bytes(report.archive_bytes_viewed),
                report.payload_copies
            );
        }
        if report.fused_loads > 0 {
            println!(
                "  fused decode: {} loads, overlap hidden {:.2?}\n",
                report.fused_loads, report.decode_overlap
            );
        }
        summary.push((
            format,
            n_req as f64 / wall.as_secs_f64(),
            m.total_p95_us / 1e3,
            report.net_bytes,
            correct as f64 / n_req as f64,
        ));
    }

    if summary.len() == 2 {
        let (o, c) = (&summary[0], &summary[1]);
        println!("=== ComPEFT vs original ===");
        println!(
            "  throughput {:.1} → {:.1} req/s ({:.2}x)   p95 {:.1} → {:.1} ms ({:.2}x)",
            o.1,
            c.1,
            c.1 / o.1,
            o.2,
            c.2,
            o.2 / c.2
        );
        println!(
            "  network bytes {} → {} ({:.1}x less)   accuracy {:.3} → {:.3}",
            human_bytes(o.3),
            human_bytes(c.3),
            o.3 as f64 / c.3 as f64,
            o.4,
            c.4
        );
    }
    Ok(())
}
