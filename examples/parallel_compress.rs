//! Parallel chunked compression engine demo: compress a synthetic
//! 4M-parameter task vector serially and on thread pools of growing
//! size, verify the outputs are bit-identical, and show the wall-clock
//! scaling of Algorithm 1, the parallel Golomb encode, and the
//! frame-table decode path the serving engine runs on expert swap-in.
//!
//! Works without artifacts. Run:
//!   cargo run --release --example parallel_compress [d]

use compeft::compeft::compress::{compress_params, decompress_params, CompressConfig};
use compeft::compeft::engine::{par_compress_paramset, par_decompress_params};
use compeft::compeft::format::{from_bytes, from_bytes_par, to_bytes, to_bytes_par, Encoding};
use compeft::compeft::golomb;
use compeft::compeft::Granularity;
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::pool::ThreadPool;
use compeft::util::rng::Pcg;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let d: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 22); // 4M params

    // A LoRA-shaped expert: a handful of tensors summing to d params.
    let mut rng = Pcg::seed(7);
    let mut tv = ParamSet::new();
    let per = d / 4;
    for i in 0..4 {
        let n = if i == 3 { d - 3 * per } else { per };
        let data: Vec<f32> = (0..n)
            .map(|_| {
                let v = rng.normal_ms(0.0, 7e-4) as f32;
                if rng.next_f32() < 0.01 { v * 20.0 } else { v }
            })
            .collect();
        tv.insert(&format!("layer.{i}.w"), Tensor::new(vec![n], data));
    }
    let cfg = CompressConfig { density: 0.05, alpha: 1.0, granularity: Granularity::Global };
    println!("τ: {} params across {} tensors, k = {}\n", d, tv.len(), cfg.density);

    // Serial reference.
    let t0 = Instant::now();
    let serial = compress_params(&tv, &cfg);
    let serial_time = t0.elapsed();
    println!("{:<26} {:>10.2?}", "serial compress", serial_time);

    // Parallel engine at increasing worker counts.
    for workers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(workers);
        let t0 = Instant::now();
        let par = par_compress_paramset(&tv, &cfg, &pool);
        let elapsed = t0.elapsed();
        let identical = par
            .parts
            .iter()
            .zip(&serial.parts)
            .all(|((na, a), (nb, b))| {
                na == nb
                    && a.len == b.len
                    && a.scale.to_bits() == b.scale.to_bits()
                    && a.plus == b.plus
                    && a.minus == b.minus
            });
        assert!(identical, "parallel output diverged at {workers} workers");
        println!(
            "{:<26} {:>10.2?}  ({:.2}x, bit-identical)",
            format!("parallel compress w={workers}"),
            elapsed,
            serial_time.as_secs_f64() / elapsed.as_secs_f64()
        );
    }

    // Parallel wire encode of the plus/minus index streams.
    let pool = ThreadPool::new(8);
    let t0 = Instant::now();
    let bytes = to_bytes(&serial, Encoding::Golomb);
    let enc_serial = t0.elapsed();
    let t0 = Instant::now();
    let bytes_par = to_bytes_par(&serial, Encoding::Golomb, &pool);
    let enc_par = t0.elapsed();
    assert_eq!(bytes, bytes_par, "parallel container encode diverged");
    println!(
        "\n{:<26} {:>10.2?}\n{:<26} {:>10.2?}  ({:.2}x, byte-identical, {} bytes)",
        "serial golomb encode",
        enc_serial,
        "parallel golomb encode w=8",
        enc_par,
        enc_serial.as_secs_f64() / enc_par.as_secs_f64(),
        bytes.len()
    );

    // Round-trip sanity through the parallel encoder's bytes.
    let global = &serial.parts[""];
    let decoded = golomb::decode(&golomb::encode_par(global, &pool, 1 << 15))?;
    assert_eq!(&decoded, global);

    // The decode mirror (serving swap-in): v2 frame-table container
    // parse + dense materialization, serial vs parallel.
    let t0 = Instant::now();
    let (c_serial, _) = from_bytes(&bytes)?;
    let tv_serial = decompress_params(&c_serial, &tv)?;
    let dec_serial = t0.elapsed();
    println!("\n{:<26} {:>10.2?}", "serial decode+material.", dec_serial);
    for workers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(workers);
        let t0 = Instant::now();
        let (c_par, _) = from_bytes_par(&bytes, &pool)?;
        let tv_par = par_decompress_params(&c_par, &tv, &pool)?;
        let elapsed = t0.elapsed();
        assert_eq!(tv_par, tv_serial, "parallel decode diverged at {workers} workers");
        println!(
            "{:<26} {:>10.2?}  ({:.2}x, bit-identical)",
            format!("parallel decode w={workers}"),
            elapsed,
            dec_serial.as_secs_f64() / elapsed.as_secs_f64()
        );
    }

    println!("\nparallel_compress OK");
    Ok(())
}
