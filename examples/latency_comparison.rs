//! Table-5-style latency demo: time the download (simulated internet)
//! and host→device (simulated PCIe) hops for an original vs ComPEFT
//! expert checkpoint, plus the host-side Golomb decode, end to end.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example latency_comparison [scale]

use anyhow::Result;
use compeft::bench_support as bs;
use compeft::compeft::compress::CompressConfig;
use compeft::compeft::entropy::human_bytes;
use compeft::coordinator::loader::ExpertLoader;
use compeft::coordinator::registry::{ExpertMethod, Registry};
use compeft::coordinator::transport::{LinkSpec, SimLink};

fn main() -> Result<()> {
    let scale = std::env::args().nth(1).unwrap_or_else(|| "m".into());
    let artifacts = bs::require_artifacts();
    let npz = artifacts.join("experts").join(&scale).join("alpaca.lora.npz");
    anyhow::ensure!(npz.exists(), "run `make artifacts` first");

    let expert = bs::load_expert(&artifacts, &scale, "alpaca", "lora", None)?;
    let mut reg = Registry::new();
    reg.register_original("orig", "alpaca", &scale, ExpertMethod::Lora, &npz)?;
    for (id, k) in [("k05", 0.05), ("k20", 0.2), ("k50", 0.5)] {
        reg.register_compeft(
            id,
            "alpaca",
            &scale,
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: k, alpha: 1.0, ..Default::default() },
        )?;
    }

    println!(
        "expert: {} LoRA task vector, {} params ({} at fp16)\n",
        scale,
        expert.tv.total_elements(),
        human_bytes(expert.tv.bytes_fp16())
    );
    println!(
        "{:<10} {:>10} {:>14} {:>14} {:>12} {:>10}",
        "format", "size", "internet", "cpu→gpu", "decode", "speedup"
    );
    let mut base_total = None;
    for id in ["orig", "k50", "k20", "k05"] {
        let rec = reg.get(id).unwrap().clone();
        let loader = ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()),
            SimLink::new("pcie", LinkSpec::pcie()),
        );
        let (bytes, fetch) = loader.fetch_encoded(&rec)?;
        let (_tv, decode) = loader.decode(&rec, &bytes, &bundle_template(&expert))?;
        let upload = loader.upload_cost(&rec);
        let total = fetch + decode + upload;
        let speedup = base_total
            .map(|b: std::time::Duration| b.as_secs_f64() / total.as_secs_f64())
            .unwrap_or(1.0);
        if base_total.is_none() {
            base_total = Some(total);
        }
        println!(
            "{:<10} {:>10} {:>12.2}ms {:>12.3}ms {:>10.2}ms {:>9.1}x",
            id,
            human_bytes(rec.encoded_bytes),
            fetch.as_secs_f64() * 1e3,
            upload.as_secs_f64() * 1e3,
            decode.as_secs_f64() * 1e3,
            speedup
        );
    }
    println!("\n(internet: 800 MB/s + 40 ms RTT; pcie: 12 GB/s + 10 µs — DESIGN.md §3.5)");
    Ok(())
}

fn bundle_template(expert: &bs::Expert) -> compeft::tensor::ParamSet {
    expert.tv.clone()
}
