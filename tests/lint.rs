//! Tier-1 gate: `compeft-lint` must report zero unsuppressed
//! violations over `rust/src`. The same pass runs as `compeft lint`
//! (CLI) and as a dedicated CI step; this test keeps it inside
//! `cargo test -q` so a violation can't land even when CI config is
//! bypassed.

use std::path::Path;

#[test]
fn tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let diags = compeft::analysis::lint_tree(root).expect("lint walk failed");
    if !diags.is_empty() {
        for d in &diags {
            eprintln!("{d}");
        }
        panic!(
            "compeft-lint: {} violation(s); fix them or annotate with \
             `// compeft-lint: allow(rule-id) -- <reason>`",
            diags.len()
        );
    }
}
