//! Integration tests in two tiers.
//!
//! **Synthetic-fixture tests** (always run): a deterministic in-memory
//! `ParamSet` built from `util::rng::Pcg` exercises the compression
//! engine (serial + parallel), the `.cpeft` container, and the expert
//! registry end to end — no artifacts required.
//!
//! **Artifact tests** (skip cleanly without `make artifacts`): the three
//! layers composed — PJRT runtime executing AOT-lowered HLO, expert
//! compression, and the serving coordinator.

use compeft::bench_support as bs;
use compeft::compeft::compress::{
    compress_params, decompress_params, CompressConfig, Granularity,
};
use compeft::compeft::engine::{
    par_compress_paramset, par_decompress_params, par_merge,
};
use compeft::compeft::format::{self, to_bytes, to_bytes_par, Encoding};
use compeft::coordinator::batcher::BatchPolicy;
use compeft::coordinator::registry::{scan_expert_npz, ExpertMethod, Registry};
use compeft::coordinator::{AdmissionConfig, Coordinator, CoordinatorConfig, LinkSpec};
use compeft::merging::ternary::merge_ternary;
use compeft::merging::{merge_dense, MergeMethod};
use compeft::runtime::AdapterKind;
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::pool::ThreadPool;
use compeft::util::prop;
use compeft::util::rng::Pcg;
use compeft::workload::sim::{self, Outcome, ServiceModel, SimConfig};
use compeft::workload::{Trace, TraceSpec};
use std::path::PathBuf;
use std::time::Duration;

fn artifacts() -> Option<PathBuf> {
    let dir = bs::artifacts_dir();
    if dir.join("models/xs/base.npz").exists() {
        Some(dir)
    } else {
        eprintln!("skipping integration test: run `make artifacts`");
        None
    }
}

// ---------------------------------------------------------------------------
// Synthetic fixture (no artifacts)
// ---------------------------------------------------------------------------

/// A LoRA-shaped synthetic expert task vector: a few tensors of mixed
/// sizes with heavy-tailed near-zero values (Table 7 statistics).
fn synthetic_tv(seed: u64, scale_elems: usize) -> ParamSet {
    let mut rng = Pcg::seed(seed);
    let mut tv = ParamSet::new();
    for (i, n) in [scale_elems, scale_elems / 2, 257, scale_elems / 4]
        .into_iter()
        .enumerate()
    {
        let data: Vec<f32> = (0..n)
            .map(|_| {
                let v = rng.normal_ms(0.0, 7e-4) as f32;
                if rng.next_f32() < 0.01 { v * 20.0 } else { v }
            })
            .collect();
        tv.insert(&format!("layers.{i}.attn.lora_a"), Tensor::new(vec![n], data));
    }
    tv
}

fn fresh_dir(name: &str) -> PathBuf {
    // Suffix with the pid so concurrent `cargo test` runs don't collide.
    let dir = std::env::temp_dir()
        .join(format!("compeft_it_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Compression → container → decompression, serial and parallel, over
/// both granularities and both wire encodings — the full L2 pipeline an
/// expert checkpoint travels, on the synthetic fixture.
#[test]
fn synthetic_compress_container_roundtrip() -> anyhow::Result<()> {
    let dir = fresh_dir("roundtrip");
    let tv = synthetic_tv(11, 20_000);
    let pool = ThreadPool::new(4);
    for granularity in [Granularity::Global, Granularity::PerTensor] {
        for enc in [Encoding::Golomb, Encoding::Bitmask] {
            let cfg = CompressConfig { density: 0.1, alpha: 1.0, granularity };
            let serial = compress_params(&tv, &cfg);
            let par = par_compress_paramset(&tv, &cfg, &pool);

            // Parallel engine must be bit-identical to serial, which the
            // byte encodings make directly observable.
            let bytes = to_bytes(&serial, enc);
            assert_eq!(bytes, to_bytes(&par, enc), "{granularity:?}/{enc:?}");
            assert_eq!(bytes, to_bytes_par(&par, enc, &pool), "{granularity:?}/{enc:?} par");

            // Disk roundtrip through the .cpeft container.
            let path = dir.join(format!("e_{granularity:?}_{enc:?}.cpeft"));
            let written = format::save(&path, &serial, enc)?;
            assert!(written > 0);
            let (back, benc) = format::load(&path)?;
            assert_eq!(benc, enc);
            assert_eq!(back, serial);

            // Reconstruction: kept coordinates carry α·σ·sgn(τ).
            let dense = decompress_params(&back, &tv)?;
            assert_eq!(dense.names(), tv.names());
            for (name, t) in dense.iter() {
                let orig = tv.get(name).unwrap();
                assert_eq!(t.shape, orig.shape);
                for (rec, o) in t.data.iter().zip(&orig.data) {
                    if *rec != 0.0 {
                        assert_eq!(rec.signum(), o.signum(), "{name}");
                    }
                }
            }
            let k = back.density();
            assert!((k - 0.1).abs() < 0.02, "density {k}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// The PR 2 serving decode path on the synthetic fixture: v2 containers
/// decode identically through the serial and the frame-parallel
/// readers, v1 containers stay readable through both, and the parallel
/// dense materialization matches the serial one — the full wire →
/// adapter pipeline an expert travels on a GPU-tier miss.
#[test]
fn synthetic_v2_parallel_decode_and_v1_compat() -> anyhow::Result<()> {
    let tv = synthetic_tv(31, 30_000);
    let pool = ThreadPool::new(4);
    for granularity in [Granularity::Global, Granularity::PerTensor] {
        for enc in [Encoding::Golomb, Encoding::Bitmask] {
            let cfg = CompressConfig { density: 0.1, alpha: 1.0, granularity };
            let c = compress_params(&tv, &cfg);
            let v2 = to_bytes(&c, enc);
            let v1 = format::to_bytes_v1(&c, enc);
            assert_ne!(v1, v2, "framing must change the wire bytes");
            for bytes in [&v2, &v1] {
                let (serial, _) = format::from_bytes(bytes)?;
                let (par, _) = format::from_bytes_par(bytes, &pool)?;
                assert_eq!(serial, c, "{granularity:?}/{enc:?}");
                assert_eq!(par, c, "{granularity:?}/{enc:?} par");
            }
            let dense_serial = decompress_params(&c, &tv)?;
            let dense_par = par_decompress_params(&c, &tv, &pool)?;
            assert_eq!(dense_serial, dense_par, "{granularity:?}/{enc:?} dense");
        }
    }
    Ok(())
}

/// Registry flow without artifacts: save the synthetic expert as npz,
/// register original + ComPEFT forms, and check the encoded-size story
/// (the paper's storage claim) end to end through real files.
#[test]
fn synthetic_registry_and_sizes() -> anyhow::Result<()> {
    let dir = fresh_dir("registry");
    let tv = synthetic_tv(23, 8_192);
    let npz = dir.join("synth.lora.npz");
    tv.save_npz(&npz)?;

    let mut reg = Registry::new();
    reg.register_original("synth/orig", "synth", "s", ExpertMethod::Lora, &npz)?;
    for (id, k) in [("synth/k05", 0.05), ("synth/k20", 0.2)] {
        reg.register_compeft(
            id,
            "synth",
            "s",
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: k, alpha: 1.0, granularity: Granularity::Global },
        )?;
    }
    let orig = reg.get("synth/orig").unwrap().encoded_bytes;
    let k05 = reg.get("synth/k05").unwrap().encoded_bytes;
    let k20 = reg.get("synth/k20").unwrap().encoded_bytes;
    assert_eq!(orig, tv.bytes_fp16());
    assert!(k05 < k20 && k20 < orig, "sizes {k05} < {k20} < {orig}");
    // Paper §2.2: at k=0.05 the Golomb-coded update is >20x below fp16.
    assert!(orig as f64 / k05 as f64 > 20.0, "ratio {}", orig as f64 / k05 as f64);

    // The registered .cpeft decodes back to the compressor's output.
    let rec = reg.get("synth/k20").unwrap();
    let (loaded, enc) = format::load(&rec.path)?;
    assert_eq!(enc, Encoding::Golomb);
    let expect = compress_params(
        &tv,
        &CompressConfig { density: 0.2, alpha: 1.0, granularity: Granularity::Global },
    );
    assert_eq!(loaded, expect);

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Ternary-domain merging over the real wire: compress N synthetic
/// experts, roundtrip each through `.cpeft` bytes, merge the decoded
/// payloads without densifying them — and match the dense
/// decompress-then-merge reference bit for bit, serial and pooled, for
/// all four merge methods. This is the exact path a merged expert takes
/// on a serving miss.
#[test]
fn synthetic_ternary_merge_matches_dense_over_wire() -> anyhow::Result<()> {
    let tvs: Vec<ParamSet> =
        (0..3).map(|i| synthetic_tv(41 + i, 12_000)).collect();
    for granularity in [Granularity::Global, Granularity::PerTensor] {
        let cfg = CompressConfig { density: 0.1, alpha: 1.0, granularity };
        // Through the wire: encode + decode each member.
        let members: Vec<_> = tvs
            .iter()
            .map(|tv| {
                let bytes = to_bytes(&compress_params(tv, &cfg), Encoding::Golomb);
                format::from_bytes(&bytes).map(|(c, _)| c)
            })
            .collect::<anyhow::Result<_>>()?;
        let refs: Vec<&_> = members.iter().collect();
        let dense: Vec<ParamSet> = members
            .iter()
            .zip(&tvs)
            .map(|(c, tv)| decompress_params(c, tv))
            .collect::<anyhow::Result<_>>()?;
        for method in [
            MergeMethod::Average,
            MergeMethod::TaskArithmetic { lambda: 0.3 },
            MergeMethod::Ties { density: 0.2, lambda: 1.0 },
            MergeMethod::Weighted { weights: vec![0.8, -0.3, 0.5] },
        ] {
            let want = merge_dense(&dense, &method)?;
            let serial = merge_ternary(&refs, &method)?;
            assert_eq!(serial, want, "{granularity:?}/{method:?} serial");
            for workers in prop::pool_sizes() {
                let pool = ThreadPool::new(workers);
                let par = par_merge(&refs, &method, &pool)?;
                assert_eq!(par, want, "{granularity:?}/{method:?} w={workers}");
            }
        }
    }
    Ok(())
}

/// Composition records end to end without artifacts: register `.cpeft`
/// experts + a composition over them, and check that what the loader
/// pipeline materializes for the composition equals the dense
/// reference merge of its members.
#[test]
fn synthetic_composition_registry_and_loader() -> anyhow::Result<()> {
    use compeft::coordinator::loader::ExpertLoader;
    use compeft::coordinator::SimLink;

    let dir = fresh_dir("composition");
    let mut reg = Registry::new();
    let cfg = CompressConfig {
        density: 0.2,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let mut tvs = Vec::new();
    for i in 0..2 {
        let tv = synthetic_tv(60 + i, 6_000);
        let npz = dir.join(format!("m{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("m{i}"), "t", "s", ExpertMethod::Lora, &npz, &cfg)?;
        tvs.push(tv);
    }
    let comp = reg
        .register_composition(
            "merged/ties",
            &["m0", "m1"],
            MergeMethod::Ties { density: 0.5, lambda: 0.8 },
        )?
        .clone();
    assert_eq!(comp.method, ExpertMethod::Lora);

    // The loader half of serving a composition: fetch, decode ternary,
    // merge (what PrepareContext::prepare runs for a composed id).
    let loader = ExpertLoader::new(
        SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
        SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
    )
    .with_pool(std::sync::Arc::new(ThreadPool::new(4)));
    let mut members = Vec::new();
    for m in &comp.members {
        let rec = reg.get(m).unwrap();
        let (bytes, _) = loader.fetch_encoded(rec)?;
        let (c, _) = loader.decode_compressed(rec, &bytes)?;
        members.push(c);
    }
    let refs: Vec<&_> = members.iter().collect();
    let (merged, _) = loader.merge_ternary(&refs, &comp.merge)?;

    let dense: Vec<ParamSet> = members
        .iter()
        .zip(&tvs)
        .map(|(c, tv)| decompress_params(c, tv))
        .collect::<anyhow::Result<_>>()?;
    let want = merge_dense(&dense, &comp.merge)?;
    assert_eq!(merged, want);

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Pipeline equivalence below the engine, no artifacts: for a mixed
/// stored+composed workload served through the public pipeline API,
/// whatever the prefetcher stages is bit-identical to the blocking
/// prepare path, at every lookahead depth and decode-worker count.
/// (The artifact-gated `prefetch_on_off_serve_identical_predictions`
/// extends this through PJRT execution to served predictions.)
#[test]
fn synthetic_prefetch_pipeline_matches_blocking() -> anyhow::Result<()> {
    use compeft::coordinator::cache::LruTier;
    use compeft::coordinator::loader::ExpertLoader;
    use compeft::coordinator::{
        PrepareContext, PreparedExpert, Prefetcher, SimLink, TakeOutcome,
    };
    use compeft::coordinator::metrics::Metrics;
    use compeft::util::sync::{rank, OrderedMutex};
    use std::sync::Arc;

    let dir = fresh_dir("prefetch_eq");
    let mut reg = Registry::new();
    let cfg = CompressConfig {
        density: 0.2,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let mut template_like = None;
    for i in 0..3u64 {
        let tv = synthetic_tv(70 + i, 6_000);
        let npz = dir.join(format!("p{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("p{i}"), "t", "s", ExpertMethod::Lora, &npz, &cfg)?;
        template_like.get_or_insert(tv);
    }
    reg.register_composition(
        "merged/ta",
        &["p0", "p1", "p2"],
        MergeMethod::TaskArithmetic { lambda: 0.4 },
    )?;
    let reg = Arc::new(reg);
    let templates = bs::zero_templates(&template_like.unwrap());
    let mk_ctx = |workers: usize| {
        Arc::new(PrepareContext {
            loader: ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
            )
            .with_pool(Arc::new(ThreadPool::new(workers))),
            registry: Arc::clone(&reg),
            templates: templates.clone(),
            cpu: Arc::new(OrderedMutex::new(
                rank::CPU_TIER,
                "cache.cpu_tier",
                LruTier::new("cpu", 64 << 20),
            )),
            archive: None,
        })
    };

    let workload = ["p1", "merged/ta", "p0", "p2", "merged/ta"];
    let reference: Vec<PreparedExpert> = {
        let ctx = mk_ctx(1);
        workload.iter().map(|id| ctx.prepare(id).unwrap()).collect()
    };
    for depth in [1usize, 2] {
        for workers in prop::pool_sizes() {
            let ctx = mk_ctx(workers);
            let metrics = Arc::new(Metrics::new());
            let pf =
                Prefetcher::start(Arc::clone(&ctx), depth, u64::MAX, Arc::clone(&metrics));
            for (step, (id, want)) in workload.iter().zip(&reference).enumerate() {
                // The engine's publication order: current target first
                // consumed, then the next `depth` ids planned.
                let upcoming: Vec<String> = workload[step + 1..]
                    .iter()
                    .take(depth)
                    .map(|s| s.to_string())
                    .collect();
                let got = match pf.take(id) {
                    TakeOutcome::Hit(p) | TakeOutcome::Waited(p, _) => p,
                    TakeOutcome::Miss => ctx.prepare(id)?,
                    TakeOutcome::Failed(e) => panic!("prefetch failed: {e}"),
                };
                pf.note_plan(upcoming);
                assert_eq!(
                    got.params, want.params,
                    "depth={depth} workers={workers} step={step} id={id}"
                );
                assert_eq!(got.upload_bytes, want.upload_bytes, "{id}");
                assert_eq!(got.dense_bytes, want.dense_bytes, "{id}");
            }
            drop(pf);
            let s = metrics.snapshot();
            assert_eq!(
                s.prefetch_hits + s.prefetch_waits + s.prefetch_misses,
                workload.len() as u64,
                "every pickup resolved one way (depth={depth} workers={workers})"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// The deterministic fault-injection suite (the sharded store's
/// acceptance bar, artifact-free): for a mixed stored+composed
/// workload, a store-backed `PrepareContext` under seeded fault plans —
/// delay-only, drop-primary, corrupt-stripes, kill-one-node — prepares
/// experts **bit-identical** to the flat single-store reference, with
/// `failovers > 0` wherever failures were injected, and with the same
/// failover counters at every pool size and on every rerun (same seed →
/// same sequence).
#[test]
fn synthetic_sharded_store_fault_sweeps_converge() -> anyhow::Result<()> {
    use compeft::coordinator::cache::LruTier;
    use compeft::coordinator::loader::ExpertLoader;
    use compeft::coordinator::metrics::Metrics;
    use compeft::coordinator::store::{ExpertStore, Placement, StoreConfig};
    use compeft::coordinator::transport::{FaultPlan, FaultSpec};
    use compeft::coordinator::{PrepareContext, PreparedExpert, SimLink};
    use compeft::util::sync::{rank, OrderedMutex};
    use std::sync::Arc;

    let dir = fresh_dir("store_faults");
    let mut reg = Registry::new();
    let cfg = CompressConfig {
        density: 0.15,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let mut template_like = None;
    for i in 0..3u64 {
        let tv = synthetic_tv(90 + i, 8_000);
        let npz = dir.join(format!("s{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("s{i}"), "t", "s", ExpertMethod::Lora, &npz, &cfg)?;
        template_like.get_or_insert(tv);
    }
    reg.register_composition(
        "merged/ties",
        &["s0", "s1", "s2"],
        MergeMethod::Ties { density: 0.4, lambda: 1.0 },
    )?;
    let reg = std::sync::Arc::new(reg);
    let templates = bs::zero_templates(&template_like.unwrap());
    let workload = ["s1", "merged/ties", "s0", "s2"];

    // Flat single-store reference (no store attached).
    let flat_ctx = PrepareContext {
        loader: ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
            SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
        )
        .with_pool(Arc::new(ThreadPool::new(2))),
        registry: Arc::clone(&reg),
        templates: templates.clone(),
        cpu: Arc::new(OrderedMutex::new(
            rank::CPU_TIER,
            "cache.cpu_tier",
            LruTier::new("cpu", 64 << 20),
        )),
        archive: None,
    };
    let reference: Vec<PreparedExpert> =
        workload.iter().map(|id| flat_ctx.prepare(id).unwrap()).collect();

    // The seeded sweeps. `must_failover` encodes which plans inject
    // actual failures (delay-only slows transfers but loses nothing).
    let kill = Placement::new(3, 2, 0).nodes_for("s0")[0];
    let sweeps: Vec<(&str, FaultPlan, bool)> = vec![
        (
            "delay-only",
            FaultPlan::new(
                101,
                FaultSpec {
                    delay_p: 1.0,
                    delay: Duration::from_millis(3),
                    ..Default::default()
                },
            ),
            false,
        ),
        (
            "drop-primary",
            FaultPlan::new(
                102,
                FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() },
            ),
            true,
        ),
        (
            "corrupt-stripes",
            FaultPlan::new(
                103,
                FaultSpec {
                    corrupt_p: 1.0,
                    first_attempt_only: true,
                    ..Default::default()
                },
            ),
            true,
        ),
        ("kill-one-node", FaultPlan::none(104).kill_node(kill), true),
    ];

    for (name, plan, must_failover) in sweeps {
        let mut counter_ref: Option<(u64, u64, u64)> = None;
        for workers in prop::pool_sizes() {
            for round in 0..2 {
                let pool = Arc::new(ThreadPool::new(workers));
                let metrics = Arc::new(Metrics::new());
                let mut scfg = StoreConfig::new(3, 2);
                scfg.time_scale = 0.0;
                scfg.stripe_bytes = 200; // several stripes per expert
                scfg.faults = plan.clone();
                let store = Arc::new(ExpertStore::new(
                    scfg,
                    Some(Arc::clone(&pool)),
                    Arc::clone(&metrics),
                ));
                let ctx = PrepareContext {
                    loader: ExpertLoader::new(
                        SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                        SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
                    )
                    .with_pool(Arc::clone(&pool))
                    .with_store(Arc::clone(&store)),
                    registry: Arc::clone(&reg),
                    templates: templates.clone(),
                    cpu: Arc::new(OrderedMutex::new(
                        rank::CPU_TIER,
                        "cache.cpu_tier",
                        LruTier::new("cpu", 64 << 20),
                    )),
                    archive: None,
                };
                for (id, want) in workload.iter().zip(&reference) {
                    let got = ctx.prepare(id)?;
                    prop::assert_paramset_bit_identical(
                        &got.params,
                        &want.params,
                        &format!("{name} w={workers} id={id}"),
                    );
                    assert_eq!(got.upload_bytes, want.upload_bytes, "{name}/{id}");
                    assert_eq!(got.dense_bytes, want.dense_bytes, "{name}/{id}");
                }
                let s = metrics.snapshot();
                if must_failover {
                    assert!(s.failovers > 0, "{name}: failures must have fired");
                    assert!(s.stripe_retries >= s.failovers, "{name}");
                } else {
                    assert_eq!(s.stripe_retries, 0, "{name}: delay loses nothing");
                    assert_eq!(s.failovers, 0, "{name}");
                }
                if name == "corrupt-stripes" {
                    assert!(s.corrupt_payloads > 0, "{name}");
                } else {
                    assert_eq!(s.corrupt_payloads, 0, "{name}");
                }
                // Same seed → same failover sequence and counters, at
                // every pool size and on every rerun.
                let counters = (s.stripe_retries, s.failovers, s.corrupt_payloads);
                match &counter_ref {
                    None => counter_ref = Some(counters),
                    Some(r) => assert_eq!(
                        counters, *r,
                        "{name}: counters drifted (w={workers}, round={round})"
                    ),
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Tier-interaction equivalence for the archive level (GPU ⊃ host ⊃
/// archive ⊃ remote), artifact-free: the same mixed stored+composed
/// workload prepared via the remote fetch, via a warmed host tier, and
/// via a local `.cpar` archive is **bit-identical** at every pool size.
/// The archive leg must additionally perform zero heap copies of
/// encoded payload bytes (the per-engine `CopyMeter`), move zero bytes
/// over the net link, and never double-cache its views in the host
/// tier; a *partial* archive serves what it has as views and falls
/// through to the remote path for the rest — still bit-identical.
#[test]
fn synthetic_archive_tier_matches_host_and_remote_paths() -> anyhow::Result<()> {
    use compeft::coordinator::archive::{build_from_registry, ArchiveBuilder, ArchiveTier};
    use compeft::coordinator::cache::LruTier;
    use compeft::coordinator::loader::ExpertLoader;
    use compeft::coordinator::metrics::Metrics;
    use compeft::coordinator::{PrepareContext, PreparedExpert, SimLink};
    use compeft::util::sync::{rank, OrderedMutex};
    use std::sync::Arc;

    let dir = fresh_dir("archive_tiers");
    let mut reg = Registry::new();
    let cfg = CompressConfig {
        density: 0.15,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let mut template_like = None;
    for i in 0..3u64 {
        let tv = synthetic_tv(110 + i, 7_000);
        let npz = dir.join(format!("a{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("a{i}"), "t", "s", ExpertMethod::Lora, &npz, &cfg)?;
        template_like.get_or_insert(tv);
    }
    reg.register_composition(
        "merged/ties",
        &["a0", "a1", "a2"],
        MergeMethod::Ties { density: 0.4, lambda: 1.0 },
    )?;
    let reg = Arc::new(reg);
    let templates = bs::zero_templates(&template_like.unwrap());
    // 3 distinct stored fetches on a cold host tier: a1, then a0+a2 as
    // composition members (a1 tier-hits), then a0/a2 tier-hit again.
    let workload = ["a1", "merged/ties", "a0", "a2"];

    let archive_path = dir.join("experts.cpar");
    let (members, written) = build_from_registry(&reg, &archive_path)?;
    assert_eq!(members, 3, "every stored expert packed (compositions are virtual)");
    assert!(written > 0);
    // A partial archive: only a0 and a1 — a2 must come from remote.
    let partial_path = dir.join("partial.cpar");
    {
        let mut b = ArchiveBuilder::new();
        for id in ["a0", "a1"] {
            let rec = reg.get(id).unwrap();
            b.add(id, std::fs::read(&rec.path)?)?;
        }
        b.write_to(&partial_path)?;
    }

    let mk_ctx = |workers: usize,
                  metrics: &Arc<Metrics>,
                  archive: Option<Arc<ArchiveTier>>| {
        let loader = ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
            SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
        )
        .with_pool(Arc::new(ThreadPool::new(workers)))
        .with_meter(metrics.copy_meter());
        let net = loader.net.clone();
        let ctx = PrepareContext {
            loader,
            registry: Arc::clone(&reg),
            templates: templates.clone(),
            cpu: Arc::new(OrderedMutex::new(
                rank::CPU_TIER,
                "cache.cpu_tier",
                LruTier::new("cpu", 64 << 20),
            )),
            archive,
        };
        (ctx, net)
    };

    // Flat remote reference, serial pool.
    let ref_metrics = Arc::new(Metrics::new());
    let (ref_ctx, _) = mk_ctx(1, &ref_metrics, None);
    let reference: Vec<PreparedExpert> =
        workload.iter().map(|id| ref_ctx.prepare(id).unwrap()).collect();

    for workers in prop::pool_sizes() {
        // Remote leg, then the same ctx again with a warmed host tier:
        // exactly one copy per stored expert, ever.
        let metrics = Arc::new(Metrics::new());
        let (ctx, net) = mk_ctx(workers, &metrics, None);
        for pass in 0..2 {
            for (id, want) in workload.iter().zip(&reference) {
                let got = ctx.prepare(id)?;
                prop::assert_paramset_bit_identical(
                    &got.params,
                    &want.params,
                    &format!("remote pass={pass} w={workers} id={id}"),
                );
                assert_eq!(got.upload_bytes, want.upload_bytes, "{id}");
                assert_eq!(got.dense_bytes, want.dense_bytes, "{id}");
            }
            let s = metrics.snapshot();
            assert_eq!(
                s.payload_copies, 3,
                "one copy per stored expert, none on host-tier hits (pass={pass})"
            );
            assert_eq!(s.archive_hits, 0, "no archive attached");
        }
        assert!(net.bytes_moved() > 0, "remote leg pays the net transfer");

        // Archive leg: every stored fetch is an in-place view.
        let metrics = Arc::new(Metrics::new());
        let tier = Arc::new(ArchiveTier::open(&archive_path, Arc::clone(&metrics))?);
        let (ctx, net) = mk_ctx(workers, &metrics, Some(tier));
        for (id, want) in workload.iter().zip(&reference) {
            let got = ctx.prepare(id)?;
            prop::assert_paramset_bit_identical(
                &got.params,
                &want.params,
                &format!("archive w={workers} id={id}"),
            );
        }
        assert_eq!(net.bytes_moved(), 0, "archive hits never touch the net");
        assert_eq!(
            ctx.cpu.lock().unwrap().stats().entries,
            0,
            "archive views are not double-cached in the host tier"
        );
        let s = metrics.snapshot();
        // a1 + members a0,a1,a2 + a0 + a2: six fetches, all archive.
        assert_eq!(s.archive_hits, 6, "every stored fetch hit the archive");
        assert!(s.archive_bytes_viewed > 0);
        assert_eq!(s.payload_copies, 0, "archive-resident serving copies nothing");
        assert_eq!(s.failovers, 0);

        // Partial archive: a0/a1 from the image, a2 from remote.
        let metrics = Arc::new(Metrics::new());
        let tier = Arc::new(ArchiveTier::open(&partial_path, Arc::clone(&metrics))?);
        let (ctx, net) = mk_ctx(workers, &metrics, Some(tier));
        for (id, want) in workload.iter().zip(&reference) {
            let got = ctx.prepare(id)?;
            prop::assert_paramset_bit_identical(
                &got.params,
                &want.params,
                &format!("partial-archive w={workers} id={id}"),
            );
        }
        let s = metrics.snapshot();
        assert!(s.archive_hits > 0, "archived members served as views");
        assert_eq!(s.payload_copies, 1, "only the missing a2 is fetched and copied");
        assert!(net.bytes_moved() > 0, "the miss fell through to remote");
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Archive-index corruption robustness at the integration level, on an
/// archive of *real* compressed experts: a seeded bit-flip pass over
/// every header/index byte (plus a strided sample of the member
/// region) must yield a structured `Err` from `open`, or a tier whose
/// every `get` is `None`-or-bit-identical — never a panic, never a
/// wrong-expert view. The truncation + trailing-garbage sweep must
/// always `Err`. And a tier carrying one corrupt member must degrade
/// that expert to the remote path **mid-pipeline**: `prepare` stays
/// bit-identical to the flat reference while the corruption is counted
/// like a bad stripe (`corrupt_payloads`/`failovers`).
#[test]
fn synthetic_archive_bitflip_and_truncation_fuzz() -> anyhow::Result<()> {
    use compeft::coordinator::archive::{build_from_registry, ArchiveTier};
    use compeft::coordinator::cache::LruTier;
    use compeft::coordinator::loader::ExpertLoader;
    use compeft::coordinator::metrics::Metrics;
    use compeft::coordinator::{PrepareContext, SimLink};
    use compeft::util::sync::{rank, OrderedMutex};
    use std::sync::Arc;

    let dir = fresh_dir("archive_fuzz");
    let mut reg = Registry::new();
    let cfg = CompressConfig {
        density: 0.1,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let mut template_like = None;
    for i in 0..2u64 {
        let tv = synthetic_tv(130 + i, 4_000);
        let npz = dir.join(format!("f{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("f{i}"), "t", "s", ExpertMethod::Lora, &npz, &cfg)?;
        template_like.get_or_insert(tv);
    }
    let reg = Arc::new(reg);
    let templates = bs::zero_templates(&template_like.unwrap());

    let archive_path = dir.join("experts.cpar");
    build_from_registry(&reg, &archive_path)?;
    let image = std::fs::read(&archive_path)?;
    let (index_end, member_bytes) = {
        let tier = ArchiveTier::from_bytes(image.clone(), Arc::new(Metrics::new()))?;
        let first_member = ["f0", "f1"]
            .iter()
            .map(|id| tier.member_range(id).unwrap().0)
            .min()
            .unwrap();
        let bytes: Vec<(String, Vec<u8>)> = ["f0", "f1"]
            .iter()
            .map(|id| {
                let (off, len) = tier.member_range(id).unwrap();
                (id.to_string(), image[off..off + len].to_vec())
            })
            .collect();
        (first_member, bytes)
    };

    // Every header/index/padding byte, one seeded bit each; the member
    // region sampled strided (each flip re-CRCs both members, so the
    // full cross product would dominate the suite's runtime).
    let mut rng = Pcg::seed(0xCA9A12);
    let positions = (0..index_end).chain((index_end..image.len()).step_by(97));
    for pos in positions {
        let mut evil = image.clone();
        evil[pos] ^= 1u8 << rng.below(8);
        match ArchiveTier::from_bytes(evil, Arc::new(Metrics::new())) {
            Err(_) => {}
            Ok(tier) => {
                for (id, want) in &member_bytes {
                    match tier.get(id) {
                        None => {}
                        Some(got) => assert_eq!(
                            &*got,
                            &want[..],
                            "flip at byte {pos} served a wrong view of {id}"
                        ),
                    }
                }
            }
        }
    }

    // Truncations and trailing garbage: structured Err, every cut.
    for cut in [0, 1, 8, 12, index_end, image.len() / 2, image.len() - 1] {
        assert!(
            ArchiveTier::from_bytes(image[..cut].to_vec(), Arc::new(Metrics::new())).is_err(),
            "truncation to {cut} bytes must be rejected"
        );
    }
    let mut long = image.clone();
    long.push(0);
    assert!(
        ArchiveTier::from_bytes(long, Arc::new(Metrics::new())).is_err(),
        "trailing garbage must be rejected"
    );

    // One corrupt member, end to end: the damaged expert degrades to
    // the remote fetch and still prepares bit-identically.
    let flat_metrics = Arc::new(Metrics::new());
    let mk_loader = |metrics: &Arc<Metrics>| {
        ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
            SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
        )
        .with_pool(Arc::new(ThreadPool::new(2)))
        .with_meter(metrics.copy_meter())
    };
    let flat_ctx = PrepareContext {
        loader: mk_loader(&flat_metrics),
        registry: Arc::clone(&reg),
        templates: templates.clone(),
        cpu: Arc::new(OrderedMutex::new(
            rank::CPU_TIER,
            "cache.cpu_tier",
            LruTier::new("cpu", 64 << 20),
        )),
        archive: None,
    };
    let want: Vec<_> = ["f0", "f1"].iter().map(|id| flat_ctx.prepare(id).unwrap()).collect();

    let metrics = Arc::new(Metrics::new());
    let mut bad = image;
    let (off, len) = {
        let tier = ArchiveTier::from_bytes(bad.clone(), Arc::new(Metrics::new()))?;
        tier.member_range("f0").unwrap()
    };
    bad[off + len / 2] ^= 0x10;
    let tier = Arc::new(ArchiveTier::from_bytes(bad, Arc::clone(&metrics))?);
    let ctx = PrepareContext {
        loader: mk_loader(&metrics),
        registry: Arc::clone(&reg),
        templates: templates.clone(),
        cpu: Arc::new(OrderedMutex::new(
            rank::CPU_TIER,
            "cache.cpu_tier",
            LruTier::new("cpu", 64 << 20),
        )),
        archive: Some(tier),
    };
    for (id, w) in ["f0", "f1"].iter().zip(&want) {
        let got = ctx.prepare(id)?;
        prop::assert_paramset_bit_identical(&got.params, &w.params, id);
    }
    let s = metrics.snapshot();
    assert!(s.corrupt_payloads > 0, "the bad member was detected");
    assert!(s.failovers > 0, "and counted as a failover to remote");
    assert_eq!(s.archive_hits, 1, "the intact member still served as a view");
    assert_eq!(s.payload_copies, 1, "only the degraded expert was fetched");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Corruption-robustness sweep for `compeft::format`: a seeded bit-flip
/// pass over **every byte** of `.cpeft` v2 buffers — header, frame
/// tables, Golomb payloads, bitmask words, and the CRC itself — must
/// return `Err` from both readers, and never panic or OOM (v2 CRCs
/// cover the full buffer, so no flip can slip through; corrupt counts
/// and lengths are structurally bounded before any allocation).
/// The CRC-consistent truncation sweep (the shared
/// `format::truncation_sweep` helper, also run by the format unit
/// suite) must fail structurally at every cut depth too.
#[test]
fn synthetic_cpeft_bitflip_fuzz_never_panics() -> anyhow::Result<()> {
    let tv = synthetic_tv(77, 6_000);
    let pool = ThreadPool::new(2);
    let mut rng = Pcg::seed(0xB17F11);
    for granularity in [Granularity::Global, Granularity::PerTensor] {
        for enc in [Encoding::Golomb, Encoding::Bitmask] {
            let cfg = CompressConfig { density: 0.1, alpha: 1.0, granularity };
            let c = compress_params(&tv, &cfg);
            let bytes = to_bytes(&c, enc);
            assert!(format::from_bytes(&bytes).is_ok(), "fixture must parse");

            // Raw bit flips: every byte position, one seeded bit each.
            for pos in 0..bytes.len() {
                let mut evil = bytes.clone();
                evil[pos] ^= 1u8 << rng.below(8);
                let res = format::from_bytes(&evil);
                assert!(
                    res.is_err(),
                    "{granularity:?}/{enc:?}: flip at byte {pos} was accepted"
                );
                // The parallel reader agrees (sampled: it shares the
                // structural parse, only payload decode fans out).
                if pos % 5 == 0 {
                    assert!(
                        format::from_bytes_par(&evil, &pool).is_err(),
                        "{granularity:?}/{enc:?}: parallel reader accepted flip at {pos}"
                    );
                }
            }

            // CRC-consistent truncations (buggy-writer model): every
            // cut fails structurally on both readers.
            for (i, cut) in format::truncation_sweep(&bytes).iter().enumerate() {
                assert!(
                    format::from_bytes(cut).is_err(),
                    "{granularity:?}/{enc:?}: truncation {i} accepted"
                );
                assert!(
                    format::from_bytes_par(cut, &pool).is_err(),
                    "{granularity:?}/{enc:?}: truncation {i} accepted (par)"
                );
            }
        }
    }
    Ok(())
}

/// npz interchange on the synthetic fixture: what the Python exporter
/// writes is what the Rust side reads (and vice versa).
#[test]
fn synthetic_npz_interchange() -> anyhow::Result<()> {
    let dir = fresh_dir("npz");
    let tv = synthetic_tv(5, 1024);
    let path = dir.join("tv.npz");
    tv.save_npz(&path)?;
    let back = ParamSet::load_npz(&path)?;
    assert_eq!(back, tv);
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

// ---------------------------------------------------------------------------
// Artifact-gated tests (skip without `make artifacts`)
// ---------------------------------------------------------------------------

/// The base model executes through PJRT and is meaningfully better than
/// chance on the held-out benchmark (it was trained on those rules).
#[test]
fn base_model_beats_chance_via_runtime() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let (_rt, bundle) = bs::load_bundle(&dir, "xs")?;
    // Full benchmark: the set is concatenated per task, so a truncated
    // prefix would cover only the first (and possibly hardest) task.
    let set = bs::load_eval(&dir, "heldout_bench")?;
    let acc = compeft::eval::evaluate(
        &bundle,
        AdapterKind::Base,
        bs::EVAL_BATCH,
        None,
        None,
        &set,
    )?;
    // Mixed 2-4-way tasks: chance ≈ 0.45; trained base must clear it.
    assert!(acc > 0.55, "base acc {acc}");
    Ok(())
}

/// ComPEFT at k=0.2, α=1 keeps an expert within a few points of its
/// uncompressed accuracy on its own task (Table 1/3 shape).
#[test]
fn compressed_expert_close_to_original() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let (_rt, bundle) = bs::load_bundle(&dir, "s")?;
    let expert = match bs::load_expert(&dir, "s", "alpaca", "lora", None) {
        Ok(e) => e,
        Err(_) => return Ok(()), // experts still building
    };
    let set = bs::load_eval(&dir, "task_alpaca")?;
    let orig = bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &set)?;
    let ctv = bs::compress_tv(&expert.tv, 0.2, 1.0);
    let comp = bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &set)?;
    assert!(
        comp >= orig - 0.10,
        "compressed {comp} fell more than 10 points below original {orig}"
    );
    Ok(())
}

/// The python-side LoRA adapter math and the Rust runtime agree: the
/// adapter whose meta.json records own_task_acc reproduces ±5 points
/// through the PJRT path.
#[test]
fn runtime_matches_training_side_accuracy() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let expert = match bs::load_expert(&dir, "s", "self-instruct", "lora", None) {
        Ok(e) => e,
        Err(_) => return Ok(()),
    };
    if expert.own_task_acc.is_nan() {
        return Ok(());
    }
    let (_rt, bundle) = bs::load_bundle(&dir, "s")?;
    let set = bs::load_eval(&dir, "task_self-instruct")?;
    let acc = bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &set)?;
    assert!(
        (acc - expert.own_task_acc).abs() < 0.06,
        "runtime {acc} vs python {}",
        expert.own_task_acc
    );
    Ok(())
}

/// Full serving path: coordinator swaps two ComPEFT experts under a
/// tiny GPU budget and answers correctly-routed requests.
#[test]
fn coordinator_serves_compressed_experts() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let found = scan_expert_npz(&dir, "s")?;
    let lora: Vec<_> = found
        .iter()
        .filter(|(t, m, _)| {
            *m == ExpertMethod::Lora
                && dir.join("eval").join(format!("task_{t}.npz")).exists()
        })
        .take(2)
        .collect();
    if lora.len() < 2 {
        return Ok(());
    }

    let mut registry = Registry::new();
    let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity: Granularity::Global };
    for (task, m, path) in &lora {
        registry.register_compeft(&format!("{task}"), task, "s", *m, path, &cfg)?;
    }

    let mut ccfg = CoordinatorConfig::new(dir.clone(), "s");
    // The GPU tier budgets *decoded* adapter bytes: room for one dense
    // adapter (n_params at fp16) plus slack, so the second expert must
    // evict the first.
    ccfg.gpu_capacity_bytes = registry.get(&lora[0].0).unwrap().n_params as u64 * 2 + 8;
    ccfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    ccfg.net = LinkSpec::internet();
    ccfg.pcie = LinkSpec::pcie();
    ccfg.time_scale = 0.0; // pure model, no sleeping in tests
    let coord = Coordinator::start(ccfg, registry)?;

    let mut pending = Vec::new();
    for (task, _, _) in &lora {
        let set = bs::load_eval(&dir, &format!("task_{task}"))?;
        for i in 0..6 {
            let tokens = set.tokens[i * set.seq..(i + 1) * set.seq].to_vec();
            pending.push(coord.submit(task, tokens, set.n_classes[i] as usize));
        }
    }
    for rx in pending {
        let p = rx.recv()?;
        assert!(p.timing.total > Duration::ZERO);
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 12);
    let report = coord.shutdown()?;
    // Both experts cannot fit: at least one swap beyond the first two loads.
    assert!(report.gpu.evictions >= 1, "expected evictions, got {:?}", report.gpu);
    assert!(report.net_bytes > 0);
    Ok(())
}

/// Full serving path for a *merged* expert: a composition registered
/// over two ComPEFT experts is materialized on demand (members pulled
/// through the host tier, merged ternary-domain), cached as a
/// first-class GPU resident, and answers requests alongside its
/// members.
#[test]
fn coordinator_serves_merged_expert() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let found = scan_expert_npz(&dir, "s")?;
    let lora: Vec<_> = found
        .iter()
        .filter(|(t, m, _)| {
            *m == ExpertMethod::Lora
                && dir.join("eval").join(format!("task_{t}.npz")).exists()
        })
        .take(2)
        .collect();
    if lora.len() < 2 {
        return Ok(());
    }

    let mut registry = Registry::new();
    let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity: Granularity::Global };
    for (task, m, path) in &lora {
        registry.register_compeft(task, task, "s", *m, path, &cfg)?;
    }
    registry.register_composition(
        "merged/avg",
        &[lora[0].0.as_str(), lora[1].0.as_str()],
        MergeMethod::Average,
    )?;

    let mut ccfg = CoordinatorConfig::new(dir.clone(), "s");
    ccfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    ccfg.time_scale = 0.0;
    let coord = Coordinator::start(ccfg, registry)?;

    // Interleave requests to a member and to the merged expert.
    let set = bs::load_eval(&dir, &format!("task_{}", lora[0].0))?;
    let mut pending = Vec::new();
    for i in 0..4 {
        let tokens = set.tokens[i * set.seq..(i + 1) * set.seq].to_vec();
        pending.push(coord.submit("merged/avg", tokens.clone(), set.n_classes[i] as usize));
        pending.push(coord.submit(&lora[0].0, tokens, set.n_classes[i] as usize));
    }
    for rx in pending {
        let p = rx.recv()?;
        assert!(p.timing.total > Duration::ZERO);
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 8);
    let report = coord.shutdown()?;
    // The merged expert moved member bytes over the net at least once.
    assert!(report.net_bytes > 0);
    assert!(report.batches >= 2);
    Ok(())
}

/// The pipeline's acceptance bar end to end: the same mixed
/// stored+composed request trace served with prefetch disabled, and
/// with prefetch enabled at different depths and decode-worker counts,
/// produces bit-identical predictions — prefetching changes when swap
/// work happens, never what is served.
#[test]
fn prefetch_on_off_serve_identical_predictions() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let found = scan_expert_npz(&dir, "s")?;
    let lora: Vec<_> = found
        .iter()
        .filter(|(t, m, _)| {
            *m == ExpertMethod::Lora
                && dir.join("eval").join(format!("task_{t}.npz")).exists()
        })
        .take(2)
        .collect();
    if lora.len() < 2 {
        return Ok(());
    }
    let build_registry = || -> anyhow::Result<Registry> {
        let mut registry = Registry::new();
        let cfg = CompressConfig {
            density: 0.2,
            alpha: 1.0,
            granularity: Granularity::Global,
        };
        for (task, m, path) in &lora {
            registry.register_compeft(task, task, "s", *m, path, &cfg)?;
        }
        registry.register_composition(
            "merged/avg",
            &[lora[0].0.as_str(), lora[1].0.as_str()],
            MergeMethod::Average,
        )?;
        Ok(registry)
    };

    // One shared trace cycling member / merged / member experts.
    let set = bs::load_eval(&dir, &format!("task_{}", lora[0].0))?;
    let trace: Vec<(String, Vec<i32>, usize)> = (0..9)
        .map(|i| {
            let expert = match i % 3 {
                0 => lora[0].0.clone(),
                1 => "merged/avg".to_string(),
                _ => lora[1].0.clone(),
            };
            let ex = i % set.n.min(4);
            (
                expert,
                set.tokens[ex * set.seq..(ex + 1) * set.seq].to_vec(),
                set.n_classes[ex] as usize,
            )
        })
        .collect();

    let serve = |prefetch_depth: usize, decode_workers: usize| -> anyhow::Result<Vec<usize>> {
        let mut ccfg = CoordinatorConfig::new(dir.clone(), "s");
        // Room for ~1 dense adapter: every expert change is a cold swap,
        // the case prefetching exists for.
        ccfg.gpu_capacity_bytes =
            build_registry()?.get(&lora[0].0).unwrap().n_params as u64 * 2 + 8;
        ccfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        ccfg.time_scale = 0.0;
        ccfg.prefetch_depth = prefetch_depth;
        ccfg.decode_workers = decode_workers;
        let coord = Coordinator::start(ccfg, build_registry()?)?;
        let pending: Vec<_> = trace
            .iter()
            .map(|(e, tokens, n)| coord.submit(e, tokens.clone(), *n))
            .collect();
        let classes: Vec<usize> =
            pending.into_iter().map(|rx| rx.recv().map(|p| p.class)).collect::<Result<_, _>>()?;
        let report = coord.shutdown()?;
        if prefetch_depth == 0 {
            assert_eq!(
                report.prefetch_hits + report.prefetch_waits + report.prefetch_misses,
                0,
                "disabled prefetch records no pickups"
            );
        }
        Ok(classes)
    };

    let reference = serve(0, 1)?;
    assert_eq!(reference.len(), trace.len());
    for (depth, workers) in [(1usize, 1usize), (3, 4), (8, 2)] {
        let got = serve(depth, workers)?;
        assert_eq!(
            got, reference,
            "predictions must be bit-identical (depth={depth} workers={workers})"
        );
    }
    Ok(())
}

/// The sharded store's acceptance bar through the full engine: the same
/// mixed stored+composed trace served by the flat single-link store,
/// by sharded stores of several node counts/replication factors, and by
/// a sharded store under a seeded fault plan, produces bit-identical
/// predictions — sharding and failover change where bytes come from,
/// never what is served.
#[test]
fn sharded_store_serve_identical_predictions() -> anyhow::Result<()> {
    use compeft::coordinator::transport::FaultSpec;

    let Some(dir) = artifacts() else { return Ok(()) };
    let found = scan_expert_npz(&dir, "s")?;
    let lora: Vec<_> = found
        .iter()
        .filter(|(t, m, _)| {
            *m == ExpertMethod::Lora
                && dir.join("eval").join(format!("task_{t}.npz")).exists()
        })
        .take(2)
        .collect();
    if lora.len() < 2 {
        return Ok(());
    }
    let build_registry = || -> anyhow::Result<Registry> {
        let mut registry = Registry::new();
        let cfg = CompressConfig {
            density: 0.2,
            alpha: 1.0,
            granularity: Granularity::Global,
        };
        for (task, m, path) in &lora {
            registry.register_compeft(task, task, "s", *m, path, &cfg)?;
        }
        registry.register_composition(
            "merged/avg",
            &[lora[0].0.as_str(), lora[1].0.as_str()],
            MergeMethod::Average,
        )?;
        Ok(registry)
    };

    let set = bs::load_eval(&dir, &format!("task_{}", lora[0].0))?;
    let trace: Vec<(String, Vec<i32>, usize)> = (0..9)
        .map(|i| {
            let expert = match i % 3 {
                0 => lora[0].0.clone(),
                1 => "merged/avg".to_string(),
                _ => lora[1].0.clone(),
            };
            let ex = i % set.n.min(4);
            (
                expert,
                set.tokens[ex * set.seq..(ex + 1) * set.seq].to_vec(),
                set.n_classes[ex] as usize,
            )
        })
        .collect();

    let serve = |store_nodes: usize,
                 replication: usize,
                 faults: Option<FaultSpec>|
     -> anyhow::Result<Vec<usize>> {
        let mut ccfg = CoordinatorConfig::new(dir.clone(), "s");
        ccfg.gpu_capacity_bytes =
            build_registry()?.get(&lora[0].0).unwrap().n_params as u64 * 2 + 8;
        ccfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        ccfg.time_scale = 0.0;
        ccfg.store_nodes = store_nodes;
        ccfg.replication = replication;
        if let Some(spec) = faults {
            ccfg.fault_seed = 77;
            ccfg.store_faults = spec;
        }
        let coord = Coordinator::start(ccfg, build_registry()?)?;
        let pending: Vec<_> = trace
            .iter()
            .map(|(e, tokens, n)| coord.submit(e, tokens.clone(), *n))
            .collect();
        let classes: Vec<usize> = pending
            .into_iter()
            .map(|rx| rx.recv().map(|p| p.class))
            .collect::<Result<_, _>>()?;
        let report = coord.shutdown()?;
        if store_nodes == 0 {
            assert_eq!(report.stripe_retries, 0, "flat store never stripes");
        }
        if faults.is_some() {
            assert!(report.failovers > 0, "fault plan must have fired");
        } else {
            assert_eq!(report.failovers, 0, "healthy store never fails over");
        }
        Ok(classes)
    };

    let reference = serve(0, 1, None)?;
    assert_eq!(reference.len(), trace.len());
    for (nodes, repl) in [(1usize, 1usize), (3, 2), (5, 3)] {
        assert_eq!(
            serve(nodes, repl, None)?,
            reference,
            "healthy sharded store (nodes={nodes} repl={repl})"
        );
    }
    // Under a drop-every-primary fault plan the store fails over on
    // every stripe and still serves the same predictions.
    let faulty = FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() };
    assert_eq!(serve(3, 2, Some(faulty))?, reference, "faulted sharded store");
    Ok(())
}

/// The archive tier's acceptance bar through the full engine: the same
/// mixed stored+composed trace served without an archive, with a
/// `.cpar` archive of the expert pool (every fetch an in-place view —
/// zero payload copies end to end), and with a *dead* archive path
/// (degrades to the remote store, counted as a failover) produces
/// bit-identical predictions — the archive changes where bytes live,
/// never what is served.
#[test]
fn archive_serve_identical_predictions_and_dead_archive_degrades() -> anyhow::Result<()> {
    use compeft::coordinator::build_from_registry;

    let Some(dir) = artifacts() else { return Ok(()) };
    let found = scan_expert_npz(&dir, "s")?;
    let lora: Vec<_> = found
        .iter()
        .filter(|(t, m, _)| {
            *m == ExpertMethod::Lora
                && dir.join("eval").join(format!("task_{t}.npz")).exists()
        })
        .take(2)
        .collect();
    if lora.len() < 2 {
        return Ok(());
    }
    let build_registry = || -> anyhow::Result<Registry> {
        let mut registry = Registry::new();
        let cfg = CompressConfig {
            density: 0.2,
            alpha: 1.0,
            granularity: Granularity::Global,
        };
        for (task, m, path) in &lora {
            registry.register_compeft(task, task, "s", *m, path, &cfg)?;
        }
        registry.register_composition(
            "merged/avg",
            &[lora[0].0.as_str(), lora[1].0.as_str()],
            MergeMethod::Average,
        )?;
        Ok(registry)
    };

    let tmp = fresh_dir("serve_archive");
    let archive_path = tmp.join("experts.cpar");
    let (members, _) = build_from_registry(&build_registry()?, &archive_path)?;
    assert_eq!(members, 2);

    let set = bs::load_eval(&dir, &format!("task_{}", lora[0].0))?;
    let trace: Vec<(String, Vec<i32>, usize)> = (0..9)
        .map(|i| {
            let expert = match i % 3 {
                0 => lora[0].0.clone(),
                1 => "merged/avg".to_string(),
                _ => lora[1].0.clone(),
            };
            let ex = i % set.n.min(4);
            (
                expert,
                set.tokens[ex * set.seq..(ex + 1) * set.seq].to_vec(),
                set.n_classes[ex] as usize,
            )
        })
        .collect();

    let serve = |archive: Option<PathBuf>| -> anyhow::Result<(Vec<usize>, compeft::coordinator::EngineReport)> {
        let mut ccfg = CoordinatorConfig::new(dir.clone(), "s");
        // Room for ~1 dense adapter: every expert change is a cold
        // swap, so the archive is consulted on every refetch.
        ccfg.gpu_capacity_bytes =
            build_registry()?.get(&lora[0].0).unwrap().n_params as u64 * 2 + 8;
        ccfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        ccfg.time_scale = 0.0;
        ccfg.archive = archive;
        let coord = Coordinator::start(ccfg, build_registry()?)?;
        let pending: Vec<_> = trace
            .iter()
            .map(|(e, tokens, n)| coord.submit(e, tokens.clone(), *n))
            .collect();
        let classes: Vec<usize> = pending
            .into_iter()
            .map(|rx| rx.recv().map(|p| p.class))
            .collect::<Result<_, _>>()?;
        let report = coord.shutdown()?;
        Ok((classes, report))
    };

    let (reference, report) = serve(None)?;
    assert_eq!(reference.len(), trace.len());
    assert_eq!(report.archive_hits, 0, "no archive attached");
    assert!(report.payload_copies > 0, "remote fetches materialize buffers");

    // Archived pool: bit-identical, every fetch an in-place view.
    let (got, report) = serve(Some(archive_path))?;
    assert_eq!(got, reference, "archive-resident serving changes no prediction");
    assert!(report.archive_hits > 0, "the archive actually served fetches");
    assert!(report.archive_bytes_viewed > 0);
    assert_eq!(
        report.payload_copies, 0,
        "archive-resident serving performs zero encoded-byte copies"
    );
    assert_eq!(report.net_bytes, 0, "nothing left for the net to move");

    // Dead archive: the engine logs, counts a failover, and serves
    // identically via the remote path.
    let (got, report) = serve(Some(tmp.join("missing.cpar")))?;
    assert_eq!(got, reference, "a dead archive degrades, never diverges");
    assert_eq!(report.archive_hits, 0);
    assert!(report.failovers >= 1, "the unusable archive is counted");

    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}

/// A request whose token vector does not match the model's sequence
/// length must not kill the engine thread (it used to panic the
/// `copy_from_slice` batch packing, taking the coordinator down for
/// every client): it is rejected at submit with a dropped sender, and
/// well-formed requests keep being served afterwards.
#[test]
fn malformed_request_cannot_take_engine_down() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let found = scan_expert_npz(&dir, "s")?;
    let lora: Vec<_> = found
        .iter()
        .filter(|(t, m, _)| {
            *m == ExpertMethod::Lora
                && dir.join("eval").join(format!("task_{t}.npz")).exists()
        })
        .take(1)
        .collect();
    let Some((task, m, path)) = lora.first() else { return Ok(()) };

    let mut registry = Registry::new();
    let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity: Granularity::Global };
    registry.register_compeft(task, task, "s", *m, path, &cfg)?;
    let mut ccfg = CoordinatorConfig::new(dir.clone(), "s");
    ccfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    ccfg.time_scale = 0.0;
    let coord = Coordinator::start(ccfg, registry)?;
    let seq = coord.seq_len();
    assert!(seq > 0);

    // Mis-sized token vectors: rejected before the engine sees them.
    let bad_empty = coord.submit(task, Vec::new(), 2);
    let bad_long = coord.submit(task, vec![1; seq + 3], 2);
    assert!(bad_empty.recv().is_err(), "empty request must be rejected");
    assert!(bad_long.recv().is_err(), "oversized request must be rejected");

    // The engine is alive and still serves well-formed requests.
    let set = bs::load_eval(&dir, &format!("task_{task}"))?;
    assert_eq!(set.seq, seq, "eval set and bundle agree on seq_len");
    let ok = coord.submit(task, set.tokens[..seq].to_vec(), set.n_classes[0] as usize);
    let p = ok.recv()?;
    assert!(p.timing.total > Duration::ZERO);
    let report = coord.shutdown()?;
    assert!(report.batches >= 1);
    Ok(())
}

/// The standalone Pallas kernel artifacts execute and agree with the
/// Rust compressor's ternarization semantics (L1 ↔ L3 agreement).
#[test]
fn pallas_and_rust_agree_on_ternarization() -> anyhow::Result<()> {
    let Some(dir) = artifacts() else { return Ok(()) };
    let path = dir.join("kernels/ternarize.hlo.txt");
    if !path.exists() {
        return Ok(());
    }
    let rt = compeft::runtime::Runtime::cpu()?;
    let exe = rt.load_hlo_text(&path)?;

    let n = 1 << 16;
    let mut rng = compeft::util::rng::Pcg::seed(77);
    let tau: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();

    // Rust side: Algorithm 1 at k=0.1, α=2.
    let cfg = CompressConfig { density: 0.1, alpha: 2.0, granularity: Granularity::Global };
    let tern = compeft::compeft::compress_vector(&tau, &cfg);
    let rust_dense = tern.to_dense();

    // Pallas side: same threshold & scale through the kernel artifact.
    let thr = tern
        .iter_nonzero()
        .map(|(i, _)| tau[i as usize].abs())
        .fold(f32::INFINITY, f32::min);
    let t = compeft::tensor::Tensor::new(vec![n], tau.clone());
    let buf = rt.upload_f32(&t)?;
    let (out, _) = exe.run_buffers(&[
        &buf,
        &rt.upload_scalar(thr)?,
        &rt.upload_scalar(tern.scale)?,
    ])?;

    let mut mismatches = 0;
    for i in 0..n {
        if (out[i] - rust_dense[i]).abs() > 1e-6 {
            mismatches += 1;
        }
    }
    // Ties at the threshold may differ (rust breaks ties by index);
    // allow a whisker of disagreement.
    assert!(mismatches <= 2, "{mismatches} mismatches");
    Ok(())
}

// ---------------------------------------------------------------------------
// Load harness + admission control (no artifacts)
// ---------------------------------------------------------------------------

fn flash_sim_config() -> SimConfig {
    SimConfig {
        admission: AdmissionConfig {
            queue_cap: 96,
            shed_deadline: true,
            est_batch_us: 20_000,
            ..Default::default()
        },
        model: ServiceModel { gpu_slots: 2, ..Default::default() },
        ..Default::default()
    }
}

/// Admission is a pure function of (trace seed, config): the per-request
/// accepted/shed/completed outcome vector, the per-reason shed counters,
/// and the service counters are bit-identical across reruns and across
/// trace-generation pool sizes (`COMPEFT_TEST_WORKERS`).
#[test]
fn loadgen_admission_outcomes_bit_identical_across_pool_sizes_and_reruns() {
    let spec = TraceSpec::flash_crowd(1_500_000, 24, 3, 900.0, 6.0);
    let seed = 0xA11CE;
    let cfg = flash_sim_config();

    let serial_trace = Trace::generate(&spec, seed);
    let baseline = sim::run(&serial_trace, &cfg);
    assert!(baseline.shed.total() > 0, "flash crowd must trigger shedding");

    for workers in prop::pool_sizes() {
        let pool = ThreadPool::new(workers);
        let trace = Trace::generate_with_pool(&spec, seed, &pool);
        assert_eq!(
            trace.events, serial_trace.events,
            "trace generation diverged at {workers} workers"
        );
        for rerun in 0..2 {
            let r = sim::run(&trace, &cfg);
            assert_eq!(
                r.outcomes, baseline.outcomes,
                "outcomes diverged (workers={workers}, rerun={rerun})"
            );
            assert_eq!(r.shed, baseline.shed, "per-reason shed counters diverged");
            assert_eq!(
                (r.accepted, r.completed, r.batches, r.fetches, r.prefetch_hits, r.max_queued),
                (
                    baseline.accepted,
                    baseline.completed,
                    baseline.batches,
                    baseline.fetches,
                    baseline.prefetch_hits,
                    baseline.max_queued
                ),
                "service counters diverged (workers={workers}, rerun={rerun})"
            );
        }
    }
}

/// Early-shed requests are free: they never consume a fetch, a swap, or a
/// batch slot. Deleting the shed events from a flash-crowd trace and
/// replaying only the survivors with admission wide open reproduces the
/// identical schedule — same batch/fetch counters and the same per-request
/// outcome for every surviving event.
#[test]
fn loadgen_flash_crowd_early_sheds_consume_no_fetch_or_service() {
    let spec = TraceSpec::flash_crowd(1_500_000, 24, 3, 900.0, 6.0);
    let trace = Trace::generate(&spec, 0xF1A5);
    let cfg = flash_sim_config();

    let shed_run = sim::run(&trace, &cfg);
    assert!(shed_run.shed.shed_deadline > 0, "flash crowd must trigger deadline sheds");

    let kept: Vec<usize> = (0..trace.events.len())
        .filter(|&i| !matches!(shed_run.outcomes[i], Outcome::Shed(_)))
        .collect();
    let pruned = Trace {
        events: kept.iter().map(|&i| trace.events[i]).collect(),
        n_experts: trace.n_experts,
        duration_us: trace.duration_us,
    };
    let open = sim::run(&pruned, &SimConfig { admission: AdmissionConfig::default(), ..cfg });

    assert_eq!(open.shed.total(), 0, "pruned replay must admit everything");
    assert_eq!(
        (open.batches, open.swaps, open.fetches, open.prefetch_hits),
        (shed_run.batches, shed_run.swaps, shed_run.fetches, shed_run.prefetch_hits),
        "shed requests must not perturb the service schedule"
    );
    for (pi, &oi) in kept.iter().enumerate() {
        assert_eq!(
            open.outcomes[pi], shed_run.outcomes[oi],
            "event {oi}: outcome changed when shed events were removed"
        );
    }
}

/// The chaos/soak layer for adaptive replication + live topology churn
/// + ternary delta updates (ROADMAP item 4's acceptance bar,
/// artifact-free): a flash-crowd trace is served through a store-backed
/// `PrepareContext` while the topology churns mid-trace — one node
/// drained at the one-third mark, a fresh node added at two-thirds —
/// a seeded fault plan drops every stripe's first attempt, a
/// popularity-driven rebalance round runs every 8 events, and the viral
/// expert takes two staged version pushes applied as ternary `.cpeftd`
/// deltas against its host-resident predecessor.
///
/// Every served expert must be **bit-identical** to a churn-free flat
/// single-store reference of the same pinned version, at every pool
/// size and on every rerun; the fault/rebalance/delta counters must
/// replay exactly; and the churn leg must actually have exercised the
/// machinery (`failovers`, `rebalances`, `replicas_added`,
/// `delta_applies` all > 0).
#[test]
fn synthetic_churn_soak_bit_identical() -> anyhow::Result<()> {
    use compeft::compeft::engine::compress_delta;
    use compeft::coordinator::cache::LruTier;
    use compeft::coordinator::loader::ExpertLoader;
    use compeft::coordinator::metrics::Metrics;
    use compeft::coordinator::store::{
        ExpertStore, RebalanceConfig, Rebalancer, StoreConfig,
    };
    use compeft::coordinator::transport::{FaultPlan, FaultSpec};
    use compeft::coordinator::{PrepareContext, PreparedExpert, SimLink};
    use compeft::util::sync::{rank, OrderedMutex};
    use std::collections::BTreeMap;
    use std::sync::Arc;

    let dir = fresh_dir("churn_soak");
    let cfg = CompressConfig { density: 0.15, alpha: 1.0, granularity: Granularity::Global };
    let n_experts = 8u32;

    // Fixture: 8 experts on disk; e0 is the viral one and gets two more
    // training rounds (v1, v2) — saved as npz next to the base.
    let mut npz_paths = Vec::new();
    let mut template_like = None;
    for i in 0..n_experts as u64 {
        let tv = synthetic_tv(200 + i, 6_000);
        let npz = dir.join(format!("e{i}.lora.npz"));
        tv.save_npz(&npz)?;
        template_like.get_or_insert(tv);
        npz_paths.push(npz);
    }
    let perturb = |tv: &ParamSet, salt: usize| -> ParamSet {
        let mut out = tv.clone();
        for (_, t) in out.iter_mut() {
            let len = t.data.len();
            for k in 0..len / 50 + 1 {
                let i = (k * 97 + salt) % len;
                t.data[i] = -t.data[i] * 1.5 + 1e-4;
            }
            for k in 0..len / 100 + 1 {
                let i = (k * 131 + 7 + salt) % len;
                t.data[i] = 0.0;
            }
        }
        out
    };
    let tv0 = ParamSet::load_npz(&npz_paths[0])?;
    let tv1 = perturb(&tv0, 3);
    let tv2 = perturb(&tv1, 11);
    let npz_v1 = dir.join("e0.lora.next1.npz");
    let npz_v2 = dir.join("e0.lora.next2.npz");
    tv1.save_npz(&npz_v1)?;
    tv2.save_npz(&npz_v2)?;

    // Fresh registry per leg: version-pin state (`current`) lives in the
    // registry, and each leg must start from "base version admitted".
    // Registration rewrites the same deterministic .cpeft bytes.
    let mk_reg = || -> anyhow::Result<Arc<Registry>> {
        let mut reg = Registry::new();
        for (i, npz) in npz_paths.iter().enumerate() {
            reg.register_compeft(&format!("e{i}"), "t", "s", ExpertMethod::Lora, npz, &cfg)?;
        }
        assert_eq!(reg.register_compeft_version("e0", &npz_v1, &cfg)?, 1);
        assert_eq!(reg.register_compeft_version("e0", &npz_v2, &cfg)?, 2);
        Ok(Arc::new(reg))
    };
    let reg0 = mk_reg()?;

    // Stage the `.cpeftd` side files the delta-apply fast path picks up:
    // v(n+1) as a ternary diff against v(n)'s compressed form.
    let (c0, c1, c2) = (
        compress_params(&tv0, &cfg),
        compress_params(&tv1, &cfg),
        compress_params(&tv2, &cfg),
    );
    for (old_c, new_c, npz, v) in [(&c0, &c1, &npz_v1, 1u32), (&c1, &c2, &npz_v2, 2)] {
        let delta = compress_delta(old_c, new_c)?;
        // Next to the versioned `.cpeft` the registration wrote — the
        // pipeline looks the delta up at `rec.path.with_extension(..)`.
        let path = npz.with_extension(format!("v{v}.cpeftd"));
        std::fs::write(&path, delta.to_bytes(Encoding::Golomb))?;
    }

    let templates = bs::zero_templates(&template_like.unwrap());

    // Churn-free flat reference, one fresh context per key so every
    // reference expert travels the plain full-fetch path (in particular
    // the versioned keys must NOT take the delta shortcut here — the
    // soak then proves delta-apply reconstructs these exact bytes).
    let keys: Vec<String> = (0..n_experts)
        .map(|i| format!("e{i}"))
        .chain(["e0@v1".to_string(), "e0@v2".to_string()])
        .collect();
    let mut reference: BTreeMap<String, PreparedExpert> = BTreeMap::new();
    for key in &keys {
        let flat = PrepareContext {
            loader: ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
            )
            .with_pool(Arc::new(ThreadPool::new(2))),
            registry: Arc::clone(&reg0),
            templates: templates.clone(),
            cpu: Arc::new(OrderedMutex::new(
                rank::CPU_TIER,
                "cache.cpu_tier",
                LruTier::new("cpu", 64 << 20),
            )),
            archive: None,
        };
        reference.insert(key.clone(), flat.prepare(key)?);
    }

    // The soak trace: steady Zipf with a flash crowd on e0 in the middle
    // third — the viral expert the delta pushes target.
    let trace = Trace::generate(&TraceSpec::flash_crowd(1_000_000, n_experts, 2, 150.0, 6.0), 77);
    let n = trace.events.len();
    assert!(n > 100, "soak trace too short ({n} events)");
    let (at_drain, at_add) = (n / 3, 2 * n / 3);
    let (at_v1, at_v2) = (n * 45 / 100, n * 70 / 100);

    let mut counter_ref: Option<(u64, u64, u64, u64, u64, u64, u64, u64)> = None;
    for workers in prop::pool_sizes() {
        for round in 0..2 {
            let leg = format!("w={workers} round={round}");
            let reg = mk_reg()?;
            let pool = Arc::new(ThreadPool::new(workers));
            let metrics = Arc::new(Metrics::new());
            let mut scfg = StoreConfig::new(3, 2);
            scfg.time_scale = 0.0;
            scfg.stripe_bytes = 200; // several stripes per expert
            // Every stripe's first attempt is dropped: all traffic
            // failovers once, nothing is lost.
            scfg.faults = FaultPlan::new(
                42,
                FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() },
            );
            let store = Arc::new(ExpertStore::new(
                scfg,
                Some(Arc::clone(&pool)),
                Arc::clone(&metrics),
            ));
            let ctx = PrepareContext {
                loader: ExpertLoader::new(
                    SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                    SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
                )
                .with_pool(Arc::clone(&pool))
                .with_store(Arc::clone(&store)),
                registry: Arc::clone(&reg),
                templates: templates.clone(),
                cpu: Arc::new(OrderedMutex::new(
                    rank::CPU_TIER,
                    "cache.cpu_tier",
                    LruTier::new("cpu", 64 << 20),
                )),
                archive: None,
            };
            // Aggressive widening so the 1-fetch-per-expert popularity
            // profile (everything stays host-resident) still exercises
            // real replica adds under the byte budget.
            let mut rb = Rebalancer::new(RebalanceConfig {
                hot_factor: 0.1,
                slack: 4,
                ..RebalanceConfig::default()
            });
            let check = |got: &PreparedExpert, key: &str, what: &str| {
                let want = reference.get(key).expect("reference key");
                prop::assert_paramset_bit_identical(
                    &got.params,
                    &want.params,
                    &format!("{leg} {what} key={key}"),
                );
                assert_eq!(got.upload_bytes, want.upload_bytes, "{leg} {what} {key}");
                assert_eq!(got.dense_bytes, want.dense_bytes, "{leg} {what} {key}");
            };

            for (k, ev) in trace.events.iter().enumerate() {
                if k == at_drain {
                    let m = store.drain_node(1)?;
                    assert!(m.moved_experts > 0, "{leg}: drain must migrate replicas");
                }
                if k == at_add {
                    let m = store.add_node();
                    assert!(m.epoch > 0, "{leg}: add must publish an epoch");
                }
                if k == at_v1 || k == at_v2 {
                    // Version push: make sure the predecessor is
                    // host-resident, flip admission, and serve the new
                    // pin — the first serve goes through the ternary
                    // delta-apply path, bit-identical to a full fetch.
                    let before = ctx.prepare(&reg.pin("e0"))?;
                    check(&before, &reg.pin("e0"), "pre-activate");
                    let v = reg.activate_next("e0").expect("staged version");
                    assert_eq!(v, if k == at_v1 { 1 } else { 2 }, "{leg}");
                    let after = ctx.prepare(&reg.pin("e0"))?;
                    check(&after, &reg.pin("e0"), "post-activate");
                }
                if k % 8 == 7 {
                    store.rebalance(&mut rb);
                }
                let key = reg.pin(&format!("e{}", ev.expert));
                let got = ctx.prepare(&key)?;
                check(&got, &key, "serve");
            }

            let s = metrics.snapshot();
            assert!(s.failovers > 0, "{leg}: the fault plan must have fired");
            assert!(s.rebalances > 0, "{leg}: rebalance rounds must have run");
            assert!(s.replicas_added > 0, "{leg}: the hot tail must widen");
            assert_eq!(s.delta_applies, 2, "{leg}: both version pushes apply as deltas");
            assert!(s.delta_bytes_saved > 0, "{leg}: deltas must beat full pushes");
            assert!(s.migrated_bytes > 0, "{leg}: drain/add/widen must move bytes");
            let counters = (
                s.failovers,
                s.stripe_retries,
                s.rebalances,
                s.replicas_added,
                s.replicas_dropped,
                s.migrated_bytes,
                s.delta_applies,
                s.delta_bytes_saved,
            );
            match &counter_ref {
                None => counter_ref = Some(counters),
                Some(r) => {
                    assert_eq!(counters, *r, "{leg}: churn counters drifted");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
