//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! This is the only place the Rust side touches XLA. Python lowers the
//! µT forward passes once (`python/compile/aot.py`); here we compile
//! them on the PJRT CPU client and serve executions on the request
//! path. Base-model parameters are uploaded to device buffers once per
//! model and reused across requests (`execute_b`), so a request only
//! transfers its tokens and, when an expert is swapped in, the adapter
//! tensors.

mod bundle;
mod client;

pub use bundle::{AdapterKind, ModelBundle, ModelMeta};
pub use client::{Executable, Runtime};
