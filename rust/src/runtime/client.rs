//! Thin wrapper over the `xla` crate's PJRT CPU client.

use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Shared PJRT client. Cheap to clone (Arc inside the xla crate's
/// PjRtClient as well; we add our own Arc for clarity of ownership).
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Upload an f32 tensor to a device buffer (kept resident).
    pub fn upload_f32(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?)
    }

    /// Upload an i32 token batch [b, s].
    pub fn upload_tokens(&self, tokens: &[i32], b: usize, s: usize) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(tokens.len() == b * s, "token count mismatch");
        Ok(self.client.buffer_from_host_buffer(tokens, &[b, s], None)?)
    }

    /// Upload a scalar f32.
    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }
}

/// One compiled HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with device-resident buffers; returns the flattened f32
    /// output of the first (single) tuple element plus its shape.
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<(Vec<f32>, Vec<usize>)> {
        let outs = self
            .exe
            .execute_b(args)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = outs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = lit.to_tuple1()?;
        let shape = out.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok((out.to_vec::<f32>()?, dims))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// End-to-end smoke: the Pallas ternarize kernel artifact executes
    /// through PJRT and matches the Rust-side semantics.
    #[test]
    fn pallas_ternarize_artifact_runs() -> Result<()> {
        let path = artifacts().join("kernels/ternarize.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return Ok(());
        }
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo_text(&path)?;

        let n = 1 << 16;
        let mut rng = crate::util::rng::Pcg::seed(5);
        let tau: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let t = crate::tensor::Tensor::new(vec![n], tau.clone());
        let buf = rt.upload_f32(&t)?;
        let thr = rt.upload_scalar(0.8)?;
        let scale = rt.upload_scalar(2.5)?;
        let (out, dims) = exe.run_buffers(&[&buf, &thr, &scale])?;
        assert_eq!(dims, vec![n]);
        for (i, (&o, &x)) in out.iter().zip(&tau).enumerate() {
            let expect = if x.abs() >= 0.8 { 2.5 * x.signum() } else { 0.0 };
            assert!((o - expect).abs() < 1e-6, "elem {i}: {o} vs {expect}");
        }
        Ok(())
    }

    /// The ternary_apply kernel artifact matches the bitmask dot-product
    /// semantics used by the coordinator.
    #[test]
    fn pallas_ternary_apply_artifact_runs() -> Result<()> {
        let path = artifacts().join("kernels/ternary_apply.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return Ok(());
        }
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo_text(&path)?;

        let (m, k, n) = (32usize, 256usize, 256usize);
        let mut rng = crate::util::rng::Pcg::seed(9);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let mut pos = vec![0.0f32; k * n];
        let mut neg = vec![0.0f32; k * n];
        for i in 0..k * n {
            let r = rng.next_f32();
            if r < 0.05 {
                pos[i] = 1.0;
            } else if r < 0.10 {
                neg[i] = 1.0;
            }
        }
        let scale = 0.125f32;
        let bx = rt.upload_f32(&Tensor::new(vec![m, k], x.clone()))?;
        let bp = rt.upload_f32(&Tensor::new(vec![k, n], pos.clone()))?;
        let bn = rt.upload_f32(&Tensor::new(vec![k, n], neg.clone()))?;
        let bs = rt.upload_scalar(scale)?;
        let (out, dims) = exe.run_buffers(&[&bx, &bp, &bn, &bs])?;
        assert_eq!(dims, vec![m, n]);
        // Reference matmul.
        for row in [0usize, 7, 31] {
            for col in [0usize, 100, 255] {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += x[row * k + kk] as f64
                        * (pos[kk * n + col] - neg[kk * n + col]) as f64;
                }
                let expect = acc as f32 * scale;
                let got = out[row * n + col];
                assert!(
                    (got - expect).abs() < 1e-3 + 1e-3 * expect.abs(),
                    "({row},{col}): {got} vs {expect}"
                );
            }
        }
        Ok(())
    }
}
