//! A loaded µT model: metadata, parameters, device buffers, and the
//! compiled forward executables for each adapter kind and batch size.

use crate::runtime::client::{Executable, Runtime};
use crate::tensor::ParamSet;
use crate::util::json::Json;
use crate::util::sync::{rank, OrderedMutex};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Which forward variant an execution uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AdapterKind {
    Base,
    Lora,
    Ia3,
}

impl AdapterKind {
    fn artifact_stem(self) -> &'static str {
        match self {
            AdapterKind::Base => "forward",
            AdapterKind::Lora => "forward_lora",
            AdapterKind::Ia3 => "forward_ia3",
        }
    }
}

/// Batch sizes exported by aot.py (see server::SERVE_BATCH).
#[allow(dead_code)]
pub const SERVE_BATCH: usize = 8;
#[allow(dead_code)]
pub const EVAL_BATCH: usize = 64;

/// Parsed `meta.json` for one scale.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub scale: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_params: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub lora_rank: usize,
    pub base_order: Vec<String>,
    pub lora_order: Vec<String>,
    pub ia3_order: Vec<String>,
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text)?;
        let get_num = |k: &str| -> Result<usize> {
            Ok(j.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("meta missing {k}"))? as usize)
        };
        let get_list = |k: &str| -> Result<Vec<String>> {
            match j.get(k) {
                Some(Json::Arr(xs)) => Ok(xs
                    .iter()
                    .filter_map(|x| x.as_str().map(|s| s.to_string()))
                    .collect()),
                _ => bail!("meta missing list {k}"),
            }
        };
        Ok(ModelMeta {
            scale: j
                .get("scale")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            d_model: get_num("d_model")?,
            n_layers: get_num("n_layers")?,
            n_params: get_num("n_params")?,
            vocab: get_num("vocab")?,
            seq_len: get_num("seq_len")?,
            lora_rank: get_num("lora_rank")?,
            base_order: get_list("base_order")?,
            lora_order: get_list("lora_order")?,
            ia3_order: get_list("ia3_order")?,
        })
    }
}

/// A fully loaded model scale.
///
/// The parameter sets are `Arc`-shared host-side data: the serving
/// pipeline's prefetch threads hold the same allocations as templates
/// (`coordinator::pipeline::Templates`) without copying the base model.
pub struct ModelBundle {
    pub meta: ModelMeta,
    pub base: Arc<ParamSet>,
    pub lora_init: Arc<ParamSet>,
    pub ia3_init: Arc<ParamSet>,
    rt: Runtime,
    dir: PathBuf,
    /// Base parameters resident on device, in `meta.base_order`.
    base_buffers: Vec<xla::PjRtBuffer>,
    /// Lazily compiled executables keyed by (kind, batch).
    exes: OrderedMutex<HashMap<(AdapterKind, usize), Arc<Executable>>>,
}

impl ModelBundle {
    /// Load a scale from `artifacts/models/{scale}`.
    pub fn load(rt: &Runtime, artifacts: &Path, scale: &str) -> Result<ModelBundle> {
        let dir = artifacts.join("models").join(scale);
        let meta = ModelMeta::load(&dir.join("meta.json"))?;
        let base = ParamSet::load_npz(&dir.join("base.npz"))?;
        let lora_init = ParamSet::load_npz(&dir.join("lora_init.npz"))?;
        let ia3_init = ParamSet::load_npz(&dir.join("ia3_init.npz"))?;

        let mut base_buffers = Vec::with_capacity(meta.base_order.len());
        for name in &meta.base_order {
            let t = base
                .get(name)
                .with_context(|| format!("base param {name:?} missing"))?;
            base_buffers.push(rt.upload_f32(t)?);
        }
        Ok(ModelBundle {
            meta,
            base: Arc::new(base),
            lora_init: Arc::new(lora_init),
            ia3_init: Arc::new(ia3_init),
            rt: rt.clone(),
            dir,
            base_buffers,
            exes: OrderedMutex::new(rank::EXEC_CACHE, "runtime.exec_cache", HashMap::new()),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Get (compiling on first use) the executable for a variant.
    pub fn executable(&self, kind: AdapterKind, batch: usize) -> Result<Arc<Executable>> {
        let mut exes = self.exes.lock().unwrap();
        if let Some(e) = exes.get(&(kind, batch)) {
            return Ok(Arc::clone(e));
        }
        let path = self.dir.join(format!("{}_b{batch}.hlo.txt", kind.artifact_stem()));
        let exe = Arc::new(self.rt.load_hlo_text(&path)?);
        exes.insert((kind, batch), Arc::clone(&exe));
        Ok(exe)
    }

    fn adapter_order(&self, kind: AdapterKind) -> &[String] {
        match kind {
            AdapterKind::Base => &[],
            AdapterKind::Lora => &self.meta.lora_order,
            AdapterKind::Ia3 => &self.meta.ia3_order,
        }
    }

    /// Upload adapter parameters in canonical order.
    pub fn upload_adapter(
        &self,
        kind: AdapterKind,
        adapter: &ParamSet,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = Vec::new();
        for name in self.adapter_order(kind) {
            let t = adapter
                .get(name)
                .with_context(|| format!("adapter param {name:?} missing"))?;
            bufs.push(self.rt.upload_f32(t)?);
        }
        Ok(bufs)
    }

    /// Upload a full replacement parameter set (full-FT experts).
    pub fn upload_full_params(&self, params: &ParamSet) -> Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = Vec::with_capacity(self.meta.base_order.len());
        for name in &self.meta.base_order {
            let t = params
                .get(name)
                .with_context(|| format!("param {name:?} missing"))?;
            bufs.push(self.rt.upload_f32(t)?);
        }
        Ok(bufs)
    }

    /// Run one already-padded batch. `adapter_bufs` must match `kind`;
    /// `full_bufs` (if given) replaces the resident base parameters.
    pub fn run_batch(
        &self,
        kind: AdapterKind,
        batch: usize,
        adapter_bufs: &[xla::PjRtBuffer],
        full_bufs: Option<&[xla::PjRtBuffer]>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == batch * self.meta.seq_len,
            "tokens {} != batch {batch} * seq {}",
            tokens.len(),
            self.meta.seq_len
        );
        let tok_buf = self.rt.upload_tokens(tokens, batch, self.meta.seq_len)?;
        let exe = self.executable(kind, batch)?;
        let base: &[xla::PjRtBuffer] = match full_bufs {
            Some(b) => b,
            None => &self.base_buffers,
        };
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(base.len() + adapter_bufs.len() + 1);
        args.extend(base.iter());
        args.extend(adapter_bufs.iter());
        args.push(&tok_buf);
        let (out, dims) = exe.run_buffers(&args)?;
        anyhow::ensure!(
            dims == vec![batch, self.meta.vocab],
            "unexpected logits shape {dims:?}"
        );
        Ok(out)
    }

    /// Compute logits for arbitrarily many examples, chunking and
    /// padding to `batch`. Returns `[n, vocab]` row-major.
    pub fn logits(
        &self,
        kind: AdapterKind,
        batch: usize,
        adapter: Option<&ParamSet>,
        full_params: Option<&ParamSet>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let s = self.meta.seq_len;
        anyhow::ensure!(tokens.len() % s == 0, "token stream not a multiple of seq");
        let n = tokens.len() / s;
        let adapter_bufs = match adapter {
            Some(a) => self.upload_adapter(kind, a)?,
            None => match kind {
                AdapterKind::Base => Vec::new(),
                AdapterKind::Lora => self.upload_adapter(kind, &*self.lora_init)?,
                AdapterKind::Ia3 => self.upload_adapter(kind, &*self.ia3_init)?,
            },
        };
        let full_bufs = match full_params {
            Some(p) => Some(self.upload_full_params(p)?),
            None => None,
        };

        let mut out = Vec::with_capacity(n * self.meta.vocab);
        let mut chunk = vec![0i32; batch * s];
        let mut i = 0;
        while i < n {
            let take = (n - i).min(batch);
            chunk[..take * s].copy_from_slice(&tokens[i * s..(i + take) * s]);
            for v in chunk[take * s..].iter_mut() {
                *v = 0; // pad rows with PAD tokens
            }
            let logits =
                self.run_batch(kind, batch, &adapter_bufs, full_bufs.as_deref(), &chunk)?;
            out.extend_from_slice(&logits[..take * self.meta.vocab]);
            i += take;
        }
        Ok(out)
    }
}
