//! Dense tensors and named parameter sets.
//!
//! Task vectors, adapter weights, and model parameters all move through
//! the coordinator as [`ParamSet`]s: an ordered map from parameter name
//! (e.g. `"layers.0.attn.wq.lora_a"`) to a dense f32 [`Tensor`]. Order
//! matters because the AOT-lowered executables take parameters
//! positionally; `ParamSet` iterates in insertion order, which the
//! Python side fixes canonically (sorted names).

use crate::util::npz::{self, NpyArray};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Elementwise a += b.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "shape mismatch in add_assign");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise a += s * b.
    pub fn add_scaled(&mut self, other: &Tensor, s: f32) {
        assert_eq!(self.shape, other.shape, "shape mismatch in add_scaled");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// L2 norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// Ordered, named collection of tensors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParamSet {
    names: Vec<String>,
    tensors: BTreeMap<String, Tensor>,
}

impl ParamSet {
    pub fn new() -> ParamSet {
        ParamSet::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.tensors.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.tensors.get_mut(name)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(move |n| (n.as_str(), &self.tensors[n]))
    }

    /// Mutable iteration in name-sorted order (the underlying map's
    /// order, *not* insertion order — fine for by-name updates like the
    /// parallel add-assign, which look tensors up per name anyway).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&str, &mut Tensor)> {
        self.tensors.iter_mut().map(|(n, t)| (n.as_str(), t))
    }

    /// Total number of scalar parameters.
    pub fn total_elements(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }

    /// Total size in bytes at 16-bit precision — the paper's baseline
    /// for "original checkpoint" storage (§2.2: 16·d bits).
    pub fn bytes_fp16(&self) -> u64 {
        self.total_elements() as u64 * 2
    }

    /// Flatten all tensors (in name order) into one vector. This is the
    /// `τ ∈ R^d` view used by Algorithm 1 when compressing globally.
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_elements());
        for (_, t) in self.iter() {
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Inverse of [`flatten`]: reshape a flat vector back into this
    /// set's structure.
    pub fn unflatten_like(&self, flat: &[f32]) -> Result<ParamSet> {
        if flat.len() != self.total_elements() {
            bail!("flat length {} != total elements {}", flat.len(), self.total_elements());
        }
        let mut out = ParamSet::new();
        let mut off = 0;
        for (name, t) in self.iter() {
            let n = t.len();
            out.insert(name, Tensor::new(t.shape.clone(), flat[off..off + n].to_vec()));
            off += n;
        }
        Ok(out)
    }

    /// self += other (matching names; missing names are an error).
    pub fn add_assign(&mut self, other: &ParamSet) -> Result<()> {
        for (name, t) in other.iter() {
            match self.tensors.get_mut(name) {
                Some(mine) => mine.add_assign(t),
                None => bail!("parameter {name:?} missing in target"),
            }
        }
        Ok(())
    }

    /// self += s * other.
    pub fn add_scaled(&mut self, other: &ParamSet, s: f32) -> Result<()> {
        for (name, t) in other.iter() {
            match self.tensors.get_mut(name) {
                Some(mine) => mine.add_scaled(t, s),
                None => bail!("parameter {name:?} missing in target"),
            }
        }
        Ok(())
    }

    /// Difference `self - other` as a new set (task vector τ = θ_ft − θ_init).
    pub fn sub(&self, other: &ParamSet) -> Result<ParamSet> {
        let mut out = ParamSet::new();
        for (name, t) in self.iter() {
            let o = other
                .get(name)
                .with_context(|| format!("parameter {name:?} missing in init"))?;
            if o.shape != t.shape {
                bail!("shape mismatch for {name:?}: {:?} vs {:?}", t.shape, o.shape);
            }
            let data = t.data.iter().zip(&o.data).map(|(a, b)| a - b).collect();
            out.insert(name, Tensor::new(t.shape.clone(), data));
        }
        Ok(out)
    }

    /// Load from an `.npz` file, inserting in sorted-name order (the
    /// canonical order fixed by the Python exporter).
    pub fn load_npz(path: &Path) -> Result<ParamSet> {
        let arrays = npz::read_npz(path)?;
        let mut out = ParamSet::new();
        for (name, arr) in arrays {
            let data = arr.to_f32().with_context(|| format!("tensor {name:?}"))?;
            out.insert(&name, Tensor::new(arr.shape.clone(), data));
        }
        Ok(out)
    }

    /// Save to an `.npz` file.
    pub fn save_npz(&self, path: &Path) -> Result<()> {
        let mut arrays = BTreeMap::new();
        for (name, t) in self.iter() {
            arrays
                .insert(name.to_string(), NpyArray::from_f32(t.shape.clone(), &t.data));
        }
        npz::write_npz(path, &arrays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ParamSet {
        let mut p = ParamSet::new();
        p.insert("a", Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]));
        p.insert("b", Tensor::new(vec![3], vec![-1., 0., 1.]));
        p
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let p = sample();
        let flat = p.flatten();
        assert_eq!(flat.len(), 7);
        let back = p.unflatten_like(&flat).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn unflatten_wrong_len_errors() {
        let p = sample();
        assert!(p.unflatten_like(&[0.0; 6]).is_err());
    }

    #[test]
    fn sub_gives_task_vector() {
        let ft = sample();
        let mut init = sample();
        init.get_mut("a").unwrap().data = vec![0.5, 2., 3., 4.];
        let tv = ft.sub(&init).unwrap();
        assert_eq!(tv.get("a").unwrap().data, vec![0.5, 0., 0., 0.]);
        assert_eq!(tv.get("b").unwrap().data, vec![0., 0., 0.]);
    }

    #[test]
    fn add_scaled_applies() {
        let mut base = sample();
        let delta = sample();
        base.add_scaled(&delta, 0.5).unwrap();
        assert_eq!(base.get("a").unwrap().data, vec![1.5, 3., 4.5, 6.]);
    }

    #[test]
    fn bytes_fp16_accounting() {
        let p = sample();
        assert_eq!(p.bytes_fp16(), 14);
    }

    #[test]
    fn npz_roundtrip() {
        let dir = std::env::temp_dir().join("compeft_tensor_test");
        let path = dir.join("p.npz");
        let p = sample();
        p.save_npz(&path).unwrap();
        let back = ParamSet::load_npz(&path).unwrap();
        assert_eq!(back.get("a").unwrap().data, p.get("a").unwrap().data);
        assert_eq!(back.get("b").unwrap().shape, vec![3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tensor_mismatch_panics() {
        let r = std::panic::catch_unwind(|| {
            Tensor::new(vec![2, 2], vec![1.0]);
        });
        assert!(r.is_err());
    }
}
