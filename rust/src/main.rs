//! `compeft` — CLI for the ComPEFT reproduction.
//!
//! Subcommands:
//!   compress   compress a task-vector .npz into a .cpeft
//!   inspect    print stats of a .cpeft / task-vector .npz
//!   eval       evaluate an expert (original or compressed) via PJRT
//!   serve      run the serving coordinator on a synthetic trace
//!   loadgen    replay a seeded trace scenario through the scheduling +
//!              admission stack on the deterministic sim clock
//!              (artifact-free)
//!   archive    `archive build` packs a scale's compressed experts into
//!              one `.cpar` archive; `serve --archive <path>` then
//!              serves them as zero-copy views of the resident image
//!   delta      `delta build` diffs two task-vector checkpoints into a
//!              ternary `.cpeftd` delta; `delta push` stages the next
//!              version of a served expert (full `.cpeft` + `.cpeftd`
//!              side file) for the coordinator's delta-apply fast path
//!   lint       run `compeft-lint` (the in-repo determinism/panic-safety/
//!              lock-discipline analyzer) over rust/src; non-zero exit on
//!              any unsuppressed violation
//!
//! `compeft <subcommand> --help` lists flags.

use anyhow::{bail, Context, Result};
use compeft::compeft::compress::{compress_params, CompressConfig, Granularity};
use compeft::compeft::entropy::human_bytes;
use compeft::compeft::format::{self, Encoding};
use compeft::coordinator::batcher::BatchPolicy;
use compeft::coordinator::{
    Coordinator, CoordinatorConfig, ExpertMethod, LinkSpec, Registry,
};
use compeft::tensor::ParamSet;
use compeft::util::cli::ArgSpec;
use compeft::util::rng::{Pcg, Zipf};
use compeft::{bench_support as bs, eval as ev};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("compress") => run(cmd_compress(&argv[1..])),
        Some("inspect") => run(cmd_inspect(&argv[1..])),
        Some("eval") => run(cmd_eval(&argv[1..])),
        Some("serve") => run(cmd_serve(&argv[1..])),
        Some("loadgen") => run(cmd_loadgen(&argv[1..])),
        Some("archive") => run(cmd_archive(&argv[1..])),
        Some("delta") => run(cmd_delta(&argv[1..])),
        Some("lint") => run(cmd_lint(&argv[1..])),
        _ => {
            eprintln!(
                "usage: compeft <compress|inspect|eval|serve|loadgen|archive|delta|lint> \
                 [flags]\n\
                 see README.md for the experiment-to-bench map"
            );
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    }
}

fn cmd_lint(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new("lint", "run compeft-lint over rust/src")
        .flag("root", "", "repo root (default: the build-time manifest dir)");
    let a = spec.parse(argv)?;
    let root = if a.get("root").is_empty() {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    } else {
        PathBuf::from(a.get("root"))
    };
    let diags = compeft::analysis::lint_tree(&root)?;
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("compeft-lint: clean");
        Ok(())
    } else {
        bail!(
            "compeft-lint: {} violation(s); fix them or annotate with \
             `// compeft-lint: allow(rule-id) -- <reason>`",
            diags.len()
        )
    }
}

fn cmd_compress(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new("compress", "compress a task-vector .npz into .cpeft")
        .required("input", "task vector .npz")
        .flag("output", "", "output path (default: input with .cpeft)")
        .flag("k", "0.2", "density (fraction of entries kept)")
        .flag("alpha", "1.0", "scaling value α")
        .flag("encoding", "golomb", "golomb | bitmask")
        .boolean("per-tensor", "compress each tensor independently");
    let a = spec.parse(argv)?;
    let input = PathBuf::from(a.get("input"));
    let tv = ParamSet::load_npz(&input)?;
    let cfg = CompressConfig {
        density: a.get_f64("k")?,
        alpha: a.get_f64("alpha")?,
        granularity: if a.get_bool("per-tensor") {
            Granularity::PerTensor
        } else {
            Granularity::Global
        },
    };
    let enc = match a.get("encoding") {
        "golomb" => Encoding::Golomb,
        "bitmask" => Encoding::Bitmask,
        other => bail!("unknown encoding {other}"),
    };
    let out = if a.get("output").is_empty() {
        input.with_extension("cpeft")
    } else {
        PathBuf::from(a.get("output"))
    };
    let compressed = compress_params(&tv, &cfg);
    let bytes = format::save(&out, &compressed, enc)?;
    let orig = tv.bytes_fp16();
    println!(
        "compressed {} ({} params, {} fp16) -> {} ({}, {:.1}x, density {:.1}%)",
        input.display(),
        tv.total_elements(),
        human_bytes(orig),
        out.display(),
        human_bytes(bytes),
        orig as f64 / bytes as f64,
        compressed.density() * 100.0,
    );
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new("inspect", "print stats of a .cpeft or task-vector .npz")
        .required("input", "path to .cpeft or .npz");
    let a = spec.parse(argv)?;
    let path = PathBuf::from(a.get("input"));
    match path.extension().and_then(|e| e.to_str()) {
        Some("cpeft") => {
            let (c, enc) = format::load(&path)?;
            println!(
                "{}: encoding {:?}, {} parts, {} params, nnz {} (density {:.2}%)",
                path.display(),
                enc,
                c.parts.len(),
                c.total_elements(),
                c.nnz(),
                c.density() * 100.0
            );
            for (name, t) in &c.parts {
                println!(
                    "  part {:12} len {:>9} nnz {:>8} scale {:+.6}",
                    if name.is_empty() { "<global>" } else { name },
                    t.len,
                    t.nnz(),
                    t.scale
                );
            }
        }
        _ => {
            let tv = ParamSet::load_npz(&path)?;
            let flat = tv.flatten();
            let sigma = compeft::util::stats::std_f32(&flat);
            let mean = compeft::util::stats::mean_f32(&flat);
            let max = flat.iter().cloned().fold(f32::MIN, f32::max);
            let min = flat.iter().cloned().fold(f32::MAX, f32::min);
            println!(
                "{}: {} tensors, {} params ({} fp16)",
                path.display(),
                tv.len(),
                tv.total_elements(),
                human_bytes(tv.bytes_fp16())
            );
            println!("  mean {mean:+.3e}  std {sigma:.3e}  max {max:+.4}  min {min:+.4}");
        }
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new("eval", "evaluate an expert via the PJRT runtime")
        .flag("scale", "s", "model scale (xs|s|m|l)")
        .required("task", "task name, e.g. alpaca")
        .flag("method", "lora", "lora | ia3 | full")
        .flag("set", "", "eval set name (default: task_{task} or glue_{task})")
        .flag("k", "", "density; if set, evaluate the ComPEFT-compressed expert")
        .flag("alpha", "1.0", "scaling value α");
    let a = spec.parse(argv)?;
    let artifacts = bs::require_artifacts();
    let scale = a.get("scale");
    let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
    let expert = bs::load_expert(&artifacts, scale, a.get("task"), a.get("method"), None)?;

    let set_name = if a.get("set").is_empty() {
        let t = a.get("task");
        let cand = format!("task_{t}");
        if artifacts.join("eval").join(format!("{cand}.npz")).exists() {
            cand
        } else {
            format!("glue_{t}")
        }
    } else {
        a.get("set").to_string()
    };
    let set = bs::load_eval(&artifacts, &set_name)?;

    let (tv, label) = if a.get("k").is_empty() {
        (expert.tv.clone(), "original".to_string())
    } else {
        let k = a.get_f64("k")?;
        let alpha = a.get_f64("alpha")?;
        (
            bs::compress_tv(&expert.tv, k, alpha),
            format!("ComPEFT(k={k}, α={alpha})"),
        )
    };
    let t0 = Instant::now();
    let acc = bs::eval_tv(&bundle, expert.method, &tv, &set)?;
    println!(
        "{label} {}/{} on {set_name}: accuracy {:.4} ({} examples, {:.2?})",
        scale,
        expert.task,
        acc,
        set.n,
        t0.elapsed()
    );
    Ok(())
}

fn cmd_loadgen(argv: &[String]) -> Result<()> {
    use compeft::coordinator::admission::AdmissionConfig;
    use compeft::util::bench::JsonSink;
    use compeft::util::json::Json;
    use compeft::workload::sim::{self, Mode, ServiceModel, SimConfig};
    use compeft::workload::{Trace, TraceSpec};

    let spec = ArgSpec::new(
        "loadgen",
        "replay seeded trace scenarios on the deterministic sim clock (artifact-free)",
    )
    .flag("scenario", "all", "steady | flash | diurnal | bursty | all")
    .flag("seed", "2026", "trace seed (same seed -> bit-identical results)")
    .flag("duration-ms", "2000", "trace length in simulated milliseconds")
    .flag("experts", "32", "expert catalog size")
    .flag("tenants", "4", "number of tenants")
    .flag("rps", "800", "total offered load, requests/second")
    .flag("queue-cap", "1024", "admission queue cap (0 = unbounded)")
    .boolean("no-shed", "disable deadline-aware shedding")
    .flag("est-batch-us", "20000", "admission queue-delay estimate per batch, us")
    .flag("gpu-slots", "4", "simulated accelerator residency, in experts")
    .flag("prefetch-depth", "2", "staged-prefetch lookahead (0 = off)")
    .flag("store-nodes", "0", "sharded-store model: nodes striping fetches (0 = flat)")
    .flag("replication", "1", "base replicas per expert in the store model")
    .boolean("rebalance", "popularity-aware adaptive replication in the store model")
    .flag("rebalance-every", "8", "batches between adaptive-replication rounds")
    .flag("concurrency", "0", "closed-loop outstanding requests (0 = open loop)")
    .flag("json", "", "write {bench,row,value,unit,config} records to this path");
    let a = spec.parse(argv)?;

    let duration_us = a.get_u64("duration-ms")? * 1_000;
    let n_experts = a.get_usize("experts")? as u32;
    let tenants = a.get_usize("tenants")?;
    let total_rps = a.get_f64("rps")?;
    let seed = a.get_u64("seed")?;
    let concurrency = a.get_usize("concurrency")?;

    let cfg = SimConfig {
        admission: AdmissionConfig {
            queue_cap: a.get_usize("queue-cap")?,
            shed_deadline: !a.get_bool("no-shed"),
            est_batch_us: a.get_u64("est-batch-us")?,
            ..Default::default()
        },
        model: ServiceModel {
            gpu_slots: a.get_usize("gpu-slots")?,
            prefetch_depth: a.get_usize("prefetch-depth")?,
            store_nodes: a.get_usize("store-nodes")?,
            replication: a.get_usize("replication")?,
            rebalance: a.get_bool("rebalance"),
            rebalance_every: a.get_u64("rebalance-every")?,
            ..Default::default()
        },
        mode: if concurrency > 0 { Mode::Closed { concurrency } } else { Mode::Open },
        ..Default::default()
    };

    let mut sink = if a.get("json").is_empty() {
        None
    } else {
        let mut config = Json::obj();
        config
            .set("seed", Json::num(seed as f64))
            .set("duration_us", Json::num(duration_us as f64))
            .set("n_experts", Json::num(f64::from(n_experts)))
            .set("tenants", Json::num(tenants as f64))
            .set("total_rps", Json::num(total_rps));
        Some(JsonSink::new(PathBuf::from(a.get("json")), "loadgen", config))
    };

    let names: Vec<&str> = match a.get("scenario") {
        "all" => vec!["steady", "flash", "diurnal", "bursty"],
        one => vec![one],
    };
    for name in names {
        let Some(tspec) = TraceSpec::scenario(name, duration_us, n_experts, tenants, total_rps)
        else {
            bail!("unknown scenario {name} (steady|flash|diurnal|bursty|all)");
        };
        let trace = Trace::generate(&tspec, seed);
        let r = sim::run(&trace, &cfg);
        println!("--- scenario {name} (seed {seed}) ---");
        println!(
            "offered {:.1} rps  submitted {}  accepted {}  completed {}  \
             shed {} ({:.1}% | deadline {}, queue_full {})",
            trace.offered_rps(),
            r.submitted,
            r.accepted,
            r.completed,
            r.shed.total(),
            r.shed_rate() * 100.0,
            r.shed.shed_deadline,
            r.shed.queue_full,
        );
        println!(
            "latency: p50 {:.2}ms  p99 {:.2}ms  p999 {:.2}ms  mean {:.2}ms",
            r.p50_us() / 1e3,
            r.p99_us() / 1e3,
            r.p999_us() / 1e3,
            r.latency.mean_us() / 1e3,
        );
        println!(
            "goodput {:.1} rps ({} met deadline)  batches {}  swaps {}  fetches {}  \
             prefetch hits {}  max queued {}",
            r.goodput_rps(),
            r.deadline_met,
            r.batches,
            r.swaps,
            r.fetches,
            r.prefetch_hits,
            r.max_queued,
        );
        if r.rebalances > 0 {
            println!(
                "rebalance: {} rounds  +{} / -{} replicas  {} migrated",
                r.rebalances,
                r.replicas_added,
                r.replicas_dropped,
                compeft::compeft::entropy::human_bytes(r.migrated_bytes)
            );
        }
        if let Some(s) = &mut sink {
            s.record(&format!("{name}/goodput_rps"), r.goodput_rps(), "rps");
            s.record(&format!("{name}/shed_rate"), r.shed_rate(), "frac");
            s.record(&format!("{name}/p50_us"), r.p50_us(), "us");
            s.record(&format!("{name}/p99_us"), r.p99_us(), "us");
            s.record(&format!("{name}/p999_us"), r.p999_us(), "us");
            s.record(&format!("{name}/fetches"), r.fetches as f64, "count");
            s.record(&format!("{name}/max_queued"), r.max_queued as f64, "count");
            s.record(&format!("{name}/rebalances"), r.rebalances as f64, "count");
            s.record(&format!("{name}/replicas_added"), r.replicas_added as f64, "count");
        }
    }
    if let Some(s) = &sink {
        s.write().context("write --json artifact")?;
    }
    Ok(())
}

/// Build the serving registry from this scale's instruct experts —
/// shared by `serve` and `archive build` so an archive packs exactly
/// the ids the coordinator will ask it for.
fn build_serve_registry(
    artifacts: &PathBuf,
    scale: &str,
    compressed: bool,
    cfg: &CompressConfig,
) -> Result<(Registry, Vec<(String, String)>)> {
    let mut registry = Registry::new();
    let found = compeft::coordinator::registry::scan_expert_npz(artifacts, scale)?;
    if found.is_empty() {
        bail!("no experts found for scale {scale} — run `make artifacts`");
    }
    let mut ids = Vec::new();
    for (task, method, path) in &found {
        if *method != ExpertMethod::Lora {
            continue;
        }
        // Only tasks with eval sets (instruct tasks).
        if !artifacts.join("eval").join(format!("task_{task}.npz")).exists() {
            continue;
        }
        let id = format!("{task}.lora");
        if compressed {
            registry.register_compeft(&id, task, scale, *method, path, cfg)?;
        } else {
            registry.register_original(&id, task, scale, *method, path)?;
        }
        ids.push((id, task.clone()));
    }
    Ok((registry, ids))
}

fn cmd_archive(argv: &[String]) -> Result<()> {
    match argv.first().map(|s| s.as_str()) {
        Some("build") => cmd_archive_build(&argv[1..]),
        _ => bail!("usage: compeft archive build [flags] (--help lists them)"),
    }
}

/// Pack a scale's compressed experts into one `.cpar` archive whose
/// members the coordinator serves as zero-copy views
/// (`serve --archive <path>`).
fn cmd_archive_build(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "archive build",
        "pack a scale's compressed experts into one .cpar archive",
    )
    .flag("scale", "s", "model scale")
    .flag("output", "", "archive path (default: <artifacts>/experts_<scale>.cpar)")
    .flag("k", "0.2", "ComPEFT density")
    .flag("alpha", "1.0", "ComPEFT α");
    let a = spec.parse(argv)?;
    let artifacts = bs::require_artifacts();
    let scale = a.get("scale");
    let cfg = CompressConfig {
        density: a.get_f64("k")?,
        alpha: a.get_f64("alpha")?,
        granularity: Granularity::Global,
    };
    let (registry, ids) = build_serve_registry(&artifacts, scale, true, &cfg)?;
    let out = if a.get("output").is_empty() {
        artifacts.join(format!("experts_{scale}.cpar"))
    } else {
        PathBuf::from(a.get("output"))
    };
    let (members, bytes) = compeft::coordinator::build_from_registry(&registry, &out)?;
    println!(
        "packed {members} of {} experts into {} ({})",
        ids.len(),
        out.display(),
        human_bytes(bytes)
    );
    println!("serve them in place with: compeft serve --archive {}", out.display());
    Ok(())
}

fn cmd_delta(argv: &[String]) -> Result<()> {
    match argv.first().map(|s| s.as_str()) {
        Some("build") => cmd_delta_build(&argv[1..]),
        Some("push") => cmd_delta_push(&argv[1..]),
        _ => bail!("usage: compeft delta <build|push> [flags] (--help lists them)"),
    }
}

/// Shared by `delta build` and `delta push`: compress two task-vector
/// checkpoints under one config and diff them in the ternary domain.
fn build_delta_pair(
    old: &std::path::Path,
    new: &std::path::Path,
    cfg: &CompressConfig,
) -> Result<(
    compeft::compeft::compress::CompressedParamSet,
    compeft::compeft::compress::CompressedParamSet,
    compeft::compeft::engine::ExpertDelta,
)> {
    let old_tv = ParamSet::load_npz(old)?;
    let new_tv = ParamSet::load_npz(new)?;
    let old_c = compress_params(&old_tv, cfg);
    let new_c = compress_params(&new_tv, cfg);
    let delta = compeft::compeft::engine::compress_delta(&old_c, &new_c)?;
    Ok((old_c, new_c, delta))
}

/// Diff two task-vector `.npz` checkpoints into a ternary `.cpeftd`
/// delta: ship only the support entries that changed sign, dropped out,
/// or appeared, instead of re-sending the whole compressed expert.
fn cmd_delta_build(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "delta build",
        "diff two task-vector .npz checkpoints into a ternary .cpeftd delta",
    )
    .required("old", "task vector .npz of the currently served version")
    .required("new", "task vector .npz of the next version")
    .flag("output", "", "delta path (default: <new> with .cpeftd)")
    .flag("k", "0.2", "density (fraction of entries kept)")
    .flag("alpha", "1.0", "scaling value α")
    .boolean("per-tensor", "compress each tensor independently");
    let a = spec.parse(argv)?;
    let old = PathBuf::from(a.get("old"));
    let new = PathBuf::from(a.get("new"));
    let cfg = CompressConfig {
        density: a.get_f64("k")?,
        alpha: a.get_f64("alpha")?,
        granularity: if a.get_bool("per-tensor") {
            Granularity::PerTensor
        } else {
            Granularity::Global
        },
    };
    let (_, new_c, delta) = build_delta_pair(&old, &new, &cfg)?;
    let out = if a.get("output").is_empty() {
        new.with_extension("cpeftd")
    } else {
        PathBuf::from(a.get("output"))
    };
    let wire = delta.to_bytes(Encoding::Golomb);
    let full = format::to_bytes(&new_c, Encoding::Golomb);
    std::fs::write(&out, &wire)
        .with_context(|| format!("write delta {}", out.display()))?;
    println!(
        "delta {} -> {}: {} touched entries, {} vs {} full push ({:.1}x smaller)",
        old.display(),
        new.display(),
        delta.nnz(),
        human_bytes(wire.len() as u64),
        human_bytes(full.len() as u64),
        full.len() as f64 / (wire.len() as f64).max(1.0),
    );
    println!("wrote {}", out.display());
    Ok(())
}

/// Stage the next version of a served expert: write the full
/// `.v<n>.cpeft` (what a cold fetch serves, and the bit-identity
/// reference) plus the `.v<n>.cpeftd` side file the coordinator's
/// delta-apply fast path picks up when version n−1 is host-resident.
fn cmd_delta_push(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "delta push",
        "stage the next version of a served expert as full .cpeft + .cpeftd delta",
    )
    .required("base", "task vector .npz the expert was registered from")
    .required("new", "task vector .npz of the next version")
    .flag("k", "0.2", "density (fraction of entries kept)")
    .flag("alpha", "1.0", "scaling value α")
    .boolean("per-tensor", "compress each tensor independently");
    let a = spec.parse(argv)?;
    let base = PathBuf::from(a.get("base"));
    let new = PathBuf::from(a.get("new"));
    let cfg = CompressConfig {
        density: a.get_f64("k")?,
        alpha: a.get_f64("alpha")?,
        granularity: if a.get_bool("per-tensor") {
            Granularity::PerTensor
        } else {
            Granularity::Global
        },
    };
    // Next version = first free .v<n>.cpeft slot next to the base npz.
    let mut next = 1u32;
    while base.with_extension(format!("v{next}.cpeft")).exists() {
        next += 1;
    }
    // The delta's base is the previous version's *compressed* form: the
    // staged .cpeft for n ≥ 2, the base npz compressed under the same
    // config for n = 1. Applying it must reconstruct the full encode
    // bit-for-bit, so verify exactly that before writing anything.
    let prev_c = if next == 1 {
        compress_params(&ParamSet::load_npz(&base)?, &cfg)
    } else {
        format::load(&base.with_extension(format!("v{}.cpeft", next - 1)))?.0
    };
    let new_c = compress_params(&ParamSet::load_npz(&new)?, &cfg);
    let delta = compeft::compeft::engine::compress_delta(&prev_c, &new_c)?;
    let check = compeft::compeft::engine::apply_delta(&prev_c, &delta)?;
    if check != new_c {
        bail!("delta apply does not reconstruct the next version (internal error)");
    }
    let full_path = base.with_extension(format!("v{next}.cpeft"));
    let delta_path = base.with_extension(format!("v{next}.cpeftd"));
    let full_bytes = format::save(&full_path, &new_c, Encoding::Golomb)?;
    let wire = delta.to_bytes(Encoding::Golomb);
    std::fs::write(&delta_path, &wire)
        .with_context(|| format!("write delta {}", delta_path.display()))?;
    println!(
        "staged v{next}: {} ({}) + {} ({}, {:.1}x smaller than the full push)",
        full_path.display(),
        human_bytes(full_bytes),
        delta_path.display(),
        human_bytes(wire.len() as u64),
        full_bytes as f64 / (wire.len() as f64).max(1.0),
    );
    println!(
        "a coordinator with v{} host-resident applies the delta instead of refetching"
        , next.saturating_sub(1)
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let spec = ArgSpec::new("serve", "run the coordinator on a synthetic trace")
        .flag("scale", "s", "model scale")
        .flag("format", "compeft", "expert checkpoint format: compeft | original")
        .flag("requests", "200", "number of requests to replay")
        .flag("gpu-mb", "1", "GPU tier capacity in MB")
        .flag("zipf", "1.1", "request skew exponent")
        .flag("k", "0.2", "ComPEFT density")
        .flag("alpha", "1.0", "ComPEFT α")
        .flag("time-scale", "1.0", "simulated-link wall-clock factor")
        .flag("prefetch-depth", "2", "experts prefetched ahead of execution (0 = off)")
        .flag("store-nodes", "0", "sharded store nodes (0 = flat single link)")
        .flag("replication", "1", "replicas per expert in the sharded store")
        .flag("fault-seed", "0", "seed of the store's deterministic fault plan")
        .boolean("rebalance", "popularity-aware adaptive replication on the store")
        .flag("rebalance-every", "8", "batches between adaptive-replication rounds")
        .flag("drain", "", "live-drain this store node after half the trace")
        .flag("archive", "", "local .cpar archive served as zero-copy views")
        .flag("seed", "0", "trace seed");
    let a = spec.parse(argv)?;
    let artifacts = bs::require_artifacts();
    let scale = a.get("scale");

    // Build the registry from the instruct experts of this scale.
    let compressed = a.get("format") == "compeft";
    let cfg = CompressConfig {
        density: a.get_f64("k")?,
        alpha: a.get_f64("alpha")?,
        granularity: Granularity::Global,
    };
    let (registry, ids) = build_serve_registry(&artifacts, scale, compressed, &cfg)?;
    println!("registered {} experts ({})", ids.len(), a.get("format"));

    let mut ccfg = CoordinatorConfig::new(artifacts.clone(), scale);
    ccfg.gpu_capacity_bytes = (a.get_f64("gpu-mb")? * 1e6) as u64;
    ccfg.policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
    ccfg.net = LinkSpec::internet();
    ccfg.pcie = LinkSpec::pcie();
    ccfg.time_scale = a.get_f64("time-scale")?;
    ccfg.prefetch_depth = a.get_usize("prefetch-depth")?;
    ccfg.store_nodes = a.get_usize("store-nodes")?;
    ccfg.replication = a.get_usize("replication")?;
    ccfg.fault_seed = a.get_u64("fault-seed")?;
    ccfg.rebalance = a.get_bool("rebalance");
    ccfg.rebalance_every = a.get_u64("rebalance-every")?;
    let drain_node = if a.get("drain").is_empty() {
        None
    } else {
        Some(a.get_usize("drain")?)
    };
    if (ccfg.rebalance || drain_node.is_some()) && ccfg.store_nodes == 0 {
        bail!("--rebalance/--drain need a sharded store (--store-nodes > 0)");
    }
    if !a.get("archive").is_empty() {
        ccfg.archive = Some(PathBuf::from(a.get("archive")));
    }
    if ccfg.store_nodes > 0 {
        // Shard layout record: how the catalog maps onto store nodes —
        // built with the same seed the engine's store uses, so the
        // printed layout always matches where fetches actually go.
        let placement = compeft::coordinator::Placement::new(
            ccfg.store_nodes,
            ccfg.replication,
            compeft::coordinator::store::DEFAULT_PLACEMENT_SEED,
        );
        let mut per_node = vec![0usize; ccfg.store_nodes];
        for (_, nodes) in registry.assignments(&placement) {
            per_node[nodes[0]] += 1;
        }
        println!(
            "sharded store: {} nodes, replication {}, primaries per node {:?}",
            ccfg.store_nodes, ccfg.replication, per_node
        );
    }
    let coord = Coordinator::start(ccfg, registry)?;

    // Replay a Zipf-skewed trace; tokens come from each task's eval set.
    let n_req = a.get_usize("requests")?;
    let mut rng = Pcg::seed(a.get_u64("seed")?);
    let zipf = Zipf::new(ids.len(), a.get_f64("zipf")?);
    let sets: Vec<ev::EvalSet> = ids
        .iter()
        .map(|(_, task)| bs::load_eval(&artifacts, &format!("task_{task}")))
        .collect::<Result<_>>()?;

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    let mut correct_labels = Vec::with_capacity(n_req);
    for r in 0..n_req {
        // Live topology churn mid-trace: drain the named node once half
        // the requests are in flight. The engine keeps serving — old
        // placement for in-flight fetches, new epoch after the cutover.
        if r == n_req / 2 {
            if let Some(node) = drain_node {
                let m = coord.drain_store_node(node)?;
                println!(
                    "drained node {node} mid-trace: {} experts ({}) migrated, epoch {}",
                    m.moved_experts,
                    human_bytes(m.migrated_bytes),
                    m.epoch
                );
            }
        }
        let e = zipf.sample(&mut rng);
        let set = &sets[e];
        let i = rng.range(0, set.n);
        let tokens = set.tokens[i * set.seq..(i + 1) * set.seq].to_vec();
        correct_labels.push(set.labels[i]);
        pending.push(coord.submit(&ids[e].0, tokens, set.n_classes[i] as usize));
    }
    let mut correct = 0usize;
    for (rx, label) in pending.into_iter().zip(&correct_labels) {
        let p = rx.recv().context("coordinator reply")?;
        if p.class as i64 == *label {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    let report = coord.shutdown()?;

    println!("--- serve summary ({}) ---", a.get("format"));
    println!(
        "requests {}  accuracy {:.3}  wall {:.2?}  throughput {:.1} req/s",
        n_req,
        correct as f64 / n_req as f64,
        wall,
        n_req as f64 / wall.as_secs_f64()
    );
    println!(
        "latency: mean {:.2}ms  p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms",
        m.total_mean_us / 1e3,
        m.total_p50_us / 1e3,
        m.total_p95_us / 1e3,
        m.total_p99_us / 1e3
    );
    println!(
        "batches {}  mean fill {:.2}  swaps {}  swap mean {:.2}ms  exec mean {:.2}ms",
        m.batches, m.mean_batch_fill, m.swaps, m.swap_mean_us / 1e3, m.exec_mean_us / 1e3
    );
    println!(
        "gpu tier: {}/{} used, {} entries, hit-rate {:.2}, evictions {}",
        human_bytes(report.gpu.used_bytes),
        human_bytes(report.gpu.capacity_bytes),
        report.gpu.entries,
        report.gpu.hit_rate(),
        report.gpu.evictions
    );
    println!(
        "bytes moved: net {}  pcie {}",
        human_bytes(report.net_bytes),
        human_bytes(report.pcie_bytes)
    );
    println!(
        "prefetch: {} hits  {} waits  {} misses  {} wasted  overlap saved {:.2?}  \
         rejected {}",
        report.prefetch_hits,
        report.prefetch_waits,
        report.prefetch_misses,
        report.prefetch_wasted,
        report.overlap_saved,
        report.rejected
    );
    if report.rejected > 0 {
        let rb = report.rejected_by;
        println!(
            "rejected by reason: shed_deadline {}  queue_full {}  malformed {}  \
             unknown_expert {}  load_failure {}  exec_error {}",
            rb.shed_deadline,
            rb.queue_full,
            rb.malformed,
            rb.unknown_expert,
            rb.load_failure,
            rb.exec_error
        );
    }
    println!(
        "store: {} stripe retries  {} failovers  {} corrupt payloads",
        report.stripe_retries, report.failovers, report.corrupt_payloads
    );
    println!(
        "rebalance: {} rounds  +{} / -{} replicas  {} migrated",
        report.rebalances,
        report.replicas_added,
        report.replicas_dropped,
        human_bytes(report.migrated_bytes)
    );
    println!(
        "delta updates: {} applied  {} saved vs full pushes",
        report.delta_applies,
        human_bytes(report.delta_bytes_saved)
    );
    println!(
        "fused decode: {} loads  overlap hidden {:.2?}",
        report.fused_loads, report.decode_overlap
    );
    println!(
        "archive: {} hits  {} viewed in place  {} payload copies",
        report.archive_hits,
        human_bytes(report.archive_bytes_viewed),
        report.payload_copies
    );
    Ok(())
}
