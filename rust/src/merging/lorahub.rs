//! LoraHub-style dynamic LoRA composition (Huang et al., 2023; paper
//! §3.6).
//!
//! Given N expert LoRA modules {Lᵢ = (Aᵢ, Bᵢ)} and a few-shot unseen
//! task, LoraHub learns scalar weights wᵢ and composes
//!
//! ```text
//! L_m = (Σᵢ wᵢ Aᵢ)(Σᵢ wᵢ Bᵢ)          (paper Eq. 1)
//! ```
//!
//! Because our model applies LoRA as `x ↦ x·A·B`, summing the A and B
//! ParamSets with weights wᵢ *is* Eq. 1 — composition happens on the
//! parameters, and the runtime multiplies the composed matrices. The
//! weights are learned with the gradient-free (1+1)-ES in
//! [`crate::merging::es`] from a few-shot objective supplied by the
//! caller (the Figure 4 bench plugs in the runtime's few-shot loss).

use crate::compeft::compress::CompressedParamSet;
use crate::merging::es::{self, EsConfig, EsResult};
use crate::merging::{ternary, weighted_sum, MergeMethod};
use crate::tensor::ParamSet;
use crate::util::rng::Pcg;
use anyhow::Result;

/// Compose expert LoRA ParamSets with fixed weights (paper Eq. 1).
pub fn compose(experts: &[ParamSet], weights: &[f64]) -> Result<ParamSet> {
    weighted_sum(experts, weights)
}

/// [`compose`] directly on compressed experts — the ternary-domain
/// weighted sum, bit-identical to composing the decompressed pool but
/// without materializing N dense task vectors. This is the hot call of
/// the ES loop below: LoraHub evaluates hundreds of candidate weight
/// vectors over the same expert pool, so keeping the pool in `.cpeft`
/// form cuts the working set from O(N·d) to O(d).
pub fn compose_ternary(
    experts: &[&CompressedParamSet],
    weights: &[f64],
) -> Result<ParamSet> {
    ternary::merge_ternary(
        experts,
        &MergeMethod::Weighted { weights: weights.to_vec() },
    )
}

/// Outcome of a LoraHub adaptation run.
#[derive(Clone, Debug)]
pub struct LoraHubResult {
    pub weights: Vec<f64>,
    pub composed: ParamSet,
    /// Best few-shot objective value seen (lower is better).
    pub best_loss: f64,
    pub evals: usize,
}

/// Learn composition weights for an unseen task.
///
/// `loss(composed)` evaluates the few-shot objective of a candidate
/// composed module (e.g. cross-entropy of the adapted model on the
/// task's few-shot examples, computed through the PJRT runtime).
pub fn learn_composition<F>(
    experts: &[ParamSet],
    cfg: &EsConfig,
    rng: &mut Pcg,
    mut loss: F,
) -> Result<LoraHubResult>
where
    F: FnMut(&ParamSet) -> f64,
{
    anyhow::ensure!(!experts.is_empty(), "no experts to compose");
    let n = experts.len();
    // LoraHub initializes all weights to 0 (base model) and perturbs.
    let r: EsResult = es::minimize(n, Some(&vec![0.0; n]), cfg, rng, |w| {
        match compose(experts, w) {
            Ok(c) => loss(&c),
            Err(_) => f64::INFINITY,
        }
    });
    let composed = compose(experts, &r.best)?;
    Ok(LoraHubResult {
        weights: r.best,
        composed,
        best_loss: r.best_value,
        evals: r.evals,
    })
}

/// [`learn_composition`] over a compressed expert pool: every candidate
/// composition is built ternary-domain ([`compose_ternary`]). Because
/// the composed module is bit-identical to composing the decompressed
/// pool, the loss sequence — and therefore the learned weights — match
/// [`learn_composition`] on the dense pool exactly (same `rng`, same
/// `cfg`, same `loss`).
pub fn learn_composition_ternary<F>(
    experts: &[&CompressedParamSet],
    cfg: &EsConfig,
    rng: &mut Pcg,
    mut loss: F,
) -> Result<LoraHubResult>
where
    F: FnMut(&ParamSet) -> f64,
{
    anyhow::ensure!(!experts.is_empty(), "no experts to compose");
    let n = experts.len();
    let r: EsResult = es::minimize(n, Some(&vec![0.0; n]), cfg, rng, |w| {
        match compose_ternary(experts, w) {
            Ok(c) => loss(&c),
            Err(_) => f64::INFINITY,
        }
    });
    let composed = compose_ternary(experts, &r.best)?;
    Ok(LoraHubResult {
        weights: r.best,
        composed,
        best_loss: r.best_value,
        evals: r.evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn expert(a: &[f32], b: &[f32]) -> ParamSet {
        let mut p = ParamSet::new();
        p.insert("l0.lora_a", Tensor::new(vec![2, 1], a.to_vec()));
        p.insert("l0.lora_b", Tensor::new(vec![1, 2], b.to_vec()));
        p
    }

    #[test]
    fn compose_is_weighted_sum_of_factors() {
        let e1 = expert(&[1.0, 0.0], &[1.0, 0.0]);
        let e2 = expert(&[0.0, 1.0], &[0.0, 1.0]);
        let c = compose(&[e1, e2], &[0.5, 2.0]).unwrap();
        assert_eq!(c.get("l0.lora_a").unwrap().data, vec![0.5, 2.0]);
        assert_eq!(c.get("l0.lora_b").unwrap().data, vec![0.5, 2.0]);
    }

    #[test]
    fn learns_to_pick_matching_expert() {
        // Loss prefers a composition equal to e1's parameters: the
        // optimizer should find w ≈ (1, 0).
        let e1 = expert(&[1.0, 2.0], &[3.0, 4.0]);
        let e2 = expert(&[-5.0, 1.0], &[0.0, -2.0]);
        let target = e1.flatten();
        let mut rng = Pcg::seed(11);
        let cfg = EsConfig { budget: 800, l1: 0.01, ..Default::default() };
        let r = learn_composition(&[e1.clone(), e2], &cfg, &mut rng, |c| {
            c.flatten()
                .iter()
                .zip(&target)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        })
        .unwrap();
        assert!(r.best_loss < 0.5, "loss={}", r.best_loss);
        assert!((r.weights[0] - 1.0).abs() < 0.3, "{:?}", r.weights);
        assert!(r.weights[1].abs() < 0.3, "{:?}", r.weights);
    }

    #[test]
    fn empty_experts_error() {
        let mut rng = Pcg::seed(1);
        assert!(
            learn_composition(&[], &EsConfig::default(), &mut rng, |_| 0.0).is_err()
        );
        assert!(learn_composition_ternary(&[], &EsConfig::default(), &mut rng, |_| 0.0)
            .is_err());
    }

    /// The ternary-domain ES run follows the dense run step for step:
    /// with the same rng/config/loss, compositions are bit-identical,
    /// so the learned weights and the final composed module agree.
    #[test]
    fn ternary_learning_matches_dense_pool() {
        use crate::compeft::compress::{
            compress_params, decompress_params, CompressConfig,
        };
        use crate::util::prop;

        let mut rng = Pcg::seed(21);
        let pool: Vec<ParamSet> = (0..3)
            .map(|_| {
                let mut p = ParamSet::new();
                p.insert(
                    "l0.lora_a",
                    Tensor::new(vec![300], prop::task_vector_like(&mut rng, 300)),
                );
                p.insert(
                    "l0.lora_b",
                    Tensor::new(vec![150], prop::task_vector_like(&mut rng, 150)),
                );
                p
            })
            .collect();
        let cfg = CompressConfig { density: 0.2, alpha: 1.0, ..Default::default() };
        let comps: Vec<_> = pool.iter().map(|p| compress_params(p, &cfg)).collect();
        let refs: Vec<&_> = comps.iter().collect();
        let dense_pool: Vec<ParamSet> = comps
            .iter()
            .zip(&pool)
            .map(|(c, p)| decompress_params(c, p).unwrap())
            .collect();

        let target = dense_pool[0].flatten();
        let loss = |c: &ParamSet| -> f64 {
            c.flatten()
                .iter()
                .zip(&target)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let es = EsConfig { budget: 60, restarts: 2, ..Default::default() };
        let mut rng_a = Pcg::seed(5);
        let dense = learn_composition(&dense_pool, &es, &mut rng_a, loss).unwrap();
        let mut rng_b = Pcg::seed(5);
        let tern = learn_composition_ternary(&refs, &es, &mut rng_b, loss).unwrap();

        assert_eq!(dense.weights, tern.weights);
        assert_eq!(dense.evals, tern.evals);
        assert!(dense.best_loss == tern.best_loss);
        assert_eq!(dense.composed, tern.composed);
    }
}
