//! Gradient-free optimizer for LoraHub-style composition weights.
//!
//! LoraHub uses the "Shiwa" meta-optimizer from Nevergrad; the
//! offline environment has no Nevergrad, so we implement the core
//! ingredient it selects at this problem size: a (1+1) evolution
//! strategy with the 1/5th-success-rule step adaptation, plus random
//! restarts. Minimizes `f(w) + l1 · ‖w‖₁` over a box, matching
//! LoraHub's L1-regularized few-shot loss.

use crate::util::rng::Pcg;

/// Optimizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct EsConfig {
    /// Total evaluation budget across restarts.
    pub budget: usize,
    /// Number of random restarts (best result wins).
    pub restarts: usize,
    /// Initial step size.
    pub sigma0: f64,
    /// Box constraint: weights clamped to [lo, hi] (LoraHub uses [-1.5, 1.5]).
    pub lo: f64,
    pub hi: f64,
    /// L1 regularization strength (LoraHub uses 0.05).
    pub l1: f64,
}

impl Default for EsConfig {
    fn default() -> Self {
        EsConfig { budget: 300, restarts: 3, sigma0: 0.3, lo: -1.5, hi: 1.5, l1: 0.05 }
    }
}

/// Result of an optimization run.
#[derive(Clone, Debug)]
pub struct EsResult {
    pub best: Vec<f64>,
    /// Best *raw* objective value (without the L1 term).
    pub best_value: f64,
    pub evals: usize,
}

/// Minimize `f` over `dim` weights with a (1+1)-ES.
///
/// `f` is the raw objective (e.g. few-shot loss); the L1 penalty is
/// added internally for selection but reported values are raw.
pub fn minimize<F: FnMut(&[f64]) -> f64>(
    dim: usize,
    init: Option<&[f64]>,
    cfg: &EsConfig,
    rng: &mut Pcg,
    mut f: F,
) -> EsResult {
    assert!(dim > 0);
    let per_restart = (cfg.budget / cfg.restarts.max(1)).max(2);
    let mut best: Option<(Vec<f64>, f64, f64)> = None; // (w, raw, penalized)
    let mut evals = 0usize;

    for restart in 0..cfg.restarts.max(1) {
        // First restart starts from `init` (or zeros); later ones random.
        let mut x: Vec<f64> = match (restart, init) {
            (0, Some(w)) => w.to_vec(),
            (0, None) => vec![0.0; dim],
            _ => (0..dim)
                .map(|_| cfg.lo + (cfg.hi - cfg.lo) * rng.next_f64())
                .collect(),
        };
        for v in &mut x {
            *v = v.clamp(cfg.lo, cfg.hi);
        }
        let raw = f(&x);
        evals += 1;
        let mut fx = raw + cfg.l1 * l1norm(&x);
        if best.as_ref().map_or(true, |(_, _, b)| fx < *b) {
            best = Some((x.clone(), raw, fx));
        }

        let mut sigma = cfg.sigma0;
        let mut successes = 0usize;
        let mut trials = 0usize;
        for _ in 0..per_restart.saturating_sub(1) {
            let cand: Vec<f64> = x
                .iter()
                .map(|&v| (v + sigma * rng.normal()).clamp(cfg.lo, cfg.hi))
                .collect();
            let raw = f(&cand);
            evals += 1;
            let fc = raw + cfg.l1 * l1norm(&cand);
            trials += 1;
            if fc <= fx {
                x = cand;
                fx = fc;
                successes += 1;
                if best.as_ref().map_or(true, |(_, _, b)| fc < *b) {
                    best = Some((x.clone(), raw, fc));
                }
            }
            // 1/5th success rule, applied every 10 trials.
            if trials >= 10 {
                let rate = successes as f64 / trials as f64;
                sigma *= if rate > 0.2 { 1.5 } else { 0.6 };
                sigma = sigma.clamp(1e-4, (cfg.hi - cfg.lo) / 2.0);
                successes = 0;
                trials = 0;
            }
        }
    }

    let (best, best_value, _) = best.unwrap();
    EsResult { best, best_value, evals }
}

fn l1norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_sphere_minimum() {
        let mut rng = Pcg::seed(42);
        let target = [0.7, -0.3, 0.1];
        let r = minimize(
            3,
            None,
            &EsConfig { budget: 1500, l1: 0.0, ..Default::default() },
            &mut rng,
            |w| {
                w.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
            },
        );
        assert!(r.best_value < 0.01, "value={}", r.best_value);
        for (a, b) in r.best.iter().zip(&target) {
            assert!((a - b).abs() < 0.15, "{:?}", r.best);
        }
    }

    #[test]
    fn respects_box_constraints() {
        let mut rng = Pcg::seed(7);
        let cfg = EsConfig { budget: 200, lo: -0.5, hi: 0.5, ..Default::default() };
        let r = minimize(4, None, &cfg, &mut rng, |w| -w.iter().sum::<f64>());
        for v in &r.best {
            assert!((-0.5..=0.5).contains(v));
        }
        // maximizing Σw → should push toward hi
        assert!(r.best.iter().sum::<f64>() > 1.0, "{:?}", r.best);
    }

    #[test]
    fn l1_drives_sparsity() {
        // Flat objective: only the L1 term matters; weights stay ~0.
        let mut rng = Pcg::seed(3);
        let cfg = EsConfig { budget: 400, l1: 1.0, ..Default::default() };
        let r = minimize(5, Some(&[1.0, 1.0, 1.0, 1.0, 1.0]), &cfg, &mut rng, |_| 0.0);
        assert!(l1norm(&r.best) < 2.0, "{:?}", r.best);
    }

    #[test]
    fn respects_budget() {
        let mut rng = Pcg::seed(1);
        let mut count = 0usize;
        let cfg = EsConfig { budget: 100, restarts: 2, ..Default::default() };
        minimize(2, None, &cfg, &mut rng, |_| {
            count += 1;
            0.0
        });
        assert!(count <= 102, "count={count}");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut rng = Pcg::seed(55);
            minimize(3, None, &EsConfig::default(), &mut rng, |w| {
                w.iter().map(|v| v * v).sum()
            })
            .best
        };
        assert_eq!(run(), run());
    }
}
