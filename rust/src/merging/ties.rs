//! TIES-Merging (Yadav et al., 2023): TrIm, Elect Sign, and disjoint
//! mErge. The paper's Table 6 merges 7 GLUE-task experts with TIES on
//! both original and ComPEFT checkpoints.
//!
//! 1. **Trim** each task vector to its top-k entries by magnitude
//!    (keeping values).
//! 2. **Elect** a sign per parameter: the sign of the summed trimmed
//!    values across tasks (mass-weighted majority).
//! 3. **Disjoint merge**: average only the contributions whose sign
//!    agrees with the elected sign.
//! 4. Scale the merged vector by λ.

use crate::compeft::sparsify::prune_to_topk;
use crate::tensor::ParamSet;
use anyhow::{bail, Result};

/// Configuration for a TIES merge.
#[derive(Clone, Copy, Debug)]
pub struct TiesConfig {
    /// Fraction of entries kept in the trim step (TIES paper uses 0.2).
    pub density: f64,
    /// Final scale λ applied to the merged task vector.
    pub lambda: f64,
}

impl Default for TiesConfig {
    fn default() -> Self {
        TiesConfig { density: 0.2, lambda: 1.0 }
    }
}

/// Merge task vectors with TIES over their flattened global view.
pub fn ties_merge(tvs: &[ParamSet], cfg: &TiesConfig) -> Result<ParamSet> {
    if tvs.is_empty() {
        bail!("no task vectors to merge");
    }
    let names: Vec<String> = tvs[0].names().to_vec();
    for tv in tvs {
        if tv.names() != names {
            bail!("task vectors have differing parameter sets");
        }
    }

    // Step 1: trim per task (flatten → top-k keep values).
    let trimmed: Vec<Vec<f32>> =
        tvs.iter().map(|tv| prune_to_topk(&tv.flatten(), cfg.density)).collect();
    let d = trimmed[0].len();

    // Step 2: elect sign from total mass.
    let mut elected = vec![0.0f32; d];
    for t in &trimmed {
        for (e, &v) in elected.iter_mut().zip(t) {
            *e += v;
        }
    }

    // Step 3: disjoint mean of sign-agreeing contributions.
    //
    // A parameter whose trimmed masses cancel exactly has zero electoral
    // mass, yet `elected[i].signum()` still reports ±1 (IEEE signum of a
    // signed zero), so one side's contributions used to be merged on the
    // strength of nothing — and *which* side depended on the sign bit of
    // the zero. Zero-mass ties now admit no contribution at all
    // (`e != 0.0` covers both ±0.0); the ternary-domain path in
    // [`crate::merging::ternary`] applies the same rule.
    let mut merged = vec![0.0f32; d];
    let mut counts = vec![0u32; d];
    for t in &trimmed {
        for i in 0..d {
            let v = t[i];
            let e = elected[i];
            if v != 0.0 && e != 0.0 && v.signum() == e.signum() {
                merged[i] += v;
                counts[i] += 1;
            }
        }
    }
    for i in 0..d {
        if counts[i] > 0 {
            merged[i] = merged[i] / counts[i] as f32 * cfg.lambda as f32;
        }
    }

    tvs[0].unflatten_like(&merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tv(vals: &[f32]) -> ParamSet {
        let mut p = ParamSet::new();
        p.insert("w", Tensor::new(vec![vals.len()], vals.to_vec()));
        p
    }

    #[test]
    fn sign_conflicts_resolved_by_mass() {
        // Param 0: +3 vs -1 → elected +, merged keeps only +3.
        // Param 1: agreeing -2, -4 → mean -3.
        let a = tv(&[3.0, -2.0]);
        let b = tv(&[-1.0, -4.0]);
        let m = ties_merge(&[a, b], &TiesConfig { density: 1.0, lambda: 1.0 }).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![3.0, -3.0]);
    }

    #[test]
    fn trim_removes_small_entries_before_election() {
        // With density 0.5, each tv keeps its single largest entry.
        let a = tv(&[10.0, 0.1]);
        let b = tv(&[0.1, -8.0]);
        let m = ties_merge(&[a, b], &TiesConfig { density: 0.5, lambda: 1.0 }).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![10.0, -8.0]);
    }

    #[test]
    fn lambda_scales_output() {
        let a = tv(&[2.0]);
        let m1 = ties_merge(&[a.clone()], &TiesConfig { density: 1.0, lambda: 1.0 }).unwrap();
        let m2 = ties_merge(&[a], &TiesConfig { density: 1.0, lambda: 0.5 }).unwrap();
        assert_eq!(m2.get("w").unwrap().data[0], m1.get("w").unwrap().data[0] * 0.5);
    }

    #[test]
    fn single_task_is_identityish() {
        let a = tv(&[1.0, -2.0, 3.0]);
        let m = ties_merge(&[a.clone()], &TiesConfig { density: 1.0, lambda: 1.0 }).unwrap();
        assert_eq!(m.get("w").unwrap().data, a.get("w").unwrap().data);
    }

    #[test]
    fn mismatched_params_error() {
        let mut b = ParamSet::new();
        b.insert("other", Tensor::new(vec![1], vec![1.0]));
        assert!(ties_merge(&[tv(&[1.0]), b], &TiesConfig::default()).is_err());
    }

    /// Regression for the zero-electoral-mass bug: when trimmed masses
    /// cancel exactly, `elected` is a signed zero whose `signum()` is
    /// ±1, so one sign's contributions were merged despite zero
    /// electoral mass (for the `+0.0` that exact cancellation produces,
    /// the positive side won). Zero-mass parameters must merge to 0.
    #[test]
    fn zero_electoral_mass_admits_nothing() {
        // Param 0: +2 vs -2 cancels exactly → no elected sign → 0.
        // Param 1: agreeing +1, +1 → mean 1 (the merge still works).
        let a = tv(&[2.0, 1.0]);
        let b = tv(&[-2.0, 1.0]);
        let m = ties_merge(&[a, b], &TiesConfig { density: 1.0, lambda: 1.0 }).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![0.0, 1.0]);

        // Three-way cancellation (+3, -1, -2) is also zero mass.
        let m3 = ties_merge(
            &[tv(&[3.0]), tv(&[-1.0]), tv(&[-2.0])],
            &TiesConfig { density: 1.0, lambda: 1.0 },
        )
        .unwrap();
        assert_eq!(m3.get("w").unwrap().data, vec![0.0]);
    }

    /// On a single task vector, trim keeps top-k values, the lone
    /// contributor elects its own sign, and the disjoint mean of one is
    /// the value itself — so `ties_merge` must equal `prune_to_topk`
    /// scaled by λ, at any density < 1.
    #[test]
    fn prop_single_task_equals_scaled_prune() {
        use crate::util::prop;
        use crate::util::rng::Pcg;
        prop::check(
            "ties(single tv) == λ·prune_to_topk",
            30,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(4000);
                let k = [0.05, 0.2, 0.5, 0.9][rng.range(0, 4)];
                let lambda = [0.3, 1.0, 1.7][rng.range(0, 3)];
                (prop::task_vector_like(rng, n), k, lambda)
            },
            |(tau, k, lambda)| {
                let mut p = ParamSet::new();
                p.insert("w", Tensor::new(vec![tau.len()], tau.clone()));
                let cfg = TiesConfig { density: *k, lambda: *lambda };
                let merged = ties_merge(&[p], &cfg).map_err(|e| e.to_string())?;
                let expect: Vec<f32> = prune_to_topk(tau, *k)
                    .iter()
                    .map(|&v| if v != 0.0 { v / 1.0 * *lambda as f32 } else { v })
                    .collect();
                let got = &merged.get("w").unwrap().data;
                for i in 0..tau.len() {
                    if got[i].to_bits() != expect[i].to_bits() {
                        return Err(format!(
                            "coord {i}: {} vs λ·pruned {}",
                            got[i], expect[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
