//! Ternary-domain merging: run TIES, averaging, task arithmetic, and
//! LoraHub composition **directly on compressed experts** — no
//! per-expert dense materialization (paper §3.6–§3.7).
//!
//! A `.cpeft` expert is `τ̃ᵢ = sᵢ · γ̃ᵢ` with one f32 scale per part and
//! a sparse sign support, so the dense merge algebra collapses:
//!
//! * **Sign election** (TIES step 2) is `sgn(Σᵢ sᵢ·γ̃ᵢ)` — a weighted
//!   sign vote accumulated over supports only.
//! * **Trim** (TIES step 1) never needs a quickselect: every entry of a
//!   part has magnitude `|sᵢ|`, so the global top-⌈k·d⌉ threshold falls
//!   out of the per-part (|scale|, nnz) table in O(parts·log parts),
//!   and tie-breaking by index becomes a per-part support *prefix*.
//! * **Disjoint merge / weighted sums** touch only coordinates in the
//!   union of supports.
//!
//! [`MergePlan`] compiles N compressed experts + a
//! [`MergeMethod`](crate::merging::MergeMethod) into per-coordinate
//! kernels over `[0, d)` chunks; [`merge_ternary`] drives them
//! serially, [`crate::compeft::engine::par_merge`] chunk-parallel on a
//! [`ThreadPool`](crate::util::pool::ThreadPool). Peak memory is
//! O(d + workers·chunk) instead of the dense path's O(N·d).
//!
//! **Equivalence contract.** Output is *bit-identical* to the dense
//! reference — decompress every expert, then
//! [`merge_dense`](crate::merging::merge_dense) — at every worker
//! count and chunk size. The kernels replay the dense per-coordinate
//! f32 operation sequence exactly (same expert order, same
//! multiply/add/divide shapes, signed zeros included) by materializing
//! each expert's *chunk slice* into a scratch buffer; chunking cannot
//! change results because every dense-path operation is
//! per-coordinate. The zero-electoral-mass rule matches the fixed
//! dense TIES: exact sign cancellation admits nothing (see
//! [`crate::merging::ties`]).
//!
//! Scales must be finite; [`MergePlan::new`] rejects NaN/∞ scales
//! rather than silently diverging from the dense reference's
//! NaN-comparison semantics.

use crate::compeft::compress::{CompressedParamSet, Granularity};
use crate::compeft::ternary::TernaryVector;
use crate::merging::MergeMethod;
use crate::tensor::{ParamSet, Tensor};
use crate::util::pool::chunk_ranges;
use anyhow::{bail, Result};

/// Which slice of a tied segment's support survives the TIES trim.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Admit {
    /// Every support entry (strictly above threshold, or tie fully
    /// inside the budget).
    All,
    /// Support entries with index strictly below the bound (a prefix in
    /// index order — how the dense path breaks exact-threshold ties).
    Prefix(u32),
    /// Nothing (below threshold, zero scale, or budget exhausted).
    Skip,
}

/// One expert part placed in the global flat coordinate space.
struct Seg<'a> {
    offset: usize,
    tern: &'a TernaryVector,
    admit: Admit,
}

impl Seg<'_> {
    fn fill_range(&self, start: usize, out: &mut [f32]) {
        let end = start + out.len();
        let lo = start.max(self.offset);
        let hi = end.min(self.offset + self.tern.len);
        if lo >= hi {
            return;
        }
        let dst = &mut out[lo - start..hi - start];
        match self.admit {
            Admit::All => self.tern.fill_dense_range(lo - self.offset, dst),
            Admit::Prefix(bound) => {
                self.tern.fill_dense_range_clipped(lo - self.offset, dst, bound)
            }
            Admit::Skip => {}
        }
    }
}

/// The merge operation compiled against borrowed expert payloads.
enum Op<'a> {
    /// `Σᵢ wᵢ·τ̃ᵢ` — average, task arithmetic, and LoraHub composition
    /// are all this with different weight vectors.
    Weighted { views: Vec<Vec<Seg<'a>>>, weights: Vec<f64> },
    /// TIES trim / elect-sign / disjoint-merge; the trim is already
    /// folded into each segment's [`Admit`].
    Ties { views: Vec<Vec<Seg<'a>>>, lambda: f64 },
}

/// A validated, trimmed, ready-to-run ternary-domain merge.
///
/// Construction does all the O(parts) global work (layout checks, TIES
/// threshold + tie budgets); [`MergePlan::run_chunk`] is then pure
/// per-chunk computation, safe to fan out across a pool.
pub struct MergePlan<'a> {
    d: usize,
    layout: &'a [(String, Vec<usize>, usize)],
    op: Op<'a>,
}

impl<'a> MergePlan<'a> {
    /// Validate experts (non-empty, identical layouts, parts present
    /// and sized, finite scales) and compile `method` against them.
    pub fn new(
        experts: &[&'a CompressedParamSet],
        method: &MergeMethod,
    ) -> Result<MergePlan<'a>> {
        if experts.is_empty() {
            bail!("no task vectors to merge");
        }
        let layout: &'a [(String, Vec<usize>, usize)] = &experts[0].layout;
        for (i, e) in experts.iter().enumerate().skip(1) {
            if e.layout.as_slice() != layout {
                bail!("expert {i} layout differs from expert 0");
            }
        }
        let d: usize = layout
            .iter()
            .map(|(_, shape, _)| shape.iter().product::<usize>())
            .sum();

        let mut views = Vec::with_capacity(experts.len());
        for (i, e) in experts.iter().enumerate() {
            let mut segs = Vec::new();
            match e.granularity {
                Granularity::Global => {
                    let tern = match e.parts.get("") {
                        Some(t) => t,
                        None => bail!("expert {i}: missing global part"),
                    };
                    if tern.len != d {
                        bail!(
                            "expert {i}: global part length {} != layout total {d}",
                            tern.len
                        );
                    }
                    if !tern.scale.is_finite() {
                        bail!("expert {i}: non-finite scale {}", tern.scale);
                    }
                    segs.push(Seg { offset: 0, tern, admit: Admit::All });
                }
                Granularity::PerTensor => {
                    for (name, shape, off) in layout {
                        let tern = match e.parts.get(name) {
                            Some(t) => t,
                            None => bail!("expert {i}: missing part {name:?}"),
                        };
                        let n: usize = shape.iter().product();
                        if tern.len != n {
                            bail!(
                                "expert {i}: part {name:?} length {} != tensor \
                                 length {n}",
                                tern.len
                            );
                        }
                        if !tern.scale.is_finite() {
                            bail!(
                                "expert {i}: non-finite scale {} in part {name:?}",
                                tern.scale
                            );
                        }
                        segs.push(Seg { offset: *off, tern, admit: Admit::All });
                    }
                }
            }
            views.push(segs);
        }

        let op = match method {
            MergeMethod::Average => {
                let w = 1.0 / experts.len() as f64;
                Op::Weighted { views, weights: vec![w; experts.len()] }
            }
            MergeMethod::TaskArithmetic { lambda } => {
                Op::Weighted { views, weights: vec![*lambda; experts.len()] }
            }
            MergeMethod::Weighted { weights } => {
                if weights.len() != experts.len() {
                    bail!(
                        "{} task vectors but {} weights",
                        experts.len(),
                        weights.len()
                    );
                }
                Op::Weighted { views, weights: weights.clone() }
            }
            MergeMethod::Ties { density, lambda } => {
                if !(*density > 0.0 && *density <= 1.0) {
                    bail!("density must be in (0,1], got {density}");
                }
                for segs in views.iter_mut() {
                    trim_segments(segs, d, *density);
                }
                Op::Ties { views, lambda: *lambda }
            }
        };
        Ok(MergePlan { d, layout, op })
    }

    /// Total flat length of the merge domain.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Compute output coordinates `[start, start + out.len())` into
    /// `out`, which the caller provides zeroed (a fresh slice of the
    /// flat output vector). Chunk boundaries never affect values.
    pub fn run_chunk(&self, start: usize, out: &mut [f32]) {
        let len = out.len();
        let mut scratch = vec![0.0f32; len];
        match &self.op {
            Op::Weighted { views, weights } => {
                // Dense reference: out = tv₀ · w₀, then out += wᵢ · tvᵢ
                // (ParamSet::scale / add_scaled) — replayed per chunk.
                fill_view(&views[0], start, &mut scratch);
                let w0 = weights[0] as f32;
                for (o, s) in out.iter_mut().zip(&scratch) {
                    *o = *s * w0;
                }
                for (segs, &w) in views.iter().zip(weights.iter()).skip(1) {
                    scratch.fill(0.0);
                    fill_view(segs, start, &mut scratch);
                    let wf = w as f32;
                    for (o, s) in out.iter_mut().zip(&scratch) {
                        *o += wf * *s;
                    }
                }
            }
            Op::Ties { views, lambda } => {
                // Elect: Σᵢ trimmedᵢ in expert order.
                let mut elected = vec![0.0f32; len];
                for segs in views {
                    scratch.fill(0.0);
                    fill_view(segs, start, &mut scratch);
                    for (e, s) in elected.iter_mut().zip(&scratch) {
                        *e += *s;
                    }
                }
                // Disjoint merge: mean of sign-agreeing contributions;
                // zero electoral mass admits nothing (see ties.rs).
                let mut counts = vec![0u32; len];
                for segs in views {
                    scratch.fill(0.0);
                    fill_view(segs, start, &mut scratch);
                    for j in 0..len {
                        let v = scratch[j];
                        let e = elected[j];
                        if v != 0.0 && e != 0.0 && v.signum() == e.signum() {
                            out[j] += v;
                            counts[j] += 1;
                        }
                    }
                }
                let lf = *lambda as f32;
                for j in 0..len {
                    if counts[j] > 0 {
                        out[j] = out[j] / counts[j] as f32 * lf;
                    }
                }
            }
        }
    }

    /// Reshape the computed flat vector into the experts' shared tensor
    /// structure (layout order = the original `ParamSet` order).
    pub fn into_paramset(&self, flat: Vec<f32>) -> ParamSet {
        debug_assert_eq!(flat.len(), self.d);
        let mut out = ParamSet::new();
        for (name, shape, off) in self.layout {
            let n: usize = shape.iter().product();
            out.insert(name, Tensor::new(shape.clone(), flat[*off..off + n].to_vec()));
        }
        out
    }
}

fn fill_view(segs: &[Seg<'_>], start: usize, out: &mut [f32]) {
    for seg in segs {
        seg.fill_range(start, out);
    }
}

/// TIES trim over one expert's segments: resolve the global top-⌈k·d⌉
/// magnitude threshold from the per-segment (|scale|, nnz) table and
/// assign each segment its [`Admit`] rule. Mirrors
/// [`prune_to_topk`](crate::compeft::sparsify::prune_to_topk) on the
/// decompressed flat vector exactly: strictly-above entries always
/// survive, exact-threshold ties fill the remaining budget in global
/// index order (segments are laid out at increasing offsets, so a
/// per-segment support prefix is a global-order prefix).
fn trim_segments(segs: &mut [Seg<'_>], d: usize, density: f64) {
    if d == 0 {
        return;
    }
    // keep_count's formula, without its u32-domain assert: the ternary
    // path never indexes the flat domain, so d may exceed u32::MAX.
    let keep = (((d as f64) * density).ceil() as usize).min(d) as u64;

    // Distinct positive magnitudes, descending. Positive finite f32s
    // order identically to their bit patterns.
    let mut mags: Vec<(u32, u64)> = segs
        .iter()
        .filter_map(|s| {
            let mag = s.tern.scale.abs();
            let nnz = s.tern.nnz() as u64;
            if mag > 0.0 && nnz > 0 {
                Some((mag.to_bits(), nnz))
            } else {
                None
            }
        })
        .collect();
    mags.sort_by_key(|&(bits, _)| std::cmp::Reverse(bits));
    let mut grouped: Vec<(u32, u64)> = Vec::new();
    for (bits, cnt) in mags {
        match grouped.last_mut() {
            Some(last) if last.0 == bits => last.1 += cnt,
            _ => grouped.push((bits, cnt)),
        }
    }

    // Walk down the magnitude ladder to the bucket holding the keep-th
    // largest |value| — the same value the dense quickselect returns.
    let mut above = 0u64;
    let mut thr_bits: Option<u32> = None;
    for (bits, cnt) in &grouped {
        if above + cnt >= keep {
            thr_bits = Some(*bits);
            break;
        }
        above += cnt;
    }

    let Some(tb) = thr_bits else {
        // keep exceeds the total nonzero support: threshold is 0.0, and
        // the dense scan keeps exactly the entries with |v| > 0.
        for s in segs.iter_mut() {
            s.admit = if s.tern.scale.abs() > 0.0 && s.tern.nnz() > 0 {
                Admit::All
            } else {
                Admit::Skip
            };
        }
        return;
    };
    let thr = f32::from_bits(tb);
    let mut budget = keep - above;
    for s in segs.iter_mut() {
        let mag = s.tern.scale.abs();
        if mag > thr {
            s.admit = Admit::All;
        } else if mag.to_bits() == tb && mag > 0.0 {
            let nnz = s.tern.nnz() as u64;
            let take = nnz.min(budget);
            budget -= take;
            s.admit = if take == 0 {
                Admit::Skip
            } else if take == nnz {
                Admit::All
            } else {
                // Entries strictly below the take-th support index are
                // exactly the first `take` entries in index order.
                Admit::Prefix(s.tern.nth_support_index(take as usize).expect("take < nnz"))
            };
        } else {
            s.admit = Admit::Skip;
        }
    }
}

/// Serial ternary-domain merge: bit-identical to the dense
/// decompress-then-merge reference, at a fraction of the memory. The
/// chunk-parallel variant is
/// [`crate::compeft::engine::par_merge`].
pub fn merge_ternary(
    experts: &[&CompressedParamSet],
    method: &MergeMethod,
) -> Result<ParamSet> {
    merge_ternary_chunked(experts, method, crate::compeft::engine::DEFAULT_CHUNK)
}

/// [`merge_ternary`] with an explicit chunk size (work division only —
/// never affects the output).
pub fn merge_ternary_chunked(
    experts: &[&CompressedParamSet],
    method: &MergeMethod,
    chunk: usize,
) -> Result<ParamSet> {
    let plan = MergePlan::new(experts, method)?;
    let mut flat = vec![0.0f32; plan.d()];
    for (s, e) in chunk_ranges(plan.d(), chunk) {
        plan.run_chunk(s, &mut flat[s..e]);
    }
    Ok(plan.into_paramset(flat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_params, decompress_params, CompressConfig};
    use crate::merging::merge_dense;
    use crate::util::prop::{self, assert_paramset_bit_identical};
    use crate::util::rng::Pcg;
    use std::collections::BTreeMap;

    fn sample_tvs(seed: u64, n_experts: usize, base: usize) -> Vec<ParamSet> {
        let mut rng = Pcg::seed(seed);
        (0..n_experts)
            .map(|_| {
                let mut p = ParamSet::new();
                for (i, n) in [base, base / 2 + 3, 129].into_iter().enumerate() {
                    p.insert(
                        &format!("layer.{i}.w"),
                        Tensor::new(vec![n], prop::task_vector_like(&mut rng, n)),
                    );
                }
                p
            })
            .collect()
    }

    fn methods() -> Vec<(&'static str, MergeMethod)> {
        vec![
            ("average", MergeMethod::Average),
            ("ta_0.3", MergeMethod::TaskArithmetic { lambda: 0.3 }),
            ("ties_k2", MergeMethod::Ties { density: 0.2, lambda: 0.7 }),
            ("ties_k1", MergeMethod::Ties { density: 1.0, lambda: 1.0 }),
            ("weighted", MergeMethod::Weighted { weights: vec![0.9, -0.4, 0.25] }),
        ]
    }

    /// The core contract: ternary-domain output equals the dense
    /// decompress-then-merge reference bit for bit, for every method,
    /// both granularities, at several chunk sizes.
    #[test]
    fn matches_dense_reference_all_methods() {
        let tvs = sample_tvs(3, 3, 2000);
        for granularity in [Granularity::Global, Granularity::PerTensor] {
            let cfg = CompressConfig { density: 0.15, alpha: 2.0, granularity };
            let comps: Vec<CompressedParamSet> =
                tvs.iter().map(|tv| compress_params(tv, &cfg)).collect();
            let refs: Vec<&CompressedParamSet> = comps.iter().collect();
            let dense_tvs: Vec<ParamSet> = comps
                .iter()
                .zip(&tvs)
                .map(|(c, tv)| decompress_params(c, tv).unwrap())
                .collect();
            for (name, method) in methods() {
                let want = merge_dense(&dense_tvs, &method).unwrap();
                for chunk in [1usize, 97, 1 << 16] {
                    let got = merge_ternary_chunked(&refs, &method, chunk).unwrap();
                    assert_paramset_bit_identical(
                        &want,
                        &got,
                        &format!("{granularity:?}/{name}/chunk={chunk}"),
                    );
                }
            }
        }
    }

    /// Mixed granularities across experts merge over the shared layout.
    #[test]
    fn mixed_granularity_experts_merge() {
        let tvs = sample_tvs(9, 2, 900);
        let cg = CompressConfig {
            density: 0.2,
            alpha: 1.0,
            granularity: Granularity::Global,
        };
        let cp = CompressConfig { granularity: Granularity::PerTensor, ..cg };
        let a = compress_params(&tvs[0], &cg);
        let b = compress_params(&tvs[1], &cp);
        let dense = [
            decompress_params(&a, &tvs[0]).unwrap(),
            decompress_params(&b, &tvs[1]).unwrap(),
        ];
        for (name, method) in methods() {
            let method = match method {
                MergeMethod::Weighted { .. } => MergeMethod::Weighted { weights: vec![0.6, -1.1] },
                m => m,
            };
            let want = merge_dense(&dense, &method).unwrap();
            let got = merge_ternary(&[&a, &b], &method).unwrap();
            assert_paramset_bit_identical(&want, &got, name);
        }
    }

    /// Randomized cross-path equivalence over sizes, densities, scales
    /// and expert counts.
    #[test]
    fn prop_matches_dense_reference() {
        prop::check(
            "merge_ternary == dense reference",
            25,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(2).min(6000);
                let experts = 1 + rng.range(0, 4);
                let k = [0.05, 0.2, 0.5, 1.0][rng.range(0, 4)];
                let tvs: Vec<Vec<f32>> = (0..experts)
                    .map(|_| prop::task_vector_like(rng, n))
                    .collect();
                let mi = rng.range(0, 4);
                let chunk = [1usize, 64, 1000, 1 << 16][rng.range(0, 4)];
                (tvs, k, mi, chunk)
            },
            |(tvs, k, mi, chunk)| {
                let n_exp = tvs.len();
                let method = match *mi {
                    0 => MergeMethod::Average,
                    1 => MergeMethod::TaskArithmetic { lambda: 0.4 },
                    2 => MergeMethod::Ties { density: 0.3, lambda: 1.2 },
                    _ => MergeMethod::Weighted {
                        weights: (0..n_exp)
                            .map(|i| 0.7 - 0.4 * i as f64)
                            .collect(),
                    },
                };
                let sets: Vec<ParamSet> = tvs
                    .iter()
                    .map(|v| {
                        let mut p = ParamSet::new();
                        p.insert("w", Tensor::new(vec![v.len()], v.clone()));
                        p
                    })
                    .collect();
                let cfg =
                    CompressConfig { density: *k, alpha: 1.5, granularity: Granularity::Global };
                let comps: Vec<CompressedParamSet> =
                    sets.iter().map(|p| compress_params(p, &cfg)).collect();
                let refs: Vec<&CompressedParamSet> = comps.iter().collect();
                let dense: Vec<ParamSet> = comps
                    .iter()
                    .zip(&sets)
                    .map(|(c, p)| decompress_params(c, p).unwrap())
                    .collect();
                let want = merge_dense(&dense, &method).map_err(|e| e.to_string())?;
                let got =
                    merge_ternary_chunked(&refs, &method, *chunk).map_err(|e| e.to_string())?;
                let wf = want.flatten();
                let gf = got.flatten();
                for i in 0..wf.len() {
                    if wf[i].to_bits() != gf[i].to_bits() {
                        return Err(format!(
                            "coord {i}: dense {} vs ternary {}",
                            wf[i], gf[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    fn handmade(len: usize, scale: f32, plus: Vec<u32>, minus: Vec<u32>) -> CompressedParamSet {
        let mut parts = BTreeMap::new();
        parts.insert(String::new(), TernaryVector { len, scale, plus, minus });
        CompressedParamSet {
            granularity: Granularity::Global,
            layout: vec![("w".to_string(), vec![len], 0)],
            parts,
        }
    }

    /// The zero-electoral-mass rule on the ternary path: equal-scale
    /// opposite signs cancel exactly and must merge to 0 (mirroring the
    /// fixed dense TIES), while agreeing coordinates still merge.
    #[test]
    fn ties_zero_mass_admits_nothing_ternary() {
        // coord 0: +s vs -s → zero mass → 0. coord 1: +s, +s → +s.
        let a = handmade(3, 0.5, vec![0, 1], vec![]);
        let b = handmade(3, 0.5, vec![1], vec![0]);
        let m = merge_ternary(&[&a, &b], &MergeMethod::Ties { density: 1.0, lambda: 1.0 })
            .unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![0.0, 0.5, 0.0]);
    }

    /// Ternary-domain trim: a two-expert pool where the tie budget cuts
    /// inside one expert's equal-magnitude support — the prefix rule
    /// must match the dense index-order tie-break.
    #[test]
    fn ties_trim_prefix_matches_dense() {
        // Expert a: support {1,3,5,7} at scale 1.0 (d=8, k=0.25 keeps
        // 2 → first two support indices 1,3 survive the trim).
        let a = handmade(8, 1.0, vec![1, 3], vec![5, 7]);
        let b = handmade(8, 0.25, vec![0, 1], vec![3]);
        let tvs = [a.parts[""].to_dense(), b.parts[""].to_dense()];
        let dense: Vec<ParamSet> = tvs
            .iter()
            .map(|v| {
                let mut p = ParamSet::new();
                p.insert("w", Tensor::new(vec![8], v.clone()));
                p
            })
            .collect();
        let method = MergeMethod::Ties { density: 0.25, lambda: 1.0 };
        let want = merge_dense(&dense, &method).unwrap();
        let got = merge_ternary(&[&a, &b], &method).unwrap();
        assert_paramset_bit_identical(&want, &got, "trim prefix");
    }

    #[test]
    fn error_paths() {
        let a = handmade(4, 0.5, vec![0], vec![2]);
        // Empty expert list.
        assert!(merge_ternary(&[], &MergeMethod::Average).is_err());
        // Layout mismatch.
        let b = handmade(5, 0.5, vec![0], vec![2]);
        assert!(merge_ternary(&[&a, &b], &MergeMethod::Average).is_err());
        // Weight count mismatch.
        assert!(
            merge_ternary(&[&a], &MergeMethod::Weighted { weights: vec![1.0, 2.0] }).is_err()
        );
        // Bad density.
        assert!(merge_ternary(&[&a], &MergeMethod::Ties { density: 0.0, lambda: 1.0 }).is_err());
        // Non-finite scale.
        let nan = handmade(4, f32::NAN, vec![0], vec![2]);
        assert!(merge_ternary(&[&nan], &MergeMethod::Average).is_err());
        // Missing global part.
        let mut missing = handmade(4, 0.5, vec![0], vec![]);
        missing.parts.clear();
        assert!(merge_ternary(&[&missing], &MergeMethod::Average).is_err());
        // Part length inconsistent with layout.
        let mut short = handmade(4, 0.5, vec![0], vec![]);
        short.parts.get_mut("").unwrap().len = 3;
        assert!(merge_ternary(&[&short], &MergeMethod::Average).is_err());
    }

    #[test]
    fn empty_domain_merges_to_empty() {
        let empty = ParamSet::new();
        let cfg = CompressConfig::default();
        let c = compress_params(&empty, &cfg);
        let m = merge_ternary(&[&c], &MergeMethod::Average).unwrap();
        assert!(m.is_empty());
        let t = merge_ternary(&[&c], &MergeMethod::Ties { density: 0.5, lambda: 1.0 }).unwrap();
        assert!(t.is_empty());
    }
}
