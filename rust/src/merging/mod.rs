//! Model merging and composition methods (paper §3.6, §3.7).
//!
//! * [`average`] — weight averaging (Choshen et al., 2022)
//! * [`task_arithmetic`] — scaled task-vector addition (Ilharco et al., 2023)
//! * [`ties`] — TIES-Merging: trim / elect-sign / disjoint-merge
//!   (Yadav et al., 2023)
//! * [`lorahub`] — dynamic LoRA composition with gradient-free weight
//!   learning (Huang et al., 2023), powered by [`es`], our (1+1)-ES
//!   stand-in for the Shiwa optimizer.
//!
//! All methods take task vectors (not full checkpoints); the merged
//! model is `base + merged_tv`. The Table 6 / Figure 4 benches call
//! these with both original and ComPEFT-decompressed task vectors.

pub mod es;
pub mod lorahub;
pub mod ties;

use crate::tensor::ParamSet;
use anyhow::{bail, Result};

/// Weighted sum of task vectors: `Σ_i w_i · tv_i`.
pub fn weighted_sum(tvs: &[ParamSet], weights: &[f64]) -> Result<ParamSet> {
    if tvs.is_empty() {
        bail!("no task vectors to merge");
    }
    if tvs.len() != weights.len() {
        bail!("{} task vectors but {} weights", tvs.len(), weights.len());
    }
    let mut out = tvs[0].clone();
    for t in out.names().to_vec() {
        out.get_mut(&t).unwrap().scale(weights[0] as f32);
    }
    for (tv, &w) in tvs.iter().zip(weights).skip(1) {
        out.add_scaled(tv, w as f32)?;
    }
    Ok(out)
}

/// Simple averaging: merged tv = mean of task vectors.
pub fn average(tvs: &[ParamSet]) -> Result<ParamSet> {
    let w = 1.0 / tvs.len() as f64;
    weighted_sum(tvs, &vec![w; tvs.len()])
}

/// Task Arithmetic: merged tv = λ · Σ task vectors. The paper tunes λ
/// on validation; Table 6 benches sweep it.
pub fn task_arithmetic(tvs: &[ParamSet], lambda: f64) -> Result<ParamSet> {
    weighted_sum(tvs, &vec![lambda; tvs.len()])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tv(vals: &[f32]) -> ParamSet {
        let mut p = ParamSet::new();
        p.insert("w", Tensor::new(vec![vals.len()], vals.to_vec()));
        p
    }

    #[test]
    fn average_is_mean() {
        let m = average(&[tv(&[1.0, 2.0]), tv(&[3.0, 6.0])]).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![2.0, 4.0]);
    }

    #[test]
    fn task_arithmetic_scales_sum() {
        let m = task_arithmetic(&[tv(&[1.0, 0.0]), tv(&[1.0, 2.0])], 0.5).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![1.0, 1.0]);
    }

    #[test]
    fn mismatched_weights_error() {
        assert!(weighted_sum(&[tv(&[1.0])], &[1.0, 2.0]).is_err());
        assert!(weighted_sum(&[], &[]).is_err());
    }
}
