//! Model merging and composition methods (paper §3.6, §3.7).
//!
//! * [`average`] — weight averaging (Choshen et al., 2022)
//! * [`task_arithmetic`] — scaled task-vector addition (Ilharco et al., 2023)
//! * [`ties`] — TIES-Merging: trim / elect-sign / disjoint-merge
//!   (Yadav et al., 2023)
//! * [`lorahub`] — dynamic LoRA composition with gradient-free weight
//!   learning (Huang et al., 2023), powered by [`es`], our (1+1)-ES
//!   stand-in for the Shiwa optimizer.
//!
//! All methods take task vectors (not full checkpoints); the merged
//! model is `base + merged_tv`. The Table 6 / Figure 4 benches call
//! these with both original and ComPEFT-decompressed task vectors.
//!
//! Every method exists in two numerically identical forms:
//!
//! * the **dense** reference here and in [`ties`], over materialized
//!   `ParamSet` task vectors, and
//! * the **ternary-domain** path in [`ternary`], over compressed
//!   `.cpeft` payloads directly — no per-expert dense materialization —
//!   chunk-parallel through [`crate::compeft::engine::par_merge`].
//!
//! [`MergeMethod`] names a method + its hyper-parameters so callers
//! (the serving registry's composition records, the benches) can route
//! one description through either path; [`merge_dense`] is the
//! reference dispatcher the equivalence suites compare against.

pub mod es;
pub mod lorahub;
pub mod ternary;
pub mod ties;

use crate::tensor::ParamSet;
use anyhow::{bail, Result};

/// A merge/composition method with its hyper-parameters — the unit the
/// serving registry stores in a composition record and the benches
/// sweep. Dispatched by [`merge_dense`] (reference) and
/// [`ternary::merge_ternary`] / [`crate::compeft::engine::par_merge`]
/// (ternary-domain), which produce bit-identical results.
#[derive(Clone, Debug, PartialEq)]
pub enum MergeMethod {
    /// [`average`]: uniform mean of the task vectors.
    Average,
    /// [`task_arithmetic`]: λ-scaled sum.
    TaskArithmetic { lambda: f64 },
    /// [`ties::ties_merge`]: trim / elect-sign / disjoint-merge.
    Ties { density: f64, lambda: f64 },
    /// [`weighted_sum`] with explicit per-expert weights — LoraHub's
    /// composition (Eq. 1) once the weights are learned.
    Weighted { weights: Vec<f64> },
}

/// Dispatch a [`MergeMethod`] over dense task vectors — the reference
/// path the ternary-domain engine is equivalence-tested against.
pub fn merge_dense(tvs: &[ParamSet], method: &MergeMethod) -> Result<ParamSet> {
    match method {
        MergeMethod::Average => average(tvs),
        MergeMethod::TaskArithmetic { lambda } => task_arithmetic(tvs, *lambda),
        MergeMethod::Ties { density, lambda } => ties::ties_merge(
            tvs,
            &ties::TiesConfig { density: *density, lambda: *lambda },
        ),
        MergeMethod::Weighted { weights } => weighted_sum(tvs, weights),
    }
}

/// Weighted sum of task vectors: `Σ_i w_i · tv_i`.
pub fn weighted_sum(tvs: &[ParamSet], weights: &[f64]) -> Result<ParamSet> {
    if tvs.is_empty() {
        bail!("no task vectors to merge");
    }
    if tvs.len() != weights.len() {
        bail!("{} task vectors but {} weights", tvs.len(), weights.len());
    }
    let mut out = tvs[0].clone();
    for t in out.names().to_vec() {
        out.get_mut(&t).unwrap().scale(weights[0] as f32);
    }
    for (tv, &w) in tvs.iter().zip(weights).skip(1) {
        out.add_scaled(tv, w as f32)?;
    }
    Ok(out)
}

/// Simple averaging: merged tv = mean of task vectors.
pub fn average(tvs: &[ParamSet]) -> Result<ParamSet> {
    let w = 1.0 / tvs.len() as f64;
    weighted_sum(tvs, &vec![w; tvs.len()])
}

/// Task Arithmetic: merged tv = λ · Σ task vectors. The paper tunes λ
/// on validation; Table 6 benches sweep it.
pub fn task_arithmetic(tvs: &[ParamSet], lambda: f64) -> Result<ParamSet> {
    weighted_sum(tvs, &vec![lambda; tvs.len()])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tv(vals: &[f32]) -> ParamSet {
        let mut p = ParamSet::new();
        p.insert("w", Tensor::new(vec![vals.len()], vals.to_vec()));
        p
    }

    #[test]
    fn average_is_mean() {
        let m = average(&[tv(&[1.0, 2.0]), tv(&[3.0, 6.0])]).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![2.0, 4.0]);
    }

    #[test]
    fn task_arithmetic_scales_sum() {
        let m = task_arithmetic(&[tv(&[1.0, 0.0]), tv(&[1.0, 2.0])], 0.5).unwrap();
        assert_eq!(m.get("w").unwrap().data, vec![1.0, 1.0]);
    }

    #[test]
    fn mismatched_weights_error() {
        assert!(weighted_sum(&[tv(&[1.0])], &[1.0, 2.0]).is_err());
        assert!(weighted_sum(&[], &[]).is_err());
    }

    #[test]
    fn merge_dense_dispatches_every_method() {
        let tvs = [tv(&[1.0, 2.0]), tv(&[3.0, 6.0])];
        let avg = merge_dense(&tvs, &MergeMethod::Average).unwrap();
        assert_eq!(avg.get("w").unwrap().data, vec![2.0, 4.0]);
        let ta =
            merge_dense(&tvs, &MergeMethod::TaskArithmetic { lambda: 0.5 }).unwrap();
        assert_eq!(ta.get("w").unwrap().data, vec![2.0, 4.0]);
        let w = merge_dense(&tvs, &MergeMethod::Weighted { weights: vec![1.0, 0.0] })
            .unwrap();
        assert_eq!(w.get("w").unwrap().data, vec![1.0, 2.0]);
        let ties =
            merge_dense(&tvs, &MergeMethod::Ties { density: 1.0, lambda: 1.0 })
                .unwrap();
        assert_eq!(ties.get("w").unwrap().data, vec![2.0, 4.0]);
        assert!(merge_dense(&[], &MergeMethod::Average).is_err());
    }
}
