//! Algorithm 1: the ComPEFT compression procedure.
//!
//! ```text
//! Input:  task vector τ, density k, scaling value α
//! Output: compressed task vector τ̃
//!   γ ← sgn(τ);  µ ← |τ|
//!   γ̃ ← keep_topk_reset_rest_to_zero(γ, µ, k)     // Step 1: sparsify
//!   τ̃ ← α · σ(τ) · γ̃                              // Step 2: quantize
//! ```
//!
//! The scalar `σ(τ)` is the standard deviation of the *original* task
//! vector (Appendix B.5: σ normalizes across model scales so a single α
//! grid works everywhere), and `α` is the only tuned hyper-parameter.

use crate::compeft::sparsify::topk_by_magnitude;
use crate::compeft::ternary::TernaryVector;
use crate::tensor::ParamSet;
use crate::util::stats::blocked_std_f32;
use anyhow::Result;
use std::collections::BTreeMap;

/// Scope over which σ and top-k are computed for a multi-tensor task
/// vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// Flatten the whole ParamSet into one τ ∈ R^d (paper default).
    Global,
    /// Compress each named tensor independently (useful when tensors
    /// have very different scales, e.g. LoRA A vs B matrices).
    PerTensor,
}

/// Compression configuration.
#[derive(Clone, Copy, Debug)]
pub struct CompressConfig {
    /// Density k ∈ (0, 1]: fraction of entries kept. Paper sweeps
    /// k ∈ {0.05, 0.1, 0.2, 0.3, 0.5}.
    pub density: f64,
    /// Scaling value α. Paper sweeps α ∈ {0.5,1,2,3,4,5,6,8,10};
    /// recommends α = 1 for ≥13B models at k ≤ 0.2.
    pub alpha: f64,
    pub granularity: Granularity,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig { density: 0.2, alpha: 1.0, granularity: Granularity::Global }
    }
}

/// Compress a flat task vector per Algorithm 1.
///
/// σ(τ) is computed with the blocked Welford fold
/// ([`crate::util::stats::blocked_moments`]) so that the parallel engine
/// ([`crate::compeft::engine`]) reproduces this serial path bit for bit:
/// the merge tree is defined by a fixed block size, not by who computes
/// the blocks.
pub fn compress_vector(tau: &[f32], cfg: &CompressConfig) -> TernaryVector {
    if tau.is_empty() {
        return TernaryVector::empty(0);
    }
    let sigma = blocked_std_f32(tau);
    let split = topk_by_magnitude(tau, cfg.density);
    TernaryVector {
        len: tau.len(),
        scale: (cfg.alpha * sigma) as f32,
        plus: split.plus,
        minus: split.minus,
    }
}

/// Reconstruct the dense approximation τ̃ from a compressed vector.
pub fn decompress_vector(t: &TernaryVector) -> Vec<f32> {
    t.to_dense()
}

/// A compressed multi-tensor task vector, preserving tensor structure so
/// it can be re-applied to a [`ParamSet`].
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedParamSet {
    /// Compression scope used (affects how `parts` map back).
    pub granularity: Granularity,
    /// Tensor name → (shape, offset into the global flat vector).
    pub layout: Vec<(String, Vec<usize>, usize)>,
    /// One ternary vector per part: a single global entry for
    /// [`Granularity::Global`], or one per tensor for `PerTensor`
    /// (keyed by tensor name; the global entry uses the key `""`).
    pub parts: BTreeMap<String, TernaryVector>,
}

impl CompressedParamSet {
    /// Total logical parameter count.
    pub fn total_elements(&self) -> usize {
        self.parts.values().map(|t| t.len).sum()
    }

    pub fn nnz(&self) -> usize {
        self.parts.values().map(|t| t.nnz()).sum()
    }

    pub fn density(&self) -> f64 {
        let d = self.total_elements();
        if d == 0 {
            0.0
        } else {
            self.nnz() as f64 / d as f64
        }
    }
}

/// Compress a ParamSet task vector.
pub fn compress_params(tv: &ParamSet, cfg: &CompressConfig) -> CompressedParamSet {
    let mut layout = Vec::new();
    let mut off = 0usize;
    for (name, t) in tv.iter() {
        layout.push((name.to_string(), t.shape.clone(), off));
        off += t.len();
    }
    let mut parts = BTreeMap::new();
    match cfg.granularity {
        Granularity::Global => {
            let flat = tv.flatten();
            parts.insert(String::new(), compress_vector(&flat, cfg));
        }
        Granularity::PerTensor => {
            for (name, t) in tv.iter() {
                parts.insert(name.to_string(), compress_vector(&t.data, cfg));
            }
        }
    }
    CompressedParamSet { granularity: cfg.granularity, layout, parts }
}

/// Reconstruct a dense ParamSet with the same structure as `like`.
pub fn decompress_params(
    c: &CompressedParamSet,
    like: &ParamSet,
) -> Result<ParamSet> {
    match c.granularity {
        Granularity::Global => {
            let flat = c.parts[""].to_dense();
            like.unflatten_like(&flat)
        }
        Granularity::PerTensor => {
            let mut out = ParamSet::new();
            for (name, t) in like.iter() {
                let tern = c
                    .parts
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("missing part {name:?}"))?;
                out.insert(
                    name,
                    crate::tensor::Tensor::new(t.shape.clone(), tern.to_dense()),
                );
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use crate::util::stats::std_f32;

    #[test]
    fn algorithm1_small_example() {
        // τ = [0.1, -2.0, 0.05, 1.0]; k = 0.5 keeps {-2.0, 1.0}.
        let tau = [0.1f32, -2.0, 0.05, 1.0];
        let cfg = CompressConfig { density: 0.5, alpha: 2.0, ..Default::default() };
        let t = compress_vector(&tau, &cfg);
        let sigma = std_f32(&tau);
        assert!((t.scale as f64 - 2.0 * sigma).abs() < 1e-6);
        assert_eq!(t.plus, vec![3]);
        assert_eq!(t.minus, vec![1]);
        let dense = decompress_vector(&t);
        assert_eq!(dense[0], 0.0);
        assert!(dense[1] < 0.0 && dense[3] > 0.0);
    }

    #[test]
    fn signs_preserved_for_kept_entries() {
        prop::check(
            "compressed signs match original",
            40,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(3000);
                prop::task_vector_like(rng, n)
            },
            |tau| {
                let cfg = CompressConfig::default();
                let t = compress_vector(tau, &cfg);
                t.validate().map_err(|e| e.to_string())?;
                for &i in &t.plus {
                    if tau[i as usize] <= 0.0 {
                        return Err(format!("plus idx {i} wrong sign"));
                    }
                }
                for &i in &t.minus {
                    if tau[i as usize] >= 0.0 {
                        return Err(format!("minus idx {i} wrong sign"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn alpha_scales_linearly() {
        let mut rng = Pcg::seed(3);
        let tau = prop::task_vector_like(&mut rng, 1000);
        let t1 = compress_vector(
            &tau,
            &CompressConfig { alpha: 1.0, ..Default::default() },
        );
        let t4 = compress_vector(
            &tau,
            &CompressConfig { alpha: 4.0, ..Default::default() },
        );
        assert!((t4.scale - 4.0 * t1.scale).abs() < 1e-6);
        assert_eq!(t1.plus, t4.plus);
    }

    #[test]
    fn prop_reconstruction_is_alpha_sigma_sign() {
        // τ̃_i = α·σ(τ)·sgn(τ_i) on kept entries, exactly 0 elsewhere —
        // the full Algorithm 1 contract, checked coordinate by
        // coordinate against the independently computed σ.
        prop::check(
            "decompress matches α·σ·sgn",
            30,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(5000);
                let k = [0.05, 0.2, 0.5, 1.0][rng.range(0, 4)];
                let alpha = [0.5, 1.0, 4.0][rng.range(0, 3)];
                (prop::task_vector_like(rng, n), k, alpha)
            },
            |(tau, k, alpha)| {
                let cfg = CompressConfig {
                    density: *k,
                    alpha: *alpha,
                    ..Default::default()
                };
                let t = compress_vector(tau, &cfg);
                t.validate().map_err(|e| e.to_string())?;
                let sigma = std_f32(tau);
                let expect_mag = (*alpha * sigma) as f32;
                if (t.scale - expect_mag).abs() > 1e-5 * (1.0 + expect_mag.abs()) {
                    return Err(format!("scale {} vs α·σ {}", t.scale, expect_mag));
                }
                let dense = decompress_vector(&t);
                let mut kept = vec![false; tau.len()];
                for &i in t.plus.iter().chain(&t.minus) {
                    kept[i as usize] = true;
                }
                for i in 0..tau.len() {
                    let want = if kept[i] {
                        t.scale * tau[i].signum()
                    } else {
                        0.0
                    };
                    if dense[i] != want {
                        return Err(format!(
                            "coord {i}: reconstructed {} want {want}",
                            dense[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    fn sample_params(rng: &mut Pcg) -> ParamSet {
        let mut p = ParamSet::new();
        p.insert("w1", Tensor::new(vec![8, 4], prop::task_vector_like(rng, 32)));
        p.insert("w2", Tensor::new(vec![16], prop::task_vector_like(rng, 16)));
        p
    }

    #[test]
    fn paramset_roundtrip_global() {
        let mut rng = Pcg::seed(7);
        let tv = sample_params(&mut rng);
        let cfg = CompressConfig { density: 1.0, alpha: 1.0, ..Default::default() };
        let c = compress_params(&tv, &cfg);
        let back = decompress_params(&c, &tv).unwrap();
        // At k=1 all signs survive; reconstruction has the right sign
        // pattern and uniform magnitude.
        for (name, t) in tv.iter() {
            let b = back.get(name).unwrap();
            for (orig, rec) in t.data.iter().zip(&b.data) {
                if *orig != 0.0 {
                    assert_eq!(orig.signum(), rec.signum(), "{name}");
                }
            }
        }
    }

    #[test]
    fn paramset_per_tensor_scales_differ() {
        let mut p = ParamSet::new();
        p.insert("small", Tensor::new(vec![64], vec![0.01; 64]));
        let mut big = vec![1.0f32; 64];
        big[0] = -3.0; // give nonzero variance
        p.insert("big", Tensor::new(vec![64], big));
        let cfg = CompressConfig {
            density: 0.5,
            alpha: 1.0,
            granularity: Granularity::PerTensor,
        };
        let c = compress_params(&p, &cfg);
        assert_eq!(c.parts.len(), 2);
        assert!(c.parts["big"].scale > c.parts["small"].scale);
    }

    #[test]
    fn density_accounting() {
        let mut rng = Pcg::seed(9);
        let tv = sample_params(&mut rng);
        let cfg = CompressConfig { density: 0.25, ..Default::default() };
        let c = compress_params(&tv, &cfg);
        assert!((c.density() - 0.25).abs() < 0.05);
    }
}
