//! `.cpeft` — the on-disk / on-wire container for compressed experts.
//!
//! One file holds a whole [`CompressedParamSet`]: a header, the tensor
//! layout table, and one payload record per part, each encoded as either
//! Golomb (storage-optimal) or bitmask (compute-optimal) per §2.2. A
//! CRC32 guards against truncated, bit-flipped, or trailing-garbage
//! transfers — important because the serving path streams these over
//! (faulty) simulated links. In **v2 the CRC covers the header too**,
//! so *any* single-bit flip anywhere in a v2 buffer — magic, version,
//! flags, granularity/encoding tags, frame tables, payloads, or the CRC
//! itself — fails the read (the bit-flip fuzz suite in
//! `tests/integration.rs` asserts exactly that); v1 keeps its legacy
//! body-only coverage for compatibility. Readers reject any bytes left
//! over after the last part: a CRC-consistent writer that appends junk
//! is a bug, not a format feature.
//!
//! **Format v2** (current writer) frames every payload for parallel
//! decode: Golomb payloads carry a per-chunk offset/first-index table
//! ([`golomb::FrameTable`], fixed-nnz chunks), bitmask payloads a word
//! chunk size (word ranges are self-describing). Framing is pure
//! metadata — payload bytes are identical to v1, and the ternary
//! semantics are unchanged. [`from_bytes`] auto-dispatches on the
//! version field, so v1 files remain readable; [`from_bytes_par`]
//! decodes v2 payload frames (and v2/v1 multi-part files) concurrently
//! on a [`ThreadPool`](crate::util::pool::ThreadPool) with output
//! identical to the serial reader.
//!
//! Both readers take `&[u8]` and never need the buffer to outlive the
//! call, so they decode **in place** from any
//! [`Payload`](crate::compeft::payload::Payload) view — including a
//! member of a `.cpar` archive
//! ([`coordinator::archive`](crate::coordinator::archive)), where
//! payloads sit at 64-byte-aligned file offsets so chunk frames keep
//! the alignment class they would have in a standalone file.
//!
//! ```text
//! magic "CPFT" | version u16 (1|2) | flags u16 | granularity u8 | encoding u8
//! n_layout u32 | [ name, ndim u32, dims u64*, offset u64 ]*
//! n_parts u32  | [ name, FRAMES?, payload_len u64, payload ]*
//! crc32 u32             (v2: over header+layout+parts; v1: layout+parts)
//!
//! FRAMES (v2 only):
//!   chunk u32    — nonzeros per Golomb frame / words per bitmask chunk
//!   n_frames u32 — 0 for bitmask payloads
//!   [ bit_offset u64, prev_index u32 ]*n_frames
//! ```

use crate::compeft::bitmask::MaskPair;
use crate::compeft::compress::{CompressedParamSet, Granularity};
use crate::compeft::golomb::{self, FrameTable};
use crate::compeft::ternary::TernaryVector;
use crate::util::pool::ThreadPool;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;

const MAGIC: &[u8; 4] = b"CPFT";
/// Current writer version (chunk-framed payloads).
const VERSION: u16 = 2;
/// Legacy unframed container (still readable).
const VERSION_V1: u16 = 1;

/// Nonzeros per Golomb frame in freshly written v2 containers. 8K
/// nonzeros ≈ 7 KB of payload at k=0.05 — a 4M-element expert (~210K
/// nonzeros) yields ~26 frames, enough to load-balance 8 workers ~3×
/// over, while the 12-byte frame entry stays < 0.2% overhead.
pub const FRAME_NNZ: usize = 1 << 13;
/// Words per bitmask decode chunk recorded in v2 containers.
pub const FRAME_WORDS: usize = 1 << 13;

/// Wire encoding for payload records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Golomb/Rice gap coding — smallest (default for storage/transfer).
    Golomb,
    /// Two binary masks — larger but enables bitwise compute on load.
    Bitmask,
}

impl Encoding {
    fn tag(self) -> u8 {
        match self {
            Encoding::Golomb => 0,
            Encoding::Bitmask => 1,
        }
    }

    fn from_tag(t: u8) -> Result<Encoding> {
        Ok(match t {
            0 => Encoding::Golomb,
            1 => Encoding::Bitmask,
            other => bail!("unknown encoding tag {other}"),
        })
    }
}

// -- CRC32 (IEEE 802.3, reflected) -----------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    for (i, slot) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *slot = c;
    }
    table
}

/// CRC32 of a byte slice (IEEE polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFFFFFFu32;
    for &b in data {
        // compeft-lint: allow(no-panic-in-parse) -- index masked to 0..=255, the table size
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFFFFFF
}

// -- serialization ----------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let n = get_u32(bytes, pos)? as usize;
    let raw = bytes
        .get(*pos..pos.checked_add(n).ok_or_else(|| anyhow!("truncated string"))?)
        .ok_or_else(|| anyhow!("truncated string"))?;
    let s = std::str::from_utf8(raw)?.to_string();
    *pos += n;
    Ok(s)
}

fn get_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let raw = bytes.get(*pos..*pos + 4).ok_or_else(|| anyhow!("truncated u32"))?;
    let v = u32::from_le_bytes(raw.try_into()?);
    *pos += 4;
    Ok(v)
}

fn get_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let raw = bytes.get(*pos..*pos + 8).ok_or_else(|| anyhow!("truncated u64"))?;
    let v = u64::from_le_bytes(raw.try_into()?);
    *pos += 8;
    Ok(v)
}

/// Serial payload encoding of one part.
fn encode_payload(tern: &TernaryVector, enc: Encoding) -> Vec<u8> {
    match enc {
        Encoding::Golomb => golomb::encode(tern),
        Encoding::Bitmask => MaskPair::from_ternary(tern).to_bytes(),
    }
}

/// Frame metadata stored alongside one part in a v2 container. For
/// bitmask payloads the table carries only the word chunk size (the
/// `chunk_nnz` field holds *words*; ranges are self-describing).
///
/// The Golomb table is an extra O(nnz) bit-cost walk on top of the
/// encode itself — a deliberate trade: keeping [`golomb::frame_table`]
/// the single source of truth for offsets (writers *and* readers
/// recompute it) is what lets every read path verify the stored table
/// exactly. If writer throughput ever matters more, the table could be
/// sampled from `BitWriter::bit_len` inside the encode loop instead.
fn part_frames(tern: &TernaryVector, enc: Encoding) -> FrameTable {
    match enc {
        Encoding::Golomb => golomb::frame_table(tern, FRAME_NNZ),
        Encoding::Bitmask => {
            FrameTable { chunk_nnz: FRAME_WORDS as u32, frames: Vec::new() }
        }
    }
}

/// Assemble the `.cpeft` container around already-encoded payloads
/// (one per part, in `c.parts` iteration order). The single source of
/// truth for the header/layout/CRC wire format — the serial and
/// parallel writers of both versions go through here. `frames` must
/// hold one table per part when `version >= 2` and is ignored for v1.
fn assemble(
    c: &CompressedParamSet,
    enc: Encoding,
    payloads: &[Vec<u8>],
    version: u16,
    frames: &[FrameTable],
) -> Vec<u8> {
    debug_assert_eq!(c.parts.len(), payloads.len());
    debug_assert!(version == VERSION_V1 || frames.len() == payloads.len());
    let mut body = Vec::new();
    // Layout table.
    body.extend_from_slice(&(c.layout.len() as u32).to_le_bytes());
    for (name, shape, offset) in &c.layout {
        put_str(&mut body, name);
        body.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            body.extend_from_slice(&(d as u64).to_le_bytes());
        }
        body.extend_from_slice(&(*offset as u64).to_le_bytes());
    }
    // Parts.
    body.extend_from_slice(&(c.parts.len() as u32).to_le_bytes());
    for (i, (name, payload)) in c.parts.keys().zip(payloads).enumerate() {
        put_str(&mut body, name);
        if version >= 2 {
            if let Some(ft) = frames.get(i) {
                body.extend_from_slice(&ft.chunk_nnz.to_le_bytes());
                body.extend_from_slice(&(ft.frames.len() as u32).to_le_bytes());
                for &(off, prev) in &ft.frames {
                    body.extend_from_slice(&off.to_le_bytes());
                    body.extend_from_slice(&prev.to_le_bytes());
                }
            }
        }
        body.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        body.extend_from_slice(payload);
    }

    // compeft-lint: allow(no-unchecked-wire-alloc) -- write path: sized from the in-memory body
    let mut out = Vec::with_capacity(body.len() + 16);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes()); // flags
    out.push(match c.granularity {
        Granularity::Global => 0,
        Granularity::PerTensor => 1,
    });
    out.push(enc.tag());
    out.extend_from_slice(&body);
    // v2 covers the header too (any bit flip in the buffer fails the
    // read); v1 keeps the legacy body-only coverage.
    let crc = if version >= 2 { crc32(&out) } else { crc32(&body) };
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Serialize a compressed expert to `.cpeft` bytes (format v2).
pub fn to_bytes(c: &CompressedParamSet, enc: Encoding) -> Vec<u8> {
    let payloads: Vec<Vec<u8>> =
        c.parts.values().map(|tern| encode_payload(tern, enc)).collect();
    let frames: Vec<FrameTable> =
        c.parts.values().map(|tern| part_frames(tern, enc)).collect();
    assemble(c, enc, &payloads, VERSION, &frames)
}

/// Serialize to the legacy unframed v1 layout. Kept for cross-version
/// tests and for producing containers older readers accept; new code
/// should write v2 ([`to_bytes`]).
pub fn to_bytes_v1(c: &CompressedParamSet, enc: Encoding) -> Vec<u8> {
    let payloads: Vec<Vec<u8>> =
        c.parts.values().map(|tern| encode_payload(tern, enc)).collect();
    assemble(c, enc, &payloads, VERSION_V1, &[])
}

/// Parallel [`to_bytes`]: byte-identical output.
///
/// Multi-part sets ([`Granularity::PerTensor`]) encode their payloads
/// (and frame tables) concurrently, one part per pool task; a
/// single-part (global) set instead parallelises *inside* the payload
/// encoder ([`golomb::encode_par`] / [`MaskPair::from_ternary_par`]).
/// Exactly one level runs on the pool either way, so no pool task ever
/// waits on the pool. Assembly then walks the same `BTreeMap` order as
/// the serial writer.
pub fn to_bytes_par(
    c: &CompressedParamSet,
    enc: Encoding,
    pool: &ThreadPool,
) -> Vec<u8> {
    // Chunk sizes for single-part payload encoding: nonzeros per golomb
    // task, words per bitmask task. Work division only — never changes
    // the bytes.
    const GOLOMB_CHUNK_NNZ: usize = 1 << 15;
    const BITMASK_CHUNK_WORDS: usize = 1 << 13;

    let terns: Vec<&TernaryVector> = c.parts.values().collect();
    let encoded: Vec<(Vec<u8>, FrameTable)> = if let [tern] = terns.as_slice() {
        let payload = match enc {
            Encoding::Golomb => golomb::encode_par(tern, pool, GOLOMB_CHUNK_NNZ),
            Encoding::Bitmask => {
                MaskPair::from_ternary_par(tern, pool, BITMASK_CHUNK_WORDS).to_bytes()
            }
        };
        vec![(payload, part_frames(tern, enc))]
    } else {
        pool.scoped_map(terns, |tern| {
            (encode_payload(tern, enc), part_frames(tern, enc))
        })
    };
    let (payloads, frames): (Vec<_>, Vec<_>) = encoded.into_iter().unzip();
    assemble(c, enc, &payloads, VERSION, &frames)
}

/// Parse `.cpeft` bytes (v1 or v2, dispatched on the version field).
pub fn from_bytes(bytes: &[u8]) -> Result<(CompressedParamSet, Encoding)> {
    from_bytes_impl(bytes, None)
}

/// Parallel [`from_bytes`]: identical result, payloads decoded on
/// `pool`.
///
/// The mirror of [`to_bytes_par`]: multi-part containers decode their
/// parts concurrently (one serial decode per pool task); a single-part
/// container parallelises *inside* the payload via the v2 frame table
/// ([`golomb::decode_par`]) or bitmask word ranges
/// ([`MaskPair::to_ternary_par`]). A single-part v1 Golomb container
/// has no frame table and falls back to serial payload decode.
pub fn from_bytes_par(
    bytes: &[u8],
    pool: &ThreadPool,
) -> Result<(CompressedParamSet, Encoding)> {
    from_bytes_impl(bytes, Some(pool))
}

// -- delta containers -------------------------------------------------------

/// Magic of the `.cpeft` **delta** wire container: two ordinary
/// `.cpeft` payloads (support removals at the old scale, additions at
/// the new scale — see [`crate::compeft::engine::compress_delta`])
/// framed by length under one whole-buffer CRC.
const DELTA_MAGIC: &[u8; 4] = b"CPFD";
const DELTA_VERSION: u16 = 1;

/// Serialize a ternary version delta:
///
/// ```text
/// magic "CPFD" | version u16 | removals_len u64 | removals | additions | crc32
/// ```
///
/// Both halves are full `.cpeft` containers (own header + CRC), so a
/// reader re-runs every structural validation on each.
pub fn delta_to_bytes(
    removals: &CompressedParamSet,
    additions: &CompressedParamSet,
    enc: Encoding,
) -> Vec<u8> {
    let rm = to_bytes(removals, enc);
    let ad = to_bytes(additions, enc);
    let mut out = Vec::new();
    out.extend_from_slice(DELTA_MAGIC);
    out.extend_from_slice(&DELTA_VERSION.to_le_bytes());
    out.extend_from_slice(&(rm.len() as u64).to_le_bytes());
    out.extend_from_slice(&rm);
    out.extend_from_slice(&ad);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse a delta container back into `(removals, additions, encoding)`.
/// Panic-free like every wire reader here: truncation, bit flips (the
/// CRC covers the whole buffer), and malformed halves all surface as
/// `Err`.
pub fn delta_from_bytes(
    bytes: &[u8],
) -> Result<(CompressedParamSet, CompressedParamSet, Encoding)> {
    // Fixed frame: magic (4) + version (2) + removals length (8) +
    // trailing CRC (4).
    if bytes.len() < 18 || bytes.get(..4) != Some(DELTA_MAGIC.as_slice()) {
        bail!("not a .cpeft delta container");
    }
    let byte = |i: usize| bytes.get(i).copied().unwrap_or(0);
    let version = u16::from_le_bytes([byte(4), byte(5)]);
    if version != DELTA_VERSION {
        bail!("unsupported delta version {version}");
    }
    let stored_crc = u32::from_le_bytes([
        byte(bytes.len() - 4),
        byte(bytes.len() - 3),
        byte(bytes.len() - 2),
        byte(bytes.len() - 1),
    ]);
    let covered = bytes.get(..bytes.len() - 4).unwrap_or_default();
    let actual = crc32(covered);
    if stored_crc != actual {
        bail!("delta crc mismatch: stored {stored_crc:#x}, computed {actual:#x}");
    }
    let rm_len = u64::from_le_bytes([
        byte(6),
        byte(7),
        byte(8),
        byte(9),
        byte(10),
        byte(11),
        byte(12),
        byte(13),
    ]) as usize;
    let body = covered.get(14..).unwrap_or_default();
    if rm_len > body.len() {
        bail!("delta removals length {rm_len} exceeds body {}", body.len());
    }
    let rm_bytes = body.get(..rm_len).unwrap_or_default();
    let ad_bytes = body.get(rm_len..).unwrap_or_default();
    let (removals, enc_rm) = from_bytes(rm_bytes).context("delta removals half")?;
    let (additions, enc_ad) = from_bytes(ad_bytes).context("delta additions half")?;
    if enc_rm != enc_ad {
        bail!("delta halves disagree on encoding: {enc_rm:?} vs {enc_ad:?}");
    }
    Ok((removals, additions, enc_ad))
}

/// A structurally validated container, payloads not yet decoded: the
/// output of [`parse_structure`], everything both readers (and the
/// fused-path planner) agree on before any payload bits are touched.
struct RawContainer {
    version: u16,
    granularity: Granularity,
    enc: Encoding,
    layout: Vec<(String, Vec<usize>, usize)>,
    /// Per part: name, v2 frame table, absolute payload byte range in
    /// the container buffer.
    parts: Vec<(String, Option<FrameTable>, std::ops::Range<usize>)>,
}

/// Every validation a `.cpeft` read performs before decoding payloads:
/// magic/version/granularity/encoding, the full-coverage CRC, the
/// layout table, the part records (with their v2 frame tables), and the
/// no-trailing-garbage rule. Both readers and
/// [`golomb_frame_plan`] go through here, so a corrupt container is
/// rejected identically on every path.
fn parse_structure(bytes: &[u8]) -> Result<RawContainer> {
    if bytes.len() < 14 || bytes.get(..4) != Some(MAGIC.as_slice()) {
        bail!("not a .cpeft file");
    }
    // Past the length check every fixed header offset exists; `byte`
    // keeps the reads panic-free regardless.
    let byte = |i: usize| bytes.get(i).copied().unwrap_or(0);
    let version = u16::from_le_bytes([byte(4), byte(5)]);
    if version != VERSION_V1 && version != VERSION {
        bail!("unsupported .cpeft version {version}");
    }
    let granularity = match byte(8) {
        0 => Granularity::Global,
        1 => Granularity::PerTensor,
        g => bail!("unknown granularity {g}"),
    };
    let enc = Encoding::from_tag(byte(9))?;

    let body = bytes.get(10..bytes.len() - 4).unwrap_or_default();
    let stored_crc = u32::from_le_bytes([
        byte(bytes.len() - 4),
        byte(bytes.len() - 3),
        byte(bytes.len() - 2),
        byte(bytes.len() - 1),
    ]);
    // v2 CRCs cover the header as well; v1 only the body (legacy).
    let covered: &[u8] =
        if version >= 2 { bytes.get(..bytes.len() - 4).unwrap_or_default() } else { body };
    let actual = crc32(covered);
    if stored_crc != actual {
        bail!("crc mismatch: stored {stored_crc:#x}, computed {actual:#x}");
    }

    let mut pos = 0usize;
    let n_layout = get_u32(body, &mut pos)? as usize;
    // Count fields size pre-allocations, so they are sanity-bounded by
    // the remaining bytes before any Vec is reserved: a corrupt count
    // must fail structurally, never allocation-bomb. A layout entry is
    // ≥ 16 bytes (name len + ndim + offset), a dim 8 bytes.
    if n_layout > body.len() / 16 + 1 {
        bail!("layout count {n_layout} exceeds what {} bytes can hold", body.len());
    }
    let mut layout = Vec::with_capacity(n_layout);
    for _ in 0..n_layout {
        let name = get_str(body, &mut pos)?;
        let ndim = get_u32(body, &mut pos)? as usize;
        if ndim > (body.len() - pos) / 8 {
            bail!("tensor {name:?}: ndim {ndim} exceeds the remaining bytes");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(get_u64(body, &mut pos)? as usize);
        }
        let offset = get_u64(body, &mut pos)? as usize;
        layout.push((name, shape, offset));
    }

    // Collect raw part records first so payload decode can fan out.
    let n_parts = get_u32(body, &mut pos)? as usize;
    if n_parts > (body.len() - pos) / 12 + 1 {
        bail!("part count {n_parts} exceeds what {} bytes can hold", body.len() - pos);
    }
    let mut raw: Vec<(String, Option<FrameTable>, std::ops::Range<usize>)> =
        Vec::with_capacity(n_parts);
    for _ in 0..n_parts {
        let name = get_str(body, &mut pos)?;
        let frames = if version >= 2 {
            let chunk = get_u32(body, &mut pos)?;
            let n_frames = get_u32(body, &mut pos)? as usize;
            if n_frames.saturating_mul(12) > body.len() - pos {
                bail!("truncated frame table for part {name:?}");
            }
            let mut entries = Vec::with_capacity(n_frames);
            for _ in 0..n_frames {
                let off = get_u64(body, &mut pos)?;
                let prev = get_u32(body, &mut pos)?;
                entries.push((off, prev));
            }
            Some(FrameTable { chunk_nnz: chunk, frames: entries })
        } else {
            None
        };
        let plen = get_u64(body, &mut pos)? as usize;
        if plen > body.len() - pos {
            bail!("truncated payload for part {name:?}");
        }
        // Absolute range in the container buffer (body starts at 10).
        raw.push((name, frames, 10 + pos..10 + pos + plen));
        pos += plen;
    }
    // A CRC-consistent writer that appends junk after the last part is
    // corrupt, not tolerated: every body byte must be accounted for.
    if pos != body.len() {
        bail!(
            "{} trailing garbage bytes after the last part",
            body.len() - pos
        );
    }
    Ok(RawContainer { version, granularity, enc, layout, parts: raw })
}

/// The v2 golomb frame-table revalidation, enforced on *every* read
/// path (serial, parallel, and the fused frame-at-a-time path): the
/// honest table is a pure function of the decoded vector and the
/// stored chunk size, so recomputing it validates every offset and
/// predecessor index — a lying but CRC-consistent table fails
/// identically however the container is opened.
fn validate_part_table(
    name: &str,
    frames: Option<&FrameTable>,
    tern: &TernaryVector,
    enc: Encoding,
) -> Result<()> {
    if matches!(enc, Encoding::Golomb) {
        if let Some(ft) = frames {
            let chunk = ft.chunk_nnz as usize;
            if chunk == 0 || *ft != golomb::frame_table(tern, chunk) {
                bail!(
                    "part {name:?}: frame table ({} frames, chunk {}) \
                     inconsistent with payload ({} nonzeros)",
                    ft.frames.len(),
                    ft.chunk_nnz,
                    tern.nnz()
                );
            }
        }
    }
    Ok(())
}

fn from_bytes_impl(
    bytes: &[u8],
    pool: Option<&ThreadPool>,
) -> Result<(CompressedParamSet, Encoding)> {
    let rc = parse_structure(bytes)?;
    let enc = rc.enc;
    let payload_at =
        |r: &std::ops::Range<usize>| bytes.get(r.clone()).unwrap_or_default();

    let serial_decode = |payload: &[u8]| -> Result<TernaryVector> {
        match enc {
            Encoding::Golomb => golomb::decode(payload),
            Encoding::Bitmask => Ok(MaskPair::from_bytes(payload)?.to_ternary()),
        }
    };
    let decoded: Vec<Result<TernaryVector>> = match (pool, rc.parts.as_slice()) {
        (None, _) => rc
            .parts
            .iter()
            .map(|(_, _, r)| serial_decode(payload_at(r)))
            .collect(),
        (Some(pool), [(_, frames, r)]) => {
            let payload = payload_at(r);
            vec![match (enc, frames) {
                (Encoding::Golomb, Some(ft)) => golomb::decode_par(payload, ft, pool),
                (Encoding::Golomb, None) => golomb::decode(payload),
                (Encoding::Bitmask, ft) => {
                    let chunk = ft
                        .as_ref()
                        .map(|t| t.chunk_nnz as usize)
                        .filter(|&c| c > 0)
                        .unwrap_or(FRAME_WORDS);
                    MaskPair::from_bytes(payload).map(|m| m.to_ternary_par(pool, chunk))
                }
            }]
        }
        (Some(pool), _) => {
            let payloads: Vec<&[u8]> =
                rc.parts.iter().map(|(_, _, r)| payload_at(r)).collect();
            pool.scoped_map(payloads, &serial_decode)
        }
    };

    let mut parts = BTreeMap::new();
    for ((name, frames, _), tern) in rc.parts.iter().zip(decoded) {
        let tern = tern.with_context(|| format!("part {name:?}"))?;
        validate_part_table(name, frames.as_ref(), &tern, enc)?;
        parts.insert(name.clone(), tern);
    }

    Ok((
        CompressedParamSet { granularity: rc.granularity, layout: rc.layout, parts },
        enc,
    ))
}

/// The fused fetch→decode plan for a container: when `bytes` is a v2
/// **single-part Golomb** container, everything the loader needs to
/// decode its payload frame by frame as fetch stripes land — the frame
/// table, the payload's absolute byte range (so stripe coverage maps
/// onto [`golomb::FrameDecoder::frame_end_byte`] watermarks), and the
/// layout/granularity to rebuild the param set at the end.
///
/// Runs every pre-decode validation [`from_bytes`] runs — full-buffer
/// CRC included — so a corrupt container is rejected before any frame
/// decodes. (In a real deployment the per-stripe CRC gates the store
/// already applies would stand in until the last stripe lands; here
/// the whole buffer is in memory, so the container CRC is simply
/// checked up front.) Returns `Ok(None)` for every other *valid* shape
/// (v1, bitmask, multi-part, empty) — the caller falls back to the
/// unfused fetch-then-decode path.
pub struct GolombFramePlan {
    /// The single part's name.
    pub name: String,
    /// Its stored frame table (revalidated against the decode at
    /// [`GolombFramePlan::finish`]).
    pub table: FrameTable,
    /// Absolute byte range of the Golomb payload in the container.
    pub payload: std::ops::Range<usize>,
    granularity: Granularity,
    layout: Vec<(String, Vec<usize>, usize)>,
}

pub fn golomb_frame_plan(bytes: &[u8]) -> Result<Option<GolombFramePlan>> {
    let rc = parse_structure(bytes)?;
    if rc.version < 2 || rc.enc != Encoding::Golomb || rc.parts.len() != 1 {
        return Ok(None);
    }
    let mut parts = rc.parts;
    let Some((name, Some(table), payload)) = parts.pop() else {
        return Ok(None);
    };
    Ok(Some(GolombFramePlan {
        name,
        table,
        payload,
        granularity: rc.granularity,
        layout: rc.layout,
    }))
}

impl GolombFramePlan {
    /// Wrap the frame-decoded vector back into the param set, applying
    /// the same stored-table revalidation as [`from_bytes`] — the fused
    /// path rejects a lying frame table exactly like the unfused ones.
    pub fn finish(self, tern: TernaryVector) -> Result<(CompressedParamSet, Encoding)> {
        validate_part_table(&self.name, Some(&self.table), &tern, Encoding::Golomb)?;
        let mut parts = BTreeMap::new();
        parts.insert(self.name, tern);
        Ok((
            CompressedParamSet {
                granularity: self.granularity,
                layout: self.layout,
                parts,
            },
            Encoding::Golomb,
        ))
    }
}

// -- corruption-sweep support (shared by the format tests and the
// integration bit-flip fuzz) ------------------------------------------------

/// Rebuild a container around a mutated body, recomputing the CRC with
/// the right per-version coverage so the corruption is CRC-consistent —
/// it models a *buggy writer*, not line noise. `original` supplies the
/// 10-byte header (and its version field decides the CRC coverage).
pub fn reassemble_body(original: &[u8], body: Vec<u8>) -> Vec<u8> {
    assert!(original.len() >= 10, "need a full header to reassemble");
    let mut out = original.get(..10).unwrap_or_default().to_vec();
    let version = u16::from_le_bytes([
        out.get(4).copied().unwrap_or(0),
        out.get(5).copied().unwrap_or(0),
    ]);
    out.extend_from_slice(&body);
    let crc = if version >= 2 { crc32(&out) } else { crc32(&body) };
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// CRC-consistent truncation variants of a container: the body cut at
/// several depths (inside the layout, the frame tables, and the
/// payloads), each re-wrapped with a freshly computed CRC. Every
/// variant must fail **structurally** (never parse short, never panic,
/// never balloon an allocation) — the contract both the format suite
/// and the integration corruption sweep assert.
pub fn truncation_sweep(bytes: &[u8]) -> Vec<Vec<u8>> {
    assert!(bytes.len() > 14, "not a plausible container");
    let body = bytes.get(10..bytes.len() - 4).unwrap_or_default();
    [1usize, 8, 40, body.len() / 2, body.len().saturating_sub(5), body.len() - 1]
        .into_iter()
        .filter(|&keep| keep < body.len())
        .map(|keep| reassemble_body(bytes, body.get(..keep).unwrap_or_default().to_vec()))
        .collect()
}

/// Write a compressed expert to disk.
pub fn save(path: &Path, c: &CompressedParamSet, enc: Encoding) -> Result<u64> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let bytes = to_bytes(c, enc);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(bytes.len() as u64)
}

/// Read a compressed expert from disk.
pub fn load(path: &Path) -> Result<(CompressedParamSet, Encoding)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read {}", path.display()))?;
    from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_params, CompressConfig};
    use crate::tensor::{ParamSet, Tensor};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn sample_compressed(granularity: Granularity) -> CompressedParamSet {
        let mut rng = Pcg::seed(21);
        let mut p = ParamSet::new();
        p.insert("layer.0.w", Tensor::new(vec![16, 8], prop::task_vector_like(&mut rng, 128)));
        p.insert("layer.1.w", Tensor::new(vec![64], prop::task_vector_like(&mut rng, 64)));
        compress_params(&p, &CompressConfig { density: 0.2, alpha: 1.5, granularity })
    }

    #[test]
    fn crc32_known_value() {
        // CRC32("123456789") = 0xCBF43926 (standard check value).
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn roundtrip_both_encodings_and_granularities() {
        for g in [Granularity::Global, Granularity::PerTensor] {
            for enc in [Encoding::Golomb, Encoding::Bitmask] {
                let c = sample_compressed(g);
                let bytes = to_bytes(&c, enc);
                let (back, benc) = from_bytes(&bytes).unwrap();
                assert_eq!(benc, enc);
                assert_eq!(back, c, "granularity {g:?} encoding {enc:?}");
            }
        }
    }

    #[test]
    fn parallel_container_is_byte_identical() {
        use crate::util::pool::ThreadPool;
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for g in [Granularity::Global, Granularity::PerTensor] {
                for enc in [Encoding::Golomb, Encoding::Bitmask] {
                    let c = sample_compressed(g);
                    let serial = to_bytes(&c, enc);
                    let par = to_bytes_par(&c, enc, &pool);
                    assert_eq!(serial, par, "workers {workers} {g:?} {enc:?}");
                }
            }
            // Empty per-tensor set exercises the zero-part path.
            let empty = compress_params(
                &ParamSet::new(),
                &CompressConfig {
                    granularity: Granularity::PerTensor,
                    ..Default::default()
                },
            );
            assert_eq!(
                to_bytes(&empty, Encoding::Golomb),
                to_bytes_par(&empty, Encoding::Golomb, &pool)
            );
        }
    }

    #[test]
    fn v1_containers_remain_readable() {
        for g in [Granularity::Global, Granularity::PerTensor] {
            for enc in [Encoding::Golomb, Encoding::Bitmask] {
                let c = sample_compressed(g);
                let v1 = to_bytes_v1(&c, enc);
                assert_eq!(u16::from_le_bytes(v1[4..6].try_into().unwrap()), 1);
                let v2 = to_bytes(&c, enc);
                assert_eq!(u16::from_le_bytes(v2[4..6].try_into().unwrap()), 2);
                // Different wire bytes, same parsed result.
                assert_ne!(v1, v2);
                let (from_v1, e1) = from_bytes(&v1).unwrap();
                let (from_v2, e2) = from_bytes(&v2).unwrap();
                assert_eq!(e1, enc);
                assert_eq!(e2, enc);
                assert_eq!(from_v1, c, "{g:?} {enc:?} v1");
                assert_eq!(from_v2, c, "{g:?} {enc:?} v2");
            }
        }
    }

    #[test]
    fn unknown_version_rejected() {
        let c = sample_compressed(Granularity::Global);
        let mut bytes = to_bytes(&c, Encoding::Golomb);
        bytes[4] = 3; // version 3 does not exist
        let err = from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn parallel_decode_matches_serial_across_versions() {
        use crate::util::pool::ThreadPool;
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for g in [Granularity::Global, Granularity::PerTensor] {
                for enc in [Encoding::Golomb, Encoding::Bitmask] {
                    let c = sample_compressed(g);
                    for bytes in [to_bytes(&c, enc), to_bytes_v1(&c, enc)] {
                        let (serial, se) = from_bytes(&bytes).unwrap();
                        let (par, pe) = from_bytes_par(&bytes, &pool).unwrap();
                        assert_eq!(se, pe);
                        assert_eq!(serial, par, "workers {workers} {g:?} {enc:?}");
                        assert_eq!(serial, c);
                    }
                }
            }
            // Empty container through both readers.
            let empty = compress_params(
                &ParamSet::new(),
                &CompressConfig {
                    granularity: Granularity::PerTensor,
                    ..Default::default()
                },
            );
            let bytes = to_bytes(&empty, Encoding::Golomb);
            assert_eq!(
                from_bytes(&bytes).unwrap().0,
                from_bytes_par(&bytes, &pool).unwrap().0
            );
        }
    }

    #[test]
    fn trailing_garbage_rejected_even_when_crc_consistent() {
        use crate::util::pool::ThreadPool;
        let c = sample_compressed(Granularity::Global);
        for bytes in [to_bytes(&c, Encoding::Golomb), to_bytes_v1(&c, Encoding::Golomb)]
        {
            let mut body = bytes[10..bytes.len() - 4].to_vec();
            body.extend_from_slice(b"JUNK");
            let evil = reassemble_body(&bytes, body);
            let err = from_bytes(&evil).unwrap_err().to_string();
            assert!(err.contains("trailing"), "{err}");
            let pool = ThreadPool::new(2);
            assert!(from_bytes_par(&evil, &pool).is_err());
        }
    }

    #[test]
    fn crc_consistent_truncation_rejected() {
        // Cuts at several depths (inside the layout, the frame tables,
        // and the payloads), always with a recomputed CRC: every cut
        // must fail structurally, never parse short. The sweep itself
        // is the shared `truncation_sweep` helper, which the
        // integration corruption suite also runs (over both encodings
        // and granularities, serial and parallel readers).
        for g in [Granularity::Global, Granularity::PerTensor] {
            for enc in [Encoding::Golomb, Encoding::Bitmask] {
                let c = sample_compressed(g);
                for bytes in [to_bytes(&c, enc), to_bytes_v1(&c, enc)] {
                    let cuts = truncation_sweep(&bytes);
                    assert!(cuts.len() >= 5, "sweep must cut at several depths");
                    for (i, cut) in cuts.iter().enumerate() {
                        assert!(
                            from_bytes(cut).is_err(),
                            "{g:?}/{enc:?} cut {i} accepted"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lying_frame_table_rejected_on_both_read_paths() {
        use crate::util::pool::ThreadPool;
        let c = sample_compressed(Granularity::Global);
        let bytes = to_bytes(&c, Encoding::Golomb);
        let body = bytes[10..bytes.len() - 4].to_vec();
        // Walk the body with the parser's own helpers to the frame-table
        // chunk field of part 0 (right after the part name), then zero it.
        let mut pos = 0usize;
        let n_layout = get_u32(&body, &mut pos).unwrap() as usize;
        for _ in 0..n_layout {
            let _ = get_str(&body, &mut pos).unwrap();
            let ndim = get_u32(&body, &mut pos).unwrap() as usize;
            for _ in 0..=ndim {
                let _ = get_u64(&body, &mut pos).unwrap(); // dims + offset
            }
        }
        let _n_parts = get_u32(&body, &mut pos).unwrap();
        let _name = get_str(&body, &mut pos).unwrap();
        let at = pos;
        assert_eq!(
            u32::from_le_bytes(body[at..at + 4].try_into().unwrap()),
            FRAME_NNZ as u32
        );
        let pool = ThreadPool::new(2);
        let mut evil_body = body.clone();
        evil_body[at..at + 4].copy_from_slice(&0u32.to_le_bytes());
        let evil = reassemble_body(&bytes, evil_body);
        assert!(from_bytes(&evil).is_err(), "serial reader accepted chunk=0");
        assert!(from_bytes_par(&evil, &pool).is_err(), "parallel reader accepted");

        // A plausible-but-wrong bit offset (count still correct) must
        // fail on both read paths too, not just the parallel one.
        let off_at = at + 8; // chunk u32 | n_frames u32 | bit_offset u64
        let stored = u64::from_le_bytes(body[off_at..off_at + 8].try_into().unwrap());
        let mut evil_body = body.clone();
        evil_body[off_at..off_at + 8].copy_from_slice(&(stored + 8).to_le_bytes());
        let evil = reassemble_body(&bytes, evil_body);
        assert!(from_bytes(&evil).is_err(), "serial reader accepted a lying offset");
        assert!(
            from_bytes_par(&evil, &pool).is_err(),
            "parallel reader accepted a lying offset"
        );
    }

    /// The fused-path planner: single-part v2 Golomb containers get a
    /// plan whose frame-by-frame decode reproduces `from_bytes`
    /// exactly; every other valid shape opts out with `Ok(None)`;
    /// corrupt containers and lying tables are rejected just like on
    /// the unfused paths.
    #[test]
    fn golomb_frame_plan_matches_from_bytes_and_validates() {
        use crate::compeft::golomb::FrameDecoder;
        let c = sample_compressed(Granularity::Global);
        let bytes = to_bytes(&c, Encoding::Golomb);
        let plan = golomb_frame_plan(&bytes).unwrap().expect("plan for v2 golomb");
        assert!(plan.payload.end <= bytes.len());
        let payload = &bytes[plan.payload.clone()];
        let mut fd = FrameDecoder::new(payload, &plan.table).unwrap();
        for _ in 0..fd.frame_count() {
            fd.decode_next().unwrap();
        }
        let tern = fd.finish().unwrap();
        let (fused, fenc) = plan.finish(tern).unwrap();
        let (unfused, uenc) = from_bytes(&bytes).unwrap();
        assert_eq!(fenc, uenc);
        assert_eq!(fused, unfused, "fused decode must be bit-identical");

        // Valid shapes the fused path declines: bitmask, v1, multi-part.
        let bm = to_bytes(&c, Encoding::Bitmask);
        assert!(golomb_frame_plan(&bm).unwrap().is_none(), "bitmask");
        let v1 = to_bytes_v1(&c, Encoding::Golomb);
        assert!(golomb_frame_plan(&v1).unwrap().is_none(), "v1");
        let multi = sample_compressed(Granularity::PerTensor);
        let mb = to_bytes(&multi, Encoding::Golomb);
        assert!(golomb_frame_plan(&mb).unwrap().is_none(), "multi-part");

        // Corruption is rejected before any frame decodes.
        let mut evil = bytes.clone();
        let mid = evil.len() / 2;
        evil[mid] ^= 0x40;
        assert!(golomb_frame_plan(&evil).is_err(), "corrupt container");

        // A lying frame table passes the plan (it is CRC-consistent)
        // but fails at finish, exactly like the unfused readers.
        let plan = golomb_frame_plan(&bytes).unwrap().unwrap();
        let payload = &bytes[plan.payload.clone()];
        let mut wrong = crate::compeft::golomb::decode(payload).unwrap();
        wrong.plus.pop();
        assert!(plan.finish(wrong).is_err(), "lying table must fail finish");
    }

    #[test]
    fn golomb_encoding_smaller_than_bitmask_at_low_density() {
        let c = sample_compressed(Granularity::Global);
        let g = to_bytes(&c, Encoding::Golomb).len();
        let b = to_bytes(&c, Encoding::Bitmask).len();
        assert!(g < b, "golomb {g} vs bitmask {b}");
    }

    #[test]
    fn corruption_detected() {
        let c = sample_compressed(Granularity::Global);
        let mut bytes = to_bytes(&c, Encoding::Golomb);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(from_bytes(&bytes).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
        assert!(from_bytes(b"JUNKJUNKJUNKJUNK").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("compeft_format_test");
        let path = dir.join("e.cpeft");
        let c = sample_compressed(Granularity::PerTensor);
        let n = save(&path, &c, Encoding::Golomb).unwrap();
        assert!(n > 0);
        let (back, enc) = load(&path).unwrap();
        assert_eq!(enc, Encoding::Golomb);
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).ok();
    }
}
