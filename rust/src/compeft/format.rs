//! `.cpeft` — the on-disk / on-wire container for compressed experts.
//!
//! One file holds a whole [`CompressedParamSet`]: a header, the tensor
//! layout table, and one payload record per part, each encoded as either
//! Golomb (storage-optimal) or bitmask (compute-optimal) per §2.2. A
//! CRC32 over everything after the header guards against truncated
//! transfers — important because the serving path streams these over
//! simulated links.
//!
//! ```text
//! magic "CPFT" | version u16 | flags u16 | granularity u8 | encoding u8
//! n_layout u32 | [ name, shape ]*            (layout table)
//! n_parts u32  | [ name, payload_len u64, payload ]*
//! crc32 u32                                   (over layout+parts)
//! ```

use crate::compeft::bitmask::MaskPair;
use crate::compeft::compress::{CompressedParamSet, Granularity};
use crate::compeft::golomb;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;

const MAGIC: &[u8; 4] = b"CPFT";
const VERSION: u16 = 1;

/// Wire encoding for payload records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Golomb/Rice gap coding — smallest (default for storage/transfer).
    Golomb,
    /// Two binary masks — larger but enables bitwise compute on load.
    Bitmask,
}

impl Encoding {
    fn tag(self) -> u8 {
        match self {
            Encoding::Golomb => 0,
            Encoding::Bitmask => 1,
        }
    }

    fn from_tag(t: u8) -> Result<Encoding> {
        Ok(match t {
            0 => Encoding::Golomb,
            1 => Encoding::Bitmask,
            other => bail!("unknown encoding tag {other}"),
        })
    }
}

// -- CRC32 (IEEE 802.3, reflected) -----------------------------------------

fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC32 of a byte slice (IEEE polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFFFFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFFFFFF
}

// -- serialization ----------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let n = get_u32(bytes, pos)? as usize;
    if *pos + n > bytes.len() {
        bail!("truncated string");
    }
    let s = std::str::from_utf8(&bytes[*pos..*pos + n])?.to_string();
    *pos += n;
    Ok(s)
}

fn get_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > bytes.len() {
        bail!("truncated u32");
    }
    let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into()?);
    *pos += 4;
    Ok(v)
}

fn get_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    if *pos + 8 > bytes.len() {
        bail!("truncated u64");
    }
    let v = u64::from_le_bytes(bytes[*pos..*pos + 8].try_into()?);
    *pos += 8;
    Ok(v)
}

/// Serial payload encoding of one part.
fn encode_payload(tern: &crate::compeft::ternary::TernaryVector, enc: Encoding) -> Vec<u8> {
    match enc {
        Encoding::Golomb => golomb::encode(tern),
        Encoding::Bitmask => MaskPair::from_ternary(tern).to_bytes(),
    }
}

/// Assemble the `.cpeft` container around already-encoded payloads
/// (one per part, in `c.parts` iteration order). The single source of
/// truth for the header/layout/CRC wire format — both the serial and
/// parallel writers go through here.
fn assemble(c: &CompressedParamSet, enc: Encoding, payloads: &[Vec<u8>]) -> Vec<u8> {
    debug_assert_eq!(c.parts.len(), payloads.len());
    let mut body = Vec::new();
    // Layout table.
    body.extend_from_slice(&(c.layout.len() as u32).to_le_bytes());
    for (name, shape, offset) in &c.layout {
        put_str(&mut body, name);
        body.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            body.extend_from_slice(&(d as u64).to_le_bytes());
        }
        body.extend_from_slice(&(*offset as u64).to_le_bytes());
    }
    // Parts.
    body.extend_from_slice(&(c.parts.len() as u32).to_le_bytes());
    for (name, payload) in c.parts.keys().zip(payloads) {
        put_str(&mut body, name);
        body.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        body.extend_from_slice(payload);
    }

    let mut out = Vec::with_capacity(body.len() + 16);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes()); // flags
    out.push(match c.granularity {
        Granularity::Global => 0,
        Granularity::PerTensor => 1,
    });
    out.push(enc.tag());
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc32(&body).to_le_bytes());
    out
}

/// Serialize a compressed expert to `.cpeft` bytes.
pub fn to_bytes(c: &CompressedParamSet, enc: Encoding) -> Vec<u8> {
    let payloads: Vec<Vec<u8>> =
        c.parts.values().map(|tern| encode_payload(tern, enc)).collect();
    assemble(c, enc, &payloads)
}

/// Parallel [`to_bytes`]: byte-identical output.
///
/// Multi-part sets ([`Granularity::PerTensor`]) encode their payloads
/// concurrently, one part per pool task; a single-part (global) set
/// instead parallelises *inside* the payload encoder
/// ([`golomb::encode_par`] / [`MaskPair::from_ternary_par`]). Exactly
/// one level runs on the pool either way, so no pool task ever waits on
/// the pool. Assembly then walks the same `BTreeMap` order as the
/// serial writer.
pub fn to_bytes_par(
    c: &CompressedParamSet,
    enc: Encoding,
    pool: &crate::util::pool::ThreadPool,
) -> Vec<u8> {
    // Chunk sizes for single-part payload encoding: nonzeros per golomb
    // task, words per bitmask task. Work division only — never changes
    // the bytes.
    const GOLOMB_CHUNK_NNZ: usize = 1 << 15;
    const BITMASK_CHUNK_WORDS: usize = 1 << 13;

    let terns: Vec<&crate::compeft::ternary::TernaryVector> = c.parts.values().collect();
    let payloads: Vec<Vec<u8>> = if terns.len() == 1 {
        let tern = terns[0];
        vec![match enc {
            Encoding::Golomb => golomb::encode_par(tern, pool, GOLOMB_CHUNK_NNZ),
            Encoding::Bitmask => {
                MaskPair::from_ternary_par(tern, pool, BITMASK_CHUNK_WORDS).to_bytes()
            }
        }]
    } else {
        pool.scoped_map(terns, |tern| encode_payload(tern, enc))
    };
    assemble(c, enc, &payloads)
}

/// Parse `.cpeft` bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<(CompressedParamSet, Encoding)> {
    if bytes.len() < 14 || &bytes[..4] != MAGIC {
        bail!("not a .cpeft file");
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into()?);
    if version != VERSION {
        bail!("unsupported .cpeft version {version}");
    }
    let granularity = match bytes[8] {
        0 => Granularity::Global,
        1 => Granularity::PerTensor,
        g => bail!("unknown granularity {g}"),
    };
    let enc = Encoding::from_tag(bytes[9])?;

    let body = &bytes[10..bytes.len() - 4];
    let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into()?);
    let actual = crc32(body);
    if stored_crc != actual {
        bail!("crc mismatch: stored {stored_crc:#x}, computed {actual:#x}");
    }

    let mut pos = 0usize;
    let n_layout = get_u32(body, &mut pos)? as usize;
    let mut layout = Vec::with_capacity(n_layout);
    for _ in 0..n_layout {
        let name = get_str(body, &mut pos)?;
        let ndim = get_u32(body, &mut pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(get_u64(body, &mut pos)? as usize);
        }
        let offset = get_u64(body, &mut pos)? as usize;
        layout.push((name, shape, offset));
    }

    let n_parts = get_u32(body, &mut pos)? as usize;
    let mut parts = BTreeMap::new();
    for _ in 0..n_parts {
        let name = get_str(body, &mut pos)?;
        let plen = get_u64(body, &mut pos)? as usize;
        if pos + plen > body.len() {
            bail!("truncated payload for part {name:?}");
        }
        let payload = &body[pos..pos + plen];
        pos += plen;
        let tern = match enc {
            Encoding::Golomb => golomb::decode(payload)
                .with_context(|| format!("part {name:?}"))?,
            Encoding::Bitmask => MaskPair::from_bytes(payload)
                .with_context(|| format!("part {name:?}"))?
                .to_ternary(),
        };
        parts.insert(name, tern);
    }

    Ok((CompressedParamSet { granularity, layout, parts }, enc))
}

/// Write a compressed expert to disk.
pub fn save(path: &Path, c: &CompressedParamSet, enc: Encoding) -> Result<u64> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let bytes = to_bytes(c, enc);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(bytes.len() as u64)
}

/// Read a compressed expert from disk.
pub fn load(path: &Path) -> Result<(CompressedParamSet, Encoding)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read {}", path.display()))?;
    from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_params, CompressConfig};
    use crate::tensor::{ParamSet, Tensor};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn sample_compressed(granularity: Granularity) -> CompressedParamSet {
        let mut rng = Pcg::seed(21);
        let mut p = ParamSet::new();
        p.insert("layer.0.w", Tensor::new(vec![16, 8], prop::task_vector_like(&mut rng, 128)));
        p.insert("layer.1.w", Tensor::new(vec![64], prop::task_vector_like(&mut rng, 64)));
        compress_params(&p, &CompressConfig { density: 0.2, alpha: 1.5, granularity })
    }

    #[test]
    fn crc32_known_value() {
        // CRC32("123456789") = 0xCBF43926 (standard check value).
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn roundtrip_both_encodings_and_granularities() {
        for g in [Granularity::Global, Granularity::PerTensor] {
            for enc in [Encoding::Golomb, Encoding::Bitmask] {
                let c = sample_compressed(g);
                let bytes = to_bytes(&c, enc);
                let (back, benc) = from_bytes(&bytes).unwrap();
                assert_eq!(benc, enc);
                assert_eq!(back, c, "granularity {g:?} encoding {enc:?}");
            }
        }
    }

    #[test]
    fn parallel_container_is_byte_identical() {
        use crate::util::pool::ThreadPool;
        for workers in [1usize, 2, 8] {
            let pool = ThreadPool::new(workers);
            for g in [Granularity::Global, Granularity::PerTensor] {
                for enc in [Encoding::Golomb, Encoding::Bitmask] {
                    let c = sample_compressed(g);
                    let serial = to_bytes(&c, enc);
                    let par = to_bytes_par(&c, enc, &pool);
                    assert_eq!(serial, par, "workers {workers} {g:?} {enc:?}");
                }
            }
            // Empty per-tensor set exercises the zero-part path.
            let empty = compress_params(
                &ParamSet::new(),
                &CompressConfig {
                    granularity: Granularity::PerTensor,
                    ..Default::default()
                },
            );
            assert_eq!(
                to_bytes(&empty, Encoding::Golomb),
                to_bytes_par(&empty, Encoding::Golomb, &pool)
            );
        }
    }

    #[test]
    fn golomb_encoding_smaller_than_bitmask_at_low_density() {
        let c = sample_compressed(Granularity::Global);
        let g = to_bytes(&c, Encoding::Golomb).len();
        let b = to_bytes(&c, Encoding::Bitmask).len();
        assert!(g < b, "golomb {g} vs bitmask {b}");
    }

    #[test]
    fn corruption_detected() {
        let c = sample_compressed(Granularity::Global);
        let mut bytes = to_bytes(&c, Encoding::Golomb);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(from_bytes(&bytes).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
        assert!(from_bytes(b"JUNKJUNKJUNKJUNK").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("compeft_format_test");
        let path = dir.join("e.cpeft");
        let c = sample_compressed(Granularity::PerTensor);
        let n = save(&path, &c, Encoding::Golomb).unwrap();
        assert!(n > 0);
        let (back, enc) = load(&path).unwrap();
        assert_eq!(enc, Encoding::Golomb);
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).ok();
    }
}
