//! Parallel chunked compression engine.
//!
//! Algorithm 1 over a 200M–65B-parameter task vector is dominated by
//! three linear passes: σ(τ), the top-⌈k·d⌉ magnitude selection, and the
//! kept-index emission. This module runs all three as chunked passes on
//! a [`ThreadPool`]:
//!
//! 1. **σ(τ)** — per-[`crate::util::stats::MOMENT_BLOCK`] Welford
//!    moments on the pool, merged in block order
//!    ([`crate::util::stats::par_blocked_moments`]).
//! 2. **Global top-k** — per-chunk histograms over the u32 magnitude
//!    keys feed an exact single-bucket quickselect refine
//!    ([`crate::compeft::sparsify::par_topk_by_magnitude`]).
//! 3. **Emission** — per-chunk scans concatenated in chunk order, so
//!    the plus/minus index lists come out sorted without a sort.
//!
//! Outputs are **bit-identical** to the serial
//! [`compress_vector`]/[`compress_params`] path at every worker count
//! and chunk size: the threshold is an exact order statistic (a value,
//! not a partition artifact), emission reuses the serial float
//! comparisons (NaN/±0/tie semantics included), and the σ merge tree is
//! fixed by block size rather than by worker assignment. The
//! equivalence is asserted across pool sizes and chunk sizes in this
//! module's tests and re-checked end-to-end in `tests/integration.rs`.
//!
//! [`Granularity::PerTensor`] parallelises across tensors instead (one
//! serial compression per tensor on the pool) — never both levels at
//! once, which keeps [`ThreadPool::scoped_map`] free of nested waits.
//!
//! **Decode mirror (PR 2).** The serving path runs the same three-pass
//! story in reverse on every GPU-tier miss: wire decode
//! ([`crate::compeft::format::from_bytes_par`] over v2 payload frames),
//! dense materialization ([`par_decompress_params`] — chunked
//! [`TernaryVector::fill_dense_range`] scatters into per-tensor
//! buffers), and adapter application ([`par_add_assign`]). Each is
//! bit-identical to its serial counterpart at any worker count and
//! chunk size, for the same reason the encode side is: chunks partition
//! the index space in order, each chunk runs the serial loop, and
//! per-element float ops happen exactly once in the same order.

use crate::compeft::compress::{
    compress_vector, CompressConfig, CompressedParamSet, Granularity,
};
use crate::compeft::sparsify::par_topk_by_magnitude;
use crate::compeft::ternary::TernaryVector;
use crate::tensor::{ParamSet, Tensor};
use crate::util::pool::ThreadPool;
use crate::util::stats::par_blocked_std_f32;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Default work-division chunk: 64K elements ≈ 256 KB of f32 per task —
/// small enough to load-balance a 4M-element τ across 8 workers ~8× per
/// pass, large enough that per-task overhead (one boxed closure + one
/// channel send) is noise.
pub const DEFAULT_CHUNK: usize = 1 << 16;

/// Tuning knobs for the parallel engine. Only affects how work is
/// divided, never what is computed.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Elements per parallel task in the top-k and emission passes.
    pub chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { chunk: DEFAULT_CHUNK }
    }
}

/// Parallel [`compress_vector`]: bit-identical output, chunked across
/// `pool` with the default chunk size.
pub fn par_compress_vector(
    tau: &[f32],
    cfg: &CompressConfig,
    pool: &ThreadPool,
) -> TernaryVector {
    par_compress_vector_cfg(tau, cfg, pool, &EngineConfig::default())
}

/// Parallel [`compress_vector`] with explicit engine tuning.
pub fn par_compress_vector_cfg(
    tau: &[f32],
    cfg: &CompressConfig,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> TernaryVector {
    if tau.is_empty() {
        return TernaryVector::empty(0);
    }
    let sigma = par_blocked_std_f32(tau, pool);
    let split = par_topk_by_magnitude(tau, cfg.density, pool, engine.chunk);
    TernaryVector {
        len: tau.len(),
        scale: (cfg.alpha * sigma) as f32,
        plus: split.plus,
        minus: split.minus,
    }
}

/// Parallel [`compress_params`](crate::compeft::compress::compress_params):
/// bit-identical output.
///
/// * [`Granularity::Global`] flattens once, then runs the chunked
///   engine over the single global τ.
/// * [`Granularity::PerTensor`] compresses tensors concurrently, one
///   serial [`compress_vector`] per tensor.
pub fn par_compress_paramset(
    tv: &ParamSet,
    cfg: &CompressConfig,
    pool: &ThreadPool,
) -> CompressedParamSet {
    par_compress_paramset_cfg(tv, cfg, pool, &EngineConfig::default())
}

/// Parallel paramset compression with explicit engine tuning.
pub fn par_compress_paramset_cfg(
    tv: &ParamSet,
    cfg: &CompressConfig,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> CompressedParamSet {
    let mut layout = Vec::new();
    let mut off = 0usize;
    for (name, t) in tv.iter() {
        layout.push((name.to_string(), t.shape.clone(), off));
        off += t.len();
    }
    let mut parts = BTreeMap::new();
    match cfg.granularity {
        Granularity::Global => {
            let flat = tv.flatten();
            parts.insert(
                String::new(),
                par_compress_vector_cfg(&flat, cfg, pool, engine),
            );
        }
        Granularity::PerTensor => {
            let items: Vec<(&str, &crate::tensor::Tensor)> = tv.iter().collect();
            let compressed = pool.scoped_map(items, |(name, t)| {
                (name.to_string(), compress_vector(&t.data, cfg))
            });
            for (name, tern) in compressed {
                parts.insert(name, tern);
            }
        }
    }
    CompressedParamSet { granularity: cfg.granularity, layout, parts }
}

/// Parallel
/// [`decompress_params`](crate::compeft::compress::decompress_params):
/// bit-identical output, default chunk size.
pub fn par_decompress_params(
    c: &CompressedParamSet,
    like: &ParamSet,
    pool: &ThreadPool,
) -> Result<ParamSet> {
    par_decompress_params_cfg(c, like, pool, &EngineConfig::default())
}

/// Parallel decompression with explicit engine tuning.
///
/// Materializes one dense buffer per tensor of `like` and scatters
/// `±scale` into it chunk by chunk
/// ([`TernaryVector::fill_dense_range`]), skipping the serial path's
/// intermediate flat vector. [`Granularity::Global`] indexes the single
/// part with each tensor's global offset; `PerTensor` indexes each
/// tensor's own part from zero. One pool pass over all (tensor × chunk)
/// tasks — never nested.
pub fn par_decompress_params_cfg(
    c: &CompressedParamSet,
    like: &ParamSet,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> Result<ParamSet> {
    // One output buffer per tensor of `like`, tied to the ternary part
    // it scatters from and the tensor's offset within that part.
    struct DecodeBuf<'a> {
        name: String,
        shape: Vec<usize>,
        data: Vec<f32>,
        tern: &'a TernaryVector,
        offset: usize,
    }

    let chunk = engine.chunk.max(1);
    let mut bufs: Vec<DecodeBuf<'_>> = Vec::with_capacity(like.len());
    match c.granularity {
        Granularity::Global => {
            let tern = c
                .parts
                .get("")
                .ok_or_else(|| anyhow::anyhow!("missing global part"))?;
            if tern.len != like.total_elements() {
                bail!(
                    "flat length {} != total elements {}",
                    tern.len,
                    like.total_elements()
                );
            }
            let mut off = 0usize;
            for (name, t) in like.iter() {
                bufs.push(DecodeBuf {
                    name: name.to_string(),
                    shape: t.shape.clone(),
                    data: vec![0.0; t.len()],
                    tern,
                    offset: off,
                });
                off += t.len();
            }
        }
        Granularity::PerTensor => {
            for (name, t) in like.iter() {
                let tern = c
                    .parts
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("missing part {name:?}"))?;
                if tern.len != t.len() {
                    bail!(
                        "part {name:?}: ternary length {} != tensor length {}",
                        tern.len,
                        t.len()
                    );
                }
                bufs.push(DecodeBuf {
                    name: name.to_string(),
                    shape: t.shape.clone(),
                    data: vec![0.0; t.len()],
                    tern,
                    offset: 0,
                });
            }
        }
    }

    let mut tasks: Vec<(&TernaryVector, usize, &mut [f32])> = Vec::new();
    for b in bufs.iter_mut() {
        let mut s = 0usize;
        for piece in b.data.chunks_mut(chunk) {
            tasks.push((b.tern, b.offset + s, piece));
            s += piece.len();
        }
    }
    pool.scoped_map(tasks, |(tern, start, dst)| tern.fill_dense_range(start, dst));

    let mut out = ParamSet::new();
    for b in bufs {
        out.insert(&b.name, Tensor::new(b.shape, b.data));
    }
    Ok(out)
}

/// Parallel [`ParamSet::add_assign`]: bit-identical result.
///
/// The serving materialization step (`adapter = init + τ̃`, or
/// `params = base + τ̃` for full-FT experts) is a pure element-wise add;
/// chunked across the pool every element is still added exactly once,
/// so the result equals the serial loop's bit for bit. Error behavior
/// is strictly cleaner than serial: a delta name missing from `dst`
/// fails *before* anything is mutated (the serial loop may have applied
/// earlier tensors already); a shape mismatch panics like
/// [`Tensor::add_assign`] does.
pub fn par_add_assign(
    dst: &mut ParamSet,
    delta: &ParamSet,
    pool: &ThreadPool,
) -> Result<()> {
    par_add_assign_cfg(dst, delta, pool, &EngineConfig::default())
}

/// Parallel add-assign with explicit engine tuning.
pub fn par_add_assign_cfg(
    dst: &mut ParamSet,
    delta: &ParamSet,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> Result<()> {
    for (name, _) in delta.iter() {
        if dst.get(name).is_none() {
            bail!("parameter {name:?} missing in target");
        }
    }
    let chunk = engine.chunk.max(1);
    let mut tasks: Vec<(&mut [f32], &[f32])> = Vec::new();
    for (name, mine) in dst.iter_mut() {
        if let Some(d) = delta.get(name) {
            assert_eq!(mine.shape, d.shape, "shape mismatch in add_assign");
            for (dc, sc) in mine.data.chunks_mut(chunk).zip(d.data.chunks(chunk)) {
                tasks.push((dc, sc));
            }
        }
    }
    pool.scoped_map(tasks, |(d, s)| {
        for (a, b) in d.iter_mut().zip(s) {
            *a += *b;
        }
    });
    Ok(())
}

/// Chunk-parallel ternary-domain merge
/// ([`crate::merging::ternary::merge_ternary`] on the pool): TIES,
/// averaging, task arithmetic, or weighted (LoraHub) composition of N
/// compressed experts, bit-identical to the dense
/// decompress-then-merge reference at any worker count and chunk size.
///
/// The [`MergePlan`](crate::merging::ternary::MergePlan) does all
/// global work up front (layout validation, TIES trim thresholds); the
/// pool then computes disjoint output chunks, each replaying the dense
/// per-coordinate operation sequence over the experts' supports. Peak
/// memory is O(d + workers·chunk) — the dense path materializes all N
/// experts at O(N·d).
pub fn par_merge(
    experts: &[&crate::compeft::compress::CompressedParamSet],
    method: &crate::merging::MergeMethod,
    pool: &ThreadPool,
) -> Result<ParamSet> {
    par_merge_cfg(experts, method, pool, &EngineConfig::default())
}

/// [`par_merge`] with explicit engine tuning.
pub fn par_merge_cfg(
    experts: &[&crate::compeft::compress::CompressedParamSet],
    method: &crate::merging::MergeMethod,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> Result<ParamSet> {
    let plan = crate::merging::ternary::MergePlan::new(experts, method)?;
    let chunk = engine.chunk.max(1);
    let mut flat = vec![0.0f32; plan.d()];
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
    let mut start = 0usize;
    for piece in flat.chunks_mut(chunk) {
        let len = piece.len();
        tasks.push((start, piece));
        start += len;
    }
    pool.scoped_map(tasks, |(s, out)| plan.run_chunk(s, out));
    Ok(plan.into_paramset(flat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::compress_params;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    pub(crate) fn assert_ternary_bit_identical(
        a: &TernaryVector,
        b: &TernaryVector,
        tag: &str,
    ) {
        assert_eq!(a.len, b.len, "{tag}: len");
        assert_eq!(
            a.scale.to_bits(),
            b.scale.to_bits(),
            "{tag}: scale {} vs {}",
            a.scale,
            b.scale
        );
        assert_eq!(a.plus, b.plus, "{tag}: plus");
        assert_eq!(a.minus, b.minus, "{tag}: minus");
    }

    fn assert_compressed_bit_identical(
        a: &CompressedParamSet,
        b: &CompressedParamSet,
        tag: &str,
    ) {
        assert_eq!(a.granularity, b.granularity, "{tag}");
        assert_eq!(a.layout, b.layout, "{tag}: layout");
        let names_a: Vec<&String> = a.parts.keys().collect();
        let names_b: Vec<&String> = b.parts.keys().collect();
        assert_eq!(names_a, names_b, "{tag}: part names");
        for (name, ta) in &a.parts {
            assert_ternary_bit_identical(ta, &b.parts[name], &format!("{tag}/{name}"));
        }
    }

    #[test]
    fn vector_engine_matches_serial_across_pools_and_chunks() {
        let mut rng = Pcg::seed(101);
        let tau = prop::task_vector_like(&mut rng, 200_000);
        let cfg = CompressConfig { density: 0.05, alpha: 2.0, ..Default::default() };
        let serial = compress_vector(&tau, &cfg);
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk in [512usize, 1 << 14, 1 << 16, 1 << 22] {
                let par = par_compress_vector_cfg(
                    &tau,
                    &cfg,
                    &pool,
                    &EngineConfig { chunk },
                );
                assert_ternary_bit_identical(
                    &serial,
                    &par,
                    &format!("workers={workers} chunk={chunk}"),
                );
            }
        }
    }

    #[test]
    fn vector_engine_edge_cases() {
        let pool = ThreadPool::new(4);
        let engine = EngineConfig { chunk: 1000 };
        let mut rng = Pcg::seed(7);
        let mut nan_tau = prop::task_vector_like(&mut rng, 3000);
        nan_tau[100] = f32::NAN;
        nan_tau[2999] = f32::NAN;
        let cases: Vec<(&str, Vec<f32>, f64)> = vec![
            ("empty", Vec::new(), 0.5),
            ("singleton", vec![-0.25], 1.0),
            ("all_zero", vec![0.0; 1024], 0.3),
            ("signed_zero", vec![0.0, -0.0, 1.0, -1.0], 0.5),
            ("all_equal", vec![2.5; 4097], 0.2),
            ("density_one", prop::task_vector_like(&mut rng, 5000), 1.0),
            ("tiny_k_keep_one", prop::task_vector_like(&mut rng, 4096), 1e-9),
            ("nan_entries", nan_tau, 0.1),
        ];
        for (name, tau, k) in &cases {
            let cfg = CompressConfig { density: *k, alpha: 1.0, ..Default::default() };
            let serial = compress_vector(tau, &cfg);
            let par = par_compress_vector_cfg(tau, &cfg, &pool, &engine);
            assert_ternary_bit_identical(&serial, &par, name);
        }
        // Spot-check the contracts behind two of the edge cases.
        let keep_one = compress_vector(
            &prop::task_vector_like(&mut rng, 4096),
            &CompressConfig { density: 1e-9, ..Default::default() },
        );
        assert_eq!(keep_one.nnz(), 1, "⌈k·d⌉ = 1 keeps exactly one entry");
        let dense_all = compress_vector(
            &[1.0f32, -2.0, 3.0, -4.0],
            &CompressConfig { density: 1.0, ..Default::default() },
        );
        assert_eq!(dense_all.nnz(), 4, "k = 1.0 keeps every nonzero");
    }

    fn sample_paramset(rng: &mut Pcg, tensors: usize) -> ParamSet {
        let mut p = ParamSet::new();
        for i in 0..tensors {
            let n = 1000 + i * 997;
            p.insert(
                &format!("layer.{i}.w"),
                Tensor::new(vec![n], prop::task_vector_like(rng, n)),
            );
        }
        p
    }

    #[test]
    fn paramset_engine_matches_serial_both_granularities() {
        let mut rng = Pcg::seed(55);
        for tensors in [0usize, 1, 7] {
            let tv = sample_paramset(&mut rng, tensors);
            for granularity in [Granularity::Global, Granularity::PerTensor] {
                let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity };
                let serial = compress_params(&tv, &cfg);
                for workers in crate::util::prop::pool_sizes() {
                    let pool = ThreadPool::new(workers);
                    let par = par_compress_paramset(&tv, &cfg, &pool);
                    assert_compressed_bit_identical(
                        &serial,
                        &par,
                        &format!("{granularity:?} tensors={tensors} workers={workers}"),
                    );
                }
            }
        }
    }

    use crate::util::prop::assert_paramset_bit_identical;

    #[test]
    fn par_decompress_matches_serial_across_pools_and_chunks() {
        use crate::compeft::compress::decompress_params;
        let mut rng = Pcg::seed(77);
        for tensors in [0usize, 1, 4] {
            let tv = sample_paramset(&mut rng, tensors);
            for granularity in [Granularity::Global, Granularity::PerTensor] {
                let cfg = CompressConfig { density: 0.15, alpha: 2.0, granularity };
                let c = compress_params(&tv, &cfg);
                let serial = decompress_params(&c, &tv).unwrap();
                for workers in crate::util::prop::pool_sizes() {
                    let pool = ThreadPool::new(workers);
                    for chunk in [1usize, 113, 1 << 16] {
                        let par = par_decompress_params_cfg(
                            &c,
                            &tv,
                            &pool,
                            &EngineConfig { chunk },
                        )
                        .unwrap();
                        assert_paramset_bit_identical(
                            &serial,
                            &par,
                            &format!(
                                "{granularity:?} tensors={tensors} \
                                 workers={workers} chunk={chunk}"
                            ),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn par_decompress_error_paths_match_serial() {
        let mut rng = Pcg::seed(81);
        let tv = sample_paramset(&mut rng, 2);
        let pool = ThreadPool::new(2);
        // Missing per-tensor part.
        let cfg = CompressConfig {
            density: 0.2,
            alpha: 1.0,
            granularity: Granularity::PerTensor,
        };
        let mut c = compress_params(&tv, &cfg);
        c.parts.remove("layer.0.w");
        assert!(par_decompress_params(&c, &tv, &pool).is_err());
        // Global length mismatch.
        let cfg = CompressConfig { granularity: Granularity::Global, ..cfg };
        let c = compress_params(&tv, &cfg);
        let smaller = sample_paramset(&mut Pcg::seed(82), 1);
        assert!(par_decompress_params(&c, &smaller, &pool).is_err());
    }

    #[test]
    fn par_add_assign_matches_serial() {
        let mut rng = Pcg::seed(91);
        for tensors in [0usize, 1, 5] {
            let base = sample_paramset(&mut rng, tensors);
            let delta = sample_paramset(&mut Pcg::seed(400 + tensors as u64), tensors);
            let mut serial = base.clone();
            serial.add_assign(&delta).unwrap();
            for workers in crate::util::prop::pool_sizes() {
                let pool = ThreadPool::new(workers);
                for chunk in [1usize, 97, 1 << 16] {
                    let mut par = base.clone();
                    par_add_assign_cfg(&mut par, &delta, &pool, &EngineConfig { chunk })
                        .unwrap();
                    assert_paramset_bit_identical(
                        &serial,
                        &par,
                        &format!("tensors={tensors} workers={workers} chunk={chunk}"),
                    );
                }
            }
        }
    }

    #[test]
    fn par_add_assign_missing_name_fails_before_mutating() {
        let mut rng = Pcg::seed(95);
        let mut dst = sample_paramset(&mut rng, 2);
        let snapshot = dst.clone();
        let mut delta = sample_paramset(&mut Pcg::seed(96), 2);
        delta.insert("not.in.dst", Tensor::new(vec![3], vec![1.0, 2.0, 3.0]));
        let pool = ThreadPool::new(2);
        assert!(par_add_assign(&mut dst, &delta, &pool).is_err());
        assert_eq!(dst, snapshot, "failed add must not partially apply");
    }

    /// Cross-path equivalence for every merge method: the dense
    /// decompress-then-merge reference, the serial ternary-domain path,
    /// and the pooled path agree bit for bit across pools {1, 2, 8} and
    /// several chunk sizes.
    #[test]
    fn par_merge_matches_dense_reference_across_pools_and_chunks() {
        use crate::compeft::compress::decompress_params;
        use crate::merging::ternary::merge_ternary;
        use crate::merging::{merge_dense, MergeMethod};

        let mut rng = Pcg::seed(131);
        let tvs: Vec<ParamSet> =
            (0..3).map(|_| sample_paramset(&mut rng, 3)).collect();
        for granularity in [Granularity::Global, Granularity::PerTensor] {
            let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity };
            let comps: Vec<_> =
                tvs.iter().map(|tv| compress_params(tv, &cfg)).collect();
            let refs: Vec<&_> = comps.iter().collect();
            let dense: Vec<ParamSet> = comps
                .iter()
                .zip(&tvs)
                .map(|(c, tv)| decompress_params(c, tv).unwrap())
                .collect();
            let methods = [
                ("average", MergeMethod::Average),
                ("ta", MergeMethod::TaskArithmetic { lambda: 0.3 }),
                ("ties", MergeMethod::Ties { density: 0.2, lambda: 1.0 }),
                (
                    "weighted",
                    MergeMethod::Weighted { weights: vec![1.0, -0.5, 0.2] },
                ),
            ];
            for (name, method) in &methods {
                let want = merge_dense(&dense, method).unwrap();
                let serial = merge_ternary(&refs, method).unwrap();
                assert_paramset_bit_identical(
                    &want,
                    &serial,
                    &format!("{granularity:?}/{name}/serial"),
                );
                for workers in crate::util::prop::pool_sizes() {
                    let pool = ThreadPool::new(workers);
                    for chunk in [1usize, 113, 1 << 16] {
                        let par = par_merge_cfg(
                            &refs,
                            method,
                            &pool,
                            &EngineConfig { chunk },
                        )
                        .unwrap();
                        assert_paramset_bit_identical(
                            &want,
                            &par,
                            &format!(
                                "{granularity:?}/{name}/workers={workers} \
                                 chunk={chunk}"
                            ),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn par_merge_error_paths_match_serial() {
        use crate::merging::MergeMethod;
        let mut rng = Pcg::seed(137);
        let tv = sample_paramset(&mut rng, 2);
        let cfg = CompressConfig::default();
        let c = compress_params(&tv, &cfg);
        let other = compress_params(&sample_paramset(&mut rng, 1), &cfg);
        let pool = ThreadPool::new(2);
        // Empty list, layout mismatch, weight-count mismatch.
        assert!(par_merge(&[], &MergeMethod::Average, &pool).is_err());
        assert!(par_merge(&[&c, &other], &MergeMethod::Average, &pool).is_err());
        assert!(par_merge(
            &[&c],
            &MergeMethod::Weighted { weights: vec![1.0, 2.0] },
            &pool
        )
        .is_err());
    }

    #[test]
    fn empty_and_single_tensor_paramsets() {
        let pool = ThreadPool::new(2);
        let cfg = CompressConfig::default();
        let empty = ParamSet::new();
        let c = par_compress_paramset(&empty, &cfg, &pool);
        assert_eq!(c.total_elements(), 0);
        assert_compressed_bit_identical(&compress_params(&empty, &cfg), &c, "empty");

        let mut rng = Pcg::seed(3);
        let single = sample_paramset(&mut rng, 1);
        let c = par_compress_paramset(&single, &cfg, &pool);
        assert_compressed_bit_identical(&compress_params(&single, &cfg), &c, "single");
        assert_eq!(c.layout.len(), 1);
    }
}
