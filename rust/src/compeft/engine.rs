//! Parallel chunked compression engine.
//!
//! Algorithm 1 over a 200M–65B-parameter task vector is dominated by
//! three linear passes: σ(τ), the top-⌈k·d⌉ magnitude selection, and the
//! kept-index emission. This module runs all three as chunked passes on
//! a [`ThreadPool`]:
//!
//! 1. **σ(τ)** — per-[`crate::util::stats::MOMENT_BLOCK`] Welford
//!    moments on the pool, merged in block order
//!    ([`crate::util::stats::par_blocked_moments`]).
//! 2. **Global top-k** — per-chunk histograms over the u32 magnitude
//!    keys feed an exact single-bucket quickselect refine
//!    ([`crate::compeft::sparsify::par_topk_by_magnitude`]).
//! 3. **Emission** — per-chunk scans concatenated in chunk order, so
//!    the plus/minus index lists come out sorted without a sort.
//!
//! Outputs are **bit-identical** to the serial
//! [`compress_vector`]/[`compress_params`] path at every worker count
//! and chunk size: the threshold is an exact order statistic (a value,
//! not a partition artifact), emission reuses the serial float
//! comparisons (NaN/±0/tie semantics included), and the σ merge tree is
//! fixed by block size rather than by worker assignment. The
//! equivalence is asserted across pool sizes and chunk sizes in this
//! module's tests and re-checked end-to-end in `tests/integration.rs`.
//!
//! [`Granularity::PerTensor`] parallelises across tensors instead (one
//! serial compression per tensor on the pool) — never both levels at
//! once, which keeps [`ThreadPool::scoped_map`] free of nested waits.

use crate::compeft::compress::{
    compress_vector, CompressConfig, CompressedParamSet, Granularity,
};
use crate::compeft::sparsify::par_topk_by_magnitude;
use crate::compeft::ternary::TernaryVector;
use crate::tensor::ParamSet;
use crate::util::pool::ThreadPool;
use crate::util::stats::par_blocked_std_f32;
use std::collections::BTreeMap;

/// Default work-division chunk: 64K elements ≈ 256 KB of f32 per task —
/// small enough to load-balance a 4M-element τ across 8 workers ~8× per
/// pass, large enough that per-task overhead (one boxed closure + one
/// channel send) is noise.
pub const DEFAULT_CHUNK: usize = 1 << 16;

/// Tuning knobs for the parallel engine. Only affects how work is
/// divided, never what is computed.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Elements per parallel task in the top-k and emission passes.
    pub chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { chunk: DEFAULT_CHUNK }
    }
}

/// Parallel [`compress_vector`]: bit-identical output, chunked across
/// `pool` with the default chunk size.
pub fn par_compress_vector(
    tau: &[f32],
    cfg: &CompressConfig,
    pool: &ThreadPool,
) -> TernaryVector {
    par_compress_vector_cfg(tau, cfg, pool, &EngineConfig::default())
}

/// Parallel [`compress_vector`] with explicit engine tuning.
pub fn par_compress_vector_cfg(
    tau: &[f32],
    cfg: &CompressConfig,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> TernaryVector {
    if tau.is_empty() {
        return TernaryVector::empty(0);
    }
    let sigma = par_blocked_std_f32(tau, pool);
    let split = par_topk_by_magnitude(tau, cfg.density, pool, engine.chunk);
    TernaryVector {
        len: tau.len(),
        scale: (cfg.alpha * sigma) as f32,
        plus: split.plus,
        minus: split.minus,
    }
}

/// Parallel [`compress_params`](crate::compeft::compress::compress_params):
/// bit-identical output.
///
/// * [`Granularity::Global`] flattens once, then runs the chunked
///   engine over the single global τ.
/// * [`Granularity::PerTensor`] compresses tensors concurrently, one
///   serial [`compress_vector`] per tensor.
pub fn par_compress_paramset(
    tv: &ParamSet,
    cfg: &CompressConfig,
    pool: &ThreadPool,
) -> CompressedParamSet {
    par_compress_paramset_cfg(tv, cfg, pool, &EngineConfig::default())
}

/// Parallel paramset compression with explicit engine tuning.
pub fn par_compress_paramset_cfg(
    tv: &ParamSet,
    cfg: &CompressConfig,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> CompressedParamSet {
    let mut layout = Vec::new();
    let mut off = 0usize;
    for (name, t) in tv.iter() {
        layout.push((name.to_string(), t.shape.clone(), off));
        off += t.len();
    }
    let mut parts = BTreeMap::new();
    match cfg.granularity {
        Granularity::Global => {
            let flat = tv.flatten();
            parts.insert(
                String::new(),
                par_compress_vector_cfg(&flat, cfg, pool, engine),
            );
        }
        Granularity::PerTensor => {
            let items: Vec<(&str, &crate::tensor::Tensor)> = tv.iter().collect();
            let compressed = pool.scoped_map(items, |(name, t)| {
                (name.to_string(), compress_vector(&t.data, cfg))
            });
            for (name, tern) in compressed {
                parts.insert(name, tern);
            }
        }
    }
    CompressedParamSet { granularity: cfg.granularity, layout, parts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::compress_params;
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    pub(crate) fn assert_ternary_bit_identical(
        a: &TernaryVector,
        b: &TernaryVector,
        tag: &str,
    ) {
        assert_eq!(a.len, b.len, "{tag}: len");
        assert_eq!(
            a.scale.to_bits(),
            b.scale.to_bits(),
            "{tag}: scale {} vs {}",
            a.scale,
            b.scale
        );
        assert_eq!(a.plus, b.plus, "{tag}: plus");
        assert_eq!(a.minus, b.minus, "{tag}: minus");
    }

    fn assert_compressed_bit_identical(
        a: &CompressedParamSet,
        b: &CompressedParamSet,
        tag: &str,
    ) {
        assert_eq!(a.granularity, b.granularity, "{tag}");
        assert_eq!(a.layout, b.layout, "{tag}: layout");
        let names_a: Vec<&String> = a.parts.keys().collect();
        let names_b: Vec<&String> = b.parts.keys().collect();
        assert_eq!(names_a, names_b, "{tag}: part names");
        for (name, ta) in &a.parts {
            assert_ternary_bit_identical(ta, &b.parts[name], &format!("{tag}/{name}"));
        }
    }

    #[test]
    fn vector_engine_matches_serial_across_pools_and_chunks() {
        let mut rng = Pcg::seed(101);
        let tau = prop::task_vector_like(&mut rng, 200_000);
        let cfg = CompressConfig { density: 0.05, alpha: 2.0, ..Default::default() };
        let serial = compress_vector(&tau, &cfg);
        for workers in [1usize, 2, 8] {
            let pool = ThreadPool::new(workers);
            for chunk in [512usize, 1 << 14, 1 << 16, 1 << 22] {
                let par = par_compress_vector_cfg(
                    &tau,
                    &cfg,
                    &pool,
                    &EngineConfig { chunk },
                );
                assert_ternary_bit_identical(
                    &serial,
                    &par,
                    &format!("workers={workers} chunk={chunk}"),
                );
            }
        }
    }

    #[test]
    fn vector_engine_edge_cases() {
        let pool = ThreadPool::new(4);
        let engine = EngineConfig { chunk: 1000 };
        let mut rng = Pcg::seed(7);
        let mut nan_tau = prop::task_vector_like(&mut rng, 3000);
        nan_tau[100] = f32::NAN;
        nan_tau[2999] = f32::NAN;
        let cases: Vec<(&str, Vec<f32>, f64)> = vec![
            ("empty", Vec::new(), 0.5),
            ("singleton", vec![-0.25], 1.0),
            ("all_zero", vec![0.0; 1024], 0.3),
            ("signed_zero", vec![0.0, -0.0, 1.0, -1.0], 0.5),
            ("all_equal", vec![2.5; 4097], 0.2),
            ("density_one", prop::task_vector_like(&mut rng, 5000), 1.0),
            ("tiny_k_keep_one", prop::task_vector_like(&mut rng, 4096), 1e-9),
            ("nan_entries", nan_tau, 0.1),
        ];
        for (name, tau, k) in &cases {
            let cfg = CompressConfig { density: *k, alpha: 1.0, ..Default::default() };
            let serial = compress_vector(tau, &cfg);
            let par = par_compress_vector_cfg(tau, &cfg, &pool, &engine);
            assert_ternary_bit_identical(&serial, &par, name);
        }
        // Spot-check the contracts behind two of the edge cases.
        let keep_one = compress_vector(
            &prop::task_vector_like(&mut rng, 4096),
            &CompressConfig { density: 1e-9, ..Default::default() },
        );
        assert_eq!(keep_one.nnz(), 1, "⌈k·d⌉ = 1 keeps exactly one entry");
        let dense_all = compress_vector(
            &[1.0f32, -2.0, 3.0, -4.0],
            &CompressConfig { density: 1.0, ..Default::default() },
        );
        assert_eq!(dense_all.nnz(), 4, "k = 1.0 keeps every nonzero");
    }

    fn sample_paramset(rng: &mut Pcg, tensors: usize) -> ParamSet {
        let mut p = ParamSet::new();
        for i in 0..tensors {
            let n = 1000 + i * 997;
            p.insert(
                &format!("layer.{i}.w"),
                Tensor::new(vec![n], prop::task_vector_like(rng, n)),
            );
        }
        p
    }

    #[test]
    fn paramset_engine_matches_serial_both_granularities() {
        let mut rng = Pcg::seed(55);
        for tensors in [0usize, 1, 7] {
            let tv = sample_paramset(&mut rng, tensors);
            for granularity in [Granularity::Global, Granularity::PerTensor] {
                let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity };
                let serial = compress_params(&tv, &cfg);
                for workers in [1usize, 2, 8] {
                    let pool = ThreadPool::new(workers);
                    let par = par_compress_paramset(&tv, &cfg, &pool);
                    assert_compressed_bit_identical(
                        &serial,
                        &par,
                        &format!("{granularity:?} tensors={tensors} workers={workers}"),
                    );
                }
            }
        }
    }

    #[test]
    fn empty_and_single_tensor_paramsets() {
        let pool = ThreadPool::new(2);
        let cfg = CompressConfig::default();
        let empty = ParamSet::new();
        let c = par_compress_paramset(&empty, &cfg, &pool);
        assert_eq!(c.total_elements(), 0);
        assert_compressed_bit_identical(&compress_params(&empty, &cfg), &c, "empty");

        let mut rng = Pcg::seed(3);
        let single = sample_paramset(&mut rng, 1);
        let c = par_compress_paramset(&single, &cfg, &pool);
        assert_compressed_bit_identical(&compress_params(&single, &cfg), &c, "single");
        assert_eq!(c.layout.len(), 1);
    }
}
