//! Parallel chunked compression engine.
//!
//! Algorithm 1 over a 200M–65B-parameter task vector is dominated by
//! three linear passes: σ(τ), the top-⌈k·d⌉ magnitude selection, and the
//! kept-index emission. This module runs all three as chunked passes on
//! a [`ThreadPool`]:
//!
//! 1. **σ(τ)** — per-[`crate::util::stats::MOMENT_BLOCK`] Welford
//!    moments on the pool, merged in block order
//!    ([`crate::util::stats::par_blocked_moments`]).
//! 2. **Global top-k** — per-chunk histograms over the u32 magnitude
//!    keys feed an exact single-bucket quickselect refine
//!    ([`crate::compeft::sparsify::par_topk_by_magnitude`]).
//! 3. **Emission** — per-chunk scans concatenated in chunk order, so
//!    the plus/minus index lists come out sorted without a sort.
//!
//! Outputs are **bit-identical** to the serial
//! [`compress_vector`]/[`compress_params`] path at every worker count
//! and chunk size: the threshold is an exact order statistic (a value,
//! not a partition artifact), emission reuses the serial float
//! comparisons (NaN/±0/tie semantics included), and the σ merge tree is
//! fixed by block size rather than by worker assignment. The
//! equivalence is asserted across pool sizes and chunk sizes in this
//! module's tests and re-checked end-to-end in `tests/integration.rs`.
//!
//! [`Granularity::PerTensor`] parallelises across tensors instead (one
//! serial compression per tensor on the pool) — never both levels at
//! once, which keeps [`ThreadPool::scoped_map`] free of nested waits.
//!
//! **Decode mirror (PR 2).** The serving path runs the same three-pass
//! story in reverse on every GPU-tier miss: wire decode
//! ([`crate::compeft::format::from_bytes_par`] over v2 payload frames),
//! dense materialization ([`par_decompress_params`] — chunked
//! [`TernaryVector::fill_dense_range`] scatters into per-tensor
//! buffers), and adapter application ([`par_add_assign`]). Each is
//! bit-identical to its serial counterpart at any worker count and
//! chunk size, for the same reason the encode side is: chunks partition
//! the index space in order, each chunk runs the serial loop, and
//! per-element float ops happen exactly once in the same order.

use crate::compeft::compress::{
    compress_vector, CompressConfig, CompressedParamSet, Granularity,
};
use crate::compeft::sparsify::par_topk_by_magnitude;
use crate::compeft::ternary::TernaryVector;
use crate::tensor::{ParamSet, Tensor};
use crate::util::pool::ThreadPool;
use crate::util::stats::par_blocked_std_f32;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Default work-division chunk: 64K elements ≈ 256 KB of f32 per task —
/// small enough to load-balance a 4M-element τ across 8 workers ~8× per
/// pass, large enough that per-task overhead (one boxed closure + one
/// channel send) is noise.
pub const DEFAULT_CHUNK: usize = 1 << 16;

/// Tuning knobs for the parallel engine. Only affects how work is
/// divided, never what is computed.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Elements per parallel task in the top-k and emission passes.
    pub chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { chunk: DEFAULT_CHUNK }
    }
}

/// Parallel [`compress_vector`]: bit-identical output, chunked across
/// `pool` with the default chunk size.
pub fn par_compress_vector(
    tau: &[f32],
    cfg: &CompressConfig,
    pool: &ThreadPool,
) -> TernaryVector {
    par_compress_vector_cfg(tau, cfg, pool, &EngineConfig::default())
}

/// Parallel [`compress_vector`] with explicit engine tuning.
pub fn par_compress_vector_cfg(
    tau: &[f32],
    cfg: &CompressConfig,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> TernaryVector {
    if tau.is_empty() {
        return TernaryVector::empty(0);
    }
    let sigma = par_blocked_std_f32(tau, pool);
    let split = par_topk_by_magnitude(tau, cfg.density, pool, engine.chunk);
    TernaryVector {
        len: tau.len(),
        scale: (cfg.alpha * sigma) as f32,
        plus: split.plus,
        minus: split.minus,
    }
}

/// Parallel [`compress_params`](crate::compeft::compress::compress_params):
/// bit-identical output.
///
/// * [`Granularity::Global`] flattens once, then runs the chunked
///   engine over the single global τ.
/// * [`Granularity::PerTensor`] compresses tensors concurrently, one
///   serial [`compress_vector`] per tensor.
pub fn par_compress_paramset(
    tv: &ParamSet,
    cfg: &CompressConfig,
    pool: &ThreadPool,
) -> CompressedParamSet {
    par_compress_paramset_cfg(tv, cfg, pool, &EngineConfig::default())
}

/// Parallel paramset compression with explicit engine tuning.
pub fn par_compress_paramset_cfg(
    tv: &ParamSet,
    cfg: &CompressConfig,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> CompressedParamSet {
    let mut layout = Vec::new();
    let mut off = 0usize;
    for (name, t) in tv.iter() {
        layout.push((name.to_string(), t.shape.clone(), off));
        off += t.len();
    }
    let mut parts = BTreeMap::new();
    match cfg.granularity {
        Granularity::Global => {
            let flat = tv.flatten();
            parts.insert(
                String::new(),
                par_compress_vector_cfg(&flat, cfg, pool, engine),
            );
        }
        Granularity::PerTensor => {
            let items: Vec<(&str, &crate::tensor::Tensor)> = tv.iter().collect();
            let compressed = pool.scoped_map(items, |(name, t)| {
                (name.to_string(), compress_vector(&t.data, cfg))
            });
            for (name, tern) in compressed {
                parts.insert(name, tern);
            }
        }
    }
    CompressedParamSet { granularity: cfg.granularity, layout, parts }
}

/// Parallel
/// [`decompress_params`](crate::compeft::compress::decompress_params):
/// bit-identical output, default chunk size.
pub fn par_decompress_params(
    c: &CompressedParamSet,
    like: &ParamSet,
    pool: &ThreadPool,
) -> Result<ParamSet> {
    par_decompress_params_cfg(c, like, pool, &EngineConfig::default())
}

/// Parallel decompression with explicit engine tuning.
///
/// Materializes one dense buffer per tensor of `like` and scatters
/// `±scale` into it chunk by chunk
/// ([`TernaryVector::fill_dense_range`]), skipping the serial path's
/// intermediate flat vector. [`Granularity::Global`] indexes the single
/// part with each tensor's global offset; `PerTensor` indexes each
/// tensor's own part from zero. One pool pass over all (tensor × chunk)
/// tasks — never nested.
pub fn par_decompress_params_cfg(
    c: &CompressedParamSet,
    like: &ParamSet,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> Result<ParamSet> {
    // One output buffer per tensor of `like`, tied to the ternary part
    // it scatters from and the tensor's offset within that part.
    struct DecodeBuf<'a> {
        name: String,
        shape: Vec<usize>,
        data: Vec<f32>,
        tern: &'a TernaryVector,
        offset: usize,
    }

    let chunk = engine.chunk.max(1);
    let mut bufs: Vec<DecodeBuf<'_>> = Vec::with_capacity(like.len());
    match c.granularity {
        Granularity::Global => {
            let tern = c
                .parts
                .get("")
                .ok_or_else(|| anyhow::anyhow!("missing global part"))?;
            if tern.len != like.total_elements() {
                bail!(
                    "flat length {} != total elements {}",
                    tern.len,
                    like.total_elements()
                );
            }
            let mut off = 0usize;
            for (name, t) in like.iter() {
                bufs.push(DecodeBuf {
                    name: name.to_string(),
                    shape: t.shape.clone(),
                    data: vec![0.0; t.len()],
                    tern,
                    offset: off,
                });
                off += t.len();
            }
        }
        Granularity::PerTensor => {
            for (name, t) in like.iter() {
                let tern = c
                    .parts
                    .get(name)
                    .ok_or_else(|| anyhow::anyhow!("missing part {name:?}"))?;
                if tern.len != t.len() {
                    bail!(
                        "part {name:?}: ternary length {} != tensor length {}",
                        tern.len,
                        t.len()
                    );
                }
                bufs.push(DecodeBuf {
                    name: name.to_string(),
                    shape: t.shape.clone(),
                    data: vec![0.0; t.len()],
                    tern,
                    offset: 0,
                });
            }
        }
    }

    let mut tasks: Vec<(&TernaryVector, usize, &mut [f32])> = Vec::new();
    for b in bufs.iter_mut() {
        let mut s = 0usize;
        for piece in b.data.chunks_mut(chunk) {
            tasks.push((b.tern, b.offset + s, piece));
            s += piece.len();
        }
    }
    pool.scoped_map(tasks, |(tern, start, dst)| tern.fill_dense_range(start, dst));

    let mut out = ParamSet::new();
    for b in bufs {
        out.insert(&b.name, Tensor::new(b.shape, b.data));
    }
    Ok(out)
}

/// Parallel [`ParamSet::add_assign`]: bit-identical result.
///
/// The serving materialization step (`adapter = init + τ̃`, or
/// `params = base + τ̃` for full-FT experts) is a pure element-wise add;
/// chunked across the pool every element is still added exactly once,
/// so the result equals the serial loop's bit for bit. Error behavior
/// is strictly cleaner than serial: a delta name missing from `dst`
/// fails *before* anything is mutated (the serial loop may have applied
/// earlier tensors already); a shape mismatch panics like
/// [`Tensor::add_assign`] does.
pub fn par_add_assign(
    dst: &mut ParamSet,
    delta: &ParamSet,
    pool: &ThreadPool,
) -> Result<()> {
    par_add_assign_cfg(dst, delta, pool, &EngineConfig::default())
}

/// Parallel add-assign with explicit engine tuning.
pub fn par_add_assign_cfg(
    dst: &mut ParamSet,
    delta: &ParamSet,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> Result<()> {
    for (name, _) in delta.iter() {
        if dst.get(name).is_none() {
            bail!("parameter {name:?} missing in target");
        }
    }
    let chunk = engine.chunk.max(1);
    let mut tasks: Vec<(&mut [f32], &[f32])> = Vec::new();
    for (name, mine) in dst.iter_mut() {
        if let Some(d) = delta.get(name) {
            assert_eq!(mine.shape, d.shape, "shape mismatch in add_assign");
            for (dc, sc) in mine.data.chunks_mut(chunk).zip(d.data.chunks(chunk)) {
                tasks.push((dc, sc));
            }
        }
    }
    pool.scoped_map(tasks, |(d, s)| {
        for (a, b) in d.iter_mut().zip(s) {
            *a += *b;
        }
    });
    Ok(())
}

/// Chunk-parallel ternary-domain merge
/// ([`crate::merging::ternary::merge_ternary`] on the pool): TIES,
/// averaging, task arithmetic, or weighted (LoraHub) composition of N
/// compressed experts, bit-identical to the dense
/// decompress-then-merge reference at any worker count and chunk size.
///
/// The [`MergePlan`](crate::merging::ternary::MergePlan) does all
/// global work up front (layout validation, TIES trim thresholds); the
/// pool then computes disjoint output chunks, each replaying the dense
/// per-coordinate operation sequence over the experts' supports. Peak
/// memory is O(d + workers·chunk) — the dense path materializes all N
/// experts at O(N·d).
pub fn par_merge(
    experts: &[&crate::compeft::compress::CompressedParamSet],
    method: &crate::merging::MergeMethod,
    pool: &ThreadPool,
) -> Result<ParamSet> {
    par_merge_cfg(experts, method, pool, &EngineConfig::default())
}

/// [`par_merge`] with explicit engine tuning.
pub fn par_merge_cfg(
    experts: &[&crate::compeft::compress::CompressedParamSet],
    method: &crate::merging::MergeMethod,
    pool: &ThreadPool,
    engine: &EngineConfig,
) -> Result<ParamSet> {
    let plan = crate::merging::ternary::MergePlan::new(experts, method)?;
    let chunk = engine.chunk.max(1);
    let mut flat = vec![0.0f32; plan.d()];
    let mut tasks: Vec<(usize, &mut [f32])> = Vec::new();
    let mut start = 0usize;
    for piece in flat.chunks_mut(chunk) {
        let len = piece.len();
        tasks.push((start, piece));
        start += len;
    }
    pool.scoped_map(tasks, |(s, out)| plan.run_chunk(s, out));
    Ok(plan.into_paramset(flat))
}

// -- ternary version deltas -------------------------------------------------

/// A ternary delta between two versions of one compressed expert —
/// ComPEFT's own compress-the-residual trick applied to its update
/// stream. `removals` holds the v(n) support entries absent (by sign)
/// from v(n+1), carried at the **old** scale; `additions` holds the
/// v(n+1) entries absent from v(n), carried at the **new** scale. The
/// additions part always ships the new `α·σ` scale even when its index
/// lists are empty, so scale-only re-calibrations are expressible as a
/// near-zero-byte delta. [`apply_delta`] on resident v(n) reconstructs
/// v(n+1) **bit-identically** — supports are exact set algebra and the
/// scale is copied, never recomputed.
#[derive(Clone, Debug)]
pub struct ExpertDelta {
    pub removals: CompressedParamSet,
    pub additions: CompressedParamSet,
}

impl ExpertDelta {
    /// Total support entries the delta touches (removed + added).
    pub fn nnz(&self) -> usize {
        self.removals.nnz() + self.additions.nnz()
    }

    /// Wire-serialize via the `.cpeft` delta container
    /// ([`crate::compeft::format::delta_to_bytes`]).
    pub fn to_bytes(&self, enc: crate::compeft::format::Encoding) -> Vec<u8> {
        crate::compeft::format::delta_to_bytes(&self.removals, &self.additions, enc)
    }

    /// Parse a `.cpeft` delta container back
    /// ([`crate::compeft::format::delta_from_bytes`]).
    pub fn from_bytes(
        bytes: &[u8],
    ) -> Result<(ExpertDelta, crate::compeft::format::Encoding)> {
        let (removals, additions, enc) =
            crate::compeft::format::delta_from_bytes(bytes)?;
        Ok((ExpertDelta { removals, additions }, enc))
    }
}

/// `a \ b` over sorted unique index lists (one merge walk).
fn sorted_difference(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut j = 0usize;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            out.push(x);
        }
    }
    out
}

/// `a ∪ b` over sorted unique lists; errors on a duplicate — a delta
/// that re-adds an index already present is malformed, and a silent
/// dedup would hide the corruption.
fn sorted_union(a: &[u32], b: &[u32]) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    loop {
        match (a.get(i), b.get(j)) {
            (None, None) => break,
            (Some(&x), Some(&y)) if x == y => bail!("delta re-adds index {x}"),
            (Some(&x), Some(&y)) if x < y => {
                out.push(x);
                i += 1;
            }
            (Some(&x), None) => {
                out.push(x);
                i += 1;
            }
            (_, Some(&y)) => {
                out.push(y);
                j += 1;
            }
        }
    }
    Ok(out)
}

/// Shared shape validation for [`compress_delta`] / [`apply_delta`]:
/// two paramsets describe versions of the *same* expert only if their
/// granularity, layout, part names, and per-part lengths all agree.
fn check_same_shape(
    old: &CompressedParamSet,
    new: &CompressedParamSet,
    what: &str,
) -> Result<()> {
    if old.granularity != new.granularity {
        bail!("{what}: granularity changed between versions");
    }
    if old.layout != new.layout {
        bail!("{what}: tensor layout changed between versions");
    }
    let old_names: Vec<&String> = old.parts.keys().collect();
    let new_names: Vec<&String> = new.parts.keys().collect();
    if old_names != new_names {
        bail!("{what}: part set changed between versions");
    }
    for (name, o) in &old.parts {
        let n = &new.parts[name];
        if o.len != n.len {
            bail!("{what}: part {name:?} length changed {} -> {}", o.len, n.len);
        }
    }
    Ok(())
}

/// Diff two versions of one compressed expert into an [`ExpertDelta`]:
/// per part, the removal lists are `old \ new` by sign at the old
/// scale, the addition lists `new \ old` by sign at the new scale. A
/// sign flip appears as one removal plus one addition; α·σ re-scaling
/// rides on the additions part's scale for free. Pure set algebra — no
/// float recomputation — so [`apply_delta`] reconstructs v(n+1) bit for
/// bit.
pub fn compress_delta(
    old: &CompressedParamSet,
    new: &CompressedParamSet,
) -> Result<ExpertDelta> {
    check_same_shape(old, new, "compress_delta")?;
    let mut removals = BTreeMap::new();
    let mut additions = BTreeMap::new();
    for (name, o) in &old.parts {
        let n = &new.parts[name];
        removals.insert(
            name.clone(),
            TernaryVector {
                len: o.len,
                scale: o.scale,
                plus: sorted_difference(&o.plus, &n.plus),
                minus: sorted_difference(&o.minus, &n.minus),
            },
        );
        additions.insert(
            name.clone(),
            TernaryVector {
                len: n.len,
                scale: n.scale,
                plus: sorted_difference(&n.plus, &o.plus),
                minus: sorted_difference(&n.minus, &o.minus),
            },
        );
    }
    Ok(ExpertDelta {
        removals: CompressedParamSet {
            granularity: old.granularity,
            layout: old.layout.clone(),
            parts: removals,
        },
        additions: CompressedParamSet {
            granularity: new.granularity,
            layout: new.layout.clone(),
            parts: additions,
        },
    })
}

/// Apply an [`ExpertDelta`] to resident v(n), reconstructing v(n+1) in
/// the ternary domain: per part,
/// `new.plus = (old.plus \ removals.plus) ∪ additions.plus` (same for
/// minus) and the scale becomes the additions part's scale. The result
/// is validated (sorted, in-range, disjoint signs), so a hostile or
/// mismatched delta errors instead of producing a silently corrupt
/// expert.
pub fn apply_delta(
    old: &CompressedParamSet,
    delta: &ExpertDelta,
) -> Result<CompressedParamSet> {
    check_same_shape(old, &delta.removals, "apply_delta(removals)")?;
    check_same_shape(old, &delta.additions, "apply_delta(additions)")?;
    let mut parts = BTreeMap::new();
    for (name, o) in &old.parts {
        let rm = &delta.removals.parts[name];
        let ad = &delta.additions.parts[name];
        let out = TernaryVector {
            len: o.len,
            scale: ad.scale,
            plus: sorted_union(&sorted_difference(&o.plus, &rm.plus), &ad.plus)?,
            minus: sorted_union(&sorted_difference(&o.minus, &rm.minus), &ad.minus)?,
        };
        out.validate()
            .map_err(|e| anyhow::anyhow!("apply_delta: part {name:?}: {e}"))?;
        parts.insert(name.clone(), out);
    }
    Ok(CompressedParamSet {
        granularity: old.granularity,
        layout: old.layout.clone(),
        parts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::compress_params;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    pub(crate) fn assert_ternary_bit_identical(
        a: &TernaryVector,
        b: &TernaryVector,
        tag: &str,
    ) {
        assert_eq!(a.len, b.len, "{tag}: len");
        assert_eq!(
            a.scale.to_bits(),
            b.scale.to_bits(),
            "{tag}: scale {} vs {}",
            a.scale,
            b.scale
        );
        assert_eq!(a.plus, b.plus, "{tag}: plus");
        assert_eq!(a.minus, b.minus, "{tag}: minus");
    }

    fn assert_compressed_bit_identical(
        a: &CompressedParamSet,
        b: &CompressedParamSet,
        tag: &str,
    ) {
        assert_eq!(a.granularity, b.granularity, "{tag}");
        assert_eq!(a.layout, b.layout, "{tag}: layout");
        let names_a: Vec<&String> = a.parts.keys().collect();
        let names_b: Vec<&String> = b.parts.keys().collect();
        assert_eq!(names_a, names_b, "{tag}: part names");
        for (name, ta) in &a.parts {
            assert_ternary_bit_identical(ta, &b.parts[name], &format!("{tag}/{name}"));
        }
    }

    #[test]
    fn vector_engine_matches_serial_across_pools_and_chunks() {
        let mut rng = Pcg::seed(101);
        let tau = prop::task_vector_like(&mut rng, 200_000);
        let cfg = CompressConfig { density: 0.05, alpha: 2.0, ..Default::default() };
        let serial = compress_vector(&tau, &cfg);
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk in [512usize, 1 << 14, 1 << 16, 1 << 22] {
                let par = par_compress_vector_cfg(
                    &tau,
                    &cfg,
                    &pool,
                    &EngineConfig { chunk },
                );
                assert_ternary_bit_identical(
                    &serial,
                    &par,
                    &format!("workers={workers} chunk={chunk}"),
                );
            }
        }
    }

    #[test]
    fn vector_engine_edge_cases() {
        let pool = ThreadPool::new(4);
        let engine = EngineConfig { chunk: 1000 };
        let mut rng = Pcg::seed(7);
        let mut nan_tau = prop::task_vector_like(&mut rng, 3000);
        nan_tau[100] = f32::NAN;
        nan_tau[2999] = f32::NAN;
        let cases: Vec<(&str, Vec<f32>, f64)> = vec![
            ("empty", Vec::new(), 0.5),
            ("singleton", vec![-0.25], 1.0),
            ("all_zero", vec![0.0; 1024], 0.3),
            ("signed_zero", vec![0.0, -0.0, 1.0, -1.0], 0.5),
            ("all_equal", vec![2.5; 4097], 0.2),
            ("density_one", prop::task_vector_like(&mut rng, 5000), 1.0),
            ("tiny_k_keep_one", prop::task_vector_like(&mut rng, 4096), 1e-9),
            ("nan_entries", nan_tau, 0.1),
        ];
        for (name, tau, k) in &cases {
            let cfg = CompressConfig { density: *k, alpha: 1.0, ..Default::default() };
            let serial = compress_vector(tau, &cfg);
            let par = par_compress_vector_cfg(tau, &cfg, &pool, &engine);
            assert_ternary_bit_identical(&serial, &par, name);
        }
        // Spot-check the contracts behind two of the edge cases.
        let keep_one = compress_vector(
            &prop::task_vector_like(&mut rng, 4096),
            &CompressConfig { density: 1e-9, ..Default::default() },
        );
        assert_eq!(keep_one.nnz(), 1, "⌈k·d⌉ = 1 keeps exactly one entry");
        let dense_all = compress_vector(
            &[1.0f32, -2.0, 3.0, -4.0],
            &CompressConfig { density: 1.0, ..Default::default() },
        );
        assert_eq!(dense_all.nnz(), 4, "k = 1.0 keeps every nonzero");
    }

    fn sample_paramset(rng: &mut Pcg, tensors: usize) -> ParamSet {
        let mut p = ParamSet::new();
        for i in 0..tensors {
            let n = 1000 + i * 997;
            p.insert(
                &format!("layer.{i}.w"),
                Tensor::new(vec![n], prop::task_vector_like(rng, n)),
            );
        }
        p
    }

    #[test]
    fn paramset_engine_matches_serial_both_granularities() {
        let mut rng = Pcg::seed(55);
        for tensors in [0usize, 1, 7] {
            let tv = sample_paramset(&mut rng, tensors);
            for granularity in [Granularity::Global, Granularity::PerTensor] {
                let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity };
                let serial = compress_params(&tv, &cfg);
                for workers in crate::util::prop::pool_sizes() {
                    let pool = ThreadPool::new(workers);
                    let par = par_compress_paramset(&tv, &cfg, &pool);
                    assert_compressed_bit_identical(
                        &serial,
                        &par,
                        &format!("{granularity:?} tensors={tensors} workers={workers}"),
                    );
                }
            }
        }
    }

    use crate::util::prop::assert_paramset_bit_identical;

    #[test]
    fn par_decompress_matches_serial_across_pools_and_chunks() {
        use crate::compeft::compress::decompress_params;
        let mut rng = Pcg::seed(77);
        for tensors in [0usize, 1, 4] {
            let tv = sample_paramset(&mut rng, tensors);
            for granularity in [Granularity::Global, Granularity::PerTensor] {
                let cfg = CompressConfig { density: 0.15, alpha: 2.0, granularity };
                let c = compress_params(&tv, &cfg);
                let serial = decompress_params(&c, &tv).unwrap();
                for workers in crate::util::prop::pool_sizes() {
                    let pool = ThreadPool::new(workers);
                    for chunk in [1usize, 113, 1 << 16] {
                        let par = par_decompress_params_cfg(
                            &c,
                            &tv,
                            &pool,
                            &EngineConfig { chunk },
                        )
                        .unwrap();
                        assert_paramset_bit_identical(
                            &serial,
                            &par,
                            &format!(
                                "{granularity:?} tensors={tensors} \
                                 workers={workers} chunk={chunk}"
                            ),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn par_decompress_error_paths_match_serial() {
        let mut rng = Pcg::seed(81);
        let tv = sample_paramset(&mut rng, 2);
        let pool = ThreadPool::new(2);
        // Missing per-tensor part.
        let cfg = CompressConfig {
            density: 0.2,
            alpha: 1.0,
            granularity: Granularity::PerTensor,
        };
        let mut c = compress_params(&tv, &cfg);
        c.parts.remove("layer.0.w");
        assert!(par_decompress_params(&c, &tv, &pool).is_err());
        // Global length mismatch.
        let cfg = CompressConfig { granularity: Granularity::Global, ..cfg };
        let c = compress_params(&tv, &cfg);
        let smaller = sample_paramset(&mut Pcg::seed(82), 1);
        assert!(par_decompress_params(&c, &smaller, &pool).is_err());
    }

    #[test]
    fn par_add_assign_matches_serial() {
        let mut rng = Pcg::seed(91);
        for tensors in [0usize, 1, 5] {
            let base = sample_paramset(&mut rng, tensors);
            let delta = sample_paramset(&mut Pcg::seed(400 + tensors as u64), tensors);
            let mut serial = base.clone();
            serial.add_assign(&delta).unwrap();
            for workers in crate::util::prop::pool_sizes() {
                let pool = ThreadPool::new(workers);
                for chunk in [1usize, 97, 1 << 16] {
                    let mut par = base.clone();
                    par_add_assign_cfg(&mut par, &delta, &pool, &EngineConfig { chunk })
                        .unwrap();
                    assert_paramset_bit_identical(
                        &serial,
                        &par,
                        &format!("tensors={tensors} workers={workers} chunk={chunk}"),
                    );
                }
            }
        }
    }

    #[test]
    fn par_add_assign_missing_name_fails_before_mutating() {
        let mut rng = Pcg::seed(95);
        let mut dst = sample_paramset(&mut rng, 2);
        let snapshot = dst.clone();
        let mut delta = sample_paramset(&mut Pcg::seed(96), 2);
        delta.insert("not.in.dst", Tensor::new(vec![3], vec![1.0, 2.0, 3.0]));
        let pool = ThreadPool::new(2);
        assert!(par_add_assign(&mut dst, &delta, &pool).is_err());
        assert_eq!(dst, snapshot, "failed add must not partially apply");
    }

    /// Cross-path equivalence for every merge method: the dense
    /// decompress-then-merge reference, the serial ternary-domain path,
    /// and the pooled path agree bit for bit across pools {1, 2, 8} and
    /// several chunk sizes.
    #[test]
    fn par_merge_matches_dense_reference_across_pools_and_chunks() {
        use crate::compeft::compress::decompress_params;
        use crate::merging::ternary::merge_ternary;
        use crate::merging::{merge_dense, MergeMethod};

        let mut rng = Pcg::seed(131);
        let tvs: Vec<ParamSet> =
            (0..3).map(|_| sample_paramset(&mut rng, 3)).collect();
        for granularity in [Granularity::Global, Granularity::PerTensor] {
            let cfg = CompressConfig { density: 0.2, alpha: 1.0, granularity };
            let comps: Vec<_> =
                tvs.iter().map(|tv| compress_params(tv, &cfg)).collect();
            let refs: Vec<&_> = comps.iter().collect();
            let dense: Vec<ParamSet> = comps
                .iter()
                .zip(&tvs)
                .map(|(c, tv)| decompress_params(c, tv).unwrap())
                .collect();
            let methods = [
                ("average", MergeMethod::Average),
                ("ta", MergeMethod::TaskArithmetic { lambda: 0.3 }),
                ("ties", MergeMethod::Ties { density: 0.2, lambda: 1.0 }),
                (
                    "weighted",
                    MergeMethod::Weighted { weights: vec![1.0, -0.5, 0.2] },
                ),
            ];
            for (name, method) in &methods {
                let want = merge_dense(&dense, method).unwrap();
                let serial = merge_ternary(&refs, method).unwrap();
                assert_paramset_bit_identical(
                    &want,
                    &serial,
                    &format!("{granularity:?}/{name}/serial"),
                );
                for workers in crate::util::prop::pool_sizes() {
                    let pool = ThreadPool::new(workers);
                    for chunk in [1usize, 113, 1 << 16] {
                        let par = par_merge_cfg(
                            &refs,
                            method,
                            &pool,
                            &EngineConfig { chunk },
                        )
                        .unwrap();
                        assert_paramset_bit_identical(
                            &want,
                            &par,
                            &format!(
                                "{granularity:?}/{name}/workers={workers} \
                                 chunk={chunk}"
                            ),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn par_merge_error_paths_match_serial() {
        use crate::merging::MergeMethod;
        let mut rng = Pcg::seed(137);
        let tv = sample_paramset(&mut rng, 2);
        let cfg = CompressConfig::default();
        let c = compress_params(&tv, &cfg);
        let other = compress_params(&sample_paramset(&mut rng, 1), &cfg);
        let pool = ThreadPool::new(2);
        // Empty list, layout mismatch, weight-count mismatch.
        assert!(par_merge(&[], &MergeMethod::Average, &pool).is_err());
        assert!(par_merge(&[&c, &other], &MergeMethod::Average, &pool).is_err());
        assert!(par_merge(
            &[&c],
            &MergeMethod::Weighted { weights: vec![1.0, 2.0] },
            &pool
        )
        .is_err());
    }

    /// Perturb ~2% of a paramset's coordinates deterministically: sign
    /// flips with new mass, plus some zeroed entries — the support
    /// shrink/growth and sign-flip cases a fine-tuning round produces.
    fn next_version(tv: &ParamSet) -> ParamSet {
        let mut out = tv.clone();
        for (_, t) in out.iter_mut() {
            let n = t.data.len();
            for k in 0..n / 50 + 1 {
                let i = (k * 97) % n;
                t.data[i] = -t.data[i] * 1.5 + 0.01;
            }
            for k in 0..n / 100 + 1 {
                let i = (k * 131 + 7) % n;
                t.data[i] = 0.0;
            }
        }
        out
    }

    /// `apply_delta(v_n, compress_delta(v_n, v_{n+1}))` reconstructs
    /// the full re-encode of v(n+1) bit for bit — across granularities,
    /// α·σ re-scaling, support shrink/growth, and the empty diff.
    #[test]
    fn delta_reconstructs_next_version_bit_identically() {
        let mut rng = Pcg::seed(2026);
        for granularity in [Granularity::Global, Granularity::PerTensor] {
            let tv = sample_paramset(&mut rng, 3);
            let tv2 = next_version(&tv);
            let cases: [(&str, f64, f64, f64, f64); 4] = [
                ("same-config", 0.1, 1.0, 0.1, 1.0),
                ("rescale", 0.1, 1.0, 0.1, 2.0),
                ("shrink", 0.2, 1.0, 0.05, 1.0),
                ("growth", 0.05, 1.0, 0.2, 1.0),
            ];
            for (name, d_old, a_old, d_new, a_new) in cases {
                let old = compress_params(
                    &tv,
                    &CompressConfig { density: d_old, alpha: a_old, granularity },
                );
                let new = compress_params(
                    &tv2,
                    &CompressConfig { density: d_new, alpha: a_new, granularity },
                );
                let delta = compress_delta(&old, &new).unwrap();
                let got = apply_delta(&old, &delta).unwrap();
                assert_compressed_bit_identical(
                    &new,
                    &got,
                    &format!("{granularity:?}/{name}"),
                );
            }

            // Scale-only update: same τ, new α → both index halves are
            // empty, yet the new scale still rides the delta.
            let old = compress_params(
                &tv,
                &CompressConfig { density: 0.1, alpha: 1.0, granularity },
            );
            let new = compress_params(
                &tv,
                &CompressConfig { density: 0.1, alpha: 2.0, granularity },
            );
            let delta = compress_delta(&old, &new).unwrap();
            assert_eq!(delta.nnz(), 0, "scale-only delta ships no indices");
            let got = apply_delta(&old, &delta).unwrap();
            assert_compressed_bit_identical(&new, &got, "scale-only");

            // Empty diff: identical versions round-trip through a
            // zero-support delta.
            let delta = compress_delta(&old, &old).unwrap();
            assert_eq!(delta.nnz(), 0);
            let got = apply_delta(&old, &delta).unwrap();
            assert_compressed_bit_identical(&old, &got, "empty-diff");
        }
    }

    /// Delta wire container: round-trips bit-identically, rejects any
    /// single bit flip / truncation / bad magic, and at paper-scale
    /// density a small update ships in ≤ 1/4 of a full re-encode.
    #[test]
    fn delta_wire_roundtrips_rejects_corruption_and_stays_small() {
        use crate::compeft::format::{to_bytes, Encoding};
        let mut rng = Pcg::seed(404);
        let mut tv = ParamSet::new();
        tv.insert(
            "w",
            Tensor::new(vec![50_000], prop::task_vector_like(&mut rng, 50_000)),
        );
        let cfg = CompressConfig {
            density: 0.05,
            alpha: 1.0,
            granularity: Granularity::Global,
        };
        let old = compress_params(&tv, &cfg);
        // Flip the sign of 8 known-support coordinates: |τ| is
        // untouched so the support set is stable, but each flip crosses
        // plus → minus, and the shifted mean nudges σ — a guaranteed
        // small, nonempty delta.
        let flips: Vec<u32> = old.parts[""].plus.iter().take(8).copied().collect();
        assert_eq!(flips.len(), 8);
        let mut tv2 = tv.clone();
        let t = tv2.get_mut("w").unwrap();
        for &i in &flips {
            t.data[i as usize] = -t.data[i as usize];
        }
        let new = compress_params(&tv2, &cfg);
        let delta = compress_delta(&old, &new).unwrap();
        assert!(delta.nnz() > 0, "sign flips must produce a nonempty delta");
        let wire = delta.to_bytes(Encoding::Golomb);
        let (back, enc) = ExpertDelta::from_bytes(&wire).unwrap();
        assert_eq!(enc, Encoding::Golomb);
        assert_compressed_bit_identical(&delta.removals, &back.removals, "wire/rm");
        assert_compressed_bit_identical(&delta.additions, &back.additions, "wire/ad");
        assert_compressed_bit_identical(
            &new,
            &apply_delta(&old, &back).unwrap(),
            "wire/apply",
        );

        let full = to_bytes(&new, Encoding::Golomb);
        assert!(
            wire.len() * 4 <= full.len(),
            "delta wire {} bytes vs full re-encode {} bytes",
            wire.len(),
            full.len()
        );

        for i in [0usize, 5, wire.len() / 2, wire.len() - 1] {
            let mut bad = wire.clone();
            bad[i] ^= 1;
            assert!(ExpertDelta::from_bytes(&bad).is_err(), "bit flip at {i}");
        }
        assert!(ExpertDelta::from_bytes(&wire[..wire.len() - 3]).is_err());
        assert!(ExpertDelta::from_bytes(b"CPFDxxxxxxxxxxxxxx").is_err());
    }

    /// Version-shape mismatches and hostile deltas error instead of
    /// silently corrupting the resident expert.
    #[test]
    fn delta_shape_mismatches_and_hostile_deltas_error() {
        let mut rng = Pcg::seed(11);
        let a = sample_paramset(&mut rng, 2);
        let b = sample_paramset(&mut rng, 1);
        let cfg = CompressConfig::default();
        let ca = compress_params(&a, &cfg);
        let cb = compress_params(&b, &cfg);
        assert!(compress_delta(&ca, &cb).is_err(), "layout mismatch");
        let per = compress_params(
            &a,
            &CompressConfig {
                granularity: Granularity::PerTensor,
                ..CompressConfig::default()
            },
        );
        assert!(compress_delta(&ca, &per).is_err(), "granularity mismatch");
        // Applying a delta to the wrong base is a shape error.
        let d = compress_delta(&ca, &ca).unwrap();
        assert!(apply_delta(&cb, &d).is_err());
        // A delta that re-adds already-present support is rejected.
        let mut bad = compress_delta(&ca, &ca).unwrap();
        let present = ca.parts.values().next().unwrap().plus.clone();
        assert!(!present.is_empty());
        bad.additions.parts.values_mut().next().unwrap().plus = present;
        assert!(apply_delta(&ca, &bad).is_err(), "duplicate add must fail");
    }

    #[test]
    fn empty_and_single_tensor_paramsets() {
        let pool = ThreadPool::new(2);
        let cfg = CompressConfig::default();
        let empty = ParamSet::new();
        let c = par_compress_paramset(&empty, &cfg, &pool);
        assert_eq!(c.total_elements(), 0);
        assert_compressed_bit_identical(&compress_params(&empty, &cfg), &c, "empty");

        let mut rng = Pcg::seed(3);
        let single = sample_paramset(&mut rng, 1);
        let c = par_compress_paramset(&single, &cfg, &pool);
        assert_compressed_bit_identical(&compress_params(&single, &cfg), &c, "single");
        assert_eq!(c.layout.len(), 1);
    }
}
