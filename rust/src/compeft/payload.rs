//! Zero-copy views of encoded expert payload bytes.
//!
//! Every layer of the serve path used to hand encoded checkpoint bytes
//! around as owned `Vec<u8>`s: the store copied each stripe off the
//! source buffer, reassembly concatenated the copies, the host tier
//! held yet another `Arc<Vec<u8>>`, and the fp16 decode path cloned the
//! whole buffer once more. None of those copies changed a byte — the
//! decode readers ([`crate::compeft::format::from_bytes`] /
//! [`from_bytes_par`](crate::compeft::format::from_bytes_par)) only
//! ever *borrow* `&[u8]`. [`Payload`] makes the borrow first-class: a
//! cheaply clonable view `(backing, start, len)` over either
//!
//! * **owned** bytes (`Arc<Vec<u8>>` — a fetched buffer, shared not
//!   copied), or
//! * a **mapped** region (an [`PayloadBacking`] such as the archive
//!   tier's simulated page cache, where the bytes stay resident in one
//!   big buffer and every expert is a sub-range view).
//!
//! `Payload` derefs to `&[u8]`, so every existing `&[u8]` consumer —
//! the container readers, the parallel decode engine, the CRC — reads
//! straight out of the view with zero further allocation. Sub-ranges
//! ([`Payload::slice`]) re-slice the same backing (stripes of one
//! fetch, members of one archive), and bounds are validated at
//! construction so deref can never panic.
//!
//! [`CopyMeter`] is the refactor's regression guard: every place that
//! still materializes encoded payload bytes into fresh heap memory
//! (the one unavoidable read off disk/remote, plus any fallback
//! concatenation) counts itself, surfacing as the `payload_copies`
//! metric. An archive-resident serve must count **zero**.

use anyhow::{bail, Result};
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A stable byte region a [`Payload`] can view without owning it — the
/// archive tier's simulated page cache implements this so expert
/// payloads are served as in-place views of the resident file image.
///
/// Contract: `as_bytes` must return the **same** slice (same address,
/// same length) for the lifetime of the backing. Views validate their
/// range once at construction and deref without re-checking.
pub trait PayloadBacking: Send + Sync {
    fn as_bytes(&self) -> &[u8];
}

impl PayloadBacking for Vec<u8> {
    fn as_bytes(&self) -> &[u8] {
        self
    }
}

/// What a [`Payload`] borrows from.
#[derive(Clone)]
enum Backing {
    /// Shared owned bytes (a fetched buffer).
    Owned(Arc<Vec<u8>>),
    /// A region of some longer-lived mapping (archive page cache).
    Mapped(Arc<dyn PayloadBacking>),
}

impl Backing {
    fn bytes(&self) -> &[u8] {
        match self {
            Backing::Owned(v) => v,
            Backing::Mapped(m) => m.as_bytes(),
        }
    }
}

/// A zero-copy, cheaply clonable view of encoded payload bytes.
///
/// Cloning bumps a refcount; slicing narrows the window over the same
/// backing. The backing stays alive as long as any view of it does, so
/// handing a view out of a cache tier — or evicting the tier entry
/// while a decode still holds a view — can never invalidate the bytes.
#[derive(Clone)]
pub struct Payload {
    backing: Backing,
    start: usize,
    len: usize,
}

impl Payload {
    /// View over a freshly materialized buffer (takes ownership; the
    /// buffer is shared from here on, never copied again).
    pub fn from_vec(bytes: Vec<u8>) -> Payload {
        Payload::from_arc(Arc::new(bytes))
    }

    /// View over already-shared owned bytes.
    pub fn from_arc(bytes: Arc<Vec<u8>>) -> Payload {
        let len = bytes.len();
        Payload { backing: Backing::Owned(bytes), start: 0, len }
    }

    /// View of `[start, start+len)` inside a mapped backing (archive
    /// region). Bounds are validated here, once, so deref cannot panic.
    pub fn mapped(
        backing: Arc<dyn PayloadBacking>,
        start: usize,
        len: usize,
    ) -> Result<Payload> {
        let total = backing.as_bytes().len();
        match start.checked_add(len) {
            Some(end) if end <= total => {
                Ok(Payload { backing: Backing::Mapped(backing), start, len })
            }
            _ => bail!("payload view [{start}, {start}+{len}) outside backing of {total} bytes"),
        }
    }

    /// Re-slice this view to `[start, start+len)` **relative to the
    /// view** — same backing, narrower window, no copy. Works on every
    /// variant (a stripe of a fetched buffer, a member of an archive).
    pub fn slice(&self, start: usize, len: usize) -> Result<Payload> {
        match start.checked_add(len) {
            Some(end) if end <= self.len => Ok(Payload {
                backing: self.backing.clone(),
                start: self.start + start,
                len,
            }),
            _ => bail!(
                "sub-view [{start}, {start}+{len}) outside payload of {} bytes",
                self.len
            ),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The viewed bytes. (Also available through `Deref`, so a
    /// `&Payload` coerces to `&[u8]` wherever one is expected.)
    pub fn as_slice(&self) -> &[u8] {
        // compeft-lint: allow(no-panic-in-parse) -- range validated once at view construction
        &self.backing.bytes()[self.start..self.start + self.len]
    }
}

impl Deref for Payload {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.backing {
            Backing::Owned(_) => "owned",
            Backing::Mapped(_) => "mapped",
        };
        write!(f, "Payload({kind}, start={}, len={})", self.start, self.len)
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Payload) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Payload {}

impl PartialEq<[u8]> for Payload {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<u8>> for Payload {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Shared counter of encoded-payload heap copies — the zero-copy
/// refactor's regression guard, surfaced as the `payload_copies`
/// metric. Each copy *event* (a buffer materialized from disk/remote,
/// a fallback reassembly concatenation) counts once; views, clones,
/// and slices count nothing. Cloning the meter shares the counter
/// (one meter per engine, handed to its loader and store), so
/// concurrently running engines/tests never contaminate each other —
/// deliberately not a process-global.
#[derive(Clone, Debug, Default)]
pub struct CopyMeter(Arc<AtomicU64>);

impl CopyMeter {
    pub fn new() -> CopyMeter {
        CopyMeter::default()
    }

    /// Count `copies` heap materializations of encoded payload bytes.
    pub fn record(&self, copies: u64) {
        self.0.fetch_add(copies, Ordering::Relaxed);
    }

    /// Copies counted so far (across every clone of this meter).
    pub fn count(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_view_derefs_and_slices_without_copy() {
        let data: Vec<u8> = (0..100u8).collect();
        let p = Payload::from_vec(data.clone());
        assert_eq!(p.len(), 100);
        assert_eq!(&*p, &data[..]);
        assert_eq!(p, data, "PartialEq<Vec<u8>>");

        // A sub-view shares the backing: same underlying addresses.
        let s = p.slice(10, 20).unwrap();
        assert_eq!(&*s, &data[10..30]);
        assert_eq!(s.as_slice().as_ptr(), unsafe { p.as_slice().as_ptr().add(10) });

        // Re-slicing a slice composes offsets.
        let ss = s.slice(5, 5).unwrap();
        assert_eq!(&*ss, &data[15..20]);

        // Clones are views too, not copies.
        let c = p.clone();
        assert_eq!(c.as_slice().as_ptr(), p.as_slice().as_ptr());
    }

    #[test]
    fn out_of_range_views_fail_at_construction_never_at_deref() {
        let p = Payload::from_vec(vec![0u8; 16]);
        assert!(p.slice(10, 7).is_err());
        assert!(p.slice(17, 0).is_err());
        assert!(p.slice(usize::MAX, 2).is_err(), "overflowing range must not wrap");
        assert!(p.slice(16, 0).is_ok(), "empty view at the end is fine");
        assert!(p.slice(16, 0).unwrap().is_empty());

        let backing: Arc<dyn PayloadBacking> = Arc::new(vec![1u8; 8]);
        assert!(Payload::mapped(Arc::clone(&backing), 6, 3).is_err());
        let m = Payload::mapped(backing, 2, 4).unwrap();
        assert_eq!(&*m, &[1u8, 1, 1, 1]);
    }

    #[test]
    fn mapped_views_read_in_place_from_the_backing() {
        struct Region(Vec<u8>);
        impl PayloadBacking for Region {
            fn as_bytes(&self) -> &[u8] {
                &self.0
            }
        }
        let region = Arc::new(Region((0..64u8).collect()));
        let a = Payload::mapped(Arc::clone(&region) as Arc<dyn PayloadBacking>, 0, 32)
            .unwrap();
        let b = Payload::mapped(region.clone() as Arc<dyn PayloadBacking>, 32, 32)
            .unwrap();
        // Adjacent views of one backing are contiguous in memory — the
        // property the store's zero-copy stripe reassembly relies on.
        assert_eq!(
            unsafe { a.as_slice().as_ptr().add(a.len()) },
            b.as_slice().as_ptr()
        );
        assert_eq!(&*b, &region.0[32..]);

        // The backing survives as long as any view does.
        drop(region);
        assert_eq!(a[5], 5);
    }

    #[test]
    fn views_outlive_their_source_handles() {
        // The cache-eviction scenario: the tier drops its entry while a
        // decode still holds a view — the bytes must stay valid.
        let held;
        {
            let p = Payload::from_vec(vec![7u8; 1024]);
            held = p.slice(100, 24).unwrap();
        } // p (the "tier entry") dropped here
        assert_eq!(&*held, &[7u8; 24][..]);
    }

    #[test]
    fn copy_meter_is_shared_across_clones() {
        let m = CopyMeter::new();
        let m2 = m.clone();
        m.record(1);
        m2.record(2);
        assert_eq!(m.count(), 3);
        assert_eq!(m2.count(), 3);
        assert_eq!(CopyMeter::new().count(), 0, "fresh meters are independent");
    }
}
