//! Step 1 of ComPEFT (Algorithm 1): magnitude-based top-k sparsification.
//!
//! Given a task vector `τ` and a density `k` (fraction in (0,1]), keep
//! the signs of the top-⌈k·d⌉ entries by |τ| and zero the rest. We find
//! the k-th largest magnitude with an in-place quickselect (O(d)
//! expected) instead of a full sort — the dominant cost of compression
//! at the 10⁷-parameter scale.

/// Indices of the top-k-by-magnitude entries, split by sign.
#[derive(Clone, Debug, PartialEq)]
pub struct TopKSplit {
    /// Sorted indices of kept entries with τ > 0.
    pub plus: Vec<u32>,
    /// Sorted indices of kept entries with τ < 0.
    pub minus: Vec<u32>,
    /// The magnitude threshold actually used (kept iff |τ| >= threshold,
    /// with ties broken toward keeping exactly ⌈k·d⌉ entries).
    pub threshold: f32,
}

/// Number of entries to keep for a density `k` over `d` elements.
pub fn keep_count(d: usize, k: f64) -> usize {
    assert!(k > 0.0 && k <= 1.0, "density must be in (0,1], got {k}");
    ((d as f64 * k).ceil() as usize).min(d)
}

/// Quickselect the `n`-th largest magnitude (0-based).
///
/// Perf (§Perf L3 iteration 1): |x| viewed as IEEE-754 bits is a
/// monotone u32 key (sign bit cleared ⇒ integer order == float order),
/// so we select on u32 — no `partial_cmp` closure, branch-free
/// comparisons, ~2.4x faster end-to-end Algorithm 1 on 4M params.
fn select_nth_largest_mag(tau: &[f32], n: usize) -> f32 {
    let mut keys: Vec<u32> = tau.iter().map(|x| x.to_bits() & 0x7FFF_FFFF).collect();
    let idx = keys.len() - 1 - n;
    let (_, pivot, _) = keys.select_nth_unstable(idx);
    f32::from_bits(*pivot)
}

/// Apply top-k sparsification to `tau`; returns kept indices split by
/// sign. Zero entries are never kept (a zero carries no direction).
pub fn topk_by_magnitude(tau: &[f32], k: f64) -> TopKSplit {
    let d = tau.len();
    if d == 0 {
        return TopKSplit { plus: Vec::new(), minus: Vec::new(), threshold: 0.0 };
    }
    let keep = keep_count(d, k);

    let threshold = select_nth_largest_mag(tau, keep - 1);

    // First pass: strictly-above-threshold entries are always kept.
    let mut plus = Vec::with_capacity(keep / 2 + 1);
    let mut minus = Vec::with_capacity(keep / 2 + 1);
    let mut kept = 0usize;
    let mut ties: Vec<u32> = Vec::new();
    for (i, &v) in tau.iter().enumerate() {
        let a = v.abs();
        if a > threshold {
            if v > 0.0 {
                plus.push(i as u32);
            } else {
                minus.push(i as u32);
            }
            kept += 1;
        } else if a == threshold && a > 0.0 {
            ties.push(i as u32);
        }
    }
    // Fill remaining budget with tie entries in index order (deterministic).
    for &i in ties.iter().take(keep.saturating_sub(kept)) {
        if tau[i as usize] > 0.0 {
            plus.push(i);
        } else {
            minus.push(i);
        }
    }
    plus.sort_unstable();
    minus.sort_unstable();
    TopKSplit { plus, minus, threshold }
}

/// Dense mask variant used by the `Pruned` ablation baseline (§4.1):
/// keep the *original values* of the top-k entries, zero the rest.
pub fn prune_to_topk(tau: &[f32], k: f64) -> Vec<f32> {
    let split = topk_by_magnitude(tau, k);
    let mut out = vec![0.0f32; tau.len()];
    for &i in &split.plus {
        out[i as usize] = tau[i as usize];
    }
    for &i in &split.minus {
        out[i as usize] = tau[i as usize];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn keeps_exact_count() {
        let tau = [0.1, -5.0, 0.2, 3.0, -0.05, 1.0, -2.0, 0.6];
        let s = topk_by_magnitude(&tau, 0.5); // keep 4
        assert_eq!(s.plus.len() + s.minus.len(), 4);
        assert_eq!(s.plus, vec![3, 5]);
        assert_eq!(s.minus, vec![1, 6]);
    }

    #[test]
    fn keep_count_rounds_up() {
        assert_eq!(keep_count(100, 0.05), 5);
        assert_eq!(keep_count(10, 0.05), 1); // ceil(0.5)
        assert_eq!(keep_count(7, 1.0), 7);
    }

    #[test]
    fn ties_are_deterministic_and_exact() {
        let tau = [1.0f32; 10];
        let s = topk_by_magnitude(&tau, 0.3); // keep 3 of 10 equal values
        assert_eq!(s.plus.len(), 3);
        assert_eq!(s.plus, vec![0, 1, 2]); // lowest indices win
    }

    #[test]
    fn zeros_never_kept() {
        let tau = [0.0f32, 0.0, 1.0, 0.0];
        let s = topk_by_magnitude(&tau, 1.0);
        assert_eq!(s.plus, vec![2]);
        assert!(s.minus.is_empty());
    }

    #[test]
    fn prune_preserves_values() {
        let tau = [0.1, -5.0, 0.2, 3.0];
        let p = prune_to_topk(&tau, 0.5);
        assert_eq!(p, vec![0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn empty_input() {
        let s = topk_by_magnitude(&[], 0.5);
        assert!(s.plus.is_empty() && s.minus.is_empty());
    }

    #[test]
    fn prop_matches_full_sort_reference() {
        prop::check(
            "topk matches sort reference",
            60,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(5000);
                let k = [0.05, 0.1, 0.2, 0.5, 1.0][rng.range(0, 5)];
                (prop::task_vector_like(rng, n), k)
            },
            |(tau, k)| {
                let s = topk_by_magnitude(tau, *k);
                let keep = keep_count(tau.len(), *k);
                let nonzero = tau.iter().filter(|x| **x != 0.0).count();
                let expect = keep.min(nonzero);
                if s.plus.len() + s.minus.len() != expect {
                    return Err(format!(
                        "kept {} expected {expect}",
                        s.plus.len() + s.minus.len()
                    ));
                }
                // Every kept magnitude >= every dropped magnitude.
                let mut kept_set = vec![false; tau.len()];
                for &i in s.plus.iter().chain(&s.minus) {
                    kept_set[i as usize] = true;
                }
                let min_kept = s
                    .plus
                    .iter()
                    .chain(&s.minus)
                    .map(|&i| tau[i as usize].abs())
                    .fold(f32::INFINITY, f32::min);
                let max_dropped = tau
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !kept_set[*i])
                    .map(|(_, v)| v.abs())
                    .fold(0.0f32, f32::max);
                if expect > 0 && min_kept < max_dropped {
                    return Err(format!("min kept {min_kept} < max dropped {max_dropped}"));
                }
                // Signs are consistent.
                for &i in &s.plus {
                    if tau[i as usize] <= 0.0 {
                        return Err(format!("plus index {i} has non-positive value"));
                    }
                }
                for &i in &s.minus {
                    if tau[i as usize] >= 0.0 {
                        return Err(format!("minus index {i} has non-negative value"));
                    }
                }
                Ok(())
            },
        );
    }
}
