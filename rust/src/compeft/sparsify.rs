//! Step 1 of ComPEFT (Algorithm 1): magnitude-based top-k sparsification.
//!
//! Given a task vector `τ` and a density `k` (fraction in (0,1]), keep
//! the signs of the top-⌈k·d⌉ entries by |τ| and zero the rest. We find
//! the k-th largest magnitude with an in-place quickselect (O(d)
//! expected) instead of a full sort — the dominant cost of compression
//! at the 10⁷-parameter scale.

use crate::util::pool::{chunk_ranges, ThreadPool};

/// Indices of the top-k-by-magnitude entries, split by sign.
#[derive(Clone, Debug, PartialEq)]
pub struct TopKSplit {
    /// Sorted indices of kept entries with τ > 0.
    pub plus: Vec<u32>,
    /// Sorted indices of kept entries with τ < 0.
    pub minus: Vec<u32>,
    /// The magnitude threshold actually used (kept iff |τ| >= threshold,
    /// with ties broken toward keeping exactly ⌈k·d⌉ entries).
    pub threshold: f32,
}

/// Number of entries to keep for a density `k` over `d` elements.
pub fn keep_count(d: usize, k: f64) -> usize {
    assert!(k > 0.0 && k <= 1.0, "density must be in (0,1], got {k}");
    assert!(
        d <= u32::MAX as usize,
        "task vector length {d} exceeds the u32 index space of TernaryVector; \
         compress per-tensor (Granularity::PerTensor) or shard the vector"
    );
    ((d as f64 * k).ceil() as usize).min(d)
}

/// Scan `tau[s..e)`, pushing above-threshold indices by sign and
/// exact-threshold candidates (`ties`) in index order. The one keep/tie
/// predicate — NaN, signed-zero, and tie semantics — shared by the
/// serial and parallel paths, so the bit-identical contract cannot be
/// broken by editing one and not the other.
fn scan_range(
    tau: &[f32],
    s: usize,
    e: usize,
    threshold: f32,
    plus: &mut Vec<u32>,
    minus: &mut Vec<u32>,
    ties: &mut Vec<u32>,
) {
    for (off, &v) in tau[s..e].iter().enumerate() {
        let i = (s + off) as u32;
        let a = v.abs();
        if a > threshold {
            if v > 0.0 {
                plus.push(i);
            } else {
                minus.push(i);
            }
        } else if a == threshold && a > 0.0 {
            ties.push(i);
        }
    }
}

/// Quickselect the `n`-th largest magnitude (0-based).
///
/// Perf (§Perf L3 iteration 1): |x| viewed as IEEE-754 bits is a
/// monotone u32 key (sign bit cleared ⇒ integer order == float order),
/// so we select on u32 — no `partial_cmp` closure, branch-free
/// comparisons, ~2.4x faster end-to-end Algorithm 1 on 4M params.
fn select_nth_largest_mag(tau: &[f32], n: usize) -> f32 {
    let mut keys: Vec<u32> = tau.iter().map(|x| x.to_bits() & 0x7FFF_FFFF).collect();
    let idx = keys.len() - 1 - n;
    let (_, pivot, _) = keys.select_nth_unstable(idx);
    f32::from_bits(*pivot)
}

/// Apply top-k sparsification to `tau`; returns kept indices split by
/// sign. Zero entries are never kept (a zero carries no direction).
pub fn topk_by_magnitude(tau: &[f32], k: f64) -> TopKSplit {
    let d = tau.len();
    if d == 0 {
        return TopKSplit { plus: Vec::new(), minus: Vec::new(), threshold: 0.0 };
    }
    let keep = keep_count(d, k);

    let threshold = select_nth_largest_mag(tau, keep - 1);

    // First pass: strictly-above-threshold entries are always kept.
    let mut plus = Vec::with_capacity(keep / 2 + 1);
    let mut minus = Vec::with_capacity(keep / 2 + 1);
    let mut ties: Vec<u32> = Vec::new();
    scan_range(tau, 0, d, threshold, &mut plus, &mut minus, &mut ties);
    let kept = plus.len() + minus.len();
    // Fill remaining budget with tie entries in index order (deterministic).
    for &i in ties.iter().take(keep.saturating_sub(kept)) {
        if tau[i as usize] > 0.0 {
            plus.push(i);
        } else {
            minus.push(i);
        }
    }
    plus.sort_unstable();
    minus.sort_unstable();
    TopKSplit { plus, minus, threshold }
}

// ---------------------------------------------------------------------------
// Parallel two-pass top-k (the engine's hot path)
// ---------------------------------------------------------------------------

/// Buckets for the histogram pre-pass: top 12 bits of the 31-bit
/// magnitude key. 4096 buckets keep per-chunk histograms at 32 KB while
/// narrowing the exact-threshold refine to a small candidate set.
const BUCKET_BITS: u32 = 12;
const N_BUCKETS: usize = 1 << BUCKET_BITS;

#[inline]
fn mag_key(x: f32) -> u32 {
    x.to_bits() & 0x7FFF_FFFF
}

#[inline]
fn bucket_of(key: u32) -> usize {
    (key >> (31 - BUCKET_BITS)) as usize
}

/// Merge two sorted, disjoint index lists into one sorted list.
fn merge_sorted(a: Vec<u32>, b: Vec<u32>) -> Vec<u32> {
    if b.is_empty() {
        return a;
    }
    if a.is_empty() {
        return b;
    }
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] < b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Parallel [`topk_by_magnitude`]: bit-identical output, computed as a
/// two-pass chunked selection on `pool`.
///
/// Pass 1 histograms magnitude keys per chunk into [`N_BUCKETS`] buckets
/// and locates the bucket containing the ⌈k·d⌉-th largest key; an exact
/// quickselect over only that bucket's keys recovers the *same
/// threshold value* the serial quickselect finds. Pass 2 re-scans the
/// chunks with the serial path's float comparisons (so NaN/±0/tie
/// semantics match exactly) and concatenates per-chunk results in chunk
/// order, which keeps the index lists sorted without a sort.
///
/// `chunk` only divides work; it does not affect the output (the
/// threshold is a value, not a partition artifact).
pub fn par_topk_by_magnitude(
    tau: &[f32],
    k: f64,
    pool: &ThreadPool,
    chunk: usize,
) -> TopKSplit {
    let d = tau.len();
    if d == 0 {
        return TopKSplit { plus: Vec::new(), minus: Vec::new(), threshold: 0.0 };
    }
    let keep = keep_count(d, k);
    let ranges = chunk_ranges(d, chunk);

    // Pass 1a: per-chunk bucket histograms over the u32 magnitude keys.
    // Histograms are 32 KB each and all live until the merge, so this
    // pass uses coarser ranges — a few per worker — keeping transient
    // memory at O(workers · 32 KB) regardless of how small the caller's
    // emission chunk is. Chunking never affects the counts.
    let hist_chunk = chunk.max(d.div_ceil(pool.worker_count().max(1) * 4).max(1));
    let hist_ranges = chunk_ranges(d, hist_chunk);
    let hists: Vec<Vec<u64>> = pool.scoped_map(hist_ranges, |(s, e)| {
        let mut h = vec![0u64; N_BUCKETS];
        for &v in &tau[s..e] {
            h[bucket_of(mag_key(v))] += 1;
        }
        h
    });
    let mut total = vec![0u64; N_BUCKETS];
    for h in &hists {
        for (t, c) in total.iter_mut().zip(h) {
            *t += *c;
        }
    }

    // Locate the bucket holding the keep-th largest key.
    let mut acc = 0u64;
    let mut target = 0usize;
    for b in (0..N_BUCKETS).rev() {
        acc += total[b];
        if acc >= keep as u64 {
            target = b;
            break;
        }
    }
    let above = acc - total[target];
    let rank_in_bucket = keep as u64 - above; // 1-based from the top

    // Pass 1b: gather the target bucket's keys and select exactly.
    let mut in_bucket: Vec<u32> = pool
        .scoped_map(ranges.clone(), |(s, e)| {
            tau[s..e]
                .iter()
                .map(|v| mag_key(*v))
                .filter(|key| bucket_of(*key) == target)
                .collect::<Vec<u32>>()
        })
        .concat();
    debug_assert!(rank_in_bucket >= 1 && rank_in_bucket <= in_bucket.len() as u64);
    let idx = in_bucket.len() - rank_in_bucket as usize;
    let (_, kth, _) = in_bucket.select_nth_unstable(idx);
    let threshold = f32::from_bits(*kth);

    // Pass 2: emit per chunk through the shared serial predicate.
    let parts: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> =
        pool.scoped_map(ranges, |(s, e)| {
            let mut plus = Vec::new();
            let mut minus = Vec::new();
            let mut ties = Vec::new();
            scan_range(tau, s, e, threshold, &mut plus, &mut minus, &mut ties);
            (plus, minus, ties)
        });

    // Chunk-order concatenation of per-chunk ascending runs is globally
    // ascending: no sort needed.
    let mut plus = Vec::with_capacity(keep / 2 + 1);
    let mut minus = Vec::with_capacity(keep / 2 + 1);
    let mut ties = Vec::new();
    for (p, m, t) in parts {
        plus.extend_from_slice(&p);
        minus.extend_from_slice(&m);
        ties.extend_from_slice(&t);
    }
    let kept = plus.len() + minus.len();
    let mut tie_plus = Vec::new();
    let mut tie_minus = Vec::new();
    for &i in ties.iter().take(keep.saturating_sub(kept)) {
        if tau[i as usize] > 0.0 {
            tie_plus.push(i);
        } else {
            tie_minus.push(i);
        }
    }
    TopKSplit {
        plus: merge_sorted(plus, tie_plus),
        minus: merge_sorted(minus, tie_minus),
        threshold,
    }
}

/// Dense mask variant used by the `Pruned` ablation baseline (§4.1):
/// keep the *original values* of the top-k entries, zero the rest.
pub fn prune_to_topk(tau: &[f32], k: f64) -> Vec<f32> {
    let split = topk_by_magnitude(tau, k);
    let mut out = vec![0.0f32; tau.len()];
    for &i in &split.plus {
        out[i as usize] = tau[i as usize];
    }
    for &i in &split.minus {
        out[i as usize] = tau[i as usize];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn keeps_exact_count() {
        let tau = [0.1, -5.0, 0.2, 3.0, -0.05, 1.0, -2.0, 0.6];
        let s = topk_by_magnitude(&tau, 0.5); // keep 4
        assert_eq!(s.plus.len() + s.minus.len(), 4);
        assert_eq!(s.plus, vec![3, 5]);
        assert_eq!(s.minus, vec![1, 6]);
    }

    #[test]
    fn keep_count_rounds_up() {
        assert_eq!(keep_count(100, 0.05), 5);
        assert_eq!(keep_count(10, 0.05), 1); // ceil(0.5)
        assert_eq!(keep_count(7, 1.0), 7);
    }

    #[test]
    fn ties_are_deterministic_and_exact() {
        let tau = [1.0f32; 10];
        let s = topk_by_magnitude(&tau, 0.3); // keep 3 of 10 equal values
        assert_eq!(s.plus.len(), 3);
        assert_eq!(s.plus, vec![0, 1, 2]); // lowest indices win
    }

    #[test]
    fn zeros_never_kept() {
        let tau = [0.0f32, 0.0, 1.0, 0.0];
        let s = topk_by_magnitude(&tau, 1.0);
        assert_eq!(s.plus, vec![2]);
        assert!(s.minus.is_empty());
    }

    #[test]
    fn prune_preserves_values() {
        let tau = [0.1, -5.0, 0.2, 3.0];
        let p = prune_to_topk(&tau, 0.5);
        assert_eq!(p, vec![0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn empty_input() {
        let s = topk_by_magnitude(&[], 0.5);
        assert!(s.plus.is_empty() && s.minus.is_empty());
    }

    /// Bitwise equality of two splits, safe under NaN thresholds (f32
    /// `==` would report NaN != NaN even for identical outputs).
    fn assert_split_bit_identical(a: &TopKSplit, b: &TopKSplit, tag: &str) {
        assert_eq!(a.plus, b.plus, "{tag}: plus");
        assert_eq!(a.minus, b.minus, "{tag}: minus");
        assert_eq!(
            a.threshold.to_bits(),
            b.threshold.to_bits(),
            "{tag}: threshold {} vs {}",
            a.threshold,
            b.threshold
        );
    }

    #[test]
    fn parallel_matches_serial_across_pools_and_chunks() {
        let mut rng = Pcg::seed(41);
        let cases: Vec<(Vec<f32>, f64)> = vec![
            (prop::task_vector_like(&mut rng, 50_000), 0.05),
            (prop::task_vector_like(&mut rng, 10_001), 0.2),
            (prop::task_vector_like(&mut rng, 777), 1.0),
            (prop::task_vector_like(&mut rng, 64), 0.001), // keep = 1
            (vec![0.5f32], 0.5),
        ];
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk in [100usize, 1 << 12, 1 << 20] {
                for (i, (tau, k)) in cases.iter().enumerate() {
                    let serial = topk_by_magnitude(tau, *k);
                    let par = par_topk_by_magnitude(tau, *k, &pool, chunk);
                    assert_split_bit_identical(
                        &serial,
                        &par,
                        &format!("case {i} workers {workers} chunk {chunk}"),
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_matches_serial_on_pathological_inputs() {
        let pool = ThreadPool::new(4);
        // All-equal magnitudes: every entry lands in one bucket and ties
        // resolve by index.
        let all_equal = vec![-1.0f32; 10_000];
        // Signed zeros and exact zeros are never kept.
        let zeros: Vec<f32> = (0..5000)
            .map(|i| match i % 3 {
                0 => 0.0,
                1 => -0.0,
                _ => (i as f32) * 1e-3,
            })
            .collect();
        // NaN entries occupy the top of the key space; the serial path's
        // float comparisons drop them, and the parallel path must agree.
        let mut with_nan: Vec<f32> = (0..4096).map(|i| (i as f32).cos()).collect();
        for i in (0..with_nan.len()).step_by(17) {
            with_nan[i] = f32::NAN;
        }
        let mut all_nan = vec![f32::NAN; 512];
        all_nan[0] = -0.0;
        for (name, tau) in [
            ("all_equal", &all_equal),
            ("zeros", &zeros),
            ("with_nan", &with_nan),
            ("all_nan", &all_nan),
        ] {
            for k in [0.05, 0.5, 1.0] {
                let serial = topk_by_magnitude(tau, k);
                let par = par_topk_by_magnitude(tau, k, &pool, 701);
                assert_split_bit_identical(&serial, &par, &format!("{name} k={k}"));
            }
        }
        // Empty input.
        let par = par_topk_by_magnitude(&[], 0.5, &pool, 64);
        assert!(par.plus.is_empty() && par.minus.is_empty());
    }

    #[test]
    fn prop_parallel_equivalence_random() {
        let pool = ThreadPool::new(3);
        prop::check(
            "par_topk == topk",
            40,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(20_000);
                let k = [0.01, 0.05, 0.2, 0.5, 1.0][rng.range(0, 5)];
                let chunk = [64, 997, 4096, 1 << 16][rng.range(0, 4)];
                (prop::task_vector_like(rng, n.max(1)), k, chunk)
            },
            |(tau, k, chunk)| {
                let serial = topk_by_magnitude(tau, *k);
                let par = par_topk_by_magnitude(tau, *k, &pool, *chunk);
                if serial.plus != par.plus || serial.minus != par.minus {
                    return Err("index sets differ".into());
                }
                if serial.threshold.to_bits() != par.threshold.to_bits() {
                    return Err(format!(
                        "thresholds differ: {} vs {}",
                        serial.threshold, par.threshold
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_matches_full_sort_reference() {
        prop::check(
            "topk matches sort reference",
            60,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(5000);
                let k = [0.05, 0.1, 0.2, 0.5, 1.0][rng.range(0, 5)];
                (prop::task_vector_like(rng, n), k)
            },
            |(tau, k)| {
                let s = topk_by_magnitude(tau, *k);
                let keep = keep_count(tau.len(), *k);
                let nonzero = tau.iter().filter(|x| **x != 0.0).count();
                let expect = keep.min(nonzero);
                if s.plus.len() + s.minus.len() != expect {
                    return Err(format!(
                        "kept {} expected {expect}",
                        s.plus.len() + s.minus.len()
                    ));
                }
                // Every kept magnitude >= every dropped magnitude.
                let mut kept_set = vec![false; tau.len()];
                for &i in s.plus.iter().chain(&s.minus) {
                    kept_set[i as usize] = true;
                }
                let min_kept = s
                    .plus
                    .iter()
                    .chain(&s.minus)
                    .map(|&i| tau[i as usize].abs())
                    .fold(f32::INFINITY, f32::min);
                let max_dropped = tau
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !kept_set[*i])
                    .map(|(_, v)| v.abs())
                    .fold(0.0f32, f32::max);
                if expect > 0 && min_kept < max_dropped {
                    return Err(format!("min kept {min_kept} < max dropped {max_dropped}"));
                }
                // Signs are consistent.
                for &i in &s.plus {
                    if tau[i as usize] <= 0.0 {
                        return Err(format!("plus index {i} has non-positive value"));
                    }
                }
                for &i in &s.minus {
                    if tau[i as usize] >= 0.0 {
                        return Err(format!("minus index {i} has non-negative value"));
                    }
                }
                Ok(())
            },
        );
    }
}
