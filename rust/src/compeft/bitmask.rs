//! Two-binary-mask representation of sparse ternary vectors (paper
//! §2.2, "Efficient Computation and Communication via Two Binary
//! Vectors").
//!
//! `τ̃⁺ = (τ̃ == +1)` and `τ̃⁻ = (τ̃ == −1)` packed into u64 words, plus
//! the shared scalar. Costs 2·d + 16 bits (more than Golomb) but turns
//! the §2.2 operations into straight-line word-parallel code:
//!
//! * distance: `XOR` + `POPCNT` per word, twice;
//! * dot product: `AND` + `POPCNT` for agreeing / disagreeing pairs;
//! * merge/add: bitwise ops + a carry vector.

use crate::compeft::ternary::TernaryVector;
use anyhow::{bail, Result};

/// Packed two-mask ternary vector.
#[derive(Clone, Debug, PartialEq)]
pub struct MaskPair {
    pub len: usize,
    pub scale: f32,
    /// Bit i set ⇔ τ̃_i = +scale. `ceil(len/64)` words, little-bit-first.
    pub plus: Vec<u64>,
    /// Bit i set ⇔ τ̃_i = −scale.
    pub minus: Vec<u64>,
}

#[inline]
fn words(len: usize) -> usize {
    len.div_ceil(64)
}

impl MaskPair {
    pub fn from_ternary(t: &TernaryVector) -> MaskPair {
        let w = words(t.len);
        // compeft-lint: allow(no-unchecked-wire-alloc) -- packs an in-memory vector, len is not wire data
        let mut plus = vec![0u64; w];
        // compeft-lint: allow(no-unchecked-wire-alloc) -- packs an in-memory vector, len is not wire data
        let mut minus = vec![0u64; w];
        for &i in &t.plus {
            // compeft-lint: allow(no-panic-in-parse) -- TernaryVector invariant: index < len <= 64*words
            plus[i as usize / 64] |= 1u64 << (i % 64);
        }
        for &i in &t.minus {
            // compeft-lint: allow(no-panic-in-parse) -- TernaryVector invariant: index < len <= 64*words
            minus[i as usize / 64] |= 1u64 << (i % 64);
        }
        MaskPair { len: t.len, scale: t.scale, plus, minus }
    }

    /// Parallel [`MaskPair::from_ternary`]: identical output.
    ///
    /// Word ranges are independent — a chunk owning words `[ws, we)`
    /// packs exactly the indices in `[64·ws, 64·we)`, found by binary
    /// search in the sorted plus/minus lists — so per-chunk word blocks
    /// concatenated in order equal the serial masks.
    pub fn from_ternary_par(
        t: &TernaryVector,
        pool: &crate::util::pool::ThreadPool,
        chunk_words: usize,
    ) -> MaskPair {
        let w = words(t.len);
        let ranges = crate::util::pool::chunk_ranges(w, chunk_words);
        let blocks: Vec<(Vec<u64>, Vec<u64>)> = pool.scoped_map(ranges, |(ws, we)| {
            let lo = ws as u64 * 64;
            let hi_excl = we as u64 * 64;
            let pack = |sorted: &[u32]| {
                let start = sorted.partition_point(|&i| (i as u64) < lo);
                let end = sorted.partition_point(|&i| (i as u64) < hi_excl);
                // compeft-lint: allow(no-unchecked-wire-alloc) -- chunk of an in-memory vector
                let mut words_block = vec![0u64; we - ws];
                for &i in sorted.get(start..end).unwrap_or_default() {
                    // compeft-lint: allow(no-panic-in-parse) -- partition_point bounds the chunk's indices
                    words_block[i as usize / 64 - ws] |= 1u64 << (i % 64);
                }
                words_block
            };
            (pack(&t.plus), pack(&t.minus))
        });
        // compeft-lint: allow(no-unchecked-wire-alloc) -- packs an in-memory vector, len is not wire data
        let mut plus = Vec::with_capacity(w);
        // compeft-lint: allow(no-unchecked-wire-alloc) -- packs an in-memory vector, len is not wire data
        let mut minus = Vec::with_capacity(w);
        for (p, m) in blocks {
            plus.extend_from_slice(&p);
            minus.extend_from_slice(&m);
        }
        MaskPair { len: t.len, scale: t.scale, plus, minus }
    }

    /// Extract the indices set in `words[ws..we]` (global word offset
    /// `ws`) into `out` — the single scan loop both `to_ternary` and
    /// `to_ternary_par` run, so their index order is identical by
    /// construction.
    fn unpack_words(words: &[u64], ws: usize, we: usize, out: &mut Vec<u32>) {
        for (w, &word) in words.get(ws..we).unwrap_or_default().iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros();
                out.push(((ws + w) * 64) as u32 + b);
                bits &= bits - 1;
            }
        }
    }

    /// Population count of a word range — pre-sizes the index lists the
    /// trailing-zeros scan fills, so expansion never reallocs mid-scan
    /// (and the popcount sweep warms the words for the scan itself).
    fn range_nnz(words: &[u64], ws: usize, we: usize) -> usize {
        words
            .get(ws..we)
            .unwrap_or_default()
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    pub fn to_ternary(&self) -> TernaryVector {
        let w = self.plus.len();
        let mut plus = Vec::with_capacity(Self::range_nnz(&self.plus, 0, w));
        let mut minus = Vec::with_capacity(Self::range_nnz(&self.minus, 0, w));
        Self::unpack_words(&self.plus, 0, w, &mut plus);
        Self::unpack_words(&self.minus, 0, w, &mut minus);
        TernaryVector { len: self.len, scale: self.scale, plus, minus }
    }

    /// Parallel [`MaskPair::to_ternary`]: identical output.
    ///
    /// Word ranges partition the index space in order — the indices
    /// packed in words `[ws, we)` are exactly `[64·ws, 64·we)` — so
    /// per-range index lists concatenated in range order equal the
    /// serial scan. `chunk_words` divides work only and never changes
    /// the output.
    pub fn to_ternary_par(
        &self,
        pool: &crate::util::pool::ThreadPool,
        chunk_words: usize,
    ) -> TernaryVector {
        let w = self.plus.len();
        let ranges = crate::util::pool::chunk_ranges(w, chunk_words);
        let blocks: Vec<(Vec<u32>, Vec<u32>)> = pool.scoped_map(ranges, |(ws, we)| {
            let mut plus = Vec::with_capacity(Self::range_nnz(&self.plus, ws, we));
            let mut minus = Vec::with_capacity(Self::range_nnz(&self.minus, ws, we));
            Self::unpack_words(&self.plus, ws, we, &mut plus);
            Self::unpack_words(&self.minus, ws, we, &mut minus);
            (plus, minus)
        });
        let mut plus = Vec::with_capacity(Self::range_nnz(&self.plus, 0, w));
        let mut minus = Vec::with_capacity(Self::range_nnz(&self.minus, 0, w));
        for (p, m) in blocks {
            plus.extend_from_slice(&p);
            minus.extend_from_slice(&m);
        }
        TernaryVector { len: self.len, scale: self.scale, plus, minus }
    }

    pub fn nnz(&self) -> usize {
        self.plus.iter().map(|w| w.count_ones() as usize).sum::<usize>()
            + self.minus.iter().map(|w| w.count_ones() as usize).sum::<usize>()
    }

    /// Wire size in bytes: two d-bit masks + 16-bit scalar (we store the
    /// scalar as f32 on disk but account 16 bits per the paper's model).
    pub fn wire_bytes(&self) -> u64 {
        (2 * self.len as u64 + 16).div_ceil(8)
    }

    /// Hamming-style distance between two ternary vectors: number of
    /// coordinates whose ternary digits differ. Implemented as
    /// XOR + POPCNT over both masks (two machine ops per 64 params,
    /// §2.2). Positions counted twice (e.g. +1 vs −1) differ "more"; we
    /// return the L1 distance in ternary digits, matching
    /// `Σ |γ_a − γ_b|` up to the shared scale.
    pub fn ternary_l1_distance(&self, other: &MaskPair) -> Result<u64> {
        if self.len != other.len {
            bail!("length mismatch {} vs {}", self.len, other.len);
        }
        let mut acc = 0u64;
        for (&a, &b) in self.plus.iter().zip(&other.plus) {
            acc += (a ^ b).count_ones() as u64;
        }
        for (&a, &b) in self.minus.iter().zip(&other.minus) {
            acc += (a ^ b).count_ones() as u64;
        }
        Ok(acc)
    }

    /// Dot product `⟨τ̃_a, τ̃_b⟩` via bitwise AND (paper §2.2): agreeing
    /// signs contribute +1, opposing signs −1, then scale by `s_a · s_b`.
    pub fn dot(&self, other: &MaskPair) -> Result<f64> {
        if self.len != other.len {
            bail!("length mismatch {} vs {}", self.len, other.len);
        }
        let mut agree = 0i64;
        let mut oppose = 0i64;
        let a_words = self.plus.iter().zip(&self.minus);
        let b_words = other.plus.iter().zip(&other.minus);
        for ((&ap, &am), (&bp, &bm)) in a_words.zip(b_words) {
            agree += (ap & bp).count_ones() as i64;
            agree += (am & bm).count_ones() as i64;
            oppose += (ap & bm).count_ones() as i64;
            oppose += (am & bp).count_ones() as i64;
        }
        Ok((agree - oppose) as f64 * self.scale as f64 * other.scale as f64)
    }

    /// Cosine similarity of the underlying ternary sign patterns.
    pub fn sign_cosine(&self, other: &MaskPair) -> Result<f64> {
        let d = self.dot(other)? / (self.scale as f64 * other.scale as f64);
        let na = (self.nnz() as f64).sqrt();
        let nb = (other.nnz() as f64).sqrt();
        if na == 0.0 || nb == 0.0 {
            return Ok(0.0);
        }
        Ok(d / (na * nb))
    }

    /// Accumulate `weight · τ̃` into a dense buffer word-by-word.
    pub fn add_into(&self, out: &mut [f32], weight: f32) {
        assert_eq!(out.len(), self.len);
        let s = self.scale * weight;
        for (w, (&p, &m)) in self.plus.iter().zip(&self.minus).enumerate() {
            let mut bits = p;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                // compeft-lint: allow(no-panic-in-parse) -- mask invariant: set bits < len == out.len()
                out[w * 64 + b] += s;
                bits &= bits - 1;
            }
            let mut bits = m;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                // compeft-lint: allow(no-panic-in-parse) -- mask invariant: set bits < len == out.len()
                out[w * 64 + b] -= s;
                bits &= bits - 1;
            }
        }
    }

    /// Serialize: len u64 | scale f32 | plus words | minus words (LE).
    pub fn to_bytes(&self) -> Vec<u8> {
        // compeft-lint: allow(no-unchecked-wire-alloc) -- write path: sized from in-memory masks
        let mut out = Vec::with_capacity(12 + 16 * self.plus.len());
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        out.extend_from_slice(&self.scale.to_le_bytes());
        for &w in &self.plus {
            out.extend_from_slice(&w.to_le_bytes());
        }
        for &w in &self.minus {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<MaskPair> {
        if bytes.len() < 12 {
            bail!("mask pair too short");
        }
        let len = u64::from_le_bytes(bytes.get(0..8).unwrap_or_default().try_into()?) as usize;
        let scale = f32::from_le_bytes(bytes.get(8..12).unwrap_or_default().try_into()?);
        let w = words(len);
        // Checked arithmetic: a corrupt `len` near usize::MAX must fail
        // here, not overflow the size computation (or allocation-bomb
        // the word vectors, which are capacity'd from `w` below).
        match w.checked_mul(16).and_then(|x| x.checked_add(12)) {
            Some(need) if need <= bytes.len() => {}
            _ => bail!(
                "mask pair truncated: len {len} needs more than the {} bytes present",
                bytes.len()
            ),
        }
        let mut plus = Vec::with_capacity(w);
        let mut minus = Vec::with_capacity(w);
        for i in 0..w {
            let raw = bytes.get(12 + 8 * i..20 + 8 * i).unwrap_or_default();
            plus.push(u64::from_le_bytes(raw.try_into()?));
        }
        let off = 12 + 8 * w;
        for i in 0..w {
            let raw = bytes.get(off + 8 * i..off + 8 + 8 * i).unwrap_or_default();
            minus.push(u64::from_le_bytes(raw.try_into()?));
        }
        let mp = MaskPair { len, scale, plus, minus };
        // Sanity: a bit set in both masks is a corrupt stream.
        for (p, m) in mp.plus.iter().zip(&mp.minus) {
            if p & m != 0 {
                bail!("corrupt mask pair: overlapping sign bits");
            }
        }
        Ok(mp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn t(len: usize, scale: f32, plus: &[u32], minus: &[u32]) -> TernaryVector {
        TernaryVector { len, scale, plus: plus.to_vec(), minus: minus.to_vec() }
    }

    #[test]
    fn ternary_mask_roundtrip() {
        let v = t(130, 0.25, &[0, 63, 64, 127, 129], &[1, 65]);
        let m = MaskPair::from_ternary(&v);
        assert_eq!(m.plus.len(), 3);
        assert_eq!(m.to_ternary(), v);
        assert_eq!(m.nnz(), 7);
    }

    #[test]
    fn bytes_roundtrip() {
        let v = t(100, -1.5, &[5, 50], &[6, 99]);
        let m = MaskPair::from_ternary(&v);
        let back = MaskPair::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn from_bytes_rejects_overlap_and_truncation() {
        let v = t(64, 1.0, &[0], &[1]);
        let m = MaskPair::from_ternary(&v);
        let mut bytes = m.to_bytes();
        bytes[12] |= 0b10; // set bit 1 in plus too → overlap with minus
        assert!(MaskPair::from_bytes(&bytes).is_err());
        let bytes = m.to_bytes();
        assert!(MaskPair::from_bytes(&bytes[..bytes.len() - 1]).is_err());
    }

    use crate::compeft::golomb::tests::random_index_sets;

    #[test]
    fn prop_mask_roundtrip_random_index_sets() {
        prop::check(
            "mask encode→decode on raw index sets",
            60,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(10_000);
                random_index_sets(rng, n)
            },
            |t| {
                let m = MaskPair::from_ternary(t);
                if m.nnz() != t.nnz() {
                    return Err(format!("nnz {} vs {}", m.nnz(), t.nnz()));
                }
                if m.to_ternary() != *t {
                    return Err("mask → ternary mismatch".into());
                }
                let back = MaskPair::from_bytes(&m.to_bytes())
                    .map_err(|e| e.to_string())?;
                if back != m {
                    return Err("byte roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn from_ternary_par_matches_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(13);
        let cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(129),
            random_index_sets(&mut rng, 64),
            random_index_sets(&mut rng, 4097),
            random_index_sets(&mut rng, 100_000),
        ];
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk_words in [1usize, 9, 1024] {
                for (i, t) in cases.iter().enumerate() {
                    let serial = MaskPair::from_ternary(t);
                    let par = MaskPair::from_ternary_par(t, &pool, chunk_words);
                    assert_eq!(
                        serial, par,
                        "case {i} workers {workers} chunk_words {chunk_words}"
                    );
                }
            }
        }
    }

    #[test]
    fn to_ternary_par_matches_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(29);
        let cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(129),
            random_index_sets(&mut rng, 64),
            random_index_sets(&mut rng, 4097),
            random_index_sets(&mut rng, 100_000),
        ];
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk_words in [1usize, 9, 1024] {
                for (i, t) in cases.iter().enumerate() {
                    let m = MaskPair::from_ternary(t);
                    let serial = m.to_ternary();
                    let par = m.to_ternary_par(&pool, chunk_words);
                    assert_eq!(
                        serial, par,
                        "case {i} workers {workers} chunk_words {chunk_words}"
                    );
                    assert_eq!(&par, t, "case {i} roundtrip");
                }
            }
        }
    }

    #[test]
    fn dot_matches_dense_reference() {
        prop::check(
            "mask dot == dense dot",
            50,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(4000);
                let a = compress_vector(
                    &prop::task_vector_like(rng, n),
                    &CompressConfig { density: 0.3, alpha: 2.0, ..Default::default() },
                );
                let b = compress_vector(
                    &prop::task_vector_like(rng, n),
                    &CompressConfig { density: 0.2, alpha: 1.0, ..Default::default() },
                );
                (a, b)
            },
            |(a, b)| {
                let (ma, mb) = (MaskPair::from_ternary(a), MaskPair::from_ternary(b));
                let fast = ma.dot(&mb).map_err(|e| e.to_string())?;
                let da = a.to_dense();
                let db = b.to_dense();
                let slow: f64 =
                    da.iter().zip(&db).map(|(x, y)| *x as f64 * *y as f64).sum();
                if (fast - slow).abs() > 1e-4 * (1.0 + slow.abs()) {
                    return Err(format!("fast={fast} slow={slow}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn distance_matches_dense_reference() {
        prop::check(
            "mask distance == sign L1",
            40,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(4000);
                let mk = |rng: &mut Pcg| {
                    compress_vector(
                        &prop::task_vector_like(rng, n),
                        &CompressConfig { density: 0.25, ..Default::default() },
                    )
                };
                (mk(rng), mk(rng))
            },
            |(a, b)| {
                let (ma, mb) = (MaskPair::from_ternary(a), MaskPair::from_ternary(b));
                let fast = ma.ternary_l1_distance(&mb).map_err(|e| e.to_string())?;
                // Reference from the sign patterns themselves (the
                // distance is defined on γ̃, independent of scale).
                let signs = |t: &TernaryVector| {
                    let mut s = vec![0i64; t.len];
                    for &i in &t.plus {
                        s[i as usize] = 1;
                    }
                    for &i in &t.minus {
                        s[i as usize] = -1;
                    }
                    s
                };
                let slow: u64 = signs(a)
                    .iter()
                    .zip(&signs(b))
                    .map(|(x, y)| (x - y).unsigned_abs())
                    .sum();
                if fast != slow {
                    return Err(format!("fast={fast} slow={slow}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn add_into_matches_ternary() {
        let v = t(70, 0.5, &[0, 69], &[33]);
        let m = MaskPair::from_ternary(&v);
        let mut a = vec![0.0f32; 70];
        let mut b = vec![0.0f32; 70];
        m.add_into(&mut a, 3.0);
        v.add_into(&mut b, 3.0);
        assert_eq!(a, b);
    }

    #[test]
    fn wire_bytes_is_2d_plus_16_bits() {
        let v = t(1000, 1.0, &[1], &[2]);
        let m = MaskPair::from_ternary(&v);
        assert_eq!(m.wire_bytes(), (2 * 1000 + 16 + 7) / 8);
    }

    #[test]
    fn len_mismatch_errors() {
        let a = MaskPair::from_ternary(&t(10, 1.0, &[1], &[]));
        let b = MaskPair::from_ternary(&t(20, 1.0, &[1], &[]));
        assert!(a.dot(&b).is_err());
        assert!(a.ternary_l1_distance(&b).is_err());
    }
}
