//! The ComPEFT compression algorithm and its wire formats.
//!
//! * [`compress`] — Algorithm 1 (sparsify → ternary-quantize with α·σ)
//! * [`engine`] — parallel chunked engine, encode *and* decode sides
//!   (bit-identical to serial)
//! * [`ternary`] — the sparse ternary vector representation
//! * [`sparsify`] — top-k-by-magnitude selection (serial + parallel)
//! * [`golomb`] — storage-optimal Golomb/Rice gap coding (§2.2), with
//!   v2 frame tables for parallel decode
//! * [`bitmask`] — compute-optimal two-binary-mask form (§2.2)
//! * [`entropy`] — storage accounting (entropy bounds, ratios)
//! * [`format`] — the `.cpeft` on-disk / on-wire container (v2:
//!   chunk-framed payloads; v1 remains readable)
//! * [`payload`] — zero-copy [`Payload`] views of encoded bytes (owned
//!   / sliced / mapped-archive regions) + the [`CopyMeter`] copy guard

pub mod bitmask;
pub mod compress;
pub mod engine;
pub mod entropy;
pub mod format;
pub mod golomb;
pub mod payload;
pub mod sparsify;
pub mod ternary;

pub use compress::{
    compress_params, compress_vector, decompress_params, decompress_vector,
    CompressConfig, CompressedParamSet, Granularity,
};
pub use engine::{
    par_add_assign, par_compress_paramset, par_compress_vector,
    par_decompress_params, par_merge, EngineConfig,
};
pub use payload::{CopyMeter, Payload, PayloadBacking};
pub use ternary::TernaryVector;
