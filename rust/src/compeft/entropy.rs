//! Storage-cost accounting (paper §2.2, "Entropy of the Sparsified Task
//! Vector").
//!
//! A dense 16-bit checkpoint needs `H_dense = 16·d` bits. The ComPEFT
//! update — a sparse ternary vector with uniformly-signed nonzeros plus
//! one 16-bit scalar — has entropy
//!
//! ```text
//! H_ComPEFT = −((1−k)·log2(1−k) + k·log2(k/2))·d + 16   bits
//! ```
//!
//! At k = 0.05 this is ≈ 0.34·d + 16 bits → ~47× below bf16. All
//! storage sizes reported by the bench harness use these functions
//! (Golomb-coded sizes by default, matching §3.1's reporting).

/// Entropy in bits of a dense 16-bit checkpoint of `d` params.
pub fn dense_entropy_bits(d: usize) -> f64 {
    16.0 * d as f64
}

/// Entropy in bits of a ComPEFT update with density `k` over `d` params.
pub fn compeft_entropy_bits(d: usize, k: f64) -> f64 {
    assert!(k >= 0.0 && k <= 1.0, "density must be in [0,1]");
    let per_param = ternary_entropy_bits_per_param(k);
    per_param * d as f64 + 16.0
}

/// Per-parameter entropy of the sparse ternary distribution
/// P(0) = 1−k, P(+1) = P(−1) = k/2.
pub fn ternary_entropy_bits_per_param(k: f64) -> f64 {
    let mut h = 0.0;
    if k < 1.0 && k > 0.0 {
        h -= (1.0 - k) * (1.0 - k).log2();
    }
    if k > 0.0 {
        h -= k * (k / 2.0).log2();
    }
    h
}

/// Compression ratio of ComPEFT entropy vs a dense 16-bit checkpoint.
pub fn entropy_compression_ratio(d: usize, k: f64) -> f64 {
    dense_entropy_bits(d) / compeft_entropy_bits(d, k)
}

/// Storage in bytes of the two-binary-mask encoding (2·d + 16 bits).
pub fn bitmask_bytes(d: usize) -> u64 {
    (2 * d as u64 + 16).div_ceil(8)
}

/// Human-readable byte size, e.g. "1.46 GB", "110 MB", "56 KB".
pub fn human_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{bytes} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_numbers_at_k_005() {
        // Paper: at k=0.05 the update entropy is 0.34·d + 16 bits.
        let per_param = ternary_entropy_bits_per_param(0.05);
        assert!((per_param - 0.34).abs() < 0.01, "per_param={per_param}");
        // and ~47x improvement over 16 bits/param.
        let ratio = entropy_compression_ratio(10_000_000, 0.05);
        assert!((44.0..=50.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn degenerate_densities() {
        assert_eq!(ternary_entropy_bits_per_param(0.0), 0.0);
        // k=1: all entries ±1 uniformly → 1 bit each.
        assert!((ternary_entropy_bits_per_param(1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_monotone_in_density_below_two_thirds() {
        // H'(k) = 0 at k = 2/3 for the ternary distribution; below that
        // it's increasing.
        let mut prev = 0.0;
        for i in 1..=13 {
            let k = i as f64 * 0.05;
            let h = ternary_entropy_bits_per_param(k);
            assert!(h > prev, "k={k}");
            prev = h;
        }
    }

    #[test]
    fn bitmask_strictly_larger_than_entropy() {
        // Paper: 2·d+16 is strictly more than the entropy bound since
        // −((1−k)log2(1−k)+k·log2(k/2)) < 2 for all k.
        for k in [0.05, 0.2, 0.5, 0.9] {
            let d = 1_000_000;
            assert!(bitmask_bytes(d) as f64 * 8.0 > compeft_entropy_bits(d, k));
        }
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(500), "500 B");
        assert_eq!(human_bytes(56_000), "56.0 KB");
        assert_eq!(human_bytes(110_000_000), "110.0 MB");
        assert_eq!(human_bytes(1_460_000_000), "1.46 GB");
    }
}
