//! The sparse ternary task-vector representation produced by ComPEFT.
//!
//! After Algorithm 1, a task vector `τ ∈ R^d` becomes
//! `τ̃ = s · γ̃` with `s = α·σ(τ)` a single f32 scalar and
//! `γ̃ ∈ {−1, 0, +1}^d` sparse. We store the nonzero coordinates as two
//! sorted index lists (positive and negative), which converts losslessly
//! to both wire encodings: Golomb gap coding (optimal storage, §2.2) and
//! the two-binary-mask form (fast compute, §2.2).

use anyhow::{bail, Result};

/// Sparse ternary vector: `value[i] = scale * (+1 | -1 | 0)`.
#[derive(Clone, Debug, PartialEq)]
pub struct TernaryVector {
    /// Logical length `d`.
    pub len: usize,
    /// The shared magnitude `s = α · σ(τ)`.
    pub scale: f32,
    /// Sorted indices with value `+scale`.
    pub plus: Vec<u32>,
    /// Sorted indices with value `-scale`.
    pub minus: Vec<u32>,
}

impl TernaryVector {
    pub fn empty(len: usize) -> TernaryVector {
        TernaryVector { len, scale: 0.0, plus: Vec::new(), minus: Vec::new() }
    }

    /// Number of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.plus.len() + self.minus.len()
    }

    /// Density k = nnz / d (the paper's `k`, as a fraction).
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.len as f64
        }
    }

    /// Validate invariants: sorted, unique, in-range, disjoint sign sets.
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [("plus", &self.plus), ("minus", &self.minus)] {
            for w in v.windows(2) {
                if w[0] >= w[1] {
                    bail!("{name} indices not strictly sorted at {}", w[0]);
                }
            }
            if let Some(&last) = v.last() {
                if last as usize >= self.len {
                    bail!("{name} index {last} out of range {}", self.len);
                }
            }
        }
        // Disjointness check via merge walk.
        let (mut i, mut j) = (0, 0);
        while i < self.plus.len() && j < self.minus.len() {
            match self.plus[i].cmp(&self.minus[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    bail!("index {} is in both plus and minus", self.plus[i])
                }
            }
        }
        Ok(())
    }

    /// Materialize the dense f32 vector `s · γ̃`.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        for &i in &self.plus {
            out[i as usize] = self.scale;
        }
        for &i in &self.minus {
            out[i as usize] = -self.scale;
        }
        out
    }

    /// Write the dense values of coordinates `[start, start + out.len())`
    /// into `out` (which must be zeroed by the caller): `+scale` at plus
    /// indices, `-scale` at minus indices, untouched elsewhere. Writes
    /// plus before minus within each block, exactly like
    /// [`TernaryVector::to_dense`], so chunked parallel materialization
    /// reproduces the serial buffer bit for bit. See
    /// [`TernaryVector::scatter_blocked`] for the scatter scheme.
    pub fn fill_dense_range(&self, start: usize, out: &mut [f32]) {
        let lo = start as u64;
        let hi = (start + out.len()) as u64;
        self.scatter_blocked(start, out, lo, hi);
    }

    /// Like [`TernaryVector::fill_dense_range`], but only for support
    /// indices strictly below `limit` (segment-local, exclusive). The
    /// ternary-domain TIES trim admits a *prefix* of a tied segment's
    /// support in index order; this writes exactly that prefix's slice
    /// of the chunk, leaving clipped coordinates untouched (caller
    /// zeroes them), so chunked trimmed materialization reproduces the
    /// dense `prune_to_topk` output bit for bit.
    pub fn fill_dense_range_clipped(&self, start: usize, out: &mut [f32], limit: u32) {
        let lo = start as u64;
        // Clamp to lo so a chunk entirely past the bound is an empty
        // index range (partition points would otherwise cross).
        let hi = ((start + out.len()) as u64).min(limit as u64).max(lo);
        self.scatter_blocked(start, out, lo, hi);
    }

    /// Cache-blocked two-list scatter behind both `fill_dense_range`
    /// variants: writes `vals[s]` at each sign-`s` index in `[lo, hi)`.
    ///
    /// Rather than sweeping the whole output range once per sign (two
    /// full passes over a buffer that may be far larger than cache),
    /// the range is walked in 32 KiB blocks with both signs scattered
    /// into a block before moving on, so every output cache line is
    /// touched in one pass. The sign's value is a select from a
    /// two-entry table (`vals[s]`), not a per-element branch, and the
    /// inner loops are pure scatters: each block's index subranges are
    /// found by one binary search per list (the lists are sorted and
    /// consumed in order — cursors only move forward). Blocks cover
    /// disjoint output regions and keep the plus-before-minus write
    /// order within a block, so the result is identical to the
    /// unblocked two-pass scatter.
    fn scatter_blocked(&self, start: usize, out: &mut [f32], lo: u64, hi: u64) {
        const BLOCK: u64 = 1 << 13; // 8K f32 = 32 KiB of output per block
        let vals = [self.scale, -self.scale];
        let lists: [&[u32]; 2] = [&self.plus, &self.minus];
        let mut cur = [0usize; 2];
        let mut end = [0usize; 2];
        for s in 0..2 {
            cur[s] = lists[s].partition_point(|&i| (i as u64) < lo);
            end[s] = lists[s].partition_point(|&i| (i as u64) < hi);
        }
        let mut bs = lo;
        while bs < hi {
            let be = (bs + BLOCK).min(hi);
            for s in 0..2 {
                let list = lists[s];
                let e = cur[s]
                    + list[cur[s]..end[s]].partition_point(|&i| (i as u64) < be);
                for &i in &list[cur[s]..e] {
                    out[i as usize - start] = vals[s];
                }
                cur[s] = e;
            }
            bs = be;
        }
    }

    /// Index of the `n`-th support entry (0-based) in global index
    /// order across both signs, or `None` when `n >= nnz`. Used to turn
    /// a "first N support entries" budget into an index bound for
    /// [`TernaryVector::fill_dense_range_clipped`].
    pub fn nth_support_index(&self, n: usize) -> Option<u32> {
        self.iter_nonzero().nth(n).map(|(i, _)| i)
    }

    /// Add `s · γ̃` into an existing buffer (decompress-free apply).
    pub fn add_into(&self, out: &mut [f32], weight: f32) {
        assert_eq!(out.len(), self.len);
        let s = self.scale * weight;
        for &i in &self.plus {
            out[i as usize] += s;
        }
        for &i in &self.minus {
            out[i as usize] -= s;
        }
    }

    /// All nonzero (index, sign) pairs in index order. Sign is ±1.
    pub fn iter_nonzero(&self) -> impl Iterator<Item = (u32, i8)> + '_ {
        MergeIter { plus: &self.plus, minus: &self.minus, i: 0, j: 0 }
    }

    /// Build from a dense slice: every entry with |x| > 0 contributes its
    /// sign; `scale` is given. (The compression path proper lives in
    /// [`crate::compeft::compress`]; this is the general constructor used
    /// by tests and codecs.)
    pub fn from_dense_signs(values: &[f32], scale: f32) -> TernaryVector {
        let mut plus = Vec::new();
        let mut minus = Vec::new();
        for (i, &v) in values.iter().enumerate() {
            if v > 0.0 {
                plus.push(i as u32);
            } else if v < 0.0 {
                minus.push(i as u32);
            }
        }
        TernaryVector { len: values.len(), scale, plus, minus }
    }

    /// Exact dot product with a dense vector: `Σ_i τ̃_i · x_i`.
    pub fn dot_dense(&self, x: &[f32]) -> f64 {
        assert_eq!(x.len(), self.len);
        let mut acc = 0.0f64;
        for &i in &self.plus {
            acc += x[i as usize] as f64;
        }
        for &i in &self.minus {
            acc -= x[i as usize] as f64;
        }
        acc * self.scale as f64
    }
}

struct MergeIter<'a> {
    plus: &'a [u32],
    minus: &'a [u32],
    i: usize,
    j: usize,
}

impl<'a> Iterator for MergeIter<'a> {
    type Item = (u32, i8);

    fn next(&mut self) -> Option<(u32, i8)> {
        let p = self.plus.get(self.i).copied();
        let m = self.minus.get(self.j).copied();
        match (p, m) {
            (None, None) => None,
            (Some(a), None) => {
                self.i += 1;
                Some((a, 1))
            }
            (None, Some(b)) => {
                self.j += 1;
                Some((b, -1))
            }
            (Some(a), Some(b)) => {
                if a < b {
                    self.i += 1;
                    Some((a, 1))
                } else {
                    self.j += 1;
                    Some((b, -1))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TernaryVector {
        TernaryVector { len: 10, scale: 0.5, plus: vec![0, 3, 7], minus: vec![2, 9] }
    }

    #[test]
    fn dense_roundtrip() {
        let t = sample();
        t.validate().unwrap();
        let d = t.to_dense();
        assert_eq!(d, vec![0.5, 0.0, -0.5, 0.5, 0.0, 0.0, 0.0, 0.5, 0.0, -0.5]);
        let back = TernaryVector::from_dense_signs(&d, t.scale);
        assert_eq!(back, t);
    }

    #[test]
    fn nnz_density() {
        let t = sample();
        assert_eq!(t.nnz(), 5);
        assert!((t.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_violations() {
        let mut t = sample();
        t.plus = vec![3, 0];
        assert!(t.validate().is_err());

        let mut t = sample();
        t.minus = vec![3];
        assert!(t.validate().is_err(), "overlap with plus");

        let mut t = sample();
        t.plus = vec![10];
        assert!(t.validate().is_err(), "out of range");
    }

    #[test]
    fn merge_iter_in_order() {
        let t = sample();
        let pairs: Vec<_> = t.iter_nonzero().collect();
        assert_eq!(pairs, vec![(0, 1), (2, -1), (3, 1), (7, 1), (9, -1)]);
    }

    #[test]
    fn add_into_and_dot() {
        let t = sample();
        let mut buf = vec![1.0f32; 10];
        t.add_into(&mut buf, 2.0);
        assert_eq!(buf[0], 2.0);
        assert_eq!(buf[2], 0.0);
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        // dot = 0.5 * (0 + 3 + 7 - 2 - 9) = -0.5
        assert!((t.dot_dense(&x) + 0.5).abs() < 1e-9);
    }

    #[test]
    fn fill_dense_range_matches_to_dense_at_every_split() {
        let t = sample();
        let dense = t.to_dense();
        for chunk in 1..=t.len {
            let mut out = vec![0.0f32; t.len];
            let mut start = 0;
            for piece in out.chunks_mut(chunk) {
                t.fill_dense_range(start, piece);
                start += piece.len();
            }
            assert_eq!(out, dense, "chunk {chunk}");
        }
        // Empty range and tail range.
        let mut none: [f32; 0] = [];
        t.fill_dense_range(5, &mut none);
        let mut tail = vec![0.0f32; 1];
        t.fill_dense_range(9, &mut tail);
        assert_eq!(tail, vec![-0.5]);
    }

    #[test]
    fn clipped_fill_is_prefix_of_support() {
        let t = sample(); // plus [0,3,7], minus [2,9]; support 0,2,3,7,9
        // limit 4 admits support {0,2,3} only, at every chunking.
        for chunk in 1..=t.len {
            let mut out = vec![0.0f32; t.len];
            let mut start = 0;
            for piece in out.chunks_mut(chunk) {
                t.fill_dense_range_clipped(start, piece, 4);
                start += piece.len();
            }
            assert_eq!(
                out,
                vec![0.5, 0.0, -0.5, 0.5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
                "chunk {chunk}"
            );
        }
        // limit 0 admits nothing; limit >= len admits everything.
        let mut none = vec![0.0f32; t.len];
        t.fill_dense_range_clipped(0, &mut none, 0);
        assert_eq!(none, vec![0.0; t.len]);
        let mut all = vec![0.0f32; t.len];
        t.fill_dense_range_clipped(0, &mut all, t.len as u32);
        assert_eq!(all, t.to_dense());
    }

    #[test]
    fn nth_support_index_walks_in_order() {
        let t = sample();
        let support: Vec<u32> = t.iter_nonzero().map(|(i, _)| i).collect();
        assert_eq!(support, vec![0, 2, 3, 7, 9]);
        for (n, &i) in support.iter().enumerate() {
            assert_eq!(t.nth_support_index(n), Some(i));
        }
        assert_eq!(t.nth_support_index(5), None);
        assert_eq!(TernaryVector::empty(3).nth_support_index(0), None);
    }

    #[test]
    fn empty_vector() {
        let t = TernaryVector::empty(4);
        t.validate().unwrap();
        assert_eq!(t.to_dense(), vec![0.0; 4]);
        assert_eq!(t.nnz(), 0);
    }
}
