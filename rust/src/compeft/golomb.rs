//! Golomb–Rice coding of sparse ternary vectors (paper §2.2, "Optimal
//! Compression via Golomb Coding").
//!
//! Nonzero positions of a k-dense vector have geometrically distributed
//! gaps, for which Golomb coding is near-entropy-optimal. Following
//! Strom (2015) and Sattler et al. (2019), we use the power-of-two
//! (Rice) parameter
//!
//! ```text
//! b* = 1 + ⌊log2( log(φ − 1) / log(1 − p) )⌋ ,   φ = (√5+1)/2
//! ```
//!
//! and encode each inter-nonzero gap as quotient (unary) + b*-bit
//! remainder, followed by one sign bit. The stream is prefixed by a
//! small self-describing header so decode needs no side channel.

use crate::compeft::ternary::TernaryVector;
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::pool::{chunk_ranges, ThreadPool};
use anyhow::{bail, Context, Result};

/// Golden ratio φ.
const PHI: f64 = 1.618033988749895;

/// Optimal Rice parameter b* for nonzero probability (density) `p`.
///
/// Returns at least 0; for p ≥ ~0.38 the optimum collapses to 0 bits of
/// remainder (pure unary).
pub fn rice_parameter(p: f64) -> u32 {
    if p <= 0.0 {
        return 31; // degenerate: no nonzeros; parameter unused
    }
    if p >= 1.0 {
        return 0;
    }
    let ratio = (PHI - 1.0).ln() / (1.0 - p).ln();
    if ratio < 1.0 {
        // log2(ratio) < 0 → b* would go negative; clamp at 0.
        return 0;
    }
    (1.0 + ratio.log2().floor()) as u32
}

/// Average bits per encoded position `b̄_pos` (paper §2.2 footnote 2):
/// `b̄_pos = b* + 1 / (1 − (1−p)^(2^b*))`.
pub fn avg_bits_per_position(p: f64) -> f64 {
    let b = rice_parameter(p) as f64;
    let denom = 1.0 - (1.0 - p).powf(2f64.powf(b));
    b + 1.0 / denom
}

const MAGIC: u32 = 0x43504754; // "CPGT"

/// Rice parameter for this vector's density (clamped to the wire
/// format's 30-bit remainder limit).
fn stream_rice_parameter(t: &TernaryVector) -> u32 {
    let p = if t.len == 0 { 0.0 } else { t.nnz() as f64 / t.len as f64 };
    rice_parameter(p).min(30)
}

/// Start a stream: writer with the self-describing header in place.
fn stream_header(t: &TernaryVector, b: u32) -> BitWriter {
    let mut w = BitWriter::with_capacity(25 + (t.nnz() * (b as usize + 3)) / 8);
    w.put_bits(MAGIC as u64, 32);
    w.put_bits(t.len as u64, 64);
    w.put_bits(t.nnz() as u64, 64);
    w.put_bits(b as u64, 8);
    w.put_bits(t.scale.to_bits() as u64, 32);
    w
}

/// Rice-encode a run of (index, sign) entries whose predecessor nonzero
/// sat at index `prev` (−1 at stream start). Both the serial and the
/// per-chunk parallel encoders funnel through this one loop, so the
/// gap/sign wire format lives in exactly one place.
fn encode_entries<I: IntoIterator<Item = (u32, i8)>>(
    w: &mut BitWriter,
    entries: I,
    mut prev: i64,
    b: u32,
) {
    for (idx, sign) in entries {
        let gap = (idx as i64 - prev - 1) as u64; // zeros between nonzeros
        w.put_unary(gap >> b);
        w.put_bits(gap & ((1u64 << b) - 1), b);
        w.put_bit(sign > 0);
        prev = idx as i64;
    }
}

/// Encode a ternary vector to a Golomb-coded byte stream.
///
/// Layout: magic u32 | len u64 | nnz u64 | b u8 | scale f32 |
/// then per nonzero (in index order): Rice(gap) ++ sign bit.
pub fn encode(t: &TernaryVector) -> Vec<u8> {
    let b = stream_rice_parameter(t);
    let mut w = stream_header(t, b);
    encode_entries(&mut w, t.iter_nonzero(), -1, b);
    w.into_bytes()
}

/// Parallel [`encode`]: byte-identical output.
///
/// The gap stream looks sequential (each gap depends on the previous
/// nonzero), but the *indices* are all known up front, so the stream
/// splits cleanly: a worker encoding nonzeros `[s, e)` seeds its first
/// gap from nonzero `s−1`'s index. Per-range substreams are then
/// bit-concatenated in range order ([`BitWriter::append`]), which
/// reproduces the serial writer's bytes exactly.
///
/// `chunk_nnz` is the number of nonzeros per parallel task; it divides
/// work only and never changes the output.
pub fn encode_par(t: &TernaryVector, pool: &ThreadPool, chunk_nnz: usize) -> Vec<u8> {
    let b = stream_rice_parameter(t);
    let mut w = stream_header(t, b);

    let merged: Vec<(u32, i8)> = t.iter_nonzero().collect();
    let ranges = chunk_ranges(merged.len(), chunk_nnz);
    let pieces: Vec<BitWriter> = pool.scoped_map(ranges, |(s, e)| {
        let mut piece = BitWriter::new();
        let prev: i64 = if s == 0 { -1 } else { merged[s - 1].0 as i64 };
        encode_entries(&mut piece, merged[s..e].iter().copied(), prev, b);
        piece
    });
    for piece in &pieces {
        w.append(piece);
    }
    w.into_bytes()
}

/// Decode a Golomb-coded byte stream back to a ternary vector.
pub fn decode(bytes: &[u8]) -> Result<TernaryVector> {
    let mut r = BitReader::new(bytes);
    let magic = r.get_bits(32).context("truncated header")? as u32;
    if magic != MAGIC {
        bail!("bad golomb magic {magic:#x}");
    }
    let len = r.get_bits(64).context("len")? as usize;
    let nnz = r.get_bits(64).context("nnz")? as usize;
    let b = r.get_bits(8).context("rice parameter")? as u32;
    if b > 30 {
        bail!("invalid rice parameter {b}");
    }
    let scale = f32::from_bits(r.get_bits(32).context("scale")? as u32);
    if nnz > len {
        bail!("nnz {nnz} exceeds len {len}");
    }

    let mut plus = Vec::with_capacity(nnz / 2 + 1);
    let mut minus = Vec::with_capacity(nnz / 2 + 1);
    let mut prev: i64 = -1;
    for _ in 0..nnz {
        let q = r.get_unary().context("truncated unary gap")?;
        let rem = r.get_bits(b).context("truncated remainder")?;
        let gap = (q << b) | rem;
        let idx = prev + 1 + gap as i64;
        if idx as usize >= len {
            bail!("decoded index {idx} out of range {len}");
        }
        let sign = r.get_bit().context("truncated sign bit")?;
        if sign {
            plus.push(idx as u32);
        } else {
            minus.push(idx as u32);
        }
        prev = idx;
    }
    Ok(TernaryVector { len, scale, plus, minus })
}

/// Exact encoded size in bytes for a ternary vector without encoding it.
pub fn encoded_size_bytes(t: &TernaryVector) -> u64 {
    let nnz = t.nnz() as u64;
    let p = if t.len == 0 { 0.0 } else { nnz as f64 / t.len as f64 };
    let b = rice_parameter(p).min(30) as u64;
    let mut bits = 32 + 64 + 64 + 8 + 32; // header
    let mut prev: i64 = -1;
    for (idx, _) in t.iter_nonzero() {
        let gap = (idx as i64 - prev - 1) as u64;
        bits += (gap >> b) + 1 + b + 1; // unary + remainder + sign
        prev = idx as i64;
    }
    bits.div_ceil(8)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn rice_parameter_examples() {
        // p = 0.05 → E[gap] = 19, b* should be ~5.
        let b = rice_parameter(0.05);
        assert!((4..=6).contains(&b), "b*={b}");
        assert_eq!(rice_parameter(1.0), 0);
        assert!(rice_parameter(0.5) <= 1);
    }

    #[test]
    fn avg_bits_decreasing_in_density() {
        // Denser vectors need fewer bits per position.
        assert!(avg_bits_per_position(0.05) > avg_bits_per_position(0.3));
        // At p=0.05 the paper reports ~0.34 bits/param total, which is
        // k * (b̄_pos + 1) ≈ 0.05 * ~7 ≈ 0.35.
        let per_param = 0.05 * (avg_bits_per_position(0.05) + 1.0);
        assert!((0.28..=0.42).contains(&per_param), "{per_param}");
    }

    #[test]
    fn roundtrip_simple() {
        let t = TernaryVector {
            len: 100,
            scale: 0.125,
            plus: vec![0, 17, 63, 64, 99],
            minus: vec![1, 50],
        };
        let bytes = encode(&t);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(bytes.len() as u64, encoded_size_bytes(&t));
    }

    #[test]
    fn roundtrip_edge_cases() {
        for t in [
            TernaryVector::empty(0),
            TernaryVector::empty(1000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
            TernaryVector {
                len: 3,
                scale: -2.5,
                plus: vec![0, 1, 2],
                minus: vec![],
            },
        ] {
            let back = decode(&encode(&t)).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn prop_roundtrip_random() {
        prop::check(
            "golomb roundtrip",
            80,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(20_000);
                let k = [0.01, 0.05, 0.1, 0.3, 0.9][rng.range(0, 5)];
                let tau = prop::task_vector_like(rng, n);
                compress_vector(&tau, &CompressConfig { density: k, ..Default::default() })
            },
            |t| {
                let bytes = encode(t);
                if bytes.len() as u64 != encoded_size_bytes(t) {
                    return Err("size prediction mismatch".into());
                }
                let back = decode(&bytes).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err("roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// Random ternary vector built directly from index sets (not via the
    /// compressor): sample nnz distinct indices, split them by a coin
    /// flip into plus/minus.
    pub(crate) fn random_index_sets(rng: &mut Pcg, len: usize) -> TernaryVector {
        let nnz = if len == 0 { 0 } else { rng.range(0, len + 1) };
        let mut idx = rng.sample_indices(len, nnz);
        idx.sort_unstable();
        let mut plus = Vec::new();
        let mut minus = Vec::new();
        for i in idx {
            if rng.next_f32() < 0.5 {
                plus.push(i as u32);
            } else {
                minus.push(i as u32);
            }
        }
        let scale = (rng.next_f64() * 4.0 - 2.0) as f32;
        TernaryVector { len, scale, plus, minus }
    }

    #[test]
    fn prop_roundtrip_random_index_sets() {
        prop::check(
            "golomb roundtrip on raw index sets",
            60,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(10_000);
                random_index_sets(rng, n)
            },
            |t| {
                t.validate().map_err(|e| e.to_string())?;
                let bytes = encode(t);
                if bytes.len() as u64 != encoded_size_bytes(t) {
                    return Err("size prediction mismatch".into());
                }
                let back = decode(&bytes).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err(format!(
                        "roundtrip mismatch: {} vs {} nonzeros",
                        back.nnz(),
                        t.nnz()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn encode_par_is_byte_identical_to_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(31);
        let mut cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(5000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
        ];
        for len in [100usize, 4097, 50_000] {
            cases.push(random_index_sets(&mut rng, len));
            let tau = prop::task_vector_like(&mut rng, len);
            cases.push(compress_vector(
                &tau,
                &CompressConfig { density: 0.05, ..Default::default() },
            ));
        }
        for workers in [1usize, 2, 8] {
            let pool = ThreadPool::new(workers);
            for chunk_nnz in [1usize, 7, 256, 1 << 20] {
                for (i, t) in cases.iter().enumerate() {
                    let serial = encode(t);
                    let par = encode_par(t, &pool, chunk_nnz);
                    assert_eq!(
                        serial, par,
                        "case {i} workers {workers} chunk_nnz {chunk_nnz}"
                    );
                }
            }
        }
    }

    #[test]
    fn near_entropy_at_low_density() {
        // Encoded size should be close to the entropy bound for random
        // sparse vectors (within ~25% at k=0.05 given the 25-byte header).
        let mut rng = Pcg::seed(5);
        let d = 100_000usize;
        let tau = prop::task_vector_like(&mut rng, d);
        let t = compress_vector(
            &tau,
            &CompressConfig { density: 0.05, ..Default::default() },
        );
        let bytes = encode(&t).len() as f64 * 8.0;
        let entropy = crate::compeft::entropy::compeft_entropy_bits(d, 0.05);
        assert!(
            bytes < entropy * 1.25,
            "encoded {bytes} bits vs entropy {entropy} bits"
        );
    }

    #[test]
    fn decode_rejects_corruption() {
        let t = TernaryVector { len: 50, scale: 1.0, plus: vec![3, 20], minus: vec![7] };
        let mut bytes = encode(&t);
        bytes[0] ^= 0xFF; // magic
        assert!(decode(&bytes).is_err());
        assert!(decode(&[]).is_err());
        let bytes = encode(&t);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err() || {
            // Truncating may still decode if padding-only; ensure indices valid then.
            decode(&bytes[..bytes.len() - 1]).map(|v| v.validate().is_ok()).unwrap_or(false)
        });
    }
}
