//! Golomb–Rice coding of sparse ternary vectors (paper §2.2, "Optimal
//! Compression via Golomb Coding").
//!
//! Nonzero positions of a k-dense vector have geometrically distributed
//! gaps, for which Golomb coding is near-entropy-optimal. Following
//! Strom (2015) and Sattler et al. (2019), we use the power-of-two
//! (Rice) parameter
//!
//! ```text
//! b* = 1 + ⌊log2( log(φ − 1) / log(1 − p) )⌋ ,   φ = (√5+1)/2
//! ```
//!
//! and encode each inter-nonzero gap as quotient (unary) + b*-bit
//! remainder, followed by one sign bit. The stream is prefixed by a
//! small self-describing header so decode needs no side channel.

use crate::compeft::ternary::TernaryVector;
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::pool::{chunk_ranges, ThreadPool};
use anyhow::{bail, Context, Result};

/// Golden ratio φ.
const PHI: f64 = 1.618033988749895;

/// Optimal Rice parameter b* for nonzero probability (density) `p`.
///
/// Returns at least 0; for p ≥ ~0.38 the optimum collapses to 0 bits of
/// remainder (pure unary).
pub fn rice_parameter(p: f64) -> u32 {
    if p <= 0.0 {
        return 31; // degenerate: no nonzeros; parameter unused
    }
    if p >= 1.0 {
        return 0;
    }
    let ratio = (PHI - 1.0).ln() / (1.0 - p).ln();
    if ratio < 1.0 {
        // log2(ratio) < 0 → b* would go negative; clamp at 0.
        return 0;
    }
    (1.0 + ratio.log2().floor()) as u32
}

/// Average bits per encoded position `b̄_pos` (paper §2.2 footnote 2):
/// `b̄_pos = b* + 1 / (1 − (1−p)^(2^b*))`.
pub fn avg_bits_per_position(p: f64) -> f64 {
    let b = rice_parameter(p) as f64;
    let denom = 1.0 - (1.0 - p).powf(2f64.powf(b));
    b + 1.0 / denom
}

const MAGIC: u32 = 0x43504754; // "CPGT"

/// Bits in the self-describing stream header:
/// magic u32 | len u64 | nnz u64 | b u8 | scale f32.
const HEADER_BITS: u64 = 32 + 64 + 64 + 8 + 32;

/// Sentinel in a [`FrameTable`] for "no preceding nonzero" (stream
/// start, logical prev = −1).
pub const NO_PREV: u32 = u32::MAX;

/// Frame index over one Golomb payload (`.cpeft` v2): entry `f` locates
/// nonzero number `f · chunk_nnz` in the bit stream, so a decoder can
/// start mid-payload without replaying the gaps before it. The table is
/// tiny (12 bytes per frame; the container default is 8K nonzeros per
/// frame) and never changes the payload bytes — framing is pure
/// metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FrameTable {
    /// Nonzeros per frame (fixed; the last frame may be short).
    pub chunk_nnz: u32,
    /// Per frame: (absolute bit offset of the frame's first codeword,
    /// index of the nonzero preceding the frame — [`NO_PREV`] at stream
    /// start).
    pub frames: Vec<(u64, u32)>,
}

impl FrameTable {
    /// Number of frames.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }
}

/// Compute the frame table for `t` without encoding it: the same
/// per-entry bit-cost walk as [`encoded_size_bytes`], sampling the
/// running offset at every `chunk_nnz`-th nonzero. Both `to_bytes` and
/// `to_bytes_par` call this, so the stored table always describes the
/// payload exactly.
pub fn frame_table(t: &TernaryVector, chunk_nnz: usize) -> FrameTable {
    let chunk_nnz = chunk_nnz.clamp(1, u32::MAX as usize);
    let b = stream_rice_parameter(t) as u64;
    // compeft-lint: allow(no-unchecked-wire-alloc) -- encode path: sized from the in-memory vector
    let mut frames = Vec::with_capacity(t.nnz().div_ceil(chunk_nnz));
    let mut bits = HEADER_BITS;
    let mut prev: i64 = -1;
    for (i, (idx, _)) in t.iter_nonzero().enumerate() {
        if i % chunk_nnz == 0 {
            frames.push((bits, if prev < 0 { NO_PREV } else { prev as u32 }));
        }
        let gap = (idx as i64 - prev - 1) as u64;
        bits += (gap >> b) + 1 + b + 1; // unary + remainder + sign
        prev = idx as i64;
    }
    FrameTable { chunk_nnz: chunk_nnz as u32, frames }
}

/// Rice parameter for this vector's density (clamped to the wire
/// format's 30-bit remainder limit).
fn stream_rice_parameter(t: &TernaryVector) -> u32 {
    let p = if t.len == 0 { 0.0 } else { t.nnz() as f64 / t.len as f64 };
    rice_parameter(p).min(30)
}

/// Start a stream: writer with the self-describing header in place.
fn stream_header(t: &TernaryVector, b: u32) -> BitWriter {
    let mut w = BitWriter::with_capacity(25 + (t.nnz() * (b as usize + 3)) / 8);
    w.put_bits(MAGIC as u64, 32);
    w.put_bits(t.len as u64, 64);
    w.put_bits(t.nnz() as u64, 64);
    w.put_bits(b as u64, 8);
    w.put_bits(t.scale.to_bits() as u64, 32);
    w
}

/// Rice-encode a run of (index, sign) entries whose predecessor nonzero
/// sat at index `prev` (−1 at stream start). Both the serial and the
/// per-chunk parallel encoders funnel through this one loop, so the
/// gap/sign wire format lives in exactly one place.
fn encode_entries<I: IntoIterator<Item = (u32, i8)>>(
    w: &mut BitWriter,
    entries: I,
    mut prev: i64,
    b: u32,
) {
    for (idx, sign) in entries {
        let gap = (idx as i64 - prev - 1) as u64; // zeros between nonzeros
        w.put_unary(gap >> b);
        w.put_bits(gap & ((1u64 << b) - 1), b);
        w.put_bit(sign > 0);
        prev = idx as i64;
    }
}

/// Encode a ternary vector to a Golomb-coded byte stream.
///
/// Layout: magic u32 | len u64 | nnz u64 | b u8 | scale f32 |
/// then per nonzero (in index order): Rice(gap) ++ sign bit.
pub fn encode(t: &TernaryVector) -> Vec<u8> {
    let b = stream_rice_parameter(t);
    let mut w = stream_header(t, b);
    encode_entries(&mut w, t.iter_nonzero(), -1, b);
    w.into_bytes()
}

/// Parallel [`encode`]: byte-identical output.
///
/// The gap stream looks sequential (each gap depends on the previous
/// nonzero), but the *indices* are all known up front, so the stream
/// splits cleanly: a worker encoding nonzeros `[s, e)` seeds its first
/// gap from nonzero `s−1`'s index. Per-range substreams are then
/// bit-concatenated in range order ([`BitWriter::append`]), which
/// reproduces the serial writer's bytes exactly.
///
/// `chunk_nnz` is the number of nonzeros per parallel task; it divides
/// work only and never changes the output.
pub fn encode_par(t: &TernaryVector, pool: &ThreadPool, chunk_nnz: usize) -> Vec<u8> {
    let b = stream_rice_parameter(t);
    let mut w = stream_header(t, b);

    let merged: Vec<(u32, i8)> = t.iter_nonzero().collect();
    let ranges = chunk_ranges(merged.len(), chunk_nnz);
    let pieces: Vec<BitWriter> = pool.scoped_map(ranges, |(s, e)| {
        let mut piece = BitWriter::new();
        // `chunk_ranges` yields in-bounds, contiguous ranges; `get`
        // keeps the closure panic-free regardless.
        let prev: i64 =
            if s == 0 { -1 } else { merged.get(s - 1).map_or(-1, |&(i, _)| i as i64) };
        let run = merged.get(s..e).unwrap_or_default();
        encode_entries(&mut piece, run.iter().copied(), prev, b);
        piece
    });
    for piece in &pieces {
        w.append(piece);
    }
    w.into_bytes()
}

/// Parsed stream header fields.
struct StreamHeader {
    len: usize,
    nnz: usize,
    b: u32,
    scale: f32,
}

fn parse_header(r: &mut BitReader, payload_bytes: usize) -> Result<StreamHeader> {
    let magic = r.get_bits(32).context("truncated header")? as u32;
    if magic != MAGIC {
        bail!("bad golomb magic {magic:#x}");
    }
    let len = r.get_bits(64).context("len")? as usize;
    let nnz = r.get_bits(64).context("nnz")? as usize;
    let b = r.get_bits(8).context("rice parameter")? as u32;
    if b > 30 {
        bail!("invalid rice parameter {b}");
    }
    let scale = f32::from_bits(r.get_bits(32).context("scale")? as u32);
    if nnz > len {
        bail!("nnz {nnz} exceeds len {len}");
    }
    // Every entry costs ≥ 2 bits (unary terminator + sign), so a
    // stream of `payload_bytes` cannot hold more than 4·bytes entries.
    // Bounds the index-list pre-allocations below: a corrupt header
    // declaring an absurd nnz fails here instead of allocation-bombing.
    if nnz > payload_bytes.saturating_mul(4) {
        bail!("declared nnz {nnz} impossible for a {payload_bytes}-byte payload");
    }
    Ok(StreamHeader { len, nnz, b, scale })
}

/// Rice-decode `count` (gap, sign) entries whose predecessor nonzero sat
/// at index `prev` (−1 at stream start), appending indices to
/// `plus`/`minus`. Returns the index of the last decoded nonzero. Both
/// the serial and the per-frame parallel decoders funnel through this
/// one loop — the exact mirror of [`encode_entries`].
fn decode_entries(
    r: &mut BitReader,
    count: usize,
    mut prev: i64,
    b: u32,
    len: usize,
    plus: &mut Vec<u32>,
    minus: &mut Vec<u32>,
) -> Result<i64> {
    for _ in 0..count {
        let q = r.get_unary().context("truncated unary gap")?;
        let rem = r.get_bits(b).context("truncated remainder")?;
        let gap = (q << b) | rem;
        let idx = prev + 1 + gap as i64;
        if idx as usize >= len {
            bail!("decoded index {idx} out of range {len}");
        }
        let sign = r.get_bit().context("truncated sign bit")?;
        if sign {
            plus.push(idx as u32);
        } else {
            minus.push(idx as u32);
        }
        prev = idx;
    }
    Ok(prev)
}

/// Decode a Golomb-coded byte stream back to a ternary vector.
pub fn decode(bytes: &[u8]) -> Result<TernaryVector> {
    let mut r = BitReader::new(bytes);
    let h = parse_header(&mut r, bytes.len())?;
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut plus = Vec::with_capacity(h.nnz / 2 + 1);
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut minus = Vec::with_capacity(h.nnz / 2 + 1);
    decode_entries(&mut r, h.nnz, -1, h.b, h.len, &mut plus, &mut minus)?;
    Ok(TernaryVector { len: h.len, scale: h.scale, plus, minus })
}

/// Parallel [`decode`]: bit-identical output at any worker count.
///
/// The gap stream is sequential (each gap is relative to the previous
/// nonzero), so decode cannot split blindly — but a [`FrameTable`]
/// records, for every `chunk_nnz`-th nonzero, the bit offset of its
/// codeword and the index of its predecessor. Each frame then decodes
/// independently with the exact serial loop ([`decode_entries`]), and
/// per-frame index lists concatenated in frame order reproduce the
/// serial decoder's output exactly (frames partition the nonzeros in
/// index order).
///
/// Frame-table consistency is verified: frame count must match
/// `⌈nnz / chunk_nnz⌉`, and every frame's declared predecessor must
/// equal the last index decoded by the previous frame — a lying table
/// (CRC-consistent but wrong) fails loudly instead of decoding garbage.
pub fn decode_par(
    bytes: &[u8],
    table: &FrameTable,
    pool: &ThreadPool,
) -> Result<TernaryVector> {
    let mut r = BitReader::new(bytes);
    let h = parse_header(&mut r, bytes.len())?;
    let chunk = table.chunk_nnz as usize;
    if chunk == 0 {
        bail!("frame table chunk_nnz is zero");
    }
    let expect = h.nnz.div_ceil(chunk);
    if table.frames.len() != expect {
        bail!(
            "frame table has {} frames, expected {expect} for nnz {}",
            table.frames.len(),
            h.nnz
        );
    }
    if h.nnz == 0 {
        return Ok(TernaryVector {
            len: h.len,
            scale: h.scale,
            plus: Vec::new(),
            minus: Vec::new(),
        });
    }

    let items: Vec<(usize, u64, u32)> = table
        .frames
        .iter()
        .enumerate()
        .map(|(f, &(off, prev))| (f, off, prev))
        .collect();
    let pieces: Vec<Result<(Vec<u32>, Vec<u32>, i64)>> =
        pool.scoped_map(items, |(f, off, prev_raw)| {
            let count = chunk.min(h.nnz - f * chunk);
            let mut fr = BitReader::new(bytes);
            fr.seek(off)
                .ok_or_else(|| anyhow::anyhow!("bit offset {off} beyond payload"))?;
            let prev: i64 = if prev_raw == NO_PREV { -1 } else { prev_raw as i64 };
            let mut plus = Vec::with_capacity(count / 2 + 1);
            let mut minus = Vec::with_capacity(count / 2 + 1);
            let last =
                decode_entries(&mut fr, count, prev, h.b, h.len, &mut plus, &mut minus)?;
            Ok((plus, minus, last))
        });

    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut plus = Vec::with_capacity(h.nnz / 2 + 1);
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut minus = Vec::with_capacity(h.nnz / 2 + 1);
    let mut prev_last: i64 = -1;
    for (f, piece) in pieces.into_iter().enumerate() {
        let (p, m, last) = piece.with_context(|| format!("frame {f}"))?;
        let declared: i64 = table
            .frames
            .get(f)
            .map_or(-1, |&(_, d)| if d == NO_PREV { -1 } else { d as i64 });
        if declared != prev_last {
            bail!(
                "frame {f}: declared prev index {declared} does not continue the \
                 previous frame (last decoded index {prev_last})"
            );
        }
        prev_last = last;
        plus.extend_from_slice(&p);
        minus.extend_from_slice(&m);
    }
    Ok(TernaryVector { len: h.len, scale: h.scale, plus, minus })
}

/// Exact encoded size in bytes for a ternary vector without encoding it.
pub fn encoded_size_bytes(t: &TernaryVector) -> u64 {
    let nnz = t.nnz() as u64;
    let p = if t.len == 0 { 0.0 } else { nnz as f64 / t.len as f64 };
    let b = rice_parameter(p).min(30) as u64;
    let mut bits = HEADER_BITS;
    let mut prev: i64 = -1;
    for (idx, _) in t.iter_nonzero() {
        let gap = (idx as i64 - prev - 1) as u64;
        bits += (gap >> b) + 1 + b + 1; // unary + remainder + sign
        prev = idx as i64;
    }
    bits.div_ceil(8)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn rice_parameter_examples() {
        // p = 0.05 → E[gap] = 19, b* should be ~5.
        let b = rice_parameter(0.05);
        assert!((4..=6).contains(&b), "b*={b}");
        assert_eq!(rice_parameter(1.0), 0);
        assert!(rice_parameter(0.5) <= 1);
    }

    #[test]
    fn avg_bits_decreasing_in_density() {
        // Denser vectors need fewer bits per position.
        assert!(avg_bits_per_position(0.05) > avg_bits_per_position(0.3));
        // At p=0.05 the paper reports ~0.34 bits/param total, which is
        // k * (b̄_pos + 1) ≈ 0.05 * ~7 ≈ 0.35.
        let per_param = 0.05 * (avg_bits_per_position(0.05) + 1.0);
        assert!((0.28..=0.42).contains(&per_param), "{per_param}");
    }

    #[test]
    fn roundtrip_simple() {
        let t = TernaryVector {
            len: 100,
            scale: 0.125,
            plus: vec![0, 17, 63, 64, 99],
            minus: vec![1, 50],
        };
        let bytes = encode(&t);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(bytes.len() as u64, encoded_size_bytes(&t));
    }

    #[test]
    fn roundtrip_edge_cases() {
        for t in [
            TernaryVector::empty(0),
            TernaryVector::empty(1000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
            TernaryVector {
                len: 3,
                scale: -2.5,
                plus: vec![0, 1, 2],
                minus: vec![],
            },
        ] {
            let back = decode(&encode(&t)).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn prop_roundtrip_random() {
        prop::check(
            "golomb roundtrip",
            80,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(20_000);
                let k = [0.01, 0.05, 0.1, 0.3, 0.9][rng.range(0, 5)];
                let tau = prop::task_vector_like(rng, n);
                compress_vector(&tau, &CompressConfig { density: k, ..Default::default() })
            },
            |t| {
                let bytes = encode(t);
                if bytes.len() as u64 != encoded_size_bytes(t) {
                    return Err("size prediction mismatch".into());
                }
                let back = decode(&bytes).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err("roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// Random ternary vector built directly from index sets (not via the
    /// compressor): sample nnz distinct indices, split them by a coin
    /// flip into plus/minus.
    pub(crate) fn random_index_sets(rng: &mut Pcg, len: usize) -> TernaryVector {
        let nnz = if len == 0 { 0 } else { rng.range(0, len + 1) };
        let mut idx = rng.sample_indices(len, nnz);
        idx.sort_unstable();
        let mut plus = Vec::new();
        let mut minus = Vec::new();
        for i in idx {
            if rng.next_f32() < 0.5 {
                plus.push(i as u32);
            } else {
                minus.push(i as u32);
            }
        }
        let scale = (rng.next_f64() * 4.0 - 2.0) as f32;
        TernaryVector { len, scale, plus, minus }
    }

    #[test]
    fn prop_roundtrip_random_index_sets() {
        prop::check(
            "golomb roundtrip on raw index sets",
            60,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(10_000);
                random_index_sets(rng, n)
            },
            |t| {
                t.validate().map_err(|e| e.to_string())?;
                let bytes = encode(t);
                if bytes.len() as u64 != encoded_size_bytes(t) {
                    return Err("size prediction mismatch".into());
                }
                let back = decode(&bytes).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err(format!(
                        "roundtrip mismatch: {} vs {} nonzeros",
                        back.nnz(),
                        t.nnz()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn encode_par_is_byte_identical_to_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(31);
        let mut cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(5000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
        ];
        for len in [100usize, 4097, 50_000] {
            cases.push(random_index_sets(&mut rng, len));
            let tau = prop::task_vector_like(&mut rng, len);
            cases.push(compress_vector(
                &tau,
                &CompressConfig { density: 0.05, ..Default::default() },
            ));
        }
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk_nnz in [1usize, 7, 256, 1 << 20] {
                for (i, t) in cases.iter().enumerate() {
                    let serial = encode(t);
                    let par = encode_par(t, &pool, chunk_nnz);
                    assert_eq!(
                        serial, par,
                        "case {i} workers {workers} chunk_nnz {chunk_nnz}"
                    );
                }
            }
        }
    }

    #[test]
    fn decode_par_is_bit_identical_to_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(47);
        let mut cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(5000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
        ];
        for len in [100usize, 4097, 50_000] {
            cases.push(random_index_sets(&mut rng, len));
            let tau = prop::task_vector_like(&mut rng, len);
            cases.push(compress_vector(
                &tau,
                &CompressConfig { density: 0.05, ..Default::default() },
            ));
        }
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk_nnz in [1usize, 7, 256, 1 << 20] {
                for (i, t) in cases.iter().enumerate() {
                    let bytes = encode(t);
                    let table = frame_table(t, chunk_nnz);
                    let serial = decode(&bytes).unwrap();
                    let par = decode_par(&bytes, &table, &pool).unwrap();
                    assert_eq!(serial.len, par.len, "case {i}");
                    assert_eq!(serial.scale.to_bits(), par.scale.to_bits(), "case {i}");
                    assert_eq!(
                        serial.plus, par.plus,
                        "case {i} workers {workers} chunk_nnz {chunk_nnz}"
                    );
                    assert_eq!(serial.minus, par.minus, "case {i}");
                }
            }
        }
    }

    #[test]
    fn prop_decode_par_roundtrip() {
        use crate::util::pool::ThreadPool;
        let pool = ThreadPool::new(4);
        prop::check(
            "framed parallel decode roundtrip",
            50,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(10_000);
                let chunk = [1usize, 13, 300, 1 << 15][rng.range(0, 4)];
                (random_index_sets(rng, n), chunk)
            },
            |(t, chunk)| {
                let bytes = encode(t);
                let table = frame_table(t, *chunk);
                if table.frames.len() != t.nnz().div_ceil((*chunk).max(1)) {
                    return Err("frame count mismatch".into());
                }
                // Offsets strictly increase (each frame holds ≥1 codeword).
                for w in table.frames.windows(2) {
                    if w[0].0 >= w[1].0 {
                        return Err("frame offsets not increasing".into());
                    }
                }
                let back = decode_par(&bytes, &table, &pool).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err("parallel roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decode_par_rejects_corrupt_tables() {
        use crate::util::pool::ThreadPool;
        let pool = ThreadPool::new(2);
        let t = TernaryVector {
            len: 500,
            scale: 1.0,
            plus: vec![3, 20, 90, 200, 333],
            minus: vec![7, 50, 450],
        };
        let bytes = encode(&t);
        let good = frame_table(&t, 3);
        assert_eq!(decode_par(&bytes, &good, &pool).unwrap(), t);

        // Wrong frame count.
        let mut bad = good.clone();
        bad.frames.pop();
        assert!(decode_par(&bytes, &bad, &pool).is_err());

        // Offset beyond the payload.
        let mut bad = good.clone();
        bad.frames[1].0 = bytes.len() as u64 * 8 + 1;
        assert!(decode_par(&bytes, &bad, &pool).is_err());

        // Lying predecessor index: breaks the continuity check.
        let mut bad = good.clone();
        bad.frames[1].1 = 499;
        assert!(decode_par(&bytes, &bad, &pool).is_err());

        // Zero chunk size.
        let bad = FrameTable { chunk_nnz: 0, frames: good.frames.clone() };
        assert!(decode_par(&bytes, &bad, &pool).is_err());
    }

    #[test]
    fn near_entropy_at_low_density() {
        // Encoded size should be close to the entropy bound for random
        // sparse vectors (within ~25% at k=0.05 given the 25-byte header).
        let mut rng = Pcg::seed(5);
        let d = 100_000usize;
        let tau = prop::task_vector_like(&mut rng, d);
        let t = compress_vector(
            &tau,
            &CompressConfig { density: 0.05, ..Default::default() },
        );
        let bytes = encode(&t).len() as f64 * 8.0;
        let entropy = crate::compeft::entropy::compeft_entropy_bits(d, 0.05);
        assert!(
            bytes < entropy * 1.25,
            "encoded {bytes} bits vs entropy {entropy} bits"
        );
    }

    #[test]
    fn decode_rejects_corruption() {
        let t = TernaryVector { len: 50, scale: 1.0, plus: vec![3, 20], minus: vec![7] };
        let mut bytes = encode(&t);
        bytes[0] ^= 0xFF; // magic
        assert!(decode(&bytes).is_err());
        assert!(decode(&[]).is_err());
        let bytes = encode(&t);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err() || {
            // Truncating may still decode if padding-only; ensure indices valid then.
            decode(&bytes[..bytes.len() - 1]).map(|v| v.validate().is_ok()).unwrap_or(false)
        });
    }
}
