//! Golomb–Rice coding of sparse ternary vectors (paper §2.2, "Optimal
//! Compression via Golomb Coding").
//!
//! Nonzero positions of a k-dense vector have geometrically distributed
//! gaps, for which Golomb coding is near-entropy-optimal. Following
//! Strom (2015) and Sattler et al. (2019), we use the power-of-two
//! (Rice) parameter
//!
//! ```text
//! b* = 1 + ⌊log2( log(φ − 1) / log(1 − p) )⌋ ,   φ = (√5+1)/2
//! ```
//!
//! and encode each inter-nonzero gap as quotient (unary) + b*-bit
//! remainder, followed by one sign bit. The stream is prefixed by a
//! small self-describing header so decode needs no side channel.

use crate::compeft::ternary::TernaryVector;
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::pool::{chunk_ranges, ThreadPool};
use anyhow::{bail, Context, Result};

/// Golden ratio φ.
const PHI: f64 = 1.618033988749895;

/// Optimal Rice parameter b* for nonzero probability (density) `p`.
///
/// Returns at least 0; for p ≥ ~0.38 the optimum collapses to 0 bits of
/// remainder (pure unary).
pub fn rice_parameter(p: f64) -> u32 {
    if p <= 0.0 {
        return 31; // degenerate: no nonzeros; parameter unused
    }
    if p >= 1.0 {
        return 0;
    }
    let ratio = (PHI - 1.0).ln() / (1.0 - p).ln();
    if ratio < 1.0 {
        // log2(ratio) < 0 → b* would go negative; clamp at 0.
        return 0;
    }
    (1.0 + ratio.log2().floor()) as u32
}

/// Average bits per encoded position `b̄_pos` (paper §2.2 footnote 2):
/// `b̄_pos = b* + 1 / (1 − (1−p)^(2^b*))`.
pub fn avg_bits_per_position(p: f64) -> f64 {
    let b = rice_parameter(p) as f64;
    let denom = 1.0 - (1.0 - p).powf(2f64.powf(b));
    b + 1.0 / denom
}

const MAGIC: u32 = 0x43504754; // "CPGT"

/// Bits in the self-describing stream header:
/// magic u32 | len u64 | nnz u64 | b u8 | scale f32.
const HEADER_BITS: u64 = 32 + 64 + 64 + 8 + 32;

/// Sentinel in a [`FrameTable`] for "no preceding nonzero" (stream
/// start, logical prev = −1).
pub const NO_PREV: u32 = u32::MAX;

/// Frame index over one Golomb payload (`.cpeft` v2): entry `f` locates
/// nonzero number `f · chunk_nnz` in the bit stream, so a decoder can
/// start mid-payload without replaying the gaps before it. The table is
/// tiny (12 bytes per frame; the container default is 8K nonzeros per
/// frame) and never changes the payload bytes — framing is pure
/// metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FrameTable {
    /// Nonzeros per frame (fixed; the last frame may be short).
    pub chunk_nnz: u32,
    /// Per frame: (absolute bit offset of the frame's first codeword,
    /// index of the nonzero preceding the frame — [`NO_PREV`] at stream
    /// start).
    pub frames: Vec<(u64, u32)>,
}

impl FrameTable {
    /// Number of frames.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }
}

/// Compute the frame table for `t` without encoding it: the same
/// per-entry bit-cost walk as [`encoded_size_bytes`], sampling the
/// running offset at every `chunk_nnz`-th nonzero. Both `to_bytes` and
/// `to_bytes_par` call this, so the stored table always describes the
/// payload exactly.
pub fn frame_table(t: &TernaryVector, chunk_nnz: usize) -> FrameTable {
    let chunk_nnz = chunk_nnz.clamp(1, u32::MAX as usize);
    let b = stream_rice_parameter(t) as u64;
    // compeft-lint: allow(no-unchecked-wire-alloc) -- encode path: sized from the in-memory vector
    let mut frames = Vec::with_capacity(t.nnz().div_ceil(chunk_nnz));
    let mut bits = HEADER_BITS;
    let mut prev: i64 = -1;
    for (i, (idx, _)) in t.iter_nonzero().enumerate() {
        if i % chunk_nnz == 0 {
            frames.push((bits, if prev < 0 { NO_PREV } else { prev as u32 }));
        }
        let gap = (idx as i64 - prev - 1) as u64;
        bits += (gap >> b) + 1 + b + 1; // unary + remainder + sign
        prev = idx as i64;
    }
    FrameTable { chunk_nnz: chunk_nnz as u32, frames }
}

/// Rice parameter for this vector's density (clamped to the wire
/// format's 30-bit remainder limit).
fn stream_rice_parameter(t: &TernaryVector) -> u32 {
    let p = if t.len == 0 { 0.0 } else { t.nnz() as f64 / t.len as f64 };
    rice_parameter(p).min(30)
}

/// Start a stream: writer with the self-describing header in place.
fn stream_header(t: &TernaryVector, b: u32) -> BitWriter {
    let mut w = BitWriter::with_capacity(25 + (t.nnz() * (b as usize + 3)) / 8);
    w.put_bits(MAGIC as u64, 32);
    w.put_bits(t.len as u64, 64);
    w.put_bits(t.nnz() as u64, 64);
    w.put_bits(b as u64, 8);
    w.put_bits(t.scale.to_bits() as u64, 32);
    w
}

/// Rice-encode a run of (index, sign) entries whose predecessor nonzero
/// sat at index `prev` (−1 at stream start). Both the serial and the
/// per-chunk parallel encoders funnel through this one loop, so the
/// gap/sign wire format lives in exactly one place.
fn encode_entries<I: IntoIterator<Item = (u32, i8)>>(
    w: &mut BitWriter,
    entries: I,
    mut prev: i64,
    b: u32,
) {
    for (idx, sign) in entries {
        let gap = (idx as i64 - prev - 1) as u64; // zeros between nonzeros
        w.put_unary(gap >> b);
        w.put_bits(gap & ((1u64 << b) - 1), b);
        w.put_bit(sign > 0);
        prev = idx as i64;
    }
}

/// Encode a ternary vector to a Golomb-coded byte stream.
///
/// Layout: magic u32 | len u64 | nnz u64 | b u8 | scale f32 |
/// then per nonzero (in index order): Rice(gap) ++ sign bit.
pub fn encode(t: &TernaryVector) -> Vec<u8> {
    let b = stream_rice_parameter(t);
    let mut w = stream_header(t, b);
    encode_entries(&mut w, t.iter_nonzero(), -1, b);
    w.into_bytes()
}

/// Parallel [`encode`]: byte-identical output.
///
/// The gap stream looks sequential (each gap depends on the previous
/// nonzero), but the *indices* are all known up front, so the stream
/// splits cleanly: a worker encoding nonzeros `[s, e)` seeds its first
/// gap from nonzero `s−1`'s index. Per-range substreams are then
/// bit-concatenated in range order ([`BitWriter::append`]), which
/// reproduces the serial writer's bytes exactly.
///
/// `chunk_nnz` is the number of nonzeros per parallel task; it divides
/// work only and never changes the output.
pub fn encode_par(t: &TernaryVector, pool: &ThreadPool, chunk_nnz: usize) -> Vec<u8> {
    let b = stream_rice_parameter(t);
    let mut w = stream_header(t, b);

    let merged: Vec<(u32, i8)> = t.iter_nonzero().collect();
    let ranges = chunk_ranges(merged.len(), chunk_nnz);
    let pieces: Vec<BitWriter> = pool.scoped_map(ranges, |(s, e)| {
        let mut piece = BitWriter::new();
        // `chunk_ranges` yields in-bounds, contiguous ranges; `get`
        // keeps the closure panic-free regardless.
        let prev: i64 =
            if s == 0 { -1 } else { merged.get(s - 1).map_or(-1, |&(i, _)| i as i64) };
        let run = merged.get(s..e).unwrap_or_default();
        encode_entries(&mut piece, run.iter().copied(), prev, b);
        piece
    });
    for piece in &pieces {
        w.append(piece);
    }
    w.into_bytes()
}

/// Parsed stream header fields.
struct StreamHeader {
    len: usize,
    nnz: usize,
    b: u32,
    scale: f32,
}

fn parse_header(r: &mut BitReader, payload_bytes: usize) -> Result<StreamHeader> {
    let magic = r.get_bits(32).context("truncated header")? as u32;
    if magic != MAGIC {
        bail!("bad golomb magic {magic:#x}");
    }
    let len = r.get_bits(64).context("len")? as usize;
    let nnz = r.get_bits(64).context("nnz")? as usize;
    let b = r.get_bits(8).context("rice parameter")? as u32;
    if b > 30 {
        bail!("invalid rice parameter {b}");
    }
    let scale = f32::from_bits(r.get_bits(32).context("scale")? as u32);
    if nnz > len {
        bail!("nnz {nnz} exceeds len {len}");
    }
    // Every entry costs ≥ 2 bits (unary terminator + sign), so a
    // stream of `payload_bytes` cannot hold more than 4·bytes entries.
    // Bounds the index-list pre-allocations below: a corrupt header
    // declaring an absurd nnz fails here instead of allocation-bombing.
    if nnz > payload_bytes.saturating_mul(4) {
        bail!("declared nnz {nnz} impossible for a {payload_bytes}-byte payload");
    }
    Ok(StreamHeader { len, nnz, b, scale })
}

/// Decode one Rice codeword (unary quotient ++ `b`-bit remainder ++
/// sign bit) starting at `r`'s position. Word-at-a-time fast path:
/// peek a 64-bit window once, and when the whole codeword fits inside
/// its valid bits, extract quotient (`leading_zeros` on the inverted
/// window), remainder, and sign with shifts alone — no per-bit loop,
/// no branch per field — then consume the codeword in one step.
/// Codewords straddling the window edge (giant gaps, or the stream
/// tail) fall back to the bit-at-a-time oracle loop, which is also
/// what reports every truncation error, so corrupt streams fail with
/// the same messages on both paths.
#[inline]
fn decode_one(r: &mut BitReader, prev: i64, b: u32, len: usize) -> Result<(u32, bool)> {
    let (w, avail) = r.peek_word();
    let ones = (!w).leading_zeros();
    // ones + terminator + remainder + sign, all inside the valid bits.
    // peek_word zero-fills below `avail`, so a unary run reaching the
    // window edge reads as `ones >= avail` and takes the slow path —
    // the guard can never mistake padding for a terminator.
    let width = ones + 2 + b;
    if width <= avail {
        let after = ones + 1; // skip the run and its terminator
        // Top `b` bits after the terminator. Two-step shift: a single
        // `>> (64 - b)` would be UB at b = 0; this form yields 0 there
        // (the first right shift zero-fills bit 63).
        let rem = ((w << after) >> 1) >> (63 - b);
        let gap = ((ones as u64) << b) | rem;
        let idx = prev + 1 + gap as i64;
        if idx as usize >= len {
            bail!("decoded index {idx} out of range {len}");
        }
        let sign = (w >> (63 - (after + b))) & 1 == 1;
        r.consume(width);
        return Ok((idx as u32, sign));
    }
    let q = r.get_unary().context("truncated unary gap")?;
    let rem = r.get_bits(b).context("truncated remainder")?;
    let gap = (q << b) | rem;
    let idx = prev + 1 + gap as i64;
    if idx as usize >= len {
        bail!("decoded index {idx} out of range {len}");
    }
    let sign = r.get_bit().context("truncated sign bit")?;
    Ok((idx as u32, sign))
}

/// Rice-decode `count` (gap, sign) entries whose predecessor nonzero sat
/// at index `prev` (−1 at stream start), appending indices to
/// `plus`/`minus`. Returns the index of the last decoded nonzero. Both
/// the serial and the per-frame parallel decoders funnel through this
/// one loop — the exact mirror of [`encode_entries`] — built on the
/// word-at-a-time [`decode_one`] kernel, with the sign dispatched by
/// select (index into a two-element array) rather than a branch.
fn decode_entries(
    r: &mut BitReader,
    count: usize,
    mut prev: i64,
    b: u32,
    len: usize,
    plus: &mut Vec<u32>,
    minus: &mut Vec<u32>,
) -> Result<i64> {
    for _ in 0..count {
        let (idx, sign) = decode_one(r, prev, b, len)?;
        [&mut *minus, &mut *plus][sign as usize].push(idx);
        prev = idx as i64;
    }
    Ok(prev)
}

/// Rice-decode exactly `slot.len()` entries into `slot` as
/// `(index, sign)` pairs, in stream order. The parallel decoder hands
/// each frame a disjoint pre-sized range of one shared buffer, so
/// frames allocate nothing. Returns the index of the last decoded
/// nonzero (the frame-continuity witness).
fn decode_entries_into(
    r: &mut BitReader,
    slot: &mut [(u32, bool)],
    mut prev: i64,
    b: u32,
    len: usize,
) -> Result<i64> {
    for e in slot.iter_mut() {
        let (idx, sign) = decode_one(r, prev, b, len)?;
        *e = (idx, sign);
        prev = idx as i64;
    }
    Ok(prev)
}

/// The original bit-at-a-time decode loop, kept verbatim as the
/// differential-test oracle for the word-at-a-time kernel (and as the
/// `ops_micro` bit-loop baseline). Never called on the serving path.
fn decode_entries_bitwise(
    r: &mut BitReader,
    count: usize,
    mut prev: i64,
    b: u32,
    len: usize,
    plus: &mut Vec<u32>,
    minus: &mut Vec<u32>,
) -> Result<i64> {
    for _ in 0..count {
        let q = r.get_unary().context("truncated unary gap")?;
        let rem = r.get_bits(b).context("truncated remainder")?;
        let gap = (q << b) | rem;
        let idx = prev + 1 + gap as i64;
        if idx as usize >= len {
            bail!("decoded index {idx} out of range {len}");
        }
        let sign = r.get_bit().context("truncated sign bit")?;
        if sign {
            plus.push(idx as u32);
        } else {
            minus.push(idx as u32);
        }
        prev = idx;
    }
    Ok(prev)
}

/// Decode a Golomb-coded byte stream back to a ternary vector.
///
/// `plus`/`minus` are each sized to the full header `nnz` bound: an
/// all-one-sign stream (legal and common for small vectors) would
/// otherwise realloc a `nnz/2`-sized list up to `nnz`, doubling the
/// worst-case decode allocations. The bound is the same
/// plausibility-checked header field either way.
pub fn decode(bytes: &[u8]) -> Result<TernaryVector> {
    let mut r = BitReader::new(bytes);
    let h = parse_header(&mut r, bytes.len())?;
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut plus = Vec::with_capacity(h.nnz);
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut minus = Vec::with_capacity(h.nnz);
    decode_entries(&mut r, h.nnz, -1, h.b, h.len, &mut plus, &mut minus)?;
    Ok(TernaryVector { len: h.len, scale: h.scale, plus, minus })
}

/// [`decode`] through the bit-at-a-time oracle loop
/// ([`decode_entries_bitwise`]): the pre-word-kernel decoder, kept as
/// the differential-test reference and the `ops_micro` bit-loop
/// baseline. Identical output and identical errors to [`decode`].
pub fn decode_bitwise(bytes: &[u8]) -> Result<TernaryVector> {
    let mut r = BitReader::new(bytes);
    let h = parse_header(&mut r, bytes.len())?;
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut plus = Vec::with_capacity(h.nnz);
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut minus = Vec::with_capacity(h.nnz);
    decode_entries_bitwise(&mut r, h.nnz, -1, h.b, h.len, &mut plus, &mut minus)?;
    Ok(TernaryVector { len: h.len, scale: h.scale, plus, minus })
}

/// Parallel [`decode`]: bit-identical output at any worker count.
///
/// The gap stream is sequential (each gap is relative to the previous
/// nonzero), so decode cannot split blindly — but a [`FrameTable`]
/// records, for every `chunk_nnz`-th nonzero, the bit offset of its
/// codeword and the index of its predecessor. Each frame then decodes
/// independently with the exact serial loop ([`decode_entries`]), and
/// per-frame index lists concatenated in frame order reproduce the
/// serial decoder's output exactly (frames partition the nonzeros in
/// index order).
///
/// Frame-table consistency is verified: frame count must match
/// `⌈nnz / chunk_nnz⌉`, and every frame's declared predecessor must
/// equal the last index decoded by the previous frame — a lying table
/// (CRC-consistent but wrong) fails loudly instead of decoding garbage.
pub fn decode_par(
    bytes: &[u8],
    table: &FrameTable,
    pool: &ThreadPool,
) -> Result<TernaryVector> {
    let mut r = BitReader::new(bytes);
    let h = parse_header(&mut r, bytes.len())?;
    let chunk = table.chunk_nnz as usize;
    if chunk == 0 {
        bail!("frame table chunk_nnz is zero");
    }
    let expect = h.nnz.div_ceil(chunk);
    if table.frames.len() != expect {
        bail!(
            "frame table has {} frames, expected {expect} for nnz {}",
            table.frames.len(),
            h.nnz
        );
    }
    if h.nnz == 0 {
        return Ok(TernaryVector {
            len: h.len,
            scale: h.scale,
            plus: Vec::new(),
            minus: Vec::new(),
        });
    }

    // Frames decode into disjoint pre-sized ranges of one shared entry
    // buffer instead of per-frame Vec pairs: `chunks_mut(chunk)` yields
    // exactly `⌈nnz / chunk⌉` slices (the last one short), matching the
    // frame count checked above, so frame `f` owns entries
    // `[f·chunk, min((f+1)·chunk, nnz))` — zero allocations inside the
    // parallel region and no concat copies afterwards.
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut entries: Vec<(u32, bool)> = vec![(0, false); h.nnz];
    let items: Vec<(u64, u32, &mut [(u32, bool)])> = table
        .frames
        .iter()
        .zip(entries.chunks_mut(chunk))
        .map(|(&(off, prev), slot)| (off, prev, slot))
        .collect();
    let lasts: Vec<Result<i64>> = pool.scoped_map(items, |(off, prev_raw, slot)| {
        let mut fr = BitReader::new(bytes);
        fr.seek(off)
            .ok_or_else(|| anyhow::anyhow!("bit offset {off} beyond payload"))?;
        let prev: i64 = if prev_raw == NO_PREV { -1 } else { prev_raw as i64 };
        decode_entries_into(&mut fr, slot, prev, h.b, h.len)
    });

    let mut prev_last: i64 = -1;
    for (f, last) in lasts.into_iter().enumerate() {
        let last = last.with_context(|| format!("frame {f}"))?;
        let declared: i64 = table
            .frames
            .get(f)
            .map_or(-1, |&(_, d)| if d == NO_PREV { -1 } else { d as i64 });
        if declared != prev_last {
            bail!(
                "frame {f}: declared prev index {declared} does not continue the \
                 previous frame (last decoded index {prev_last})"
            );
        }
        prev_last = last;
    }
    // Split by sign in stream (index) order — exactly the order the
    // serial decoder pushes, so output is bit-identical.
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut plus = Vec::with_capacity(h.nnz);
    // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
    let mut minus = Vec::with_capacity(h.nnz);
    for &(idx, sign) in &entries {
        [&mut minus, &mut plus][sign as usize].push(idx);
    }
    Ok(TernaryVector { len: h.len, scale: h.scale, plus, minus })
}

/// Sequential per-frame decoder for the fused fetch→decode path.
///
/// [`decode_par`] needs the whole payload before it can start; the
/// fused loader instead decodes frame `f` the moment the fetch has
/// delivered the bytes up to [`FrameDecoder::frame_end_byte`]`(f)`,
/// overlapping decode with the stripes still in flight. The decoder
/// performs the same header, frame-count, and frame-continuity
/// validation as `decode_par` (a lying table fails loudly here too),
/// runs the same word-at-a-time [`decode_one`] kernel into the same
/// shared entry buffer, and [`FrameDecoder::finish`] performs the same
/// stream-order sign split — so the fused path's output is
/// bit-identical to the serial and parallel decoders'.
pub struct FrameDecoder<'a> {
    bytes: &'a [u8],
    table: &'a FrameTable,
    header: StreamHeader,
    /// Shared (index, sign) buffer; frame `f` owns
    /// `[f·chunk, min((f+1)·chunk, nnz))`.
    entries: Vec<(u32, bool)>,
    /// Frames decoded so far — also the next frame to decode.
    next: usize,
    /// Last index decoded by the previous frame (continuity witness).
    prev_last: i64,
}

impl<'a> FrameDecoder<'a> {
    /// Validate the header against the frame table and set up the
    /// shared entry buffer. Fails on everything [`decode_par`] would
    /// reject before decoding (bad header, zero chunk, wrong frame
    /// count).
    pub fn new(bytes: &'a [u8], table: &'a FrameTable) -> Result<FrameDecoder<'a>> {
        let mut r = BitReader::new(bytes);
        let header = parse_header(&mut r, bytes.len())?;
        let chunk = table.chunk_nnz as usize;
        if chunk == 0 {
            bail!("frame table chunk_nnz is zero");
        }
        let expect = header.nnz.div_ceil(chunk);
        if table.frames.len() != expect {
            bail!(
                "frame table has {} frames, expected {expect} for nnz {}",
                table.frames.len(),
                header.nnz
            );
        }
        // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
        let entries: Vec<(u32, bool)> = vec![(0, false); header.nnz];
        Ok(FrameDecoder { bytes, table, header, entries, next: 0, prev_last: -1 })
    }

    /// Total number of frames in the payload.
    pub fn frame_count(&self) -> usize {
        self.table.frames.len()
    }

    /// Frames decoded so far.
    pub fn frames_done(&self) -> usize {
        self.next
    }

    /// The payload byte prefix that must have landed before frame `f`
    /// can decode: frame `f`'s codewords end where frame `f + 1`'s
    /// begin (rounded up to a whole byte); the last frame needs the
    /// full payload. This is the fusion readiness watermark the loader
    /// compares against stripe arrivals.
    pub fn frame_end_byte(&self, f: usize) -> usize {
        match self.table.frames.get(f + 1) {
            Some(&(off, _)) => (off.div_ceil(8) as usize).min(self.bytes.len()),
            None => self.bytes.len(),
        }
    }

    /// Decode the next frame (in order): check its declared predecessor
    /// continues the previous frame, then run the word kernel over its
    /// slice of the shared entry buffer.
    pub fn decode_next(&mut self) -> Result<()> {
        let f = self.next;
        let Some(&(off, prev_raw)) = self.table.frames.get(f) else {
            bail!("frame {f} out of range ({} frames)", self.table.frames.len());
        };
        let declared: i64 = if prev_raw == NO_PREV { -1 } else { prev_raw as i64 };
        if declared != self.prev_last {
            bail!(
                "frame {f}: declared prev index {declared} does not continue the \
                 previous frame (last decoded index {})",
                self.prev_last
            );
        }
        let chunk = self.table.chunk_nnz as usize;
        let lo = (f * chunk).min(self.header.nnz);
        let hi = ((f + 1) * chunk).min(self.header.nnz);
        let slot = self.entries.get_mut(lo..hi).unwrap_or_default();
        let mut r = BitReader::new(self.bytes);
        r.seek(off)
            .ok_or_else(|| anyhow::anyhow!("bit offset {off} beyond payload"))?;
        self.prev_last =
            decode_entries_into(&mut r, slot, declared, self.header.b, self.header.len)
                .with_context(|| format!("frame {f}"))?;
        self.next = f + 1;
        Ok(())
    }

    /// All frames decoded → the ternary vector, via the same
    /// stream-order sign split as [`decode_par`].
    pub fn finish(self) -> Result<TernaryVector> {
        if self.next != self.table.frames.len() {
            bail!(
                "finish with {} of {} frames decoded",
                self.next,
                self.table.frames.len()
            );
        }
        // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
        let mut plus = Vec::with_capacity(self.header.nnz);
        // compeft-lint: allow(no-unchecked-wire-alloc) -- nnz plausibility-bounded in parse_header
        let mut minus = Vec::with_capacity(self.header.nnz);
        for &(idx, sign) in &self.entries {
            [&mut minus, &mut plus][sign as usize].push(idx);
        }
        Ok(TernaryVector {
            len: self.header.len,
            scale: self.header.scale,
            plus,
            minus,
        })
    }
}

/// Exact encoded size in bytes for a ternary vector without encoding it.
pub fn encoded_size_bytes(t: &TernaryVector) -> u64 {
    let nnz = t.nnz() as u64;
    let p = if t.len == 0 { 0.0 } else { nnz as f64 / t.len as f64 };
    let b = rice_parameter(p).min(30) as u64;
    let mut bits = HEADER_BITS;
    let mut prev: i64 = -1;
    for (idx, _) in t.iter_nonzero() {
        let gap = (idx as i64 - prev - 1) as u64;
        bits += (gap >> b) + 1 + b + 1; // unary + remainder + sign
        prev = idx as i64;
    }
    bits.div_ceil(8)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn rice_parameter_examples() {
        // p = 0.05 → E[gap] = 19, b* should be ~5.
        let b = rice_parameter(0.05);
        assert!((4..=6).contains(&b), "b*={b}");
        assert_eq!(rice_parameter(1.0), 0);
        assert!(rice_parameter(0.5) <= 1);
    }

    #[test]
    fn avg_bits_decreasing_in_density() {
        // Denser vectors need fewer bits per position.
        assert!(avg_bits_per_position(0.05) > avg_bits_per_position(0.3));
        // At p=0.05 the paper reports ~0.34 bits/param total, which is
        // k * (b̄_pos + 1) ≈ 0.05 * ~7 ≈ 0.35.
        let per_param = 0.05 * (avg_bits_per_position(0.05) + 1.0);
        assert!((0.28..=0.42).contains(&per_param), "{per_param}");
    }

    #[test]
    fn roundtrip_simple() {
        let t = TernaryVector {
            len: 100,
            scale: 0.125,
            plus: vec![0, 17, 63, 64, 99],
            minus: vec![1, 50],
        };
        let bytes = encode(&t);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(bytes.len() as u64, encoded_size_bytes(&t));
    }

    #[test]
    fn roundtrip_edge_cases() {
        for t in [
            TernaryVector::empty(0),
            TernaryVector::empty(1000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
            TernaryVector {
                len: 3,
                scale: -2.5,
                plus: vec![0, 1, 2],
                minus: vec![],
            },
        ] {
            let back = decode(&encode(&t)).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn prop_roundtrip_random() {
        prop::check(
            "golomb roundtrip",
            80,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).max(1).min(20_000);
                let k = [0.01, 0.05, 0.1, 0.3, 0.9][rng.range(0, 5)];
                let tau = prop::task_vector_like(rng, n);
                compress_vector(&tau, &CompressConfig { density: k, ..Default::default() })
            },
            |t| {
                let bytes = encode(t);
                if bytes.len() as u64 != encoded_size_bytes(t) {
                    return Err("size prediction mismatch".into());
                }
                let back = decode(&bytes).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err("roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// Random ternary vector built directly from index sets (not via the
    /// compressor): sample nnz distinct indices, split them by a coin
    /// flip into plus/minus.
    pub(crate) fn random_index_sets(rng: &mut Pcg, len: usize) -> TernaryVector {
        let nnz = if len == 0 { 0 } else { rng.range(0, len + 1) };
        let mut idx = rng.sample_indices(len, nnz);
        idx.sort_unstable();
        let mut plus = Vec::new();
        let mut minus = Vec::new();
        for i in idx {
            if rng.next_f32() < 0.5 {
                plus.push(i as u32);
            } else {
                minus.push(i as u32);
            }
        }
        let scale = (rng.next_f64() * 4.0 - 2.0) as f32;
        TernaryVector { len, scale, plus, minus }
    }

    #[test]
    fn prop_roundtrip_random_index_sets() {
        prop::check(
            "golomb roundtrip on raw index sets",
            60,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(10_000);
                random_index_sets(rng, n)
            },
            |t| {
                t.validate().map_err(|e| e.to_string())?;
                let bytes = encode(t);
                if bytes.len() as u64 != encoded_size_bytes(t) {
                    return Err("size prediction mismatch".into());
                }
                let back = decode(&bytes).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err(format!(
                        "roundtrip mismatch: {} vs {} nonzeros",
                        back.nnz(),
                        t.nnz()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn encode_par_is_byte_identical_to_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(31);
        let mut cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(5000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
        ];
        for len in [100usize, 4097, 50_000] {
            cases.push(random_index_sets(&mut rng, len));
            let tau = prop::task_vector_like(&mut rng, len);
            cases.push(compress_vector(
                &tau,
                &CompressConfig { density: 0.05, ..Default::default() },
            ));
        }
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk_nnz in [1usize, 7, 256, 1 << 20] {
                for (i, t) in cases.iter().enumerate() {
                    let serial = encode(t);
                    let par = encode_par(t, &pool, chunk_nnz);
                    assert_eq!(
                        serial, par,
                        "case {i} workers {workers} chunk_nnz {chunk_nnz}"
                    );
                }
            }
        }
    }

    #[test]
    fn decode_par_is_bit_identical_to_serial() {
        use crate::util::pool::ThreadPool;
        let mut rng = Pcg::seed(47);
        let mut cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(5000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
        ];
        for len in [100usize, 4097, 50_000] {
            cases.push(random_index_sets(&mut rng, len));
            let tau = prop::task_vector_like(&mut rng, len);
            cases.push(compress_vector(
                &tau,
                &CompressConfig { density: 0.05, ..Default::default() },
            ));
        }
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            for chunk_nnz in [1usize, 7, 256, 1 << 20] {
                for (i, t) in cases.iter().enumerate() {
                    let bytes = encode(t);
                    let table = frame_table(t, chunk_nnz);
                    let serial = decode(&bytes).unwrap();
                    let par = decode_par(&bytes, &table, &pool).unwrap();
                    assert_eq!(serial.len, par.len, "case {i}");
                    assert_eq!(serial.scale.to_bits(), par.scale.to_bits(), "case {i}");
                    assert_eq!(
                        serial.plus, par.plus,
                        "case {i} workers {workers} chunk_nnz {chunk_nnz}"
                    );
                    assert_eq!(serial.minus, par.minus, "case {i}");
                }
            }
        }
    }

    #[test]
    fn prop_decode_par_roundtrip() {
        use crate::util::pool::ThreadPool;
        let pool = ThreadPool::new(4);
        prop::check(
            "framed parallel decode roundtrip",
            50,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(10_000);
                let chunk = [1usize, 13, 300, 1 << 15][rng.range(0, 4)];
                (random_index_sets(rng, n), chunk)
            },
            |(t, chunk)| {
                let bytes = encode(t);
                let table = frame_table(t, *chunk);
                if table.frames.len() != t.nnz().div_ceil((*chunk).max(1)) {
                    return Err("frame count mismatch".into());
                }
                // Offsets strictly increase (each frame holds ≥1 codeword).
                for w in table.frames.windows(2) {
                    if w[0].0 >= w[1].0 {
                        return Err("frame offsets not increasing".into());
                    }
                }
                let back = decode_par(&bytes, &table, &pool).map_err(|e| e.to_string())?;
                if back != *t {
                    return Err("parallel roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decode_par_rejects_corrupt_tables() {
        use crate::util::pool::ThreadPool;
        let pool = ThreadPool::new(2);
        let t = TernaryVector {
            len: 500,
            scale: 1.0,
            plus: vec![3, 20, 90, 200, 333],
            minus: vec![7, 50, 450],
        };
        let bytes = encode(&t);
        let good = frame_table(&t, 3);
        assert_eq!(decode_par(&bytes, &good, &pool).unwrap(), t);

        // Wrong frame count.
        let mut bad = good.clone();
        bad.frames.pop();
        assert!(decode_par(&bytes, &bad, &pool).is_err());

        // Offset beyond the payload.
        let mut bad = good.clone();
        bad.frames[1].0 = bytes.len() as u64 * 8 + 1;
        assert!(decode_par(&bytes, &bad, &pool).is_err());

        // Lying predecessor index: breaks the continuity check.
        let mut bad = good.clone();
        bad.frames[1].1 = 499;
        assert!(decode_par(&bytes, &bad, &pool).is_err());

        // Zero chunk size.
        let bad = FrameTable { chunk_nnz: 0, frames: good.frames.clone() };
        assert!(decode_par(&bytes, &bad, &pool).is_err());
    }

    #[test]
    fn near_entropy_at_low_density() {
        // Encoded size should be close to the entropy bound for random
        // sparse vectors (within ~25% at k=0.05 given the 25-byte header).
        let mut rng = Pcg::seed(5);
        let d = 100_000usize;
        let tau = prop::task_vector_like(&mut rng, d);
        let t = compress_vector(
            &tau,
            &CompressConfig { density: 0.05, ..Default::default() },
        );
        let bytes = encode(&t).len() as f64 * 8.0;
        let entropy = crate::compeft::entropy::compeft_entropy_bits(d, 0.05);
        assert!(
            bytes < entropy * 1.25,
            "encoded {bytes} bits vs entropy {entropy} bits"
        );
    }

    /// Differential contract of the word-at-a-time kernel: on random
    /// streams — whose codewords land at every 64-bit window alignment
    /// — [`decode`] (word path) and [`decode_bitwise`] (bit-at-a-time
    /// oracle) produce identical vectors, and on truncated streams
    /// both fail.
    #[test]
    fn prop_word_decode_matches_bitwise_oracle() {
        prop::check(
            "word-at-a-time decode vs bitwise oracle",
            80,
            |rng: &mut Pcg| {
                let n = prop::sizes(rng).min(20_000);
                random_index_sets(rng, n)
            },
            |t| {
                let bytes = encode(t);
                let word = decode(&bytes).map_err(|e| e.to_string())?;
                let oracle = decode_bitwise(&bytes).map_err(|e| e.to_string())?;
                if word != oracle {
                    return Err("word kernel diverged from bitwise oracle".into());
                }
                if word != *t {
                    return Err("decode roundtrip mismatch".into());
                }
                // Truncation: chop a byte off a nonempty payload — both
                // paths must agree on accept/reject and on the value.
                if bytes.len() > 25 {
                    let cut = &bytes[..bytes.len() - 1];
                    match (decode(cut), decode_bitwise(cut)) {
                        (Ok(a), Ok(b)) if a == b => {}
                        (Err(_), Err(_)) => {}
                        _ => return Err("paths disagree on truncated stream".into()),
                    }
                }
                Ok(())
            },
        );
    }

    /// Golomb edge cases the word kernel must cover exactly: `b = 0`
    /// (unary-only Rice, dense vectors), `chunk_nnz = 1` (every frame a
    /// single codeword), `nnz` an exact multiple of `chunk_nnz`, and a
    /// final frame shorter than the chunk.
    #[test]
    fn word_kernel_edge_cases() {
        use crate::util::pool::ThreadPool;
        let pool = ThreadPool::new(3);

        // Dense vector: density 1.0 → rice_parameter = 0, every gap is
        // pure unary (quotient + terminator + sign, no remainder bits).
        let dense = TernaryVector {
            len: 97,
            scale: 0.5,
            plus: (0..97).step_by(2).collect(),
            minus: (1..97).step_by(2).collect(),
        };
        assert_eq!(super::stream_rice_parameter(&dense), 0, "b = 0 case");

        // One-sign stream (satellite: decode sizes by the full nnz
        // bound, so this must not realloc — and must roundtrip).
        let one_sign = TernaryVector {
            len: 64,
            scale: 1.0,
            plus: (0..64).collect(),
            minus: vec![],
        };

        // Sparse vector with giant gaps (deep unary quotients that can
        // straddle the 64-bit window and exercise the slow path).
        let sparse = TernaryVector {
            len: 3_000_000,
            scale: -1.5,
            plus: vec![0, 1_499_999],
            minus: vec![2_999_999],
        };

        // nnz = 12: exact multiple of chunk 4 and 6; short final frame
        // for chunk 5; single-codeword frames for chunk 1.
        let twelve = TernaryVector {
            len: 400,
            scale: 2.0,
            plus: vec![3, 17, 40, 41, 99, 250],
            minus: vec![5, 20, 77, 130, 300, 399],
        };

        // All nonzeros clustered at the tail: the density-derived Rice
        // parameter is small but the leading gap is enormous, so its
        // unary run is far longer than any 64-bit window — the kernel
        // must take the bit-at-a-time fallback and still agree.
        let clustered = TernaryVector {
            len: 10_000,
            scale: 0.25,
            plus: (9_900..9_950).collect(),
            minus: (9_950..10_000).collect(),
        };

        for (name, t) in [
            ("dense_b0", &dense),
            ("one_sign", &one_sign),
            ("sparse_gaps", &sparse),
            ("twelve", &twelve),
            ("clustered_tail", &clustered),
        ] {
            let bytes = encode(t);
            assert_eq!(&decode(&bytes).unwrap(), t, "{name}: word decode");
            assert_eq!(&decode_bitwise(&bytes).unwrap(), t, "{name}: oracle");
            for chunk in [1usize, 4, 5, 6, 12, 1 << 20] {
                let table = frame_table(t, chunk);
                assert_eq!(
                    table.frames.len(),
                    t.nnz().div_ceil(chunk),
                    "{name} chunk {chunk}: frame count"
                );
                let par = decode_par(&bytes, &table, &pool).unwrap();
                assert_eq!(&par, t, "{name} chunk {chunk}: par decode");
            }
        }
    }

    /// The fused-path frame decoder is bit-identical to the serial
    /// decoder at every chunk size, its byte watermarks are monotone
    /// and end at the payload length, and it rejects the same lying
    /// tables and out-of-order use that `decode_par` rejects.
    #[test]
    fn frame_decoder_matches_serial_and_validates() {
        let mut rng = Pcg::seed(61);
        let mut cases = vec![
            TernaryVector::empty(0),
            TernaryVector::empty(5000),
            TernaryVector { len: 1, scale: 1.0, plus: vec![0], minus: vec![] },
        ];
        for len in [100usize, 4097, 20_000] {
            cases.push(random_index_sets(&mut rng, len));
        }
        for (i, t) in cases.iter().enumerate() {
            let bytes = encode(t);
            for chunk in [1usize, 7, 256, 1 << 20] {
                let table = frame_table(t, chunk);
                let mut fd = FrameDecoder::new(&bytes, &table).unwrap();
                assert_eq!(fd.frame_count(), t.nnz().div_ceil(chunk));
                let mut prev_end = 0usize;
                for f in 0..fd.frame_count() {
                    let end = fd.frame_end_byte(f);
                    assert!(end >= prev_end, "case {i} chunk {chunk}: monotone");
                    assert!(end <= bytes.len());
                    prev_end = end;
                    fd.decode_next().unwrap();
                    assert_eq!(fd.frames_done(), f + 1);
                }
                if fd.frame_count() > 0 {
                    assert_eq!(
                        fd.frame_end_byte(fd.frame_count() - 1),
                        bytes.len(),
                        "last frame needs the full payload"
                    );
                }
                let got = fd.finish().unwrap();
                assert_eq!(&got, &decode(&bytes).unwrap(), "case {i} chunk {chunk}");
            }
        }

        // Rejections mirror decode_par's.
        let t = TernaryVector {
            len: 500,
            scale: 1.0,
            plus: vec![3, 20, 90, 200, 333],
            minus: vec![7, 50, 450],
        };
        let bytes = encode(&t);
        let good = frame_table(&t, 3);
        let mut bad = good.clone();
        bad.frames.pop();
        assert!(FrameDecoder::new(&bytes, &bad).is_err(), "wrong frame count");
        let bad = FrameTable { chunk_nnz: 0, frames: good.frames.clone() };
        assert!(FrameDecoder::new(&bytes, &bad).is_err(), "zero chunk");
        let mut bad = good.clone();
        bad.frames[1].1 = 499;
        let mut fd = FrameDecoder::new(&bytes, &bad).unwrap();
        let r = (0..fd.frame_count()).try_for_each(|_| fd.decode_next());
        assert!(r.is_err(), "lying predecessor must fail");
        // Early finish fails loudly.
        let fd = FrameDecoder::new(&bytes, &good).unwrap();
        assert!(fd.finish().is_err(), "finish before all frames decoded");
    }

    #[test]
    fn decode_rejects_corruption() {
        let t = TernaryVector { len: 50, scale: 1.0, plus: vec![3, 20], minus: vec![7] };
        let mut bytes = encode(&t);
        bytes[0] ^= 0xFF; // magic
        assert!(decode(&bytes).is_err());
        assert!(decode(&[]).is_err());
        let bytes = encode(&t);
        assert!(decode(&bytes[..bytes.len() - 1]).is_err() || {
            // Truncating may still decode if padding-only; ensure indices valid then.
            decode(&bytes[..bytes.len() - 1]).map(|v| v.validate().is_ok()).unwrap_or(false)
        });
    }
}
