//! Rank-classification evaluation through the PJRT runtime.
//!
//! Mirrors the paper's T5/T0/MMLU protocol (Appendix B.1): the model's
//! logits at the QUERY position are restricted to the candidate answer
//! tokens and the top-ranked candidate is compared to the label. All
//! accuracy numbers in the benches flow through this module — the
//! request path is Rust + PJRT, never Python.

use crate::runtime::{AdapterKind, ModelBundle};
use crate::tensor::ParamSet;
use crate::util::npz;
use anyhow::{Context, Result};
use std::path::Path;

/// First answer-token id (matches python/compile/config.py ANSWER_BASE).
pub const ANSWER_BASE: usize = 10;

/// A loaded evaluation set.
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub name: String,
    /// Flattened [n, seq] token matrix.
    pub tokens: Vec<i32>,
    pub labels: Vec<i64>,
    /// Number of answer candidates per example.
    pub n_classes: Vec<i64>,
    pub n: usize,
    pub seq: usize,
}

impl EvalSet {
    pub fn load(path: &Path) -> Result<EvalSet> {
        let arrays = npz::read_npz(path)?;
        let tok = arrays.get("tokens").context("eval set missing tokens")?;
        let labels = arrays.get("labels").context("missing labels")?.to_i64()?;
        let n_classes = arrays.get("n_classes").context("missing n_classes")?.to_i64()?;
        let n = tok.shape[0];
        let seq = tok.shape[1];
        let tokens: Vec<i32> = tok.to_i64()?.iter().map(|&v| v as i32).collect();
        anyhow::ensure!(labels.len() == n && n_classes.len() == n, "ragged eval set");
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(EvalSet { name, tokens, labels, n_classes, n, seq })
    }

    /// Take the first `k` examples (for quick validation splits).
    pub fn truncate(mut self, k: usize) -> EvalSet {
        let k = k.min(self.n);
        self.tokens.truncate(k * self.seq);
        self.labels.truncate(k);
        self.n_classes.truncate(k);
        self.n = k;
        self
    }
}

/// Rank-classification accuracy from raw logits `[n, vocab]`.
pub fn rank_accuracy_from_logits(
    logits: &[f32],
    vocab: usize,
    labels: &[i64],
    n_classes: &[i64],
) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * vocab);
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let c = n_classes[i] as usize;
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (j, &v) in row[ANSWER_BASE..ANSWER_BASE + c].iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = j;
            }
        }
        if best as i64 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Mean cross-entropy of the correct answer token (LoraHub's few-shot
/// objective). Lower is better.
pub fn answer_cross_entropy(
    logits: &[f32],
    vocab: usize,
    labels: &[i64],
) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * vocab);
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse: f64 =
            maxv + row.iter().map(|&v| ((v as f64) - maxv).exp()).sum::<f64>().ln();
        let target = ANSWER_BASE + labels[i] as usize;
        total += lse - row[target] as f64;
    }
    total / n as f64
}

/// Evaluate a model variant on an eval set. `adapter` rides on top of
/// the resident base; `full_params` replaces the base entirely.
pub fn evaluate(
    bundle: &ModelBundle,
    kind: AdapterKind,
    batch: usize,
    adapter: Option<&ParamSet>,
    full_params: Option<&ParamSet>,
    set: &EvalSet,
) -> Result<f64> {
    anyhow::ensure!(set.seq == bundle.meta.seq_len, "seq mismatch");
    let logits = bundle.logits(kind, batch, adapter, full_params, &set.tokens)?;
    Ok(rank_accuracy_from_logits(
        &logits,
        bundle.meta.vocab,
        &set.labels,
        &set.n_classes,
    ))
}

/// Few-shot loss of a candidate adapter on a small support set.
pub fn fewshot_loss(
    bundle: &ModelBundle,
    kind: AdapterKind,
    batch: usize,
    adapter: &ParamSet,
    set: &EvalSet,
) -> Result<f64> {
    let logits = bundle.logits(kind, batch, Some(adapter), None, &set.tokens)?;
    let answer_labels: Vec<i64> = set.labels.clone();
    Ok(answer_cross_entropy(&logits, bundle.meta.vocab, &answer_labels))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_accuracy_counts_correct_rows() {
        let vocab = 20;
        // Two examples, 2 classes. Candidates at tokens 10, 11.
        let mut logits = vec![0.0f32; 2 * vocab];
        logits[10] = 1.0; // example 0 predicts class 0
        logits[vocab + 11] = 2.0; // example 1 predicts class 1
        let acc = rank_accuracy_from_logits(&logits, vocab, &[0, 0], &[2, 2]);
        assert!((acc - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cross_entropy_prefers_confident_correct() {
        let vocab = 16;
        let mut good = vec![0.0f32; vocab];
        good[10] = 10.0;
        let mut bad = vec![0.0f32; vocab];
        bad[11] = 10.0;
        let ce_good = answer_cross_entropy(&good, vocab, &[0]);
        let ce_bad = answer_cross_entropy(&bad, vocab, &[0]);
        assert!(ce_good < 0.01);
        assert!(ce_bad > 5.0);
    }

    #[test]
    fn eval_set_truncate() {
        let set = EvalSet {
            name: "t".into(),
            tokens: vec![0; 10 * 4],
            labels: vec![0; 10],
            n_classes: vec![2; 10],
            n: 10,
            seq: 4,
        };
        let t = set.truncate(3);
        assert_eq!(t.n, 3);
        assert_eq!(t.tokens.len(), 12);
    }
}
