//! Shared plumbing for the paper-reproduction benches (`benches/*.rs`).
//!
//! Each bench regenerates one table/figure (DESIGN.md §5). The common
//! work — loading expert task vectors, running the (k, α) validation
//! sweep of §3.1, applying compressed task vectors, entropy-based size
//! accounting — lives here so benches stay declarative and the logic is
//! unit-testable.

use crate::compeft::compress::{
    compress_params, decompress_params, CompressConfig, Granularity,
};
use crate::compeft::engine::par_compress_paramset;
use crate::compeft::format::{to_bytes, to_bytes_par, Encoding};
use crate::coordinator::registry::ExpertMethod;
use crate::util::pool::ThreadPool;
use crate::eval::{evaluate, EvalSet};
use crate::runtime::{AdapterKind, ModelBundle, Runtime, };
use crate::tensor::ParamSet;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// The paper's hyper-parameter grid (§3.1).
pub const DENSITIES: [f64; 5] = [0.05, 0.10, 0.20, 0.30, 0.50];
pub const ALPHAS: [f64; 9] = [0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0];

/// Evaluation batch exported by aot.py.
pub const EVAL_BATCH: usize = 64;

pub fn artifacts_dir() -> PathBuf {
    std::env::var("COMPEFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Abort politely when `make artifacts` has not run.
pub fn require_artifacts() -> PathBuf {
    let dir = artifacts_dir();
    if !dir.join("models").exists() {
        eprintln!(
            "bench requires artifacts — run `make artifacts` first (dir: {})",
            dir.display()
        );
        std::process::exit(0);
    }
    dir
}

/// All-zeros [`Templates`](crate::coordinator::pipeline::Templates)
/// matching the shapes of `like` — the artifact-free stand-in for a
/// model bundle's adapter inits, shared by the pipeline equivalence
/// tests and the `table5_latency` prefetch bench.
pub fn zero_templates(like: &ParamSet) -> crate::coordinator::pipeline::Templates {
    let mut z = ParamSet::new();
    for (name, t) in like.iter() {
        z.insert(name, crate::tensor::Tensor::zeros(t.shape.clone()));
    }
    let z = std::sync::Arc::new(z);
    crate::coordinator::pipeline::Templates {
        base: std::sync::Arc::clone(&z),
        lora_init: std::sync::Arc::clone(&z),
        ia3_init: z,
    }
}

/// A loaded expert task vector + its metadata.
#[derive(Clone, Debug)]
pub struct Expert {
    pub task: String,
    pub method: ExpertMethod,
    pub scale: String,
    pub tv: ParamSet,
    pub own_task_acc: f64,
    pub path: PathBuf,
}

/// Load `{task}.{method}[.r{rank}].npz` + meta.
pub fn load_expert(
    artifacts: &Path,
    scale: &str,
    task: &str,
    method: &str,
    rank: Option<usize>,
) -> Result<Expert> {
    // NOTE: filenames contain dots ("alpaca.lora.npz"), so build them
    // textually — Path::with_extension would clobber ".lora".
    let suffix = rank.map(|r| format!(".r{r}")).unwrap_or_default();
    let dir = artifacts.join("experts").join(scale);
    let stem = format!("{task}.{method}{suffix}");
    let npz_path = dir.join(format!("{stem}.npz"));
    let tv = ParamSet::load_npz(&npz_path)
        .with_context(|| format!("expert {}", npz_path.display()))?;
    let meta_path = dir.join(format!("{stem}.meta.json"));
    let own = std::fs::read_to_string(&meta_path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.get("own_task_acc").and_then(|v| v.as_f64()))
        .unwrap_or(f64::NAN);
    Ok(Expert {
        task: task.to_string(),
        method: ExpertMethod::parse(method).context("method")?,
        scale: scale.to_string(),
        tv,
        own_task_acc: own,
        path: npz_path,
    })
}

/// Map an expert method to its runtime kind + adapter init.
pub fn kind_and_init<'a>(
    bundle: &'a ModelBundle,
    method: ExpertMethod,
) -> (AdapterKind, &'a ParamSet) {
    match method {
        ExpertMethod::Lora => (AdapterKind::Lora, &*bundle.lora_init),
        ExpertMethod::Ia3 => (AdapterKind::Ia3, &*bundle.ia3_init),
        ExpertMethod::Full => (AdapterKind::Base, &*bundle.base),
    }
}

/// Evaluate an expert given its (possibly compressed) task vector.
pub fn eval_tv(
    bundle: &ModelBundle,
    method: ExpertMethod,
    tv: &ParamSet,
    set: &EvalSet,
) -> Result<f64> {
    let (kind, init) = kind_and_init(bundle, method);
    match method {
        ExpertMethod::Full => {
            let mut params = (*bundle.base).clone();
            params.add_assign(tv)?;
            evaluate(bundle, kind, EVAL_BATCH, None, Some(&params), set)
        }
        _ => {
            let mut adapter = init.clone();
            adapter.add_assign(tv)?;
            evaluate(bundle, kind, EVAL_BATCH, Some(&adapter), None, set)
        }
    }
}

/// Reconstructed dense task vector after ComPEFT at (k, α).
pub fn compress_tv(tv: &ParamSet, density: f64, alpha: f64) -> ParamSet {
    let cfg = CompressConfig { density, alpha, granularity: Granularity::Global };
    let c = compress_params(tv, &cfg);
    decompress_params(&c, tv).expect("structure preserved")
}

/// [`compress_tv`] on the parallel engine — bit-identical result, for
/// callers that already hold a pool (the artifact benches can swap it
/// in for [`compress_tv`] wherever sweep compression time matters).
pub fn compress_tv_par(
    tv: &ParamSet,
    density: f64,
    alpha: f64,
    pool: &ThreadPool,
) -> ParamSet {
    let cfg = CompressConfig { density, alpha, granularity: Granularity::Global };
    let c = par_compress_paramset(tv, &cfg, pool);
    decompress_params(&c, tv).expect("structure preserved")
}

/// Golomb-coded size in bytes of ComPEFT at (k, α) for this tv.
pub fn compeft_bytes(tv: &ParamSet, density: f64, alpha: f64) -> u64 {
    let cfg = CompressConfig { density, alpha, granularity: Granularity::Global };
    let c = compress_params(tv, &cfg);
    to_bytes(&c, Encoding::Golomb).len() as u64
}

/// [`compeft_bytes`] with both compression and Golomb encoding on the
/// pool — byte-identical container, same drop-in contract as
/// [`compress_tv_par`].
pub fn compeft_bytes_par(
    tv: &ParamSet,
    density: f64,
    alpha: f64,
    pool: &ThreadPool,
) -> u64 {
    let cfg = CompressConfig { density, alpha, granularity: Granularity::Global };
    let c = par_compress_paramset(tv, &cfg, pool);
    to_bytes_par(&c, Encoding::Golomb, pool).len() as u64
}

/// One grid point of the validation sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub density: f64,
    pub alpha: f64,
    pub val_acc: f64,
}

/// §3.1 hyper-parameter selection: evaluate every (k, α) on the
/// validation set. The caller picks argmax (Table 1) or slices the grid
/// (Figures 5/6).
pub fn sweep(
    bundle: &ModelBundle,
    expert: &Expert,
    val: &EvalSet,
    densities: &[f64],
    alphas: &[f64],
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::with_capacity(densities.len() * alphas.len());
    for &density in densities {
        for &alpha in alphas {
            let ctv = compress_tv(&expert.tv, density, alpha);
            let val_acc = eval_tv(bundle, expert.method, &ctv, val)?;
            out.push(SweepPoint { density, alpha, val_acc });
        }
    }
    Ok(out)
}

/// Best grid point by validation accuracy (ties → smaller density).
pub fn best_point(points: &[SweepPoint]) -> SweepPoint {
    *points
        .iter()
        .max_by(|a, b| {
            (a.val_acc, -a.density)
                .partial_cmp(&(b.val_acc, -b.density))
                .unwrap()
        })
        .expect("non-empty sweep")
}

/// Load bundle + eval sets for a scale.
pub fn load_bundle(artifacts: &Path, scale: &str) -> Result<(Runtime, ModelBundle)> {
    let rt = Runtime::cpu()?;
    let bundle = ModelBundle::load(&rt, artifacts, scale)?;
    Ok((rt, bundle))
}

pub fn load_eval(artifacts: &Path, name: &str) -> Result<EvalSet> {
    EvalSet::load(&artifacts.join("eval").join(format!("{name}.npz")))
}

/// Persist/load sweep results so repeated benches skip recomputation.
pub fn sweep_cached(
    bundle: &ModelBundle,
    expert: &Expert,
    val: &EvalSet,
    cache_tag: &str,
) -> Result<Vec<SweepPoint>> {
    let dir = Path::new("target/bench/sweeps");
    std::fs::create_dir_all(dir).ok();
    let path = dir.join(format!("{cache_tag}.json"));
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(Json::Arr(rows)) = Json::parse(&text) {
            let pts: Vec<SweepPoint> = rows
                .iter()
                .filter_map(|r| {
                    Some(SweepPoint {
                        density: r.get("k")?.as_f64()?,
                        alpha: r.get("alpha")?.as_f64()?,
                        val_acc: r.get("val_acc")?.as_f64()?,
                    })
                })
                .collect();
            if pts.len() == DENSITIES.len() * ALPHAS.len() {
                return Ok(pts);
            }
        }
    }
    let pts = sweep(bundle, expert, val, &DENSITIES, &ALPHAS)?;
    let rows: Vec<Json> = pts
        .iter()
        .map(|p| {
            let mut j = Json::obj();
            j.set("k", Json::num(p.density))
                .set("alpha", Json::num(p.alpha))
                .set("val_acc", Json::num(p.val_acc));
            j
        })
        .collect();
    std::fs::write(&path, Json::Arr(rows).to_string()).ok();
    Ok(pts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_point_prefers_accuracy_then_sparsity() {
        let pts = vec![
            SweepPoint { density: 0.5, alpha: 1.0, val_acc: 0.8 },
            SweepPoint { density: 0.05, alpha: 2.0, val_acc: 0.9 },
            SweepPoint { density: 0.2, alpha: 1.0, val_acc: 0.9 },
        ];
        let b = best_point(&pts);
        assert_eq!(b.alpha, 2.0);
        assert_eq!(b.density, 0.05); // tie on acc → sparser wins
    }

    #[test]
    fn compress_tv_preserves_structure() {
        use crate::tensor::Tensor;
        use crate::util::{prop, rng::Pcg};
        let mut rng = Pcg::seed(1);
        let mut tv = ParamSet::new();
        tv.insert("x", Tensor::new(vec![100], prop::task_vector_like(&mut rng, 100)));
        let c = compress_tv(&tv, 0.2, 1.0);
        assert_eq!(c.names(), tv.names());
        assert_eq!(c.get("x").unwrap().shape, vec![100]);
        let bytes = compeft_bytes(&tv, 0.2, 1.0);
        assert!(bytes > 0 && bytes < tv.bytes_fp16());
    }

    #[test]
    fn parallel_helpers_match_serial() {
        use crate::tensor::Tensor;
        use crate::util::{prop, rng::Pcg};
        let mut rng = Pcg::seed(2);
        let mut tv = ParamSet::new();
        tv.insert("a", Tensor::new(vec![4000], prop::task_vector_like(&mut rng, 4000)));
        tv.insert("b", Tensor::new(vec![600], prop::task_vector_like(&mut rng, 600)));
        for workers in [1usize, 4] {
            let pool = ThreadPool::new(workers);
            assert_eq!(
                compress_tv(&tv, 0.1, 2.0),
                compress_tv_par(&tv, 0.1, 2.0, &pool),
                "workers={workers}"
            );
            assert_eq!(
                compeft_bytes(&tv, 0.1, 2.0),
                compeft_bytes_par(&tv, 0.1, 2.0, &pool),
                "workers={workers}"
            );
        }
    }
}
