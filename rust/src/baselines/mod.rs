//! Comparator methods from the paper's ablation (§4.1) and extended
//! baseline study (Appendix C.1):
//!
//! | Method    | Sparsify      | Quantize             | Scale                  |
//! |-----------|---------------|----------------------|------------------------|
//! | ComPEFT   | top-k by |τ|  | ternary              | α·σ(τ), α tuned        |
//! | STC       | top-k by |τ|  | ternary              | mean |τ| of kept       |
//! | Pruned    | top-k by |τ|  | none (keeps values)  | 1                      |
//! | BitDelta  | none (k = 1)  | binary sign          | mean |τ| (no training) |
//! | DAREx-q   | random drop p | none (keeps values)  | 1/q per-layer rescale  |
//!
//! All functions are training-free, mirroring the paper's setting
//! ("BitDelta (Training)" learns α by SGD and is reported in the paper
//! as not directly comparable; we implement the No-Training variant).

pub mod bitdelta;
pub mod darex;
pub mod sparse_float;
pub mod stc;

pub use sparse_float::SparseFloat;

use crate::compeft::sparsify::prune_to_topk;

/// The `Pruned` ablation (§4.1): top-k sparsification only — original
/// magnitudes kept, no ternarization, no scaling.
pub fn pruned(tau: &[f32], density: f64) -> SparseFloat {
    SparseFloat::from_dense(&prune_to_topk(tau, density))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pruned_keeps_values_and_density() {
        let tau = [0.1f32, -5.0, 0.2, 3.0, 0.0, -1.0, 0.4, 2.0];
        let p = pruned(&tau, 0.5);
        assert_eq!(p.nnz(), 4);
        let d = p.to_dense();
        assert_eq!(d[1], -5.0);
        assert_eq!(d[3], 3.0);
        assert_eq!(d[0], 0.0);
    }
}
