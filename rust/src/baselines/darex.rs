//! DARE / DAREx-q (Yu et al., 2023; Deng et al., 2024) — Appendix C.1
//! comparator.
//!
//! DARE drops each task-vector entry independently with probability `p`
//! and rescales survivors by `1/q` (q = 1−p) to keep the update
//! unbiased in expectation. DAREx-q additionally tunes the inverse
//! scaling `1/q_v` per layer on labelled data; we expose `q_scale` so
//! the bench harness can sweep it per part, and default to the unbiased
//! `1/q`.

use crate::baselines::sparse_float::SparseFloat;
use crate::util::rng::Pcg;

/// Configuration for a DARE compression pass.
#[derive(Clone, Copy, Debug)]
pub struct DareConfig {
    /// Drop probability p (paper uses 0.95 and 0.99).
    pub drop_p: f64,
    /// Multiplier applied to surviving entries. `None` → unbiased 1/q.
    pub q_scale: Option<f64>,
}

impl Default for DareConfig {
    fn default() -> Self {
        DareConfig { drop_p: 0.95, q_scale: None }
    }
}

/// Compress `tau` with DARE(x): random drop + rescale.
pub fn dare_compress(tau: &[f32], cfg: &DareConfig, rng: &mut Pcg) -> SparseFloat {
    assert!((0.0..1.0).contains(&cfg.drop_p), "drop_p in [0,1)");
    let q = 1.0 - cfg.drop_p;
    let scale = cfg.q_scale.unwrap_or(1.0 / q) as f32;
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for (i, &v) in tau.iter().enumerate() {
        if v != 0.0 && rng.next_f64() >= cfg.drop_p {
            idx.push(i as u32);
            val.push(v * scale);
        }
    }
    SparseFloat { len: tau.len(), idx, val }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn drop_rate_matches_p() {
        let mut rng = Pcg::seed(12);
        let tau = vec![1.0f32; 100_000];
        let s = dare_compress(&tau, &DareConfig { drop_p: 0.95, q_scale: None }, &mut rng);
        let kept = s.nnz() as f64 / tau.len() as f64;
        assert!((kept - 0.05).abs() < 0.005, "kept={kept}");
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Pcg::seed(99);
        let tau = prop::task_vector_like(&mut rng, 200_000);
        let sum_orig: f64 = tau.iter().map(|&x| x as f64).sum();
        let s = dare_compress(&tau, &DareConfig::default(), &mut rng);
        let sum_dare: f64 = s.val.iter().map(|&x| x as f64).sum();
        let sigma = crate::util::stats::std_f32(&tau);
        // E[sum] preserved; tolerance ~ several std errors of the estimator.
        let tol = 6.0 * sigma * (tau.len() as f64).sqrt() / (0.05f64).sqrt();
        assert!(
            (sum_orig - sum_dare).abs() < tol.max(1e-3),
            "orig={sum_orig} dare={sum_dare} tol={tol}"
        );
    }

    #[test]
    fn custom_q_scale_applies() {
        let mut rng = Pcg::seed(1);
        let tau = vec![2.0f32; 1000];
        let s = dare_compress(
            &tau,
            &DareConfig { drop_p: 0.5, q_scale: Some(3.0) },
            &mut rng,
        );
        for &v in &s.val {
            assert!((v - 6.0).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tau = prop::task_vector_like(&mut Pcg::seed(5), 5000);
        let a = dare_compress(&tau, &DareConfig::default(), &mut Pcg::seed(7));
        let b = dare_compress(&tau, &DareConfig::default(), &mut Pcg::seed(7));
        assert_eq!(a, b);
    }
}
