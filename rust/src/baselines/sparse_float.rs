//! Sparse float (COO) vectors for baselines that keep original values
//! (`Pruned`, DAREx). Storage accounting follows the paper's Appendix
//! C.1, which stores DAREx checkpoints as `coo_sparse` matrices: one
//! 32-bit index plus one 16-bit value per nonzero.

/// COO sparse float vector.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseFloat {
    pub len: usize,
    /// Sorted nonzero indices.
    pub idx: Vec<u32>,
    /// Values at those indices.
    pub val: Vec<f32>,
}

impl SparseFloat {
    pub fn from_dense(dense: &[f32]) -> SparseFloat {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                idx.push(i as u32);
                val.push(v);
            }
        }
        SparseFloat { len: dense.len(), idx, val }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Storage bytes in the paper's COO accounting: 32-bit index +
    /// 16-bit (fp16) value per nonzero.
    pub fn coo_bytes(&self) -> u64 {
        (self.nnz() as u64 * (32 + 16)).div_ceil(8)
    }

    /// Accumulate `weight · v` into a dense buffer.
    pub fn add_into(&self, out: &mut [f32], weight: f32) {
        assert_eq!(out.len(), self.len);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += weight * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseFloat::from_dense(&dense);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense(), dense);
        assert_eq!(s.coo_bytes(), 12);
    }

    #[test]
    fn add_into_weights() {
        let s = SparseFloat::from_dense(&[0.0, 2.0, 0.0]);
        let mut buf = vec![1.0f32; 3];
        s.add_into(&mut buf, 0.5);
        assert_eq!(buf, vec![1.0, 2.0, 1.0]);
    }
}
