//! BitDelta (Liu et al., 2024), "No Training" variant — Appendix C.1
//! comparator.
//!
//! BitDelta keeps *every* parameter's sign (density 1, values ±α) with
//! the scale set to the mean absolute value of the task vector. Unlike
//! STC there is no sparsification step, so the encoded form is a single
//! dense bitmask: 1 bit/param + scalar.

use crate::compeft::ternary::TernaryVector;

/// Compress `tau` with BitDelta (No Training).
pub fn bitdelta_compress(tau: &[f32]) -> TernaryVector {
    if tau.is_empty() {
        return TernaryVector::empty(0);
    }
    let mean_abs =
        tau.iter().map(|x| x.abs() as f64).sum::<f64>() / tau.len() as f64;
    let mut plus = Vec::new();
    let mut minus = Vec::new();
    for (i, &v) in tau.iter().enumerate() {
        // Zero entries get sign +1 by convention (sgn(0) treated as +):
        // BitDelta has no zero state — every weight is ±α.
        if v >= 0.0 {
            plus.push(i as u32);
        } else {
            minus.push(i as u32);
        }
    }
    TernaryVector { len: tau.len(), scale: mean_abs as f32, plus, minus }
}

/// BitDelta wire size: one dense bitmask (1 bit/param) + 16-bit scalar.
/// (Paper Appendix C.1 stores BitDelta with a bitmask.)
pub fn bitdelta_bytes(d: usize) -> u64 {
    (d as u64 + 16).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_is_one() {
        let tau = [0.5f32, -0.25, 0.0, 2.0];
        let t = bitdelta_compress(&tau);
        assert_eq!(t.nnz(), 4);
        assert!((t.density() - 1.0).abs() < 1e-12);
        assert_eq!(t.plus, vec![0, 2, 3]);
        assert_eq!(t.minus, vec![1]);
    }

    #[test]
    fn scale_is_mean_abs() {
        let tau = [1.0f32, -3.0, 0.0, 4.0];
        let t = bitdelta_compress(&tau);
        assert!((t.scale - 2.0).abs() < 1e-6);
    }

    #[test]
    fn bytes_accounting() {
        // 70B params → ~8.75 GB at 1 bit/param... scaled: 1M → 125 KB.
        assert_eq!(bitdelta_bytes(1_000_000), 125_002);
    }

    #[test]
    fn reconstruction_error_vs_stc() {
        // On a sparse-heavy task vector, STC (which zeroes small entries)
        // should reconstruct better in L2 than BitDelta's all-±α.
        use crate::util::{prop, rng::Pcg};
        let mut rng = Pcg::seed(8);
        let tau = prop::task_vector_like(&mut rng, 10_000);
        let bd = bitdelta_compress(&tau);
        let stc = crate::baselines::stc::stc_compress(&tau, 0.2);
        let l2 = |t: &TernaryVector| -> f64 {
            t.to_dense()
                .iter()
                .zip(&tau)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(l2(&stc) < l2(&bd), "stc={} bitdelta={}", l2(&stc), l2(&bd));
    }
}
