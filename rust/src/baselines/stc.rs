//! Sparse Ternary Compression (Sattler et al., 2019) — the closest
//! prior method and the paper's main ablation comparator (§4.1).
//!
//! STC sparsifies to the top-k magnitudes like ComPEFT, but quantizes
//! with the *mean magnitude of the kept entries* rather than a tuned
//! α·σ. The paper shows this fixed scale is what costs STC its accuracy
//! at small model scales (Figure 5).

use crate::compeft::sparsify::topk_by_magnitude;
use crate::compeft::ternary::TernaryVector;

/// Compress `tau` with STC at density `k`.
pub fn stc_compress(tau: &[f32], density: f64) -> TernaryVector {
    if tau.is_empty() {
        return TernaryVector::empty(0);
    }
    let split = topk_by_magnitude(tau, density);
    let kept = split.plus.iter().chain(&split.minus);
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for &i in kept {
        sum += tau[i as usize].abs() as f64;
        n += 1;
    }
    let scale = if n == 0 { 0.0 } else { (sum / n as f64) as f32 };
    TernaryVector { len: tau.len(), scale, plus: split.plus, minus: split.minus }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn scale_is_mean_kept_magnitude() {
        let tau = [1.0f32, -3.0, 0.1, 0.2];
        let t = stc_compress(&tau, 0.5); // keeps 1.0 and -3.0
        assert!((t.scale - 2.0).abs() < 1e-6);
        assert_eq!(t.plus, vec![0]);
        assert_eq!(t.minus, vec![1]);
    }

    #[test]
    fn same_support_as_compeft() {
        // STC and ComPEFT share the sparsification step; only the scale
        // differs (Figure 5's comparison is apples-to-apples on support).
        let mut rng = Pcg::seed(17);
        let tau = prop::task_vector_like(&mut rng, 2000);
        let s = stc_compress(&tau, 0.1);
        let c = compress_vector(
            &tau,
            &CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() },
        );
        assert_eq!(s.plus, c.plus);
        assert_eq!(s.minus, c.minus);
        assert_ne!(s.scale, c.scale);
    }

    #[test]
    fn stc_scale_exceeds_sigma_at_low_density() {
        // Mean of top-5% magnitudes is far above σ for gaussian-ish τ —
        // exactly why a tuned α is needed to match it.
        let mut rng = Pcg::seed(3);
        let tau: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32).collect();
        let s = stc_compress(&tau, 0.05);
        let sigma = crate::util::stats::std_f32(&tau) as f32;
        assert!(s.scale > 1.5 * sigma, "scale={} sigma={sigma}", s.scale);
    }

    #[test]
    fn empty_input() {
        let t = stc_compress(&[], 0.5);
        assert_eq!(t.len, 0);
    }
}
