//! Virtual-clock load simulator: drives a seeded trace through the
//! **real** batcher, admission control, and metrics on a deterministic
//! simulated clock.
//!
//! The engine's wall-clock serving path cannot give reproducible
//! latency numbers — thread scheduling and link pacing inject real-time
//! jitter. The simulator replaces only the *clock* and the *service
//! times*: scheduling ([`Batcher::try_next_batch`]), admission
//! ([`admit`]), WFQ, and the reject accounting ([`Metrics`]) are the
//! production code paths. Service times come from an analytic
//! [`ServiceModel`] (pure arithmetic over [`LinkSpec::duration_for`]),
//! so a `(trace, config)` pair yields bit-identical outcomes, counters,
//! and quantiles on any machine at any `COMPEFT_TEST_WORKERS` setting.
//!
//! Residency is a deterministic LRU over `gpu_slots` experts with a
//! staged-prefetch model mirroring the engine's pipeline: after each
//! batch the scheduler's queue plan stages the next `prefetch_depth`
//! non-resident experts, and a staged expert's cold swap pays only the
//! PCIe upload hop (its store fetch ran off the critical path).

use crate::coordinator::admission::{self, AdmissionConfig};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::metrics::{Metrics, RejectCounts, RejectReason};
use crate::coordinator::store::{RebalanceConfig, Rebalancer};
use crate::coordinator::transport::LinkSpec;
use crate::util::stats::LogHistogram;
use crate::workload::Trace;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Analytic service-time model: what one batch costs on the sim clock.
#[derive(Clone, Copy, Debug)]
pub struct ServiceModel {
    /// Store → host link for cold expert fetches.
    pub net: LinkSpec,
    /// Host → accelerator link for the upload hop of every swap.
    pub pcie: LinkSpec,
    /// Encoded expert size fetched over `net` on a cold swap.
    pub expert_bytes: u64,
    /// Decoded bytes moved over `pcie` on every swap.
    pub upload_bytes: u64,
    /// Execution time of one batch, µs.
    pub exec_us: u64,
    /// Accelerator residency, in experts (deterministic LRU).
    pub gpu_slots: usize,
    /// Upcoming non-resident experts staged per batch (0 disables the
    /// prefetch model).
    pub prefetch_depth: usize,
    /// Sharded-store model: node count bounding replica widening.
    /// `0` keeps the flat single-link fetch cost (the pre-store model).
    pub store_nodes: usize,
    /// Base replicas per expert when `store_nodes > 0`. A fetch stripes
    /// across an expert's replicas in parallel, so its cost is
    /// `net.duration_for(expert_bytes / replicas)` — the same shape as
    /// the engine store's striped multi-replica transfer.
    pub replication: usize,
    /// Popularity-aware adaptive replication: feed per-expert fetch
    /// counts into a real [`Rebalancer`] every
    /// [`ServiceModel::rebalance_every`] batches, so hot experts widen
    /// (and fetch faster) while cold ones narrow back to base.
    pub rebalance: bool,
    /// Batches between rebalance rounds (ignored unless `rebalance`).
    pub rebalance_every: u64,
    /// Controller tuning shared with the engine store's rebalancer.
    pub rebalance_cfg: RebalanceConfig,
}

impl Default for ServiceModel {
    fn default() -> Self {
        ServiceModel {
            net: LinkSpec::internet(),
            pcie: LinkSpec::pcie(),
            expert_bytes: 2 << 20,
            upload_bytes: 4 << 20,
            exec_us: 2_000,
            gpu_slots: 4,
            prefetch_depth: 2,
            store_nodes: 0,
            replication: 1,
            rebalance: false,
            rebalance_every: 8,
            rebalance_cfg: RebalanceConfig::default(),
        }
    }
}

impl ServiceModel {
    /// Swap cost, µs, given whether the expert was staged by prefetch
    /// and how many store replicas its fetch stripes across.
    fn swap_us(&self, staged: bool, replicas: usize) -> u64 {
        let upload = self.pcie.duration_for(self.upload_bytes).as_micros() as u64;
        if staged {
            return upload;
        }
        let fetch_bytes = if self.store_nodes > 0 {
            // Striped fetch: each of `replicas` node links carries an
            // equal share in parallel (ceil so a lone replica pays the
            // full transfer).
            self.expert_bytes.div_ceil(replicas.max(1) as u64)
        } else {
            self.expert_bytes
        };
        self.net.duration_for(fetch_bytes).as_micros() as u64 + upload
    }

    /// Replicas a fetch of `expert` stripes across right now.
    fn replicas_for(&self, rb: Option<&Rebalancer>, expert: &str) -> usize {
        if self.store_nodes == 0 {
            return 1;
        }
        let base = self.replication.max(1).min(self.store_nodes);
        match rb {
            Some(rb) => rb.replicas_of(expert, base).min(self.store_nodes),
            None => base,
        }
    }
}

/// How the driver feeds the trace to the coordinator.
#[derive(Clone, Copy, Debug)]
pub enum Mode {
    /// Open loop: arrivals land at their trace timestamps regardless of
    /// service progress (the production regime; queues can grow).
    Open,
    /// Closed loop: at most `concurrency` requests outstanding; the next
    /// trace event is issued as soon as a slot frees (throughput-probe
    /// regime; arrival timestamps are ignored).
    Closed { concurrency: usize },
}

/// Full simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub policy: BatchPolicy,
    pub admission: AdmissionConfig,
    pub model: ServiceModel,
    pub mode: Mode,
    /// WFQ weight per tenant index (empty = all weight 1).
    pub tenant_weights: Vec<u64>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            policy: BatchPolicy::default(),
            admission: AdmissionConfig::default(),
            model: ServiceModel::default(),
            mode: Mode::Open,
            tenant_weights: Vec::new(),
        }
    }
}

/// What happened to one trace event (indexed like `trace.events`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Rejected at the door; never touched a queue, a fetch, or a batch.
    Shed(RejectReason),
    /// Served: completion time, queueing+service latency, deadline met.
    Done { finish_us: u64, latency_us: u64, met: bool },
}

/// Simulation result: aggregate service quality plus the per-event
/// outcome vector the determinism tests compare bit-for-bit.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub submitted: u64,
    pub accepted: u64,
    pub completed: u64,
    /// Door rejections by reason (from the real [`Metrics`] path).
    pub shed: RejectCounts,
    /// Completed requests that met their deadline (goodput numerator).
    pub deadline_met: u64,
    /// Sim time at which the last batch finished (≥ trace duration).
    pub duration_us: u64,
    pub latency: LogHistogram,
    pub batches: u64,
    /// Batches served by a non-resident expert (cold or staged swap).
    pub swaps: u64,
    /// Expert fetches over the store link (prefetched or on-demand).
    pub fetches: u64,
    /// Swaps whose fetch was already staged by the prefetch model.
    pub prefetch_hits: u64,
    /// High-water mark of the batcher queue.
    pub max_queued: usize,
    /// Adaptive-replication rounds executed (0 with rebalance off).
    pub rebalances: u64,
    /// Replicas widened across all rebalance rounds.
    pub replicas_added: u64,
    /// Replicas narrowed across all rebalance rounds.
    pub replicas_dropped: u64,
    /// Bytes the widening rounds migrated (≤ budget × rounds).
    pub migrated_bytes: u64,
    pub outcomes: Vec<Outcome>,
}

impl SimReport {
    /// Deadline-meeting completions per second of simulated time.
    pub fn goodput_rps(&self) -> f64 {
        if self.duration_us == 0 {
            return 0.0;
        }
        self.deadline_met as f64 / (self.duration_us as f64 / 1e6)
    }

    /// Fraction of submitted requests shed at the door.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed.total() as f64 / self.submitted as f64
    }

    pub fn p50_us(&self) -> f64 {
        self.latency.quantile_us(0.50)
    }

    pub fn p99_us(&self) -> f64 {
        self.latency.quantile_us(0.99)
    }

    pub fn p999_us(&self) -> f64 {
        self.latency.quantile_us(0.999)
    }
}

/// Run `trace` through the coordinator's scheduling + admission stack on
/// a virtual clock. Deterministic in `(trace, cfg)`.
pub fn run(trace: &Trace, cfg: &SimConfig) -> SimReport {
    let batcher: Batcher<usize> = Batcher::new(cfg.policy);
    let metrics = Metrics::new();
    for (ti, &w) in cfg.tenant_weights.iter().enumerate() {
        batcher.set_tenant_weight(ti as u32, w);
    }
    // The batcher speaks `Instant`; anchor virtual µs to an arbitrary
    // origin. Only differences of these instants are ever used, so the
    // origin's wall value cannot leak into any outcome.
    // compeft-lint: allow(no-wall-clock) -- arbitrary origin for the virtual clock; only differences are used
    let origin = Instant::now();
    let at = |t_us: u64| origin + Duration::from_micros(t_us);
    let us_of = |i: Instant| i.duration_since(origin).as_micros() as u64;

    let events = &trace.events;
    let n = events.len();
    let mut outcomes: Vec<Option<Outcome>> = vec![None; n];
    let mut ei = 0usize;
    let mut now_us = 0u64;
    // Deterministic LRU residency: most recently served last.
    let mut resident: Vec<String> = Vec::new();
    let mut staged: Vec<String> = Vec::new();
    let mut hint: Option<String> = None;
    let (mut batches, mut swaps, mut fetches, mut prefetch_hits) = (0u64, 0u64, 0u64, 0u64);
    let mut max_queued = 0usize;
    let mut latency = LogHistogram::new();
    let (mut accepted, mut completed, mut deadline_met) = (0u64, 0u64, 0u64);
    // Adaptive replication: the production Rebalancer, fed per-round
    // fetch counts at batch-counter boundaries — the same pure state
    // machine the engine store drives, so sim rebalance schedules are
    // bit-identical at any worker count.
    let mut rebalancer = if cfg.model.rebalance && cfg.model.store_nodes > 0 {
        Some(Rebalancer::new(cfg.model.rebalance_cfg))
    } else {
        None
    };
    let mut round_counts: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    let (mut rebalances, mut replicas_added, mut replicas_dropped) = (0u64, 0u64, 0u64);
    let mut migrated_bytes = 0u64;

    loop {
        // Admit every due arrival. Open loop: events whose timestamp has
        // passed. Closed loop: refill outstanding slots in trace order.
        loop {
            let queued = batcher.queued();
            let due = match cfg.mode {
                Mode::Open => ei < n && events[ei].t_us <= now_us,
                Mode::Closed { concurrency } => ei < n && queued < concurrency.max(1),
            };
            if !due {
                break;
            }
            let e = &events[ei];
            let arrive_us = match cfg.mode {
                Mode::Open => e.t_us,
                Mode::Closed { .. } => now_us,
            };
            let verdict = admission::admit(&cfg.admission, queued, Some(e.deadline_us));
            match verdict.reject_reason() {
                Some(reason) => {
                    metrics.record_rejected(reason, 1);
                    outcomes[ei] = Some(Outcome::Shed(reason));
                }
                None => {
                    batcher.push_at(&e.expert.to_string(), e.tenant, ei, at(arrive_us));
                    accepted += 1;
                    max_queued = max_queued.max(batcher.queued());
                }
            }
            ei += 1;
        }

        // Serve a batch if the scheduler releases one at the current
        // virtual instant.
        if let Some((expert, batch)) = batcher.try_next_batch(hint.as_deref(), at(now_us)) {
            let mut service_us = cfg.model.exec_us;
            let swapped = if let Some(pos) = resident.iter().position(|r| *r == expert) {
                let r = resident.remove(pos);
                resident.push(r); // LRU touch
                false
            } else {
                swaps += 1;
                fetches += 1;
                let was_staged = staged.contains(&expert);
                if was_staged {
                    prefetch_hits += 1;
                }
                let replicas = cfg.model.replicas_for(rebalancer.as_ref(), &expert);
                service_us += cfg.model.swap_us(was_staged, replicas);
                // Popularity feed: every fetch (staged ones included —
                // their store transfer still happened, just off the
                // critical path) counts toward the next round.
                let c = round_counts.entry(expert.clone()).or_insert((0, 0));
                c.0 += 1;
                c.1 = cfg.model.expert_bytes;
                resident.push(expert.clone());
                if resident.len() > cfg.model.gpu_slots.max(1) {
                    resident.remove(0);
                }
                true
            };
            batches += 1;
            metrics.record_batch(batch.len(), swapped);
            if let Some(rb) = rebalancer.as_mut() {
                if batches % cfg.model.rebalance_every.max(1) == 0 {
                    let base = cfg.model.replication.max(1).min(cfg.model.store_nodes);
                    let d = rb.round(&round_counts, base, cfg.model.store_nodes);
                    round_counts.clear();
                    rebalances += 1;
                    replicas_added += d.added.len() as u64;
                    replicas_dropped += d.dropped.len() as u64;
                    migrated_bytes += d.migrated_bytes;
                }
            }
            now_us += service_us;
            for p in &batch {
                let e = &events[p.payload];
                let latency_us = now_us - us_of(p.enqueued);
                let met = latency_us <= e.deadline_us;
                latency.record_us(latency_us as f64);
                completed += 1;
                deadline_met += u64::from(met);
                outcomes[p.payload] =
                    Some(Outcome::Done { finish_us: now_us, latency_us, met });
            }
            // Mirror the engine's prefetch pipeline: stage the next
            // non-resident experts from the scheduler's plan while this
            // batch "executes".
            staged = if cfg.model.prefetch_depth > 0 {
                batcher
                    .plan(cfg.model.prefetch_depth + 2, Some(&expert))
                    .into_iter()
                    .filter(|id| *id != expert && !resident.contains(id))
                    .take(cfg.model.prefetch_depth)
                    .collect()
            } else {
                Vec::new()
            };
            hint = Some(expert);
            continue;
        }

        // Idle at `now_us`: advance the clock to the next thing that can
        // change scheduler state — an arrival or a head-of-line request
        // crossing `max_wait`. Both are strictly in the future (due
        // arrivals were admitted above; an expired head would have been
        // released), so the loop always makes progress.
        let next_arrival = match cfg.mode {
            Mode::Open if ei < n => Some(events[ei].t_us),
            _ => None,
        };
        let next_deadline = batcher.next_deadline().map(us_of);
        match [next_arrival, next_deadline].into_iter().flatten().min() {
            Some(t) => now_us = now_us.max(t),
            None => break, // no pending work, no future arrivals: done
        }
    }

    let snap = metrics.snapshot();
    SimReport {
        submitted: n as u64,
        accepted,
        completed,
        shed: snap.rejected_by,
        deadline_met,
        duration_us: now_us.max(trace.duration_us),
        latency,
        batches,
        swaps,
        fetches,
        prefetch_hits,
        max_queued,
        rebalances,
        replicas_added,
        replicas_dropped,
        migrated_bytes,
        outcomes: outcomes
            .into_iter()
            .map(|o| o.expect("every event is shed or completed"))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceSpec;

    fn small_trace() -> Trace {
        Trace::generate(&TraceSpec::steady_zipf(1_000_000, 8, 2, 800.0), 42)
    }

    /// The same (trace, config) replays bit-identically: outcomes,
    /// counters, and the latency histogram all match across reruns.
    #[test]
    fn reruns_are_bit_identical() {
        let trace = small_trace();
        let cfg = SimConfig {
            admission: AdmissionConfig {
                queue_cap: 64,
                shed_deadline: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let a = run(&trace, &cfg);
        let b = run(&trace, &cfg);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.shed, b.shed);
        assert_eq!(
            (a.batches, a.swaps, a.fetches, a.prefetch_hits, a.max_queued),
            (b.batches, b.swaps, b.fetches, b.prefetch_hits, b.max_queued)
        );
        assert_eq!(a.latency.quantile_us(0.999), b.latency.quantile_us(0.999));
        assert_eq!(a.duration_us, b.duration_us);
    }

    /// Accounting invariants: every event is shed or completed, goodput
    /// counts only deadline-meeting completions, queues were observed.
    #[test]
    fn accounting_is_conservative() {
        let trace = small_trace();
        let r = run(&trace, &SimConfig::default());
        assert_eq!(r.submitted, trace.events.len() as u64);
        assert_eq!(r.accepted + r.shed.total(), r.submitted);
        assert_eq!(r.completed, r.accepted, "open queue drains fully");
        assert!(r.deadline_met <= r.completed);
        assert_eq!(r.latency.count(), r.completed);
        assert!(r.batches > 0 && r.max_queued > 0);
        assert!(r.duration_us >= trace.duration_us);
    }

    /// The overload story the bench's headline row tells: with the
    /// server far past saturation, deadline-aware shedding yields
    /// strictly more deadline-meeting completions per second than
    /// admitting everything (where queueing delay blows every budget).
    #[test]
    fn shedding_beats_no_shedding_on_goodput_under_overload() {
        let mut spec = TraceSpec::steady_zipf(3_000_000, 64, 2, 1_500.0);
        for t in &mut spec.tenants {
            t.deadline_us = 100_000;
        }
        let trace = Trace::generate(&spec, 7);
        // One residency slot, no prefetch: nearly every batch pays the
        // full cold-swap cost (~46 ms), so the server saturates near
        // 170 rps against 1500 rps offered — ~9× overload.
        let model = ServiceModel { gpu_slots: 1, prefetch_depth: 0, ..Default::default() };
        let off = run(&trace, &SimConfig { model, ..Default::default() });
        let on = run(
            &trace,
            &SimConfig {
                model,
                admission: AdmissionConfig {
                    shed_deadline: true,
                    // Honest per-batch estimate ≈ cold swap + exec.
                    est_batch_us: 46_000,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        assert!(on.shed.shed_deadline > 0, "overload must trigger shedding");
        assert!(
            on.goodput_rps() > off.goodput_rps(),
            "shedding goodput {:.1} rps must beat no-shedding {:.1} rps",
            on.goodput_rps(),
            off.goodput_rps()
        );
    }

    /// Adaptive replication: the rebalancer widens the Zipf head, every
    /// widened fetch stripes across more nodes, and the tail of the
    /// latency distribution never gets worse than the fixed-replication
    /// baseline — while the whole schedule stays bit-identical across
    /// reruns.
    #[test]
    fn adaptive_replication_widens_hot_experts_and_never_hurts_tail() {
        let trace = Trace::generate(&TraceSpec::steady_zipf(2_000_000, 32, 2, 600.0), 9);
        // Two residency slots, no prefetch: the Zipf head churns through
        // the LRU and refetches constantly, so fetch time dominates and
        // popularity-aware widening has something to optimize.
        let base = ServiceModel {
            gpu_slots: 2,
            prefetch_depth: 0,
            store_nodes: 4,
            replication: 1,
            ..Default::default()
        };
        let fixed = run(&trace, &SimConfig { model: base, ..Default::default() });
        let model = ServiceModel { rebalance: true, ..base };
        let a = run(&trace, &SimConfig { model, ..Default::default() });
        let b = run(&trace, &SimConfig { model, ..Default::default() });
        assert_eq!(a.outcomes, b.outcomes, "adaptive schedule must be deterministic");
        assert_eq!(
            (a.rebalances, a.replicas_added, a.replicas_dropped, a.migrated_bytes),
            (b.rebalances, b.replicas_added, b.replicas_dropped, b.migrated_bytes)
        );
        assert!(a.rebalances > 0, "rounds must have run");
        assert!(a.replicas_added > 0, "the Zipf head must widen");
        assert!(
            a.p99_us() <= fixed.p99_us(),
            "adaptive p99 {:.0}us must not exceed fixed-replication p99 {:.0}us",
            a.p99_us(),
            fixed.p99_us()
        );
        // Per-round migration is bounded by the configured budget.
        assert!(a.migrated_bytes <= a.rebalances * model.rebalance_cfg.byte_budget);
        // With rebalance off the new counters stay zero and the fixed
        // baseline itself replays bit-identically.
        assert_eq!(
            (fixed.rebalances, fixed.replicas_added, fixed.migrated_bytes),
            (0, 0, 0)
        );
    }

    /// Closed loop keeps at most `concurrency` requests outstanding.
    #[test]
    fn closed_loop_bounds_outstanding_requests() {
        let trace = small_trace();
        let r = run(
            &trace,
            &SimConfig { mode: Mode::Closed { concurrency: 16 }, ..Default::default() },
        );
        assert!(r.max_queued <= 16, "max_queued {} > concurrency", r.max_queued);
        assert_eq!(r.completed, r.accepted);
    }

    /// Bounded-queue backpressure: the queue never exceeds the cap and
    /// overflow is counted under `queue_full`.
    #[test]
    fn queue_cap_bounds_queue_depth() {
        let mut spec = TraceSpec::steady_zipf(1_000_000, 64, 2, 2_000.0);
        for t in &mut spec.tenants {
            t.deadline_us = 50_000;
        }
        let trace = Trace::generate(&spec, 5);
        let r = run(
            &trace,
            &SimConfig {
                model: ServiceModel { gpu_slots: 2, ..Default::default() },
                admission: AdmissionConfig { queue_cap: 32, ..Default::default() },
                ..Default::default()
            },
        );
        assert!(r.max_queued <= 32, "max_queued {} > cap", r.max_queued);
        assert!(r.shed.queue_full > 0, "overload must hit the cap");
    }
}
