//! Serving metrics: latency breakdowns, throughput, swap accounting.
//!
//! The hot path records into lock-guarded log-histograms (bucket
//! increment only); snapshots are taken off the request path by benches
//! and the CLI's `serve` summary.

use crate::compeft::payload::CopyMeter;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;
use crate::util::sync::{rank, OrderedMutex};
use std::time::Duration;

/// Why a request was dropped without a reply. The catch-all `rejected`
/// counter used to conflate admission-control policy (shedding,
/// backpressure) with client errors (malformed submits) and server
/// faults (load/exec failures); every drop now names its reason.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Admission control: the queue-delay estimate already blew the
    /// request's deadline, so it was shed at submit.
    ShedDeadline,
    /// Admission control: the bounded queue was full (backpressure).
    QueueFull,
    /// Client error: mis-sized token vector at submit.
    Malformed,
    /// The expert id names neither a stored expert nor a composition.
    UnknownExpert,
    /// The expert failed to fetch/decode/upload.
    LoadFailure,
    /// Batch execution failed mid-way; these requests never got logits.
    ExecError,
}

/// Per-reason drop counters (see [`RejectReason`]). `total()` is the
/// old catch-all `rejected` value.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RejectCounts {
    pub shed_deadline: u64,
    pub queue_full: u64,
    pub malformed: u64,
    pub unknown_expert: u64,
    pub load_failure: u64,
    pub exec_error: u64,
}

impl RejectCounts {
    pub fn total(&self) -> u64 {
        self.shed_deadline
            + self.queue_full
            + self.malformed
            + self.unknown_expert
            + self.load_failure
            + self.exec_error
    }

    fn slot(&mut self, reason: RejectReason) -> &mut u64 {
        match reason {
            RejectReason::ShedDeadline => &mut self.shed_deadline,
            RejectReason::QueueFull => &mut self.queue_full,
            RejectReason::Malformed => &mut self.malformed,
            RejectReason::UnknownExpert => &mut self.unknown_expert,
            RejectReason::LoadFailure => &mut self.load_failure,
            RejectReason::ExecError => &mut self.exec_error,
        }
    }
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    swaps: u64,
    batch_fill: u64, // sum of batch sizes, for mean fill
    /// Requests dropped without a reply, split by reason.
    rejected: RejectCounts,
    /// Swaps fully served from the prefetch staging slot (fetch+decode
    /// already done off the engine thread; only the upload hop paid).
    prefetch_hits: u64,
    /// Swaps that found the prefetch in flight and waited for it
    /// (partial overlap).
    prefetch_waits: u64,
    /// Cold swaps the prefetcher had not staged (engine ran the full
    /// blocking fetch→decode path).
    prefetch_misses: u64,
    /// Staged experts dropped unused (plan changed / staging budget).
    prefetch_wasted: u64,
    /// Simulated fetch+decode time removed from the engine critical
    /// path by prefetching, in µs (the "overlap time saved" counter).
    overlap_saved_us: u64,
    /// Cold-swap time hidden by the fused fetch→decode path (frames
    /// decoded as stripes land): `fetch + decode − fused`, in µs.
    decode_overlap_us: u64,
    /// Cold swaps that ran the fused fetch→decode path.
    fused_loads: u64,
    /// Extra stripe fetch attempts beyond the first, across all striped
    /// store fetches (every failover retry and corruption re-fetch).
    stripe_retries: u64,
    /// Stripes that succeeded on a replica other than their first
    /// choice (counted once per stripe, however many retries it took).
    failovers: u64,
    /// Stripe payloads received corrupt (per-stripe CRC mismatch) and
    /// re-fetched from another replica.
    corrupt_payloads: u64,
    /// Expert payloads served as zero-copy views out of the local
    /// archive tier (no host-tier copy, no remote fetch).
    archive_hits: u64,
    /// Total encoded bytes served as archive views.
    archive_bytes_viewed: u64,
    /// Popularity-driven rebalance rounds executed by the store.
    rebalances: u64,
    /// Expert replicas added by rebalance rounds (widening).
    replicas_added: u64,
    /// Expert replicas dropped by rebalance rounds (narrowing).
    replicas_dropped: u64,
    /// Encoded bytes copied between store nodes by rebalance rounds and
    /// topology changes (drain / add migrations).
    migrated_bytes: u64,
    /// Expert version upgrades applied as ternary deltas in place.
    delta_applies: u64,
    /// Bytes saved by shipping deltas instead of full re-encodes
    /// (`Σ full encoded bytes − delta wire bytes`).
    delta_bytes_saved: u64,
    queue: LogHistogram,
    swap: LogHistogram,
    exec: LogHistogram,
    total: LogHistogram,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: OrderedMutex<Inner>,
    /// Lock-free counter of encoded-payload heap copies, shared with
    /// this engine's loader and store via [`Metrics::copy_meter`] so
    /// `payload_copies` in the snapshot reflects exactly this engine.
    copy_meter: CopyMeter,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            inner: OrderedMutex::new(rank::METRICS, "metrics.inner", Inner::default()),
            copy_meter: CopyMeter::default(),
        }
    }
}

/// Per-request latency breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestTiming {
    pub queue: Duration,
    pub swap: Duration,
    pub exec: Duration,
    pub total: Duration,
    pub swapped: bool,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, t: &RequestTiming) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        if t.swapped {
            // swap counted per batch elsewhere; histogram per request
        }
        g.queue.record_us(t.queue.as_secs_f64() * 1e6);
        g.swap.record_us(t.swap.as_secs_f64() * 1e6);
        g.exec.record_us(t.exec.as_secs_f64() * 1e6);
        g.total.record_us(t.total.as_secs_f64() * 1e6);
    }

    pub fn record_batch(&self, size: usize, swapped: bool) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_fill += size as u64;
        if swapped {
            g.swaps += 1;
        }
    }

    /// Count `n` requests dropped without a reply, attributed to
    /// `reason` (shedding, backpressure, malformed submits, unknown
    /// experts, load/exec failures).
    pub fn record_rejected(&self, reason: RejectReason, n: u64) {
        *self.inner.lock().unwrap().rejected.slot(reason) += n;
    }

    /// A cold swap fully served from the staging slot; `saved` is the
    /// simulated fetch+decode time kept off the engine critical path.
    pub fn record_prefetch_hit(&self, saved: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.prefetch_hits += 1;
        g.overlap_saved_us += saved.as_micros() as u64;
    }

    /// A cold swap that found its prefetch still in flight and waited
    /// for it. Credited **zero** overlap savings: how much of the
    /// staged cost was already hidden when the engine arrived cannot be
    /// split between the sim and wall clocks, so the whole staged cost
    /// is charged to the request like a miss — prefetch-on latency is
    /// never flattered by partial overlaps.
    pub fn record_prefetch_wait(&self) {
        self.inner.lock().unwrap().prefetch_waits += 1;
    }

    /// A cold swap the prefetcher had not staged.
    pub fn record_prefetch_miss(&self) {
        self.inner.lock().unwrap().prefetch_misses += 1;
    }

    /// `n` staged experts dropped unused.
    pub fn record_prefetch_wasted(&self, n: u64) {
        self.inner.lock().unwrap().prefetch_wasted += n;
    }

    /// One cold swap ran the fused fetch→decode path; `hidden` is the
    /// cold-swap time the stripe/frame overlap removed
    /// (`fetch + decode − fused`).
    pub fn record_decode_overlap(&self, hidden: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.fused_loads += 1;
        g.decode_overlap_us += hidden.as_micros() as u64;
    }

    /// Striped-store fault accounting for one fetch: extra attempts
    /// beyond the first (`retries`), stripes served by a non-first
    /// replica (`failovers`), and corrupt receptions (`corrupts`).
    pub fn record_store_faults(&self, retries: u64, failovers: u64, corrupts: u64) {
        let mut g = self.inner.lock().unwrap();
        g.stripe_retries += retries;
        g.failovers += failovers;
        g.corrupt_payloads += corrupts;
    }

    /// One expert payload served as a zero-copy view out of the local
    /// archive tier (`bytes` = its encoded size).
    pub fn record_archive_hit(&self, bytes: u64) {
        let mut g = self.inner.lock().unwrap();
        g.archive_hits += 1;
        g.archive_bytes_viewed += bytes;
    }

    /// One store rebalance round: `added`/`dropped` replicas and the
    /// bytes its widening migrations copied.
    pub fn record_rebalance(&self, added: u64, dropped: u64, migrated: u64) {
        let mut g = self.inner.lock().unwrap();
        g.rebalances += 1;
        g.replicas_added += added;
        g.replicas_dropped += dropped;
        g.migrated_bytes += migrated;
    }

    /// Encoded bytes copied between store nodes by a topology change
    /// (node drain or add).
    pub fn record_migrated(&self, bytes: u64) {
        self.inner.lock().unwrap().migrated_bytes += bytes;
    }

    /// One expert version upgrade applied as a ternary delta in place:
    /// `delta_bytes` went over the wire instead of `full_bytes`.
    pub fn record_delta_apply(&self, delta_bytes: u64, full_bytes: u64) {
        let mut g = self.inner.lock().unwrap();
        g.delta_applies += 1;
        g.delta_bytes_saved += full_bytes.saturating_sub(delta_bytes);
    }

    /// A handle on this engine's copy counter — hand clones to the
    /// loader/store (`with_meter`) so every encoded-byte heap copy they
    /// make lands in this snapshot's `payload_copies`.
    pub fn copy_meter(&self) -> CopyMeter {
        self.copy_meter.clone()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            swaps: g.swaps,
            rejected: g.rejected.total(),
            rejected_by: g.rejected,
            prefetch_hits: g.prefetch_hits,
            prefetch_waits: g.prefetch_waits,
            prefetch_misses: g.prefetch_misses,
            prefetch_wasted: g.prefetch_wasted,
            overlap_saved_us: g.overlap_saved_us,
            decode_overlap_us: g.decode_overlap_us,
            fused_loads: g.fused_loads,
            stripe_retries: g.stripe_retries,
            failovers: g.failovers,
            corrupt_payloads: g.corrupt_payloads,
            archive_hits: g.archive_hits,
            archive_bytes_viewed: g.archive_bytes_viewed,
            rebalances: g.rebalances,
            replicas_added: g.replicas_added,
            replicas_dropped: g.replicas_dropped,
            migrated_bytes: g.migrated_bytes,
            delta_applies: g.delta_applies,
            delta_bytes_saved: g.delta_bytes_saved,
            payload_copies: self.copy_meter.count(),
            mean_batch_fill: if g.batches == 0 {
                0.0
            } else {
                g.batch_fill as f64 / g.batches as f64
            },
            queue_p50_us: g.queue.quantile_us(0.5),
            total_p50_us: g.total.quantile_us(0.5),
            total_p95_us: g.total.quantile_us(0.95),
            total_p99_us: g.total.quantile_us(0.99),
            total_mean_us: g.total.mean_us(),
            swap_mean_us: g.swap.mean_us(),
            exec_mean_us: g.exec.mean_us(),
        }
    }
}

/// Off-path snapshot of the counters.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub swaps: u64,
    /// Requests dropped without a reply (sum of `rejected_by`).
    pub rejected: u64,
    /// The same drops split by reason.
    pub rejected_by: RejectCounts,
    /// Cold swaps served entirely from the prefetch staging slot.
    pub prefetch_hits: u64,
    /// Cold swaps that waited on an in-flight prefetch.
    pub prefetch_waits: u64,
    /// Cold swaps with nothing staged (full blocking path).
    pub prefetch_misses: u64,
    /// Staged experts dropped unused.
    pub prefetch_wasted: u64,
    /// Simulated fetch+decode time hidden behind batch execution, µs.
    pub overlap_saved_us: u64,
    /// Cold-swap time hidden by fused fetch→decode (frames decoded as
    /// stripes landed): `fetch + decode − fused`, µs.
    pub decode_overlap_us: u64,
    /// Cold swaps that ran the fused fetch→decode path.
    pub fused_loads: u64,
    /// Extra stripe fetch attempts beyond the first (striped store).
    pub stripe_retries: u64,
    /// Stripes served by a replica other than their first choice.
    pub failovers: u64,
    /// Stripe payloads received corrupt and re-fetched elsewhere.
    pub corrupt_payloads: u64,
    /// Experts served as zero-copy views out of the local archive tier.
    pub archive_hits: u64,
    /// Total encoded bytes served as archive views.
    pub archive_bytes_viewed: u64,
    /// Popularity-driven rebalance rounds executed by the store.
    pub rebalances: u64,
    /// Expert replicas added by rebalance rounds (widening).
    pub replicas_added: u64,
    /// Expert replicas dropped by rebalance rounds (narrowing).
    pub replicas_dropped: u64,
    /// Encoded bytes copied between store nodes (rebalance + drain/add).
    pub migrated_bytes: u64,
    /// Expert version upgrades applied as ternary deltas in place.
    pub delta_applies: u64,
    /// Bytes saved by shipping deltas instead of full re-encodes.
    pub delta_bytes_saved: u64,
    /// Heap copies of encoded payload bytes (the zero-copy regression
    /// counter — archive-resident serving must keep this at 0).
    pub payload_copies: u64,
    pub mean_batch_fill: f64,
    pub queue_p50_us: f64,
    pub total_p50_us: f64,
    pub total_p95_us: f64,
    pub total_p99_us: f64,
    pub total_mean_us: f64,
    pub swap_mean_us: f64,
    pub exec_mean_us: f64,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("requests", Json::num(self.requests as f64))
            .set("batches", Json::num(self.batches as f64))
            .set("swaps", Json::num(self.swaps as f64))
            .set("rejected", Json::num(self.rejected as f64))
            .set("shed_deadline", Json::num(self.rejected_by.shed_deadline as f64))
            .set("queue_full", Json::num(self.rejected_by.queue_full as f64))
            .set("malformed", Json::num(self.rejected_by.malformed as f64))
            .set("unknown_expert", Json::num(self.rejected_by.unknown_expert as f64))
            .set("load_failure", Json::num(self.rejected_by.load_failure as f64))
            .set("exec_error", Json::num(self.rejected_by.exec_error as f64))
            .set("prefetch_hits", Json::num(self.prefetch_hits as f64))
            .set("prefetch_waits", Json::num(self.prefetch_waits as f64))
            .set("prefetch_misses", Json::num(self.prefetch_misses as f64))
            .set("prefetch_wasted", Json::num(self.prefetch_wasted as f64))
            .set("overlap_saved_us", Json::num(self.overlap_saved_us as f64))
            .set("decode_overlap_us", Json::num(self.decode_overlap_us as f64))
            .set("fused_loads", Json::num(self.fused_loads as f64))
            .set("stripe_retries", Json::num(self.stripe_retries as f64))
            .set("failovers", Json::num(self.failovers as f64))
            .set("corrupt_payloads", Json::num(self.corrupt_payloads as f64))
            .set("archive_hits", Json::num(self.archive_hits as f64))
            .set("archive_bytes_viewed", Json::num(self.archive_bytes_viewed as f64))
            .set("rebalances", Json::num(self.rebalances as f64))
            .set("replicas_added", Json::num(self.replicas_added as f64))
            .set("replicas_dropped", Json::num(self.replicas_dropped as f64))
            .set("migrated_bytes", Json::num(self.migrated_bytes as f64))
            .set("delta_applies", Json::num(self.delta_applies as f64))
            .set("delta_bytes_saved", Json::num(self.delta_bytes_saved as f64))
            .set("payload_copies", Json::num(self.payload_copies as f64))
            .set("mean_batch_fill", Json::num(self.mean_batch_fill))
            .set("total_p50_us", Json::num(self.total_p50_us))
            .set("total_p95_us", Json::num(self.total_p95_us))
            .set("total_p99_us", Json::num(self.total_p99_us))
            .set("total_mean_us", Json::num(self.total_mean_us))
            .set("swap_mean_us", Json::num(self.swap_mean_us))
            .set("exec_mean_us", Json::num(self.exec_mean_us));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(&RequestTiming {
                queue: Duration::from_micros(10),
                swap: Duration::from_micros(if i % 10 == 0 { 5000 } else { 0 }),
                exec: Duration::from_micros(200),
                total: Duration::from_micros(250 + i),
                swapped: i % 10 == 0,
            });
        }
        m.record_batch(8, true);
        m.record_batch(4, false);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.swaps, 1);
        assert!((s.mean_batch_fill - 6.0).abs() < 1e-9);
        assert!(s.total_p95_us >= s.total_p50_us);
        assert!(s.total_mean_us > 250.0);
        let j = s.to_json().to_string();
        assert!(j.contains("\"requests\":100"));
    }

    /// The rejected counters and the prefetch overlap counters survive
    /// the snapshot + JSON paths (regression for the unknown-expert
    /// branch that claimed "metrics still count them" but recorded
    /// nothing).
    #[test]
    fn rejected_and_prefetch_counters_round_trip() {
        let m = Metrics::new();
        m.record_rejected(RejectReason::UnknownExpert, 3);
        m.record_rejected(RejectReason::Malformed, 2);
        m.record_prefetch_hit(Duration::from_micros(1500));
        // Waits are counted but credited no overlap savings (the whole
        // staged cost is charged to the request, like a miss).
        m.record_prefetch_wait();
        m.record_prefetch_wait();
        m.record_prefetch_miss();
        m.record_prefetch_wasted(4);
        m.record_store_faults(3, 2, 1);
        m.record_store_faults(1, 1, 0);
        m.record_decode_overlap(Duration::from_micros(700));
        m.record_decode_overlap(Duration::from_micros(300));
        m.record_archive_hit(4096);
        m.record_archive_hit(1024);
        m.record_rebalance(3, 1, 2048);
        m.record_rebalance(0, 2, 0);
        m.record_migrated(512);
        m.record_delta_apply(100, 1000);
        m.record_delta_apply(250, 200); // saving saturates at zero
        m.copy_meter().record(3);
        let s = m.snapshot();
        assert_eq!(s.rejected, 5);
        assert_eq!(s.rejected_by.unknown_expert, 3);
        assert_eq!(s.rejected_by.malformed, 2);
        assert_eq!(s.stripe_retries, 4);
        assert_eq!(s.failovers, 3);
        assert_eq!(s.corrupt_payloads, 1);
        assert_eq!(s.prefetch_hits, 1);
        assert_eq!(s.prefetch_waits, 2);
        assert_eq!(s.prefetch_misses, 1);
        assert_eq!(s.prefetch_wasted, 4);
        assert_eq!(s.overlap_saved_us, 1500);
        assert_eq!(s.decode_overlap_us, 1000);
        assert_eq!(s.fused_loads, 2);
        assert_eq!(s.archive_hits, 2);
        assert_eq!(s.archive_bytes_viewed, 5120);
        assert_eq!(s.rebalances, 2);
        assert_eq!(s.replicas_added, 3);
        assert_eq!(s.replicas_dropped, 3);
        assert_eq!(s.migrated_bytes, 2560);
        assert_eq!(s.delta_applies, 2);
        assert_eq!(s.delta_bytes_saved, 900);
        assert_eq!(s.payload_copies, 3);
        let j = s.to_json().to_string();
        assert!(j.contains("\"rejected\":5"));
        assert!(j.contains("\"prefetch_hits\":1"));
        assert!(j.contains("\"overlap_saved_us\":1500"));
        assert!(j.contains("\"decode_overlap_us\":1000"));
        assert!(j.contains("\"fused_loads\":2"));
        assert!(j.contains("\"stripe_retries\":4"));
        assert!(j.contains("\"failovers\":3"));
        assert!(j.contains("\"corrupt_payloads\":1"));
        assert!(j.contains("\"archive_hits\":2"));
        assert!(j.contains("\"archive_bytes_viewed\":5120"));
        assert!(j.contains("\"rebalances\":2"));
        assert!(j.contains("\"replicas_added\":3"));
        assert!(j.contains("\"replicas_dropped\":3"));
        assert!(j.contains("\"migrated_bytes\":2560"));
        assert!(j.contains("\"delta_applies\":2"));
        assert!(j.contains("\"delta_bytes_saved\":900"));
        assert!(j.contains("\"payload_copies\":3"));
    }

    /// Regression for the catch-all `rejected` counter: every reason
    /// lands in its own slot, the aggregate is exactly their sum, and
    /// the JSON snapshot exposes each reason under a stable key — so
    /// policy shedding can no longer masquerade as client error (or
    /// vice versa).
    #[test]
    fn rejected_reasons_are_split_and_sum_to_total() {
        let m = Metrics::new();
        let reasons = [
            (RejectReason::ShedDeadline, 7),
            (RejectReason::QueueFull, 5),
            (RejectReason::Malformed, 3),
            (RejectReason::UnknownExpert, 2),
            (RejectReason::LoadFailure, 1),
            (RejectReason::ExecError, 4),
        ];
        for (r, n) in reasons {
            m.record_rejected(r, n);
        }
        let s = m.snapshot();
        assert_eq!(
            s.rejected_by,
            RejectCounts {
                shed_deadline: 7,
                queue_full: 5,
                malformed: 3,
                unknown_expert: 2,
                load_failure: 1,
                exec_error: 4,
            }
        );
        assert_eq!(s.rejected, 22, "aggregate stays the per-reason sum");
        assert_eq!(s.rejected_by.total(), s.rejected);
        let j = s.to_json().to_string();
        for key in [
            "\"shed_deadline\":7",
            "\"queue_full\":5",
            "\"malformed\":3",
            "\"unknown_expert\":2",
            "\"load_failure\":1",
            "\"exec_error\":4",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
