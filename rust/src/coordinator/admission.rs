//! Admission control: bounded-queue backpressure and deadline-aware
//! early load shedding.
//!
//! Under overload, admitting every request makes *every* request late —
//! queues grow without bound and even requests that will eventually be
//! served have already blown their deadlines by the time they reach the
//! accelerator (goodput collapses to zero while throughput stays high).
//! Shedding at the door keeps the queue short enough that admitted
//! requests still finish in time: lower throughput, strictly higher
//! goodput. The `service_load` bench's overload row measures exactly
//! this trade.
//!
//! [`admit`] is a **pure function** of (config, queue depth, deadline):
//! no clocks, no RNG, no global state. Given the same arrival sequence
//! — which the trace generator guarantees from a seed — the accept/shed
//! set is bit-identical across runs, machines, and worker counts.

use crate::coordinator::metrics::RejectReason;

/// Admission policy. The default admits everything (unbounded queue, no
/// shedding) — the coordinator's pre-admission behavior.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Reject new requests once this many are queued (0 = unbounded).
    pub queue_cap: usize,
    /// Shed a request at submit when the queue-delay estimate already
    /// exceeds its deadline.
    pub shed_deadline: bool,
    /// Estimated service time of one batch (swap amortization + exec),
    /// µs — the knob that turns queue depth into a delay estimate.
    pub est_batch_us: u64,
    /// Expected requests per released batch (the policy's `max_batch`).
    pub max_batch: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            queue_cap: 0,
            shed_deadline: false,
            est_batch_us: 5_000,
            max_batch: 8,
        }
    }
}

impl AdmissionConfig {
    /// Estimated queueing delay with `queued` requests ahead, µs: the
    /// number of batches that must drain first times the per-batch
    /// service estimate. Deliberately simple — a conservative FIFO
    /// bound that ignores batching overlap — because the estimate only
    /// needs to be monotone in queue depth and deterministic.
    pub fn queue_delay_us(&self, queued: usize) -> u64 {
        let batches = queued.div_ceil(self.max_batch.max(1)) as u64;
        batches.saturating_mul(self.est_batch_us)
    }
}

/// Admission verdict for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    Admit,
    /// Shed: the queue-delay estimate already exceeds the deadline.
    ShedDeadline,
    /// Rejected by bounded-queue backpressure.
    QueueFull,
}

impl AdmitDecision {
    /// The metrics reason a non-admit verdict records.
    pub fn reject_reason(&self) -> Option<RejectReason> {
        match self {
            AdmitDecision::Admit => None,
            AdmitDecision::ShedDeadline => Some(RejectReason::ShedDeadline),
            AdmitDecision::QueueFull => Some(RejectReason::QueueFull),
        }
    }
}

/// Decide whether to admit a request given the current total queue
/// depth and the request's latency budget (µs; None = no deadline,
/// never deadline-shed). Pure in its inputs.
pub fn admit(
    cfg: &AdmissionConfig,
    queued: usize,
    deadline_us: Option<u64>,
) -> AdmitDecision {
    if cfg.queue_cap > 0 && queued >= cfg.queue_cap {
        return AdmitDecision::QueueFull;
    }
    if cfg.shed_deadline {
        if let Some(d) = deadline_us {
            if cfg.queue_delay_us(queued) > d {
                return AdmitDecision::ShedDeadline;
            }
        }
    }
    AdmitDecision::Admit
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_admits_everything() {
        let cfg = AdmissionConfig::default();
        for queued in [0usize, 1, 1_000, 1_000_000] {
            assert_eq!(admit(&cfg, queued, Some(0)), AdmitDecision::Admit);
            assert_eq!(admit(&cfg, queued, None), AdmitDecision::Admit);
        }
    }

    #[test]
    fn queue_cap_backpressure_kicks_in_at_the_cap() {
        let cfg = AdmissionConfig { queue_cap: 64, ..Default::default() };
        assert_eq!(admit(&cfg, 63, None), AdmitDecision::Admit);
        assert_eq!(admit(&cfg, 64, None), AdmitDecision::QueueFull);
        assert_eq!(admit(&cfg, 10_000, None), AdmitDecision::QueueFull);
    }

    #[test]
    fn deadline_shedding_is_monotone_in_queue_depth() {
        let cfg = AdmissionConfig {
            shed_deadline: true,
            est_batch_us: 1_000,
            max_batch: 8,
            ..Default::default()
        };
        // 16 queued = 2 batches ahead = 2 ms estimate.
        assert_eq!(admit(&cfg, 16, Some(2_000)), AdmitDecision::Admit);
        assert_eq!(admit(&cfg, 17, Some(2_000)), AdmitDecision::ShedDeadline);
        // No deadline → never deadline-shed.
        assert_eq!(admit(&cfg, 10_000, None), AdmitDecision::Admit);
        // Estimates are monotone: once shed at depth d, shed at d' > d.
        let d = (0..200)
            .find(|&q| admit(&cfg, q, Some(3_500)) != AdmitDecision::Admit)
            .unwrap();
        for q in d..d + 50 {
            assert_ne!(admit(&cfg, q, Some(3_500)), AdmitDecision::Admit, "q={q}");
        }
    }

    #[test]
    fn queue_full_takes_precedence_over_shedding() {
        let cfg = AdmissionConfig {
            queue_cap: 8,
            shed_deadline: true,
            est_batch_us: 1_000_000,
            max_batch: 1,
            ..Default::default()
        };
        assert_eq!(admit(&cfg, 8, Some(0)), AdmitDecision::QueueFull);
        assert_eq!(
            admit(&cfg, 8, Some(0)).reject_reason(),
            Some(RejectReason::QueueFull)
        );
        assert_eq!(
            admit(&cfg, 1, Some(0)).reject_reason(),
            Some(RejectReason::ShedDeadline)
        );
        assert_eq!(admit(&cfg, 0, None).reject_reason(), None);
    }
}
