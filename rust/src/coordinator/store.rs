//! Sharded, replicated expert store with striped parallel fetch.
//!
//! The single flat store behind one `net` [`SimLink`] was both the
//! fetch-throughput bottleneck and a single point of failure — at the
//! paper's "ship experts over the internet per query" scale (§5.4),
//! that link serializes every expert download. This module simulates a
//! **multi-node store**:
//!
//! * [`Placement`] — consistent-hash placement with virtual nodes:
//!   `nodes_for(id)` returns `[primary, replicas…]`, a pure function of
//!   `(id, node set, seed)`. Adding a node remaps only ~K/n expert ids
//!   (bounded churn), so a growing store does not reshuffle the world.
//! * [`ExpertStore`] — one [`SimLink`] per node. A fetch splits the
//!   payload into **stripes** (default: one per replica) pulled
//!   concurrently from different replicas on the shared [`ThreadPool`]
//!   and reassembled byte-identically, so remote fetch latency scales
//!   down with replication instead of serializing on one NIC.
//! * **Failover** — a dropped or corrupt-on-read attempt (injected by
//!   the links' deterministic [`FaultPlan`]) fails the stripe's CRC-32
//!   integrity gate and the stripe is re-fetched from the next replica.
//!   (The gate is evaluated analytically: the injected corruption is a
//!   single flipped byte, a burst ≤ 8 bits, which CRC-32 — linear over
//!   XOR, detecting every burst ≤ 32 bits — catches unconditionally, so
//!   no corrupted copy is ever materialized; counters and timing are
//!   bit-identical to the old materialize-then-compare gate.) With ≥ 1
//!   surviving replica per stripe the reassembled bytes — and therefore
//!   the served predictions — are bit-identical to the single-store
//!   path. Retries/failovers/corruptions are counted into
//!   [`Metrics`] (`stripe_retries`, `failovers`, `corrupt_payloads`).
//!
//! ## Zero-copy stripes
//!
//! Stripes are [`Payload`] views of the fetched source buffer, not
//! copies; when every stripe succeeds (from whichever replica), the
//! reassembled payload is the source view itself — the only heap
//! materialization in a store fetch is the initial file read, counted
//! on the engine's copy meter.
//!
//! ## Determinism
//!
//! Stripe geometry depends only on the payload size and config, and
//! faults are keyed on `(id, stripe, attempt)` — never on wall-clock or
//! arrival order — so the same seed yields the same failover sequence
//! and counters at any pool size. The reported fetch duration is
//! likewise computed from the analytic link model (per-replica service
//! sums, max across replicas = parallel completion), not from wall
//! timing, so it is reproducible too.
//!
//! ## Byte accounting
//!
//! Stripes charge the links a proportional share of the record's
//! `encoded_bytes` (the same accounting the flat path used), so
//! `net_bytes` and Table-5-style timing stay comparable whether the
//! store is on or off: a 1-node, 1-replica store fetch costs exactly
//! `latency + encoded_bytes/bandwidth`, the flat link's cost.
//!
//! ## Adaptive replication & placement epochs
//!
//! The store additionally tracks per-expert fetch popularity (the
//! `stats` lock) and exposes live topology operations. All placement
//! state lives in an immutable [`PlacementView`] behind the `epoch`
//! lock:
//!
//! ```text
//!   fetch ──► clone Arc<PlacementView> ──► stripe over its replicas
//!                                          (old view until done)
//!   rebalance/drain/add ──► migrate bytes ──► publish epoch N+1
//!                                             (single Arc swap)
//! ```
//!
//! A fetch clones the current view's `Arc` once at entry, so an
//! in-flight fetch keeps its assignment even while a rebalance, drain,
//! or node add migrates data and publishes the next epoch — cutover is
//! one atomic swap, never a partial view. The [`Rebalancer`] is a pure
//! state machine (EWMA popularity → per-expert replica overrides)
//! whose rounds depend only on the fed counts, so the same trace
//! yields the same rebalance schedule at any worker count.
//! [`Placement::nodes_for_k`] walks the same ring for any target k,
//! and the walk's prefix property (the k-replica set is a prefix of
//! the (k+1)-replica set) makes widening append one node and
//! narrowing drop the tail — bounded churn by construction.

use crate::compeft::payload::Payload;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::ExpertRecord;
use crate::coordinator::transport::{Fault, FaultPlan, LinkSpec, SimLink};
use crate::util::pool::{chunk_ranges, ThreadPool};
use crate::util::rng::{fnv1a_64, splitmix64};
use crate::util::sync::{rank, OrderedMutex};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// A store node's id (index into the store's link array).
pub type NodeId = usize;

/// Virtual nodes per physical node on the hash ring. More vnodes →
/// smoother load split and tighter churn bounds, at O(nodes · vnodes)
/// ring size. 128 keeps per-node share within a few percent of 1/n.
const VNODES: usize = 128;

/// Default placement seed: the one the coordinator's serve path uses
/// (shared with the `serve` CLI's shard-layout printout so the record
/// it prints always matches where the store actually fetches from).
pub const DEFAULT_PLACEMENT_SEED: u64 = 0;

fn hash_id(seed: u64, id: &str) -> u64 {
    splitmix64(fnv1a_64(seed, id.as_bytes()))
}

/// Consistent-hash placement of expert ids onto store nodes.
///
/// Pure data: building the same `(nodes, replication, seed)` twice
/// yields the same ring, and [`Placement::nodes_for`] is a pure
/// function of the id — no interior state, no randomness at query time.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Sorted (point, node) ring of virtual nodes.
    ring: Vec<(u64, NodeId)>,
    nodes: Vec<NodeId>,
    replication: usize,
    seed: u64,
}

impl Placement {
    /// Placement over nodes `0..nodes`.
    pub fn new(nodes: usize, replication: usize, seed: u64) -> Placement {
        let ids: Vec<NodeId> = (0..nodes.max(1)).collect();
        Placement::with_nodes(&ids, replication, seed)
    }

    /// Placement over an explicit node set (ids need not be contiguous
    /// — the churn property tests grow the set one node at a time).
    pub fn with_nodes(nodes: &[NodeId], replication: usize, seed: u64) -> Placement {
        assert!(!nodes.is_empty(), "placement needs at least one node");
        let mut ring = Vec::with_capacity(nodes.len() * VNODES);
        for &node in nodes {
            for v in 0..VNODES {
                let point =
                    splitmix64(seed ^ splitmix64(((node as u64) << 32) | v as u64));
                ring.push((point, node));
            }
        }
        ring.sort_unstable();
        Placement {
            ring,
            nodes: nodes.to_vec(),
            replication: replication.max(1),
            seed,
        }
    }

    /// Nodes holding `id`, primary first, then `replication - 1`
    /// distinct replicas (fewer if the cluster is smaller): walk the
    /// ring clockwise from the id's hash point collecting distinct
    /// nodes — the textbook consistent-hashing successor walk.
    pub fn nodes_for(&self, id: &str) -> Vec<NodeId> {
        self.nodes_for_k(id, self.replication)
    }

    /// [`Placement::nodes_for`] generalized to an explicit target
    /// replica count `k` (clamped to the node count). The walk starts
    /// at the same hash point for every k, so `nodes_for_k(id, k)` is
    /// always a **prefix** of `nodes_for_k(id, k + 1)`: widening an
    /// expert appends exactly one node and narrowing drops exactly the
    /// tail — no other replica moves.
    pub fn nodes_for_k(&self, id: &str, k: usize) -> Vec<NodeId> {
        let want = k.max(1).min(self.nodes.len());
        let h = hash_id(self.seed ^ 0xA5A5_A5A5_A5A5_A5A5, id);
        let start = self.ring.partition_point(|&(p, _)| p < h);
        let mut out = Vec::with_capacity(want);
        for i in 0..self.ring.len() {
            let (_, node) = self.ring[(start + i) % self.ring.len()];
            if !out.contains(&node) {
                out.push(node);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    /// Configured replication factor.
    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The node universe this placement maps onto.
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// The placement seed (ring layout; topology changes reuse it so
    /// the surviving assignment overlap is maximal).
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

/// One immutable placement epoch: the consistent-hash ring plus the
/// rebalancer's per-expert replica overrides. Fetches clone the store's
/// current `Arc<PlacementView>` once at entry and stripe against it, so
/// a concurrently published epoch never gives any fetch a partial view.
#[derive(Clone, Debug)]
pub struct PlacementView {
    /// Monotone epoch counter (0 = the view the store was built with).
    pub epoch: u64,
    placement: Placement,
    /// Per-expert replica-count overrides (absent = base replication).
    overrides: BTreeMap<String, usize>,
}

impl PlacementView {
    /// Replica count in effect for `id`: the override if present,
    /// clamped to `[base replication, node count]`.
    pub fn replication_of(&self, id: &str) -> usize {
        let base = self.placement.replication();
        self.overrides
            .get(id)
            .copied()
            .unwrap_or(base)
            .max(base)
            .min(self.placement.nodes().len().max(1))
    }

    /// Nodes serving `id` under this epoch (override-aware).
    pub fn replicas_for(&self, id: &str) -> Vec<NodeId> {
        self.placement.nodes_for_k(id, self.replication_of(id))
    }

    /// The underlying consistent-hash placement.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// The per-expert replica overrides this epoch carries.
    pub fn overrides(&self) -> &BTreeMap<String, usize> {
        &self.overrides
    }
}

/// Tuning of the popularity-driven [`Rebalancer`].
#[derive(Clone, Copy, Debug)]
pub struct RebalanceConfig {
    /// EWMA weight on history per round (`0` = this round only).
    pub decay: f64,
    /// Max bytes of replica migration per round (widening a replica
    /// copies the expert's encoded bytes to the new node).
    pub byte_budget: u64,
    /// Hard cap on replicas per expert (also clamped to node count).
    pub max_replicas: usize,
    /// Allowed net replica-mass drift per round: widening beyond the
    /// replicas freed by narrowing is limited to this many slots.
    pub slack: usize,
    /// An expert earns its first extra replica at `hot_factor ×` the
    /// mean EWMA popularity, its second at `2 × hot_factor ×`, …
    pub hot_factor: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            decay: 0.5,
            byte_budget: 8 << 20,
            max_replicas: 8,
            slack: 2,
            hot_factor: 2.0,
        }
    }
}

/// What one rebalance round decided (already applied to the
/// rebalancer's override state; the store applies it to an epoch).
#[derive(Clone, Debug, Default)]
pub struct RebalanceDecision {
    /// Widened replicas: `(expert, new replica count, bytes copied)`,
    /// one entry per added replica, hottest experts first.
    pub added: Vec<(String, usize, u64)>,
    /// Narrowed replicas: `(expert, new replica count)`, one entry per
    /// dropped replica, coldest experts first. Dropping moves no bytes.
    pub dropped: Vec<(String, usize)>,
    /// Total migration bytes of this round (`Σ added bytes`), always
    /// ≤ the configured byte budget.
    pub migrated_bytes: u64,
}

/// Popularity-driven replica planner: EWMA per-expert fetch rates are
/// folded in at explicit [`Rebalancer::round`] boundaries, and each
/// round widens hot experts / narrows cold ones under three bounds —
/// the per-round migration byte budget, the replica-mass slack, and
/// the base-replication floor. Pure state machine: decisions depend
/// only on the constructor config and the sequence of fed counts, so
/// a trace's rebalance schedule is identical at any worker count.
#[derive(Clone, Debug)]
pub struct Rebalancer {
    cfg: RebalanceConfig,
    /// Smoothed popularity per expert (updated once per round).
    ewma: BTreeMap<String, f64>,
    /// Current replica-count overrides (only entries above base).
    overrides: BTreeMap<String, usize>,
    rounds: u64,
}

impl Rebalancer {
    pub fn new(cfg: RebalanceConfig) -> Rebalancer {
        Rebalancer { cfg, ewma: BTreeMap::new(), overrides: BTreeMap::new(), rounds: 0 }
    }

    /// Replica overrides currently in force (experts at base have no
    /// entry).
    pub fn overrides(&self) -> &BTreeMap<String, usize> {
        &self.overrides
    }

    /// Rounds executed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Replica count currently planned for `id` under base `base`.
    pub fn replicas_of(&self, id: &str, base: usize) -> usize {
        self.overrides.get(id).copied().unwrap_or(base).max(base)
    }

    /// Run one round over a popularity snapshot: `counts` maps expert →
    /// `(fetches this round, encoded bytes)`. `base` is the placement's
    /// base replication, `live_nodes` the current node count.
    pub fn round(
        &mut self,
        counts: &BTreeMap<String, (u64, u64)>,
        base: usize,
        live_nodes: usize,
    ) -> RebalanceDecision {
        self.rounds += 1;
        let base = base.max(1);
        let cap = self.cfg.max_replicas.max(base).min(live_nodes.max(1));

        // EWMA update over the union of known and newly seen experts.
        // BTreeMap iteration keeps every walk in id order, so the
        // round is a pure function of (config, fed counts).
        for id in counts.keys() {
            self.ewma.entry(id.clone()).or_insert(0.0);
        }
        for (id, w) in self.ewma.iter_mut() {
            let hits = counts.get(id).map(|&(h, _)| h).unwrap_or(0) as f64;
            *w = self.cfg.decay * *w + (1.0 - self.cfg.decay) * hits;
        }
        if self.ewma.is_empty() {
            return RebalanceDecision::default();
        }
        let mean =
            self.ewma.values().sum::<f64>() / self.ewma.len() as f64;

        // Targets, monotone in EWMA popularity: the j-th extra replica
        // needs `j × hot_factor × mean` smoothed popularity.
        let step = (mean * self.cfg.hot_factor).max(f64::MIN_POSITIVE);
        let target = |w: f64| -> usize {
            (base + (w / step) as usize).min(cap)
        };

        // Expansion steps, hottest first (ties broken by id): one step
        // per replica so a partially funded round still widens the
        // hottest expert before the merely warm ones.
        let mut by_heat: Vec<(&String, f64)> =
            self.ewma.iter().map(|(id, &w)| (id, w)).collect();
        by_heat.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(b.0))
        });
        let mut adds: Vec<(String, usize, u64)> = Vec::new();
        let mut drops: Vec<(String, usize)> = Vec::new();
        for &(id, w) in &by_heat {
            let cur = self.replicas_of(id, base);
            let want = target(w);
            let bytes = counts.get(id).map(|&(_, b)| b).unwrap_or(0);
            for k in cur + 1..=want {
                adds.push((id.clone(), k, bytes));
            }
        }
        // Contraction steps, coldest first.
        for &(id, w) in by_heat.iter().rev() {
            let cur = self.replicas_of(id, base);
            let want = target(w);
            for k in (want..cur).rev() {
                drops.push((id.clone(), k));
            }
        }

        // Byte budget caps widening (dropping is free). A cut step
        // also cuts the same expert's later steps: replica sets are
        // prefix chains, so count k + 1 cannot land before count k.
        let mut spent = 0u64;
        let mut cut: std::collections::BTreeSet<String> =
            std::collections::BTreeSet::new();
        adds.retain(|(id, _, bytes)| {
            if !cut.contains(id) && spent + bytes <= self.cfg.byte_budget {
                spent += bytes;
                true
            } else {
                cut.insert(id.clone());
                false
            }
        });
        // Replica-mass conservation: net drift per round ≤ slack, so
        // widening is funded by narrowing (plus the slack allowance)
        // and narrowing never free-falls far past the widening it pays
        // for.
        let n_add = adds.len().min(drops.len() + self.cfg.slack);
        adds.truncate(n_add);
        drops.truncate(n_add + self.cfg.slack);

        // Apply to the override state. Adds run hottest-first and
        // drops coldest-first, so each expert's final count is the
        // last surviving step in its direction.
        for (id, k, _) in &adds {
            self.set_override(id, *k, base);
        }
        for (id, k) in &drops {
            self.set_override(id, *k, base);
        }
        let migrated_bytes = adds.iter().map(|&(_, _, b)| b).sum();
        RebalanceDecision { added: adds, dropped: drops, migrated_bytes }
    }

    fn set_override(&mut self, id: &str, k: usize, base: usize) {
        if k > base {
            self.overrides.insert(id.to_string(), k);
        } else {
            self.overrides.remove(id);
        }
    }
}

/// Configuration of the simulated multi-node store.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Number of store nodes (each with its own [`SimLink`]).
    pub nodes: usize,
    /// Replicas per expert (clamped to the node count at placement).
    pub replication: usize,
    /// Placement seed (the hash ring; independent of the fault seed).
    pub placement_seed: u64,
    /// Link model of every node's pipe.
    pub link: LinkSpec,
    /// Wall-clock compression for the node links (see
    /// [`SimLink::with_time_scale`]).
    pub time_scale: f64,
    /// Stripe size in *encoded* bytes; `0` = auto (one stripe per
    /// replica, the latency-optimal split for high-latency links).
    pub stripe_bytes: u64,
    /// Deterministic fault injection applied to every node link.
    pub faults: FaultPlan,
}

impl StoreConfig {
    pub fn new(nodes: usize, replication: usize) -> StoreConfig {
        StoreConfig {
            nodes: nodes.max(1),
            replication: replication.max(1),
            placement_seed: DEFAULT_PLACEMENT_SEED,
            link: LinkSpec::internet(),
            time_scale: 1.0,
            stripe_bytes: 0,
            faults: FaultPlan::none(0),
        }
    }
}

/// Per-fetch fault accounting (also accumulated into [`Metrics`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FetchFaults {
    /// Extra attempts beyond the first, summed over stripes.
    pub stripe_retries: u64,
    /// Stripes that succeeded on a non-first replica (once per stripe).
    pub failovers: u64,
    /// Attempts whose payload arrived corrupt (per-stripe CRC caught).
    pub corrupt_payloads: u64,
}

/// Mutable topology behind the store's `epoch` lock: the current
/// placement view plus one contended link per node ever added (links
/// are indexed by [`NodeId`] and never removed — a drained node's link
/// simply stops appearing in any replica set).
struct Topology {
    view: Arc<PlacementView>,
    links: Vec<SimLink>,
}

/// Report of one topology migration (node drain or add).
#[derive(Clone, Copy, Debug, Default)]
pub struct MigrationReport {
    /// The epoch the operation published.
    pub epoch: u64,
    /// Experts whose replica set changed.
    pub moved_experts: u64,
    /// Encoded bytes copied onto newly assigned nodes.
    pub migrated_bytes: u64,
}

/// The simulated multi-node expert store.
pub struct ExpertStore {
    /// Current placement epoch + node links. Fetches clone the view
    /// `Arc` and the links once at entry, so topology changes never
    /// hand any in-flight fetch a partial assignment.
    epoch: OrderedMutex<Topology>,
    /// Per-expert fetch popularity: id → (fetches since the last
    /// rebalance round, last-seen encoded bytes). Commutative counts,
    /// so any fetch interleaving yields the same round snapshot.
    stats: OrderedMutex<BTreeMap<String, (u64, u64)>>,
    spec: LinkSpec,
    time_scale: f64,
    faults: FaultPlan,
    stripe_bytes: u64,
    pool: Option<Arc<ThreadPool>>,
    metrics: Arc<Metrics>,
}

/// One stripe's fetch work order.
struct StripeJob {
    stripe: u32,
    /// Byte range in the payload.
    start: usize,
    end: usize,
    /// Link charge for this range (proportional share of encoded_bytes).
    charge: u64,
    /// Replica attempt order (placement rotated by stripe index).
    replicas: Vec<NodeId>,
}

/// One stripe's outcome: the verified payload view, per-node simulated
/// service time spent (successful + failed attempts), and fault counts.
struct StripeDone {
    start: usize,
    view: Payload,
    node_time: Vec<(NodeId, Duration)>,
    faults: FetchFaults,
}

/// Real-time notice on the fusion completion channel: one stripe's
/// bytes have passed their CRC gate and are decodable, sent from the
/// fetch workers while sibling stripes are still in flight. The fused
/// loader path uses these to start decoding a payload's leading frames
/// before the fetch as a whole returns.
#[derive(Clone, Copy, Debug)]
pub struct StripeLanded {
    pub stripe: u32,
    /// Byte range of the payload this stripe covers.
    pub start: usize,
    pub end: usize,
}

/// An event on the fusion completion channel
/// ([`ExpertStore::fetch_streamed`]).
pub enum FetchEvent {
    /// The fetch's source buffer — one zero-copy view, sent once before
    /// any stripe dispatches. Streamed consumers parse container
    /// *metadata* from it but must treat payload bytes past the
    /// landed-stripe watermark as not yet arrived (the buffer is local;
    /// the stripes model when its ranges land over the network).
    Source(Payload),
    /// One stripe's bytes passed their per-stripe CRC gate.
    Stripe(StripeLanded),
}

/// One stripe's place in the analytic fetch timeline. `sim_ready` is
/// the simulated instant (from fetch start) at which the stripe's
/// bytes have landed: nodes serialize their own stripes in stripe-index
/// order and replicas run in parallel, so a stripe is ready at the
/// cumulative service time of the nodes it touched — the same model
/// whose per-node maximum is the fetch's reported duration, computed in
/// job-index order so the schedule is identical at every pool size.
#[derive(Clone, Copy, Debug)]
pub struct StripeArrival {
    pub stripe: u32,
    /// Byte range of the payload this stripe covers.
    pub start: usize,
    pub end: usize,
    /// Simulated completion offset of this stripe within the fetch.
    pub sim_ready: Duration,
}

impl ExpertStore {
    /// Build the store. The pool (shared with the decode engine) runs
    /// stripe fetches concurrently; without one, stripes fetch serially
    /// (identical bytes and counters, longer wall time).
    pub fn new(
        cfg: StoreConfig,
        pool: Option<Arc<ThreadPool>>,
        metrics: Arc<Metrics>,
    ) -> ExpertStore {
        let nodes = cfg.nodes.max(1);
        let links = (0..nodes)
            .map(|n| {
                SimLink::new("store", cfg.link)
                    .with_time_scale(cfg.time_scale)
                    .with_faults(cfg.faults.clone(), n)
            })
            .collect();
        let view = Arc::new(PlacementView {
            epoch: 0,
            placement: Placement::new(nodes, cfg.replication, cfg.placement_seed),
            overrides: BTreeMap::new(),
        });
        ExpertStore {
            epoch: OrderedMutex::new(rank::STORE_EPOCH, "store.epoch", Topology {
                view,
                links,
            }),
            stats: OrderedMutex::new(rank::STORE_STATS, "store.stats", BTreeMap::new()),
            spec: cfg.link,
            time_scale: cfg.time_scale,
            faults: cfg.faults,
            stripe_bytes: cfg.stripe_bytes,
            pool,
            metrics,
        }
    }

    /// The current placement epoch (cheap `Arc` clone).
    pub fn view(&self) -> Arc<PlacementView> {
        self.epoch.lock().unwrap().view.clone()
    }

    /// One consistent snapshot of (view, links) — what a fetch or a
    /// migration works against while later epochs publish concurrently.
    fn topology(&self) -> (Arc<PlacementView>, Vec<SimLink>) {
        let g = self.epoch.lock().unwrap();
        (g.view.clone(), g.links.clone())
    }

    /// Publish the next placement epoch: a single `Arc` swap, so no
    /// fetch ever observes a partial topology.
    fn publish(&self, placement: Placement, overrides: BTreeMap<String, usize>) -> u64 {
        let mut g = self.epoch.lock().unwrap();
        let epoch = g.view.epoch + 1;
        g.view = Arc::new(PlacementView { epoch, placement, overrides });
        epoch
    }

    /// Total node count ever provisioned (links are never removed;
    /// drained nodes just leave the placement).
    pub fn nodes(&self) -> usize {
        self.epoch.lock().unwrap().links.len()
    }

    /// The metrics sink this store's fault and fusion counters land in
    /// (shared with the coordinator that built the store).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Payload bytes moved across all node links.
    pub fn bytes_moved(&self) -> u64 {
        let links = self.epoch.lock().unwrap().links.clone();
        links.iter().map(|l| l.bytes_moved()).sum()
    }

    /// Count one served fetch of `id` into the popularity stats.
    fn record_fetch(&self, id: &str, encoded_bytes: u64) {
        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(id.to_string()).or_insert((0, 0));
        e.0 += 1;
        e.1 = encoded_bytes;
    }

    /// Snapshot of the popularity stats: id → (fetches this round,
    /// encoded bytes).
    pub fn popularity(&self) -> BTreeMap<String, (u64, u64)> {
        self.stats.lock().unwrap().clone()
    }

    /// Snapshot the popularity stats and reset the per-round fetch
    /// counts (sizes are retained — migrations still need them).
    fn take_popularity(&self) -> BTreeMap<String, (u64, u64)> {
        let mut stats = self.stats.lock().unwrap();
        let snap = stats.clone();
        for e in stats.values_mut() {
            e.0 = 0;
        }
        snap
    }

    /// Fetch an expert's encoded payload: striped across its replicas,
    /// CRC-gated per stripe, reassembled byte-identically as a
    /// zero-copy [`Payload`] view. Returns the payload and the
    /// simulated fetch time (analytic model: per-replica service sums,
    /// max across replicas).
    pub fn fetch(&self, rec: &ExpertRecord) -> Result<(Payload, Duration)> {
        let bytes = std::fs::read(&rec.path)
            .with_context(|| format!("read {}", rec.path.display()))?;
        // The one heap materialization of a store fetch.
        self.metrics.copy_meter().record(1);
        let data = Payload::from_vec(bytes);
        let (out, sim, faults) = self.fetch_payload(&rec.id, &data, rec.encoded_bytes)?;
        self.record_fetch(&rec.id, rec.encoded_bytes);
        self.metrics.record_store_faults(
            faults.stripe_retries,
            faults.failovers,
            faults.corrupt_payloads,
        );
        Ok((out, sim))
    }

    /// [`ExpertStore::fetch`] with the fusion completion channel: the
    /// source buffer is posted first ([`FetchEvent::Source`]), then
    /// each stripe posts a [`StripeLanded`] notice the moment it passes
    /// its CRC gate (real completion order, while siblings are in
    /// flight), and the returned [`StripeArrival`] schedule places
    /// every stripe on the analytic timeline so the caller can replay
    /// byte availability deterministically. Bytes, faults, counters,
    /// and the reported duration are identical to `fetch` — the channel
    /// is an extra observation, not a different fetch.
    pub fn fetch_streamed(
        &self,
        rec: &ExpertRecord,
        events: &std::sync::mpsc::Sender<FetchEvent>,
    ) -> Result<(Payload, Duration, Vec<StripeArrival>)> {
        let bytes = std::fs::read(&rec.path)
            .with_context(|| format!("read {}", rec.path.display()))?;
        // The one heap materialization of a store fetch.
        self.metrics.copy_meter().record(1);
        let data = Payload::from_vec(bytes);
        let _ = events.send(FetchEvent::Source(data.clone()));
        let (out, sim, faults, arrivals) =
            self.fetch_payload_inner(&rec.id, &data, rec.encoded_bytes, Some(events))?;
        self.record_fetch(&rec.id, rec.encoded_bytes);
        self.metrics.record_store_faults(
            faults.stripe_retries,
            faults.failovers,
            faults.corrupt_payloads,
        );
        Ok((out, sim, arrivals))
    }

    /// The striped fetch over an in-memory payload (`fetch` minus the
    /// file read and metrics sink) — also the unit the store tests
    /// drive directly. `encoded_bytes` is the link-charge total
    /// (`rec.encoded_bytes`); stripes charge proportional shares that
    /// sum to it exactly. Stripes are views of `data`; when every
    /// stripe succeeds the result is `data` itself (no concatenation).
    pub fn fetch_payload(
        &self,
        id: &str,
        data: &Payload,
        encoded_bytes: u64,
    ) -> Result<(Payload, Duration, FetchFaults)> {
        let (out, sim, faults, _) = self.fetch_payload_inner(id, data, encoded_bytes, None)?;
        Ok((out, sim, faults))
    }

    /// The full striped fetch: optionally posts real-time
    /// [`StripeLanded`] notices as stripes clear their CRC gates, and
    /// always returns the deterministic [`StripeArrival`] schedule
    /// alongside the reassembled payload.
    fn fetch_payload_inner(
        &self,
        id: &str,
        data: &Payload,
        encoded_bytes: u64,
        events: Option<&std::sync::mpsc::Sender<FetchEvent>>,
    ) -> Result<(Payload, Duration, FetchFaults, Vec<StripeArrival>)> {
        // One epoch snapshot per fetch: the replica assignment and the
        // link set stay coherent for the whole stripe plan even if a
        // rebalance/drain/add publishes a later epoch mid-flight.
        let (view, links) = self.topology();
        let replicas = view.replicas_for(id);
        if data.is_empty() {
            bail!("expert {id:?} has an empty payload");
        }
        let stripe = if self.stripe_bytes > 0 {
            self.stripe_bytes as usize
        } else {
            data.len().div_ceil(replicas.len())
        };
        let jobs: Vec<StripeJob> = chunk_ranges(data.len(), stripe)
            .into_iter()
            .enumerate()
            .map(|(i, (start, end))| {
                // Proportional encoded-byte charge; prefix differences
                // sum to encoded_bytes exactly, so striping never
                // changes the total byte accounting. The prefix product
                // runs in u128: multi-GiB payloads would overflow the
                // u64 intermediate (encoded_bytes · offset).
                let share = |off: usize| -> u64 {
                    (encoded_bytes as u128 * off as u128 / data.len() as u128) as u64
                };
                let charge = share(end) - share(start);
                // Rotate the replica order per stripe so stripes spread
                // across the replica set instead of hammering the
                // primary.
                let r = i % replicas.len();
                let order: Vec<NodeId> = replicas[r..]
                    .iter()
                    .chain(replicas[..r].iter())
                    .copied()
                    .collect();
                StripeJob { stripe: i as u32, start, end, charge, replicas: order }
            })
            .collect();

        let fetch_one = |job: &StripeJob| -> Result<StripeDone> {
            let want = data
                .slice(job.start, job.end - job.start)
                .expect("stripe ranges are within the payload");
            let mut node_time = Vec::with_capacity(job.replicas.len());
            let mut faults = FetchFaults::default();
            for (attempt, &node) in job.replicas.iter().enumerate() {
                let out = links[node].transfer_keyed(
                    job.charge,
                    id,
                    job.stripe,
                    attempt as u32,
                );
                // The per-stripe CRC-32 integrity gate, evaluated
                // analytically: a delivered payload is the source view
                // itself (trivially CRC-equal), and the Corrupt fault's
                // single flipped byte is a burst ≤ 8 bits, which CRC-32
                // (linear over XOR, catching every burst ≤ 32 bits)
                // fails unconditionally — so the gate's outcome is
                // known without materializing a damaged copy. Counters
                // and per-node service time match the old
                // copy-then-compare gate bit for bit.
                let delivered: Option<bool> = match out.fault {
                    Fault::Drop => {
                        // Connection latency paid, nothing delivered.
                        node_time.push((node, self.spec.latency));
                        None
                    }
                    Fault::Corrupt => {
                        // Full (wasted) transfer of damaged bytes.
                        node_time.push((node, self.spec.duration_for(job.charge)));
                        Some(false)
                    }
                    Fault::Delay(d) => {
                        node_time.push((node, self.spec.duration_for(job.charge) + d));
                        Some(true)
                    }
                    Fault::None => {
                        node_time.push((node, self.spec.duration_for(job.charge)));
                        Some(true)
                    }
                };
                match delivered {
                    Some(true) => {
                        if attempt > 0 {
                            faults.failovers += 1;
                        }
                        // Fusion channel: announce the stripe the moment
                        // its bytes are verified. A hung-up receiver is
                        // fine — the fetch still completes normally.
                        if let Some(tx) = events {
                            let _ = tx.send(FetchEvent::Stripe(StripeLanded {
                                stripe: job.stripe,
                                start: job.start,
                                end: job.end,
                            }));
                        }
                        return Ok(StripeDone {
                            start: job.start,
                            view: want,
                            node_time,
                            faults,
                        });
                    }
                    Some(false) => {
                        faults.corrupt_payloads += 1;
                        faults.stripe_retries += 1;
                    }
                    None => faults.stripe_retries += 1,
                }
            }
            bail!(
                "stripe {} of {id:?}: all {} replicas failed",
                job.stripe,
                job.replicas.len()
            )
        };

        let results: Vec<Result<StripeDone>> = match &self.pool {
            Some(pool) => {
                let refs: Vec<&StripeJob> = jobs.iter().collect();
                pool.scoped_map(refs, |job| fetch_one(job))
            }
            None => jobs.iter().map(fetch_one).collect(),
        };

        // Reassemble + aggregate the analytic time model: each node's
        // link serializes its own stripes (sum), replicas run in
        // parallel (max across nodes). Walking results in job-index
        // order (scoped_map preserves it) makes the per-stripe arrival
        // schedule a pure function of the fault plan — identical at
        // every pool size — and a stripe is ready once every node it
        // touched has worked through its queue up to and including this
        // stripe, so the schedule's maximum is exactly `sim`.
        let mut parts: Vec<(usize, Payload)> = Vec::with_capacity(jobs.len());
        let mut arrivals: Vec<StripeArrival> = Vec::with_capacity(jobs.len());
        let mut per_node = vec![Duration::ZERO; links.len()];
        let mut faults = FetchFaults::default();
        for (job, done) in jobs.iter().zip(results) {
            let done = done?;
            let mut ready = Duration::ZERO;
            for (node, d) in done.node_time {
                per_node[node] += d;
                ready = ready.max(per_node[node]);
            }
            arrivals.push(StripeArrival {
                stripe: job.stripe,
                start: done.start,
                end: done.start + done.view.len(),
                sim_ready: ready,
            });
            parts.push((done.start, done.view));
            faults.stripe_retries += done.faults.stripe_retries;
            faults.failovers += done.faults.failovers;
            faults.corrupt_payloads += done.faults.corrupt_payloads;
        }
        let sim = per_node.into_iter().max().unwrap_or(Duration::ZERO);
        parts.sort_by_key(|&(start, _)| start);

        // Zero-copy reassembly: every delivered stripe is a view of
        // `data`, so when the views tile the payload in place (they
        // always do — failover changes *which replica* served a
        // stripe, not *what bytes* it is), the reassembled payload is
        // the source view itself. The concatenating fallback is kept
        // for safety and counted as the copy it is.
        let base = data.as_slice().as_ptr() as usize;
        let mut covered = 0usize;
        let in_place = parts.iter().all(|(start, v)| {
            let tiles = *start == covered
                && v.as_slice().as_ptr() as usize == base + start;
            covered = start + v.len();
            tiles
        }) && covered == data.len();
        let out = if in_place {
            data.clone()
        } else {
            self.metrics.copy_meter().record(1);
            let mut buf = vec![0u8; data.len()];
            for (start, v) in &parts {
                buf[*start..*start + v.len()].copy_from_slice(v);
            }
            Payload::from_vec(buf)
        };
        Ok((out, sim, faults, arrivals))
    }

    // -- adaptive replication & live topology --------------------------------
    //
    // Admin operations (rebalance / add_node / drain_node) are serialized
    // by their caller (the engine thread, or a test); fetches may run
    // concurrently with any of them and always see a complete epoch.

    /// Run one popularity-driven rebalance round: drain the fetch
    /// counters into `rb`, copy each widened expert onto its appended
    /// replica node, and publish the next epoch carrying the updated
    /// overrides. Pure in the fed fetch sequence — the same trace yields
    /// the same decisions at any worker count.
    pub fn rebalance(&self, rb: &mut Rebalancer) -> RebalanceDecision {
        let (view, links) = self.topology();
        let counts = self.take_popularity();
        let base = view.placement().replication();
        let live = view.placement().nodes().len();
        let d = rb.round(&counts, base, live);
        // Widening copies the expert's encoded bytes onto the k-set's
        // new tail node (the appended replica, by the prefix property
        // of `nodes_for_k`); narrowing moves no bytes.
        let jobs: Vec<(NodeId, u64)> = d
            .added
            .iter()
            .filter_map(|(id, k, bytes)| {
                view.placement().nodes_for_k(id, *k).last().copied().map(|n| (n, *bytes))
            })
            .collect();
        self.run_migration(&jobs, &links);
        if !d.added.is_empty() || !d.dropped.is_empty() {
            self.publish(view.placement().clone(), rb.overrides().clone());
        }
        self.metrics.record_rebalance(
            d.added.len() as u64,
            d.dropped.len() as u64,
            d.migrated_bytes,
        );
        d
    }

    /// Add a store node live: provision its link, copy every expert the
    /// new placement assigns to it, then cut over in one epoch swap.
    /// Returns the published epoch and migration totals.
    pub fn add_node(&self) -> MigrationReport {
        // Provision the new node's link first; the current epoch never
        // references it, so fetches racing this call are unaffected.
        let (old_view, links) = {
            let mut g = self.epoch.lock().unwrap();
            let new_node = g.links.len();
            g.links.push(
                SimLink::new("store", self.spec)
                    .with_time_scale(self.time_scale)
                    .with_faults(self.faults.clone(), new_node),
            );
            (g.view.clone(), g.links.clone())
        };
        let mut nodes = old_view.placement().nodes().to_vec();
        nodes.push(links.len() - 1);
        let placement = Placement::with_nodes(
            &nodes,
            old_view.placement().replication(),
            old_view.placement().seed(),
        );
        let (moved, migrated) =
            self.migrate_assignments(&old_view, &placement, old_view.overrides(), &links);
        self.metrics.record_migrated(migrated);
        let epoch = self.publish(placement, old_view.overrides().clone());
        MigrationReport { epoch, moved_experts: moved, migrated_bytes: migrated }
    }

    /// Drain a node live: rebuild the placement without it, copy every
    /// reassigned expert onto its gaining replicas, then cut over in one
    /// epoch swap. The node's link stays provisioned (NodeIds are stable
    /// forever) — it simply stops appearing in any replica set.
    pub fn drain_node(&self, node: NodeId) -> Result<MigrationReport> {
        let (old_view, links) = self.topology();
        let nodes: Vec<NodeId> = old_view
            .placement()
            .nodes()
            .iter()
            .copied()
            .filter(|&n| n != node)
            .collect();
        ensure!(
            nodes.len() < old_view.placement().nodes().len(),
            "node {node} is not in the placement"
        );
        ensure!(!nodes.is_empty(), "cannot drain the last store node");
        let placement = Placement::with_nodes(
            &nodes,
            old_view.placement().replication(),
            old_view.placement().seed(),
        );
        let (moved, migrated) =
            self.migrate_assignments(&old_view, &placement, old_view.overrides(), &links);
        self.metrics.record_migrated(migrated);
        let epoch = self.publish(placement, old_view.overrides().clone());
        Ok(MigrationReport { epoch, moved_experts: moved, migrated_bytes: migrated })
    }

    /// Copy every tracked expert onto the replicas it gains under the
    /// next placement (relative to `old`). Returns
    /// `(moved experts, migrated bytes)`. Sizes come from the stats map,
    /// which keeps last-seen encoded bytes across rounds — an expert the
    /// store never served has nothing resident to move.
    fn migrate_assignments(
        &self,
        old: &PlacementView,
        next_placement: &Placement,
        overrides: &BTreeMap<String, usize>,
        links: &[SimLink],
    ) -> (u64, u64) {
        let stats = self.popularity();
        let next = PlacementView {
            epoch: 0,
            placement: next_placement.clone(),
            overrides: overrides.clone(),
        };
        let mut jobs: Vec<(NodeId, u64)> = Vec::new();
        let mut moved = 0u64;
        for (id, &(_, bytes)) in &stats {
            let have: std::collections::BTreeSet<NodeId> =
                old.replicas_for(id).into_iter().collect();
            let gained: Vec<NodeId> = next
                .replicas_for(id)
                .into_iter()
                .filter(|n| !have.contains(n))
                .collect();
            if !gained.is_empty() {
                moved += 1;
            }
            for n in gained {
                jobs.push((n, bytes));
            }
        }
        let migrated = self.run_migration(&jobs, links);
        (moved, migrated)
    }

    /// Execute migration copies as unkeyed (never faulted) transfers on
    /// the gaining nodes' links — striped across the shared pool when
    /// one is attached, serially otherwise. Background traffic only: it
    /// contends for link wall-time but cannot perturb any fetch's
    /// reported duration (those come from the analytic model).
    fn run_migration(&self, jobs: &[(NodeId, u64)], links: &[SimLink]) -> u64 {
        match &self.pool {
            Some(pool) => {
                let refs: Vec<&(NodeId, u64)> = jobs.iter().collect();
                let _ = pool.scoped_map(refs, |job| {
                    links[job.0].transfer(job.1);
                });
            }
            None => {
                for &(node, bytes) in jobs {
                    links[node].transfer(bytes);
                }
            }
        }
        jobs.iter().map(|&(_, b)| b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_params, CompressConfig};
    use crate::compeft::format::{self, Encoding};
    use crate::coordinator::registry::{ExpertFormat, ExpertMethod};
    use crate::coordinator::transport::FaultSpec;
    use crate::tensor::{ParamSet, Tensor};
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use std::collections::BTreeSet;
    use std::path::PathBuf;

    // -- placement properties ----------------------------------------------

    /// Every id gets exactly `min(replication, n)` distinct nodes, and
    /// placement is a pure function of (id, node set, seed).
    #[test]
    fn placement_replicates_distinctly_and_is_pure() {
        for (n, r) in [(1usize, 1usize), (2, 2), (5, 2), (8, 3), (8, 12)] {
            let a = Placement::new(n, r, 9);
            let b = Placement::new(n, r, 9);
            let other_seed = Placement::new(n, r, 10);
            let mut moved_by_seed = 0;
            for i in 0..200 {
                let id = format!("expert/{i}");
                let nodes = a.nodes_for(&id);
                assert_eq!(nodes.len(), r.min(n), "n={n} r={r}");
                let distinct: BTreeSet<_> = nodes.iter().collect();
                assert_eq!(distinct.len(), nodes.len(), "replicas distinct");
                assert!(nodes.iter().all(|&x| x < n), "nodes in range");
                // Pure: a fresh instance agrees exactly.
                assert_eq!(nodes, b.nodes_for(&id));
                if nodes != other_seed.nodes_for(&id) {
                    moved_by_seed += 1;
                }
            }
            if n > 1 {
                assert!(moved_by_seed > 0, "seed must matter (n={n} r={r})");
            }
        }
    }

    /// Consistent-hashing churn bound: adding one node remaps at most
    /// ~K/n primaries, and every id that moves, moves TO the new node.
    #[test]
    fn placement_adding_a_node_has_bounded_churn() {
        const K: usize = 600;
        for seed in [0u64, 7, 2026] {
            for n in [4usize, 8] {
                let before_nodes: Vec<NodeId> = (0..n).collect();
                let mut after_nodes = before_nodes.clone();
                after_nodes.push(n); // the new node
                let before = Placement::with_nodes(&before_nodes, 2, seed);
                let after = Placement::with_nodes(&after_nodes, 2, seed);
                let mut moved = 0usize;
                for i in 0..K {
                    let id = format!("expert/{seed}/{i}");
                    let p0 = before.nodes_for(&id)[0];
                    let p1 = after.nodes_for(&id)[0];
                    if p0 != p1 {
                        moved += 1;
                        assert_eq!(
                            p1, n,
                            "a remapped primary must land on the new node"
                        );
                    }
                }
                // Expected ~K/(n+1); 3x slack covers vnode variance.
                let bound = 3 * K / (n + 1);
                assert!(
                    moved > 0 && moved <= bound,
                    "seed={seed} n={n}: moved {moved}, bound {bound}"
                );
            }
        }
    }

    /// Load balance: with 128 vnodes no node owns a wildly unfair share
    /// of primaries.
    #[test]
    fn placement_spreads_primaries() {
        let n = 6;
        let p = Placement::new(n, 1, 3);
        let mut counts = vec![0usize; n];
        const K: usize = 1200;
        for i in 0..K {
            counts[p.nodes_for(&format!("e{i}"))[0]] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(
            *min * 3 >= *max,
            "share spread too wide: {counts:?} (min {min}, max {max})"
        );
    }

    // -- striped fetch ------------------------------------------------------

    fn temp_record(dir: &PathBuf, seed: u64) -> (ExpertRecord, Vec<u8>) {
        std::fs::create_dir_all(dir).unwrap();
        let mut rng = Pcg::seed(seed);
        let mut p = ParamSet::new();
        p.insert(
            "w",
            Tensor::new(vec![6000], prop::task_vector_like(&mut rng, 6000)),
        );
        let c = compress_params(
            &p,
            &CompressConfig { density: 0.2, ..Default::default() },
        );
        let path = dir.join(format!("e{seed}.cpeft"));
        let bytes = format::save(&path, &c, Encoding::Golomb).unwrap();
        let data = std::fs::read(&path).unwrap();
        (
            ExpertRecord {
                id: format!("e{seed}"),
                task: "t".into(),
                scale: "s".into(),
                method: ExpertMethod::Lora,
                format: ExpertFormat::Compeft,
                path,
                encoded_bytes: bytes,
                n_params: 6000,
            },
            data,
        )
    }

    fn store(cfg: StoreConfig, workers: usize) -> ExpertStore {
        let pool = if workers == 0 {
            None
        } else {
            Some(Arc::new(ThreadPool::new(workers)))
        };
        ExpertStore::new(cfg, pool, Arc::new(Metrics::new()))
    }

    /// Fault-free striped fetch reassembles the exact payload at every
    /// node count, replication factor, stripe size, and pool size, and
    /// the byte accounting equals the flat path's `encoded_bytes`.
    #[test]
    fn striped_fetch_is_byte_identical_and_charges_encoded_bytes() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_eq_{}", std::process::id()));
        let (rec, want) = temp_record(&dir, 11);
        let want = Payload::from_vec(want);
        for (nodes, repl) in [(1usize, 1usize), (3, 2), (5, 3), (4, 8)] {
            for stripe_bytes in [0u64, 257, 4096] {
                // 0 workers = the poolless serial fetch path.
                for workers in std::iter::once(0).chain(prop::pool_sizes()) {
                    let mut cfg = StoreConfig::new(nodes, repl);
                    cfg.time_scale = 0.0;
                    cfg.stripe_bytes = stripe_bytes;
                    let s = store(cfg, workers);
                    let (got, sim, faults) = s
                        .fetch_payload(&rec.id, &want, rec.encoded_bytes)
                        .unwrap();
                    assert_eq!(
                        got, want,
                        "nodes={nodes} repl={repl} stripe={stripe_bytes} w={workers}"
                    );
                    // Zero-copy reassembly: the stripes tiled the source
                    // in place, so the result IS the source view (the
                    // old path concatenated fresh heap copies here).
                    assert_eq!(
                        got.as_slice().as_ptr(),
                        want.as_slice().as_ptr(),
                        "reassembly must not copy when all stripes succeed"
                    );
                    assert_eq!(faults, FetchFaults::default(), "fault-free run");
                    assert!(sim > Duration::ZERO);
                    assert_eq!(
                        s.bytes_moved(),
                        rec.encoded_bytes,
                        "stripe charges must sum to encoded_bytes exactly"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The latency story: with R replicas and auto-striping, the
    /// analytic fetch time is `latency + (bytes/R)/bw` — strictly below
    /// the single-node `latency + bytes/bw` whenever R > 1.
    #[test]
    fn striping_beats_single_link_on_the_model() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_lat_{}", std::process::id()));
        let (rec, data) = temp_record(&dir, 13);
        let data = Payload::from_vec(data);
        let mut single_cfg = StoreConfig::new(1, 1);
        single_cfg.time_scale = 0.0;
        let flat_cost = single_cfg.link.duration_for(rec.encoded_bytes);
        let single = store(single_cfg, 2);
        let (_, t1, _) = single.fetch_payload(&rec.id, &data, rec.encoded_bytes).unwrap();
        // 1 node, 1 replica, auto stripe = the flat link's exact cost.
        assert_eq!(t1, flat_cost);

        let mut prev = t1;
        for repl in [2usize, 3] {
            let mut cfg = StoreConfig::new(repl, repl);
            cfg.time_scale = 0.0;
            let s = store(cfg, 4);
            let (_, t, _) = s.fetch_payload(&rec.id, &data, rec.encoded_bytes).unwrap();
            assert!(
                t < prev,
                "replication {repl}: {t:?} not below {prev:?}"
            );
            prev = t;
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Failover: faulted fetches still reassemble the exact payload,
    /// count their retries/failovers/corruptions, and the counters are
    /// identical across pool sizes and repeated runs (determinism).
    #[test]
    fn faulted_fetch_recovers_and_counts_deterministically() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_fault_{}", std::process::id()));
        let (rec, want) = temp_record(&dir, 17);
        let want = Payload::from_vec(want);
        let plans: Vec<(&str, FaultPlan)> = vec![
            (
                "drop-primary",
                FaultPlan::new(
                    5,
                    FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() },
                ),
            ),
            (
                "corrupt-primary",
                FaultPlan::new(
                    6,
                    FaultSpec {
                        corrupt_p: 1.0,
                        first_attempt_only: true,
                        ..Default::default()
                    },
                ),
            ),
            (
                "kill-primary-node",
                // Kill the node that is this id's primary, so stripe 0
                // (whose attempt order starts at the primary) is
                // guaranteed to fail over.
                FaultPlan::none(7)
                    .kill_node(Placement::new(3, 2, 0).nodes_for(&rec.id)[0]),
            ),
        ];
        for (name, plan) in plans {
            let mut reference: Option<FetchFaults> = None;
            for &workers in &prop::pool_sizes() {
                for round in 0..2 {
                    let mut cfg = StoreConfig::new(3, 2);
                    cfg.time_scale = 0.0;
                    cfg.stripe_bytes = 256; // several stripes per fetch
                    cfg.faults = plan.clone();
                    let s = store(cfg, workers);
                    let (got, _, faults) =
                        s.fetch_payload(&rec.id, &want, rec.encoded_bytes).unwrap();
                    assert_eq!(got, want, "{name} w={workers}");
                    // Failover changes which replica served a stripe,
                    // never what bytes it is — the reassembly stays a
                    // zero-copy view of the source even under faults.
                    assert_eq!(
                        got.as_slice().as_ptr(),
                        want.as_slice().as_ptr(),
                        "{name}: faulted reassembly must still be in place"
                    );
                    assert!(
                        faults.stripe_retries > 0,
                        "{name}: plan must actually fire"
                    );
                    assert!(faults.failovers > 0, "{name}: failover must occur");
                    if name == "corrupt-primary" {
                        assert!(faults.corrupt_payloads > 0, "{name}");
                    }
                    match &reference {
                        None => reference = Some(faults),
                        Some(r) => assert_eq!(
                            faults, *r,
                            "{name}: counters must not depend on pool size \
                             (w={workers}, round={round})"
                        ),
                    }
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The fusion observation layer: the per-stripe arrival schedule is
    /// a pure function of the fault plan (identical at every pool
    /// size), its maximum equals the reported fetch duration, the
    /// arrivals tile the payload in stripe order, and the completion
    /// channel posts every stripe exactly once with its exact range.
    #[test]
    fn stripe_arrivals_are_deterministic_and_bounded_by_sim() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_arrv_{}", std::process::id()));
        let (rec, want) = temp_record(&dir, 29);
        let want = Payload::from_vec(want);
        let plan = FaultPlan::new(
            5,
            FaultSpec { drop_p: 0.5, first_attempt_only: true, ..Default::default() },
        );
        let mut reference: Option<Vec<(u32, usize, usize, Duration)>> = None;
        for &workers in &prop::pool_sizes() {
            let mut cfg = StoreConfig::new(3, 2);
            cfg.time_scale = 0.0;
            cfg.stripe_bytes = 256; // several stripes per fetch
            cfg.faults = plan.clone();
            let s = store(cfg, workers);
            let (tx, rx) = std::sync::mpsc::channel();
            let (got, sim, _faults, arrivals) = s
                .fetch_payload_inner(&rec.id, &want, rec.encoded_bytes, Some(&tx))
                .unwrap();
            drop(tx);
            assert_eq!(got, want, "w={workers}");
            let max = arrivals.iter().map(|a| a.sim_ready).max().unwrap();
            assert_eq!(max, sim, "schedule max must equal fetch sim (w={workers})");
            let mut covered = 0usize;
            for a in &arrivals {
                assert_eq!(a.start, covered, "arrivals tile in stripe order");
                assert!(a.sim_ready > Duration::ZERO);
                covered = a.end;
            }
            assert_eq!(covered, want.len());
            let mut landed: Vec<StripeLanded> = rx
                .iter()
                .filter_map(|ev| match ev {
                    FetchEvent::Stripe(l) => Some(l),
                    FetchEvent::Source(_) => None,
                })
                .collect();
            landed.sort_by_key(|l| l.stripe);
            assert_eq!(landed.len(), arrivals.len(), "one notice per stripe");
            for (l, a) in landed.iter().zip(&arrivals) {
                assert_eq!(
                    (l.stripe, l.start, l.end),
                    (a.stripe, a.start, a.end),
                    "channel notice must match the schedule"
                );
            }
            let sig: Vec<_> = arrivals
                .iter()
                .map(|a| (a.stripe, a.start, a.end, a.sim_ready))
                .collect();
            match &reference {
                None => reference = Some(sig),
                Some(r) => assert_eq!(
                    &sig, r,
                    "arrival schedule must not depend on pool size (w={workers})"
                ),
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A stripe with no surviving replica fails loudly (never returns
    /// silently corrupt bytes): killing every node makes fetch error.
    #[test]
    fn fetch_fails_when_no_replica_survives() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_dead_{}", std::process::id()));
        let (rec, data) = temp_record(&dir, 19);
        let data = Payload::from_vec(data);
        let mut cfg = StoreConfig::new(2, 2);
        cfg.time_scale = 0.0;
        cfg.faults = FaultPlan::none(0).kill_node(0).kill_node(1);
        let s = store(cfg, 2);
        let err = s
            .fetch_payload(&rec.id, &data, rec.encoded_bytes)
            .unwrap_err()
            .to_string();
        assert!(err.contains("replicas failed"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `fetch` end to end over a real file + metrics sink: payload
    /// parses back as the original container, counters land in the
    /// shared Metrics.
    #[test]
    fn fetch_reads_file_and_records_metrics() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_file_{}", std::process::id()));
        let (rec, _) = temp_record(&dir, 23);
        let metrics = Arc::new(Metrics::new());
        let mut cfg = StoreConfig::new(3, 2);
        cfg.time_scale = 0.0;
        cfg.stripe_bytes = 512;
        cfg.faults = FaultPlan::new(
            1,
            FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() },
        );
        let s = ExpertStore::new(cfg, Some(Arc::new(ThreadPool::new(2))), metrics.clone());
        let (bytes, sim) = s.fetch(&rec).unwrap();
        assert!(format::from_bytes(&bytes).is_ok(), "payload survives striping");
        assert!(sim > Duration::ZERO);
        let snap = metrics.snapshot();
        assert!(snap.stripe_retries > 0);
        assert_eq!(snap.stripe_retries, snap.failovers, "every drop failed over");
        assert_eq!(snap.corrupt_payloads, 0);
        assert_eq!(
            snap.payload_copies, 1,
            "a store fetch is one file materialization, zero reassembly copies"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    // -- adaptive replication ----------------------------------------------

    /// `nodes_for_k(id, k)` is a prefix of `nodes_for_k(id, k + 1)`:
    /// widening an expert appends exactly one node and narrowing drops
    /// exactly the tail — the bounded-churn foundation of rebalancing.
    #[test]
    fn nodes_for_k_is_a_prefix_chain() {
        for (n, seed) in [(3usize, 0u64), (6, 7), (9, 42)] {
            let p = Placement::new(n, 2, seed);
            for i in 0..100 {
                let id = format!("expert/{i}");
                for k in 1..n {
                    let a = p.nodes_for_k(&id, k);
                    let b = p.nodes_for_k(&id, k + 1);
                    assert_eq!(a.len(), k);
                    assert_eq!(b.len(), k + 1);
                    assert_eq!(&b[..k], &a[..], "prefix property (n={n} k={k})");
                }
                // Clamped: k beyond the node count returns every node.
                assert_eq!(p.nodes_for_k(&id, n + 5).len(), n);
            }
        }
    }

    /// Rebalancer invariants over random popularity streams: every
    /// round respects the byte budget, net replica-mass drift per round
    /// stays within the slack, and no override ever leaves (base, cap].
    #[test]
    fn rebalancer_rounds_respect_budget_mass_and_bounds() {
        prop::check(
            "rebalancer_rounds",
            24,
            |rng: &mut Pcg| {
                let n_experts = 2 + rng.range(0, 7);
                let rounds = 1 + rng.range(0, 5);
                let mut feeds = Vec::new();
                for _ in 0..rounds {
                    let mut counts = BTreeMap::new();
                    for e in 0..n_experts {
                        let hits = rng.range(0, 50) as u64;
                        let bytes = 1 + rng.range(0, 32 << 10) as u64;
                        counts.insert(format!("e{e}"), (hits, bytes));
                    }
                    feeds.push(counts);
                }
                feeds
            },
            |feeds| {
                let cfg = RebalanceConfig {
                    decay: 0.5,
                    byte_budget: 64 << 10,
                    max_replicas: 4,
                    slack: 2,
                    hot_factor: 1.5,
                };
                let (base, live) = (1usize, 6usize);
                let cap = cfg.max_replicas.min(live);
                let mut rb = Rebalancer::new(cfg);
                let mut mass_before = 0i64;
                for (i, counts) in feeds.iter().enumerate() {
                    let d = rb.round(counts, base, live);
                    if d.migrated_bytes > cfg.byte_budget {
                        return Err(format!(
                            "round {i}: migrated {} > budget {}",
                            d.migrated_bytes, cfg.byte_budget
                        ));
                    }
                    let mass: i64 =
                        rb.overrides().values().map(|&k| (k - base) as i64).sum();
                    if (mass - mass_before).abs() > cfg.slack as i64 {
                        return Err(format!(
                            "round {i}: mass drift {} exceeds slack {}",
                            mass - mass_before,
                            cfg.slack
                        ));
                    }
                    mass_before = mass;
                    for (id, &k) in rb.overrides() {
                        if k <= base || k > cap {
                            return Err(format!(
                                "round {i}: {id} at {k} outside ({base}, {cap}]"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// One fresh round with an unconstrained budget: planned replica
    /// counts are monotone in measured popularity.
    #[test]
    fn rebalancer_targets_are_monotone_in_popularity() {
        prop::check(
            "rebalancer_monotone",
            24,
            |rng: &mut Pcg| {
                let n = 3 + rng.range(0, 6);
                let mut counts = BTreeMap::new();
                for e in 0..n {
                    counts.insert(format!("e{e}"), (rng.range(0, 200) as u64, 4096u64));
                }
                counts
            },
            |counts| {
                let cfg = RebalanceConfig {
                    byte_budget: u64::MAX / 2,
                    slack: 1 << 20,
                    ..Default::default()
                };
                let mut rb = Rebalancer::new(cfg);
                rb.round(counts, 1, 8);
                let mut by_hits: Vec<(&String, u64)> =
                    counts.iter().map(|(id, &(h, _))| (id, h)).collect();
                by_hits.sort_by_key(|&(_, h)| h);
                for w in by_hits.windows(2) {
                    let (lo, hi) = (w[0], w[1]);
                    let (rl, rh) = (rb.replicas_of(lo.0, 1), rb.replicas_of(hi.0, 1));
                    if rl > rh {
                        return Err(format!(
                            "{}({} hits) planned {rl} replicas > {}({} hits) planned {rh}",
                            lo.0, lo.1, hi.0, hi.1
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Live churn end to end: popularity-driven widening, a node drain,
    /// and a node add all keep fetches byte-identical, move bytes, and
    /// bump epochs — and the whole schedule is identical at every pool
    /// size (determinism of the adaptive layer).
    #[test]
    fn rebalance_drain_and_add_keep_fetches_byte_identical() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_store_churn_{}", std::process::id()));
        let (hot, hot_bytes) = temp_record(&dir, 31);
        let (cold, cold_bytes) = temp_record(&dir, 37);
        let hot_want = Payload::from_vec(hot_bytes);
        let cold_want = Payload::from_vec(cold_bytes);
        let mut reference: Option<(u64, Vec<(String, usize)>)> = None;
        for &workers in &prop::pool_sizes() {
            let mut cfg = StoreConfig::new(4, 1);
            cfg.time_scale = 0.0;
            let s = store(cfg, workers);
            assert_eq!(s.view().epoch, 0);
            // Skewed traffic: the hot expert dominates the round.
            for _ in 0..40 {
                let (got, _) = s.fetch(&hot).unwrap();
                assert_eq!(got, hot_want);
            }
            let (got, _) = s.fetch(&cold).unwrap();
            assert_eq!(got, cold_want);

            // Popularity-driven widening: hot earns replicas, cold
            // stays at base, the copy lands on the appended node.
            let mut rb = Rebalancer::new(RebalanceConfig {
                hot_factor: 0.5,
                ..Default::default()
            });
            let d = s.rebalance(&mut rb);
            assert!(
                rb.replicas_of(&hot.id, 1) > 1,
                "hot expert must widen (w={workers})"
            );
            assert_eq!(rb.replicas_of(&cold.id, 1), 1, "cold stays at base");
            assert!(d.migrated_bytes > 0, "widening copies bytes");
            let view = s.view();
            assert!(view.epoch >= 1, "rebalance publishes an epoch");
            assert!(view.replicas_for(&hot.id).len() > 1);
            let (got, _) = s.fetch(&hot).unwrap();
            assert_eq!(got, hot_want, "post-rebalance fetch identical (w={workers})");

            // Drain the hot expert's primary: its assignments leave the
            // node, replacement bytes migrate, fetches stay identical.
            let victim = view.replicas_for(&hot.id)[0];
            let rep = s.drain_node(victim).unwrap();
            assert!(rep.epoch > view.epoch);
            assert!(rep.moved_experts > 0 && rep.migrated_bytes > 0);
            let after = s.view();
            for id in [&hot.id, &cold.id] {
                assert!(
                    !after.replicas_for(id).contains(&victim),
                    "drained node must hold nothing (w={workers})"
                );
            }
            let (got, _) = s.fetch(&hot).unwrap();
            assert_eq!(got, hot_want, "post-drain fetch identical (w={workers})");
            // Draining a node outside the placement errors loudly.
            assert!(s.drain_node(victim).is_err());

            // Add a node live: fetches still byte-identical.
            let rep = s.add_node();
            assert_eq!(s.nodes(), 5);
            assert!(rep.epoch > after.epoch);
            let (got, _) = s.fetch(&cold).unwrap();
            assert_eq!(got, cold_want, "post-add fetch identical (w={workers})");

            // The schedule is a pure function of the fetch sequence:
            // identical overrides and epoch at every pool size.
            let sig = (
                s.view().epoch,
                rb.overrides()
                    .iter()
                    .map(|(k, &v)| (k.clone(), v))
                    .collect::<Vec<_>>(),
            );
            match &reference {
                None => reference = Some(sig),
                Some(r) => {
                    assert_eq!(&sig, r, "churn schedule must not depend on pool size")
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
