//! Fetch → decode → materialize pipeline for expert checkpoints.
//!
//! On a GPU-tier miss the engine pulls an expert up the hierarchy:
//!
//! ```text
//! remote/disk --net link--> host RAM (encoded)   [CPU tier]
//! host RAM    --pcie link-> device (adapter)     [GPU tier]
//! ```
//!
//! Bytes on each hop are the expert's *encoded* size, so ComPEFT's
//! 8x–50x smaller checkpoints translate directly into proportionally
//! faster swaps (paper Table 5). Decode (Golomb → ternary → dense
//! adapter) happens host-side and is measured separately.
//!
//! ## Stages
//!
//! A swap-in decomposes into three explicit stages, and the methods
//! here map onto them one-to-one so callers can run each stage on the
//! thread that owns its resources:
//!
//! 1. **fetch** — [`ExpertLoader::fetch_encoded`]: net link → encoded
//!    bytes as a zero-copy [`Payload`] view (the one unavoidable heap
//!    materialization off disk/remote is counted on the loader's
//!    [`CopyMeter`]; archive-resident views skip even that).
//!    Thread-agnostic; safe from background prefetch threads
//!    (the [`SimLink`] serializes concurrent transfers like one NIC).
//!    With a sharded [`ExpertStore`] attached
//!    ([`ExpertLoader::with_store`]) this stage becomes a striped
//!    multi-replica fetch with CRC-verified failover — same bytes,
//!    lower latency, no single point of failure.
//! 2. **decode** — [`ExpertLoader::decode`] /
//!    [`ExpertLoader::decode_compressed`] + [`ExpertLoader::merge_ternary`]
//!    + [`ExpertLoader::materialize`]: encoded bytes → dense host-side
//!    parameters. Pool-parallel, thread-agnostic, bit-identical at any
//!    worker count.
//! 3. **upload** — [`ExpertLoader::upload_cost`] plus the device-buffer
//!    creation in `server.rs`: PCIe hop + PjRt buffers. **Engine-thread
//!    only** (PjRt buffers are not `Send`).
//!
//! The serving engine's prefetcher ([`crate::coordinator::pipeline`])
//! runs stages 1–2 for *upcoming* experts on background threads while
//! the engine thread executes the current batch, leaving only the
//! upload hop on the swap critical path.
//!
//! With a store attached, stages 1–2 can also **fuse**
//! ([`ExpertLoader::fetch_decode_fused`]): the striped fetch posts
//! per-stripe completion events, and a decode worker consumes the
//! payload's Golomb frames as their bytes land, so a cold swap costs
//! ≈ `max(fetch, decode)` instead of `fetch + decode` — bit-identical
//! output, same corruption rejects, and the saved time is reported as
//! `decode_overlap_us` in [`Metrics`](crate::coordinator::metrics).
//!
//! With a thread pool attached ([`ExpertLoader::with_pool`]) the
//! decode half scales with cores: `.cpeft` v2 frame tables let
//! [`format::from_bytes_par`] split the Golomb payload across workers,
//! [`engine::par_decompress_params`] materializes dense tensors in
//! chunked scatters, and [`engine::par_add_assign`] applies the update
//! to the adapter init. Every parallel stage is bit-identical to its
//! serial counterpart, so attaching a pool changes latency only, never
//! the served weights.

use crate::compeft::compress::{decompress_params, CompressedParamSet};
use crate::compeft::engine;
use crate::compeft::format;
use crate::compeft::golomb::FrameDecoder;
use crate::compeft::payload::{CopyMeter, Payload};
use crate::compeft::ternary::TernaryVector;
use crate::coordinator::registry::{ExpertFormat, ExpertMethod, ExpertRecord};
use crate::coordinator::store::{ExpertStore, FetchEvent};
use crate::coordinator::transport::SimLink;
use crate::merging::{ternary, MergeMethod};
use crate::tensor::ParamSet;
use crate::util::pool::ThreadPool;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Loads expert checkpoints over simulated links.
///
/// Cloning is cheap (shared links + shared decode pool) and is how the
/// prefetch pipeline hands the fetch/decode stages to background
/// threads while the engine thread keeps its own handle for uploads.
#[derive(Clone)]
pub struct ExpertLoader {
    /// Remote → host link (internet or disk, depending on deployment).
    /// Unused for fetches when a sharded [`ExpertStore`] is attached.
    pub net: SimLink,
    /// Host → device link.
    pub pcie: SimLink,
    /// Optional decode pool: when set, `.cpeft` parsing, dense
    /// materialization, and adapter application run chunked across it.
    pool: Option<Arc<ThreadPool>>,
    /// Optional sharded store: when set, [`ExpertLoader::fetch_encoded`]
    /// runs the striped multi-replica fetch (with failover) instead of
    /// the flat single-link transfer. Bytes are identical either way.
    store: Option<Arc<ExpertStore>>,
    /// Counts encoded-byte heap copies (the flat fetch's one
    /// materialization off disk). Share the engine's meter via
    /// [`ExpertLoader::with_meter`] so they land in `payload_copies`.
    meter: CopyMeter,
}

/// Outcome of one fused fetch→decode
/// ([`ExpertLoader::fetch_decode_fused`]): the decoded task vector is
/// bit-identical to fetch-then-decode; the timing fields separate the
/// unfused accounting (`fetch + decode`) from the fused critical path.
pub struct FusedLoad {
    /// The decoded dense task vector.
    pub tv: ParamSet,
    /// The fetched container bytes (zero-copy view) — callers insert
    /// these into the host tier exactly as on the unfused path.
    pub payload: Payload,
    /// Unfused accounting: the fetch's simulated duration.
    pub fetch: Duration,
    /// Unfused accounting: total real decode time (frames + finish +
    /// densify).
    pub decode: Duration,
    /// The fused critical path: frame decode replayed against the
    /// stripe arrival schedule, so ≈ `max(fetch, decode)` + tails,
    /// never more than `fetch + decode`.
    pub fused: Duration,
    /// `fetch + decode − fused`: the cold-swap time the overlap hid.
    pub overlap: Duration,
}

/// Timing breakdown of one load.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadTiming {
    /// Simulated network/disk transfer time.
    pub fetch: Duration,
    /// Host-side decode time (real).
    pub decode: Duration,
    /// Simulated host→device transfer time.
    pub upload: Duration,
}

impl LoadTiming {
    pub fn total(&self) -> Duration {
        self.fetch + self.decode + self.upload
    }
}

impl ExpertLoader {
    pub fn new(net: SimLink, pcie: SimLink) -> ExpertLoader {
        ExpertLoader { net, pcie, pool: None, store: None, meter: CopyMeter::new() }
    }

    /// Attach a decode pool; subsequent [`ExpertLoader::decode`] and
    /// [`ExpertLoader::materialize`] calls run their chunked parallel
    /// paths (bit-identical outputs, lower latency).
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> ExpertLoader {
        self.pool = Some(pool);
        self
    }

    /// Attach a sharded expert store: fetches become striped
    /// multi-replica transfers with CRC-verified failover. The decoded
    /// bytes — and everything downstream — are bit-identical to the
    /// single-link path; only the (simulated) latency and the fault
    /// tolerance change.
    pub fn with_store(mut self, store: Arc<ExpertStore>) -> ExpertLoader {
        self.store = Some(store);
        self
    }

    /// Share the engine's copy meter so this loader's encoded-byte
    /// materializations are counted in the engine's `payload_copies`.
    pub fn with_meter(mut self, meter: CopyMeter) -> ExpertLoader {
        self.meter = meter;
        self
    }

    /// This loader's copy meter (shared handle).
    pub fn meter(&self) -> CopyMeter {
        self.meter.clone()
    }

    /// Fetch the encoded checkpoint bytes as a zero-copy [`Payload`]
    /// view: striped from the sharded store when one is attached,
    /// otherwise a flat transfer over the net link. Either way the
    /// returned view is shared from here on — downstream decode, tier
    /// insertion, and staging never copy the encoded bytes again.
    pub fn fetch_encoded(&self, rec: &ExpertRecord) -> Result<(Payload, Duration)> {
        if let Some(store) = &self.store {
            return store.fetch(rec);
        }
        let bytes = std::fs::read(&rec.path)
            .with_context(|| format!("read {}", rec.path.display()))?;
        // The one unavoidable materialization off disk/remote.
        self.meter.record(1);
        let sim = self.net.transfer(rec.encoded_bytes);
        Ok((Payload::from_vec(bytes), sim))
    }

    /// Decode encoded bytes into a dense task vector with the structure
    /// of `template` (the adapter/base init, which fixes names+shapes).
    pub fn decode(
        &self,
        rec: &ExpertRecord,
        bytes: &[u8],
        template: &ParamSet,
    ) -> Result<(ParamSet, Duration)> {
        let t0 = Instant::now();
        let tv = match rec.format {
            ExpertFormat::OriginalFp16 => {
                // npz container (dense f32; fp16 is the accounting
                // model). The reader seeks over the borrowed bytes —
                // no owned copy of the container.
                let cursor = std::io::Cursor::new(bytes);
                let arrays = crate::util::npz::read_npz_from(cursor)?;
                let mut p = ParamSet::new();
                for (name, arr) in arrays {
                    p.insert(
                        &name,
                        crate::tensor::Tensor::new(arr.shape.clone(), arr.to_f32()?),
                    );
                }
                p
            }
            ExpertFormat::Compeft => match &self.pool {
                Some(pool) => {
                    let (compressed, _) = format::from_bytes_par(bytes, pool)?;
                    engine::par_decompress_params(&compressed, template, pool)?
                }
                None => {
                    let (compressed, _) = format::from_bytes(bytes)?;
                    decompress_params(&compressed, template)?
                }
            },
        };
        Ok((tv, t0.elapsed()))
    }

    /// Fused fetch→decode: stream the striped store fetch and decode
    /// the payload's Golomb frames *as their bytes land*, instead of
    /// fetch-then-decode. Requires an attached [`ExpertStore`] and a
    /// `.cpeft` expert — returns `Ok(None)` otherwise so callers fall
    /// back to the staged path.
    ///
    /// A decode worker thread drains the store's completion channel:
    /// the [`FetchEvent::Source`] buffer first (container metadata —
    /// header, CRC, frame table — is validated up front via
    /// [`format::golomb_frame_plan`]), then per-stripe
    /// [`FetchEvent::Stripe`] notices advance a contiguous-coverage
    /// watermark, and frame `f` decodes the moment the watermark passes
    /// its last payload byte. Real wall time overlaps; the *simulated*
    /// fused duration replays the same frame decode against the
    /// deterministic [`StripeArrival`](crate::coordinator::store::StripeArrival)
    /// schedule, so the reported cold-swap cost is
    /// ≈ `max(fetch, decode)` rather than their sum. Output is
    /// bit-identical to [`ExpertLoader::fetch_encoded`] +
    /// [`ExpertLoader::decode`]: same kernels, same frame-table
    /// revalidation, same rejects.
    pub fn fetch_decode_fused(
        &self,
        rec: &ExpertRecord,
        template: &ParamSet,
    ) -> Result<Option<FusedLoad>> {
        let Some(store) = &self.store else { return Ok(None) };
        if rec.format != ExpertFormat::Compeft {
            return Ok(None);
        }

        // (need, duration) per frame: the container byte prefix the
        // frame waited for, and its real decode time.
        type FrameRun = (format::GolombFramePlan, TernaryVector, Vec<(usize, Duration)>);
        let (tx, rx) = std::sync::mpsc::channel::<FetchEvent>();
        let decoder = std::thread::spawn(move || -> Result<Option<FrameRun>> {
            // The source buffer always arrives before any stripe; if
            // the fetch dies before sending it, bow out — the fetch
            // error is authoritative.
            let Ok(FetchEvent::Source(payload)) = rx.recv() else {
                return Ok(None);
            };
            let plan = match format::golomb_frame_plan(&payload)? {
                Some(p) => p,
                None => return Ok(None), // valid but not a fused-able shape
            };
            let bytes = payload.as_slice();
            let slice = bytes.get(plan.payload.clone()).unwrap_or_default();
            let mut fd = FrameDecoder::new(slice, &plan.table)?;
            // Contiguous-coverage watermark over container bytes:
            // stripes land in any order; a frame decodes once the
            // prefix through its last byte is covered.
            let mut pending: BTreeMap<usize, usize> = BTreeMap::new();
            let mut watermark = 0usize;
            let mut frames: Vec<(usize, Duration)> =
                Vec::with_capacity(fd.frame_count());
            let mut open = true;
            for f in 0..fd.frame_count() {
                // The final frame also waits for the container's
                // trailing CRC — the whole buffer.
                let need = if f + 1 == fd.frame_count() {
                    bytes.len()
                } else {
                    plan.payload.start + fd.frame_end_byte(f)
                };
                while open && watermark < need {
                    match rx.recv() {
                        Ok(FetchEvent::Stripe(l)) => {
                            pending.insert(l.start, l.end);
                            while let Some((&s, &e)) = pending.first_key_value() {
                                if s > watermark {
                                    break;
                                }
                                watermark = watermark.max(e);
                                pending.remove(&s);
                            }
                        }
                        Ok(FetchEvent::Source(_)) => {}
                        // Channel closed: the fetch is over. On success
                        // every byte is in the buffer; on failure the
                        // caller discards this result for the fetch
                        // error either way.
                        Err(_) => open = false,
                    }
                }
                let t = Instant::now();
                fd.decode_next()?;
                frames.push((need, t.elapsed()));
            }
            let tern = fd.finish()?;
            Ok(Some((plan, tern, frames)))
        });

        let fetched = store.fetch_streamed(rec, &tx);
        drop(tx); // close the channel so the decode worker drains out
        let joined = decoder
            .join()
            .map_err(|_| anyhow::anyhow!("fused decode worker panicked"))?;
        let (payload, fetch, arrivals) = fetched?;
        let Some((plan, tern, frames)) = joined? else {
            // Valid container, but not the fused shape (v1, bitmask,
            // multi-part): plain decode of the already-fetched bytes.
            let (tv, decode) = self.decode(rec, &payload, template)?;
            return Ok(Some(FusedLoad {
                tv,
                payload,
                fetch,
                decode,
                fused: fetch + decode,
                overlap: Duration::ZERO,
            }));
        };

        // Post-frame work (sign split + table revalidation + densify)
        // runs after the last frame on both paths.
        let t_post = Instant::now();
        let (compressed, _) = plan.finish(tern)?;
        let tv = match &self.pool {
            Some(pool) => engine::par_decompress_params(&compressed, template, pool)?,
            None => decompress_params(&compressed, template)?,
        };
        let post = t_post.elapsed();

        // The fused critical path: replay the measured frame decode
        // against the deterministic arrival schedule. Frame `f` starts
        // at max(its bytes' simulated arrival, frame `f−1`'s end).
        // Arrivals tile the payload in start order, so "every byte
        // below `need` has landed" is a prefix maximum of `sim_ready`.
        let mut t_end = Duration::ZERO;
        for &(need, d) in &frames {
            let ready = arrivals
                .iter()
                .take_while(|a| a.start < need)
                .map(|a| a.sim_ready)
                .max()
                .unwrap_or(Duration::ZERO);
            t_end = t_end.max(ready) + d;
        }
        let decode = frames.iter().map(|&(_, d)| d).sum::<Duration>() + post;
        let fused = t_end.max(fetch) + post;
        let overlap = (fetch + decode).saturating_sub(fused);
        store.metrics().record_decode_overlap(overlap);
        Ok(Some(FusedLoad { tv, payload, fetch, decode, fused, overlap }))
    }

    /// Decode `.cpeft` bytes into the compressed (ternary) form
    /// *without* densifying — the input the ternary-domain merge
    /// engine consumes. Frame-parallel when a pool is attached.
    pub fn decode_compressed(
        &self,
        rec: &ExpertRecord,
        bytes: &[u8],
    ) -> Result<(CompressedParamSet, Duration)> {
        if rec.format != ExpertFormat::Compeft {
            bail!(
                "expert {:?} is stored as {:?}, not `.cpeft` — cannot decode \
                 to the ternary domain",
                rec.id,
                rec.format
            );
        }
        let t0 = Instant::now();
        let c = match &self.pool {
            Some(pool) => format::from_bytes_par(bytes, pool)?.0,
            None => format::from_bytes(bytes)?.0,
        };
        Ok((c, t0.elapsed()))
    }

    /// Ternary-domain merge of member experts into one dense task
    /// vector (chunk-parallel when a pool is attached; bit-identical
    /// either way). The members are never materialized densely — peak
    /// memory stays O(d), not O(members·d).
    pub fn merge_ternary(
        &self,
        members: &[&CompressedParamSet],
        method: &MergeMethod,
    ) -> Result<(ParamSet, Duration)> {
        let t0 = Instant::now();
        let merged = match &self.pool {
            Some(pool) => engine::par_merge(members, method, pool)?,
            None => ternary::merge_ternary(members, method)?,
        };
        Ok((merged, t0.elapsed()))
    }

    /// Materialize an already-parsed compressed paramset densely
    /// (chunk-parallel when a pool is attached; bit-identical either
    /// way). The ternary-domain half of [`ExpertLoader::decode`], for
    /// callers that produced the compressed form some other way — e.g.
    /// a delta apply.
    pub fn densify(
        &self,
        c: &CompressedParamSet,
        template: &ParamSet,
    ) -> Result<(ParamSet, Duration)> {
        let t0 = Instant::now();
        let tv = match &self.pool {
            Some(pool) => engine::par_decompress_params(c, template, pool)?,
            None => decompress_params(c, template)?,
        };
        Ok((tv, t0.elapsed()))
    }

    /// Apply a `.cpeft` delta container ([`engine::ExpertDelta`] wire
    /// form) to the resident compressed expert, reconstructing the next
    /// version **in the ternary domain** — no dense round-trip, no
    /// float recomputation, so the result is bit-identical to decoding
    /// a full re-encode of v(n+1).
    ///
    /// Timing: `fetch` is the simulated net hop for the delta's wire
    /// bytes (an update push travels the same link a full checkpoint
    /// would, just carrying far fewer bytes); `decode` is the real
    /// parse+apply time; `upload` stays zero (re-uploading the
    /// refreshed adapter is the caller's existing swap path). When a
    /// store is attached the apply lands on its shared metrics as
    /// `delta_applies` / `delta_bytes_saved`, with `full_encoded_bytes`
    /// as the counterfactual full-push cost.
    pub fn apply_delta(
        &self,
        old: &CompressedParamSet,
        delta_bytes: &[u8],
        full_encoded_bytes: u64,
    ) -> Result<(CompressedParamSet, LoadTiming)> {
        let fetch = self.net.transfer(delta_bytes.len() as u64);
        let t0 = Instant::now();
        let (delta, _) = engine::ExpertDelta::from_bytes(delta_bytes)?;
        let next = engine::apply_delta(old, &delta)?;
        let decode = t0.elapsed();
        if let Some(store) = &self.store {
            store
                .metrics()
                .record_delta_apply(delta_bytes.len() as u64, full_encoded_bytes);
        }
        Ok((next, LoadTiming { fetch, decode, upload: Duration::ZERO }))
    }

    /// Materialize the servable adapter: init + task vector.
    pub fn materialize(
        &self,
        method: ExpertMethod,
        init: &ParamSet,
        tv: &ParamSet,
    ) -> Result<ParamSet> {
        let mut adapter = init.clone();
        match &self.pool {
            Some(pool) => engine::par_add_assign(&mut adapter, tv, pool)?,
            None => adapter.add_assign(tv)?,
        }
        let _ = method;
        Ok(adapter)
    }

    /// Simulate the host→device hop for this expert's encoded bytes.
    pub fn upload_cost(&self, rec: &ExpertRecord) -> Duration {
        self.pcie.transfer(rec.encoded_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_params, CompressConfig};
    use crate::coordinator::registry::Registry;
    use crate::coordinator::transport::{LinkSpec, SimLink};
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn fast_links() -> ExpertLoader {
        ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
            SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
        )
    }

    fn sample_tv(seed: u64) -> ParamSet {
        let mut rng = Pcg::seed(seed);
        let mut p = ParamSet::new();
        p.insert(
            "a.lora_a",
            Tensor::new(vec![512, 4], prop::task_vector_like(&mut rng, 2048)),
        );
        p.insert(
            "a.lora_b",
            Tensor::new(vec![4, 512], prop::task_vector_like(&mut rng, 2048)),
        );
        p
    }

    #[test]
    fn roundtrip_original_and_compeft() {
        let dir = std::env::temp_dir().join("compeft_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let tv = sample_tv(3);
        let npz = dir.join("t.lora.npz");
        tv.save_npz(&npz).unwrap();

        let mut reg = Registry::new();
        reg.register_original("orig", "t", "s", ExpertMethod::Lora, &npz).unwrap();
        reg.register_compeft(
            "comp",
            "t",
            "s",
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.2, alpha: 1.0, ..Default::default() },
        )
        .unwrap();

        let loader = fast_links();
        // Original decodes to the exact tv.
        let rec = reg.get("orig").unwrap();
        let (bytes, _) = loader.fetch_encoded(rec).unwrap();
        let (decoded, _) = loader.decode(rec, &bytes, &tv).unwrap();
        assert_eq!(decoded, tv);
        assert_eq!(
            loader.meter().count(),
            1,
            "a flat fetch is exactly one materialization; decode adds none"
        );

        // ComPEFT decodes to the ternary approximation (same support
        // signs as the rust compressor's output).
        let rec = reg.get("comp").unwrap();
        let (bytes, _) = loader.fetch_encoded(rec).unwrap();
        let (decoded, _) = loader.decode(rec, &bytes, &tv).unwrap();
        let expect = decompress_params(
            &compress_params(&tv, &CompressConfig { density: 0.2, alpha: 1.0, ..Default::default() }),
            &tv,
        )
        .unwrap();
        assert_eq!(decoded, expect);

        // Materialize: init + tv.
        let mut init = ParamSet::new();
        init.insert("a.lora_a", Tensor::zeros(vec![512, 4]));
        init.insert("a.lora_b", Tensor::zeros(vec![4, 512]));
        let adapter = loader.materialize(ExpertMethod::Lora, &init, &decoded).unwrap();
        assert_eq!(adapter, decoded);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pooled_loader_decodes_and_materializes_identically() {
        let dir = std::env::temp_dir().join(format!(
            "compeft_loader_pool_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let tv = sample_tv(9);
        let npz = dir.join("t.lora.npz");
        tv.save_npz(&npz).unwrap();
        let mut reg = Registry::new();
        reg.register_compeft(
            "c",
            "t",
            "s",
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() },
        )
        .unwrap();
        let rec = reg.get("c").unwrap().clone();

        let serial = fast_links();
        let (bytes, _) = serial.fetch_encoded(&rec).unwrap();
        let (tv_serial, _) = serial.decode(&rec, &bytes, &tv).unwrap();
        let mut init = ParamSet::new();
        init.insert("a.lora_a", Tensor::new(vec![512, 4], vec![0.25; 2048]));
        init.insert("a.lora_b", Tensor::new(vec![4, 512], vec![-0.5; 2048]));
        let adapter_serial =
            serial.materialize(ExpertMethod::Lora, &init, &tv_serial).unwrap();

        for workers in crate::util::prop::pool_sizes() {
            let pooled = fast_links()
                .with_pool(std::sync::Arc::new(crate::util::pool::ThreadPool::new(
                    workers,
                )));
            let (tv_par, _) = pooled.decode(&rec, &bytes, &tv).unwrap();
            assert_eq!(tv_par, tv_serial, "decode workers={workers}");
            let adapter_par =
                pooled.materialize(ExpertMethod::Lora, &init, &tv_par).unwrap();
            assert_eq!(adapter_par, adapter_serial, "materialize workers={workers}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Ternary-domain merge through the loader: fetch two `.cpeft`
    /// experts, decode to compressed form, merge — and get exactly what
    /// the dense decompress-then-merge reference produces, with and
    /// without a pool. This is the loader half of serving a merged
    /// expert, with no artifacts required.
    #[test]
    fn loader_merges_compressed_experts_like_dense_reference() {
        use crate::merging::{merge_dense, MergeMethod};

        let dir = std::env::temp_dir().join(format!(
            "compeft_loader_merge_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut reg = Registry::new();
        let cfg = CompressConfig { density: 0.15, alpha: 1.0, ..Default::default() };
        let mut originals = Vec::new();
        for (i, seed) in [21u64, 22, 23].iter().enumerate() {
            let tv = sample_tv(*seed);
            let npz = dir.join(format!("t{i}.lora.npz"));
            tv.save_npz(&npz).unwrap();
            reg.register_compeft(
                &format!("e{i}"),
                "t",
                "s",
                ExpertMethod::Lora,
                &npz,
                &cfg,
            )
            .unwrap();
            originals.push(tv);
        }

        let loader = fast_links();
        let mut members = Vec::new();
        for i in 0..3 {
            let rec = reg.get(&format!("e{i}")).unwrap();
            let (bytes, _) = loader.fetch_encoded(rec).unwrap();
            let (c, _) = loader.decode_compressed(rec, &bytes).unwrap();
            members.push(c);
        }
        let refs: Vec<&_> = members.iter().collect();

        // Dense reference over the decompressed members.
        let dense: Vec<ParamSet> = members
            .iter()
            .zip(&originals)
            .map(|(c, tv)| decompress_params(c, tv).unwrap())
            .collect();
        for method in [
            MergeMethod::Average,
            MergeMethod::Ties { density: 0.3, lambda: 1.0 },
            MergeMethod::Weighted { weights: vec![0.5, -0.2, 1.0] },
        ] {
            let want = merge_dense(&dense, &method).unwrap();
            let (serial, _) = loader.merge_ternary(&refs, &method).unwrap();
            assert_eq!(serial, want, "serial {method:?}");
            for workers in crate::util::prop::pool_sizes() {
                let pooled = fast_links().with_pool(std::sync::Arc::new(
                    crate::util::pool::ThreadPool::new(workers),
                ));
                let (par, _) = pooled.merge_ternary(&refs, &method).unwrap();
                assert_eq!(par, want, "workers={workers} {method:?}");
            }
        }

        // decode_compressed refuses non-.cpeft experts.
        let npz = dir.join("orig.lora.npz");
        sample_tv(5).save_npz(&npz).unwrap();
        reg.register_original("orig", "t", "s", ExpertMethod::Lora, &npz).unwrap();
        let rec = reg.get("orig").unwrap();
        let (bytes, _) = loader.fetch_encoded(rec).unwrap();
        assert!(loader.decode_compressed(rec, &bytes).is_err());

        std::fs::remove_dir_all(&dir).ok();
    }

    /// A store-backed loader fetches byte-identical payloads (decoding
    /// to the same ternary form) even while the store is failing over
    /// around a dead node, and the flat `net` link stays untouched.
    #[test]
    fn store_backed_loader_fetches_identical_bytes_under_faults() {
        use crate::coordinator::metrics::Metrics;
        use crate::coordinator::store::{ExpertStore, Placement, StoreConfig};
        use crate::coordinator::transport::FaultPlan;

        let dir = std::env::temp_dir().join(format!(
            "compeft_loader_store_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let tv = sample_tv(29);
        let npz = dir.join("t.lora.npz");
        tv.save_npz(&npz).unwrap();
        let mut reg = Registry::new();
        reg.register_compeft(
            "c",
            "t",
            "s",
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.2, alpha: 1.0, ..Default::default() },
        )
        .unwrap();
        let rec = reg.get("c").unwrap().clone();

        let flat = fast_links();
        let (want, _) = flat.fetch_encoded(&rec).unwrap();

        let metrics = std::sync::Arc::new(Metrics::new());
        let mut cfg = StoreConfig::new(3, 2);
        cfg.time_scale = 0.0;
        cfg.stripe_bytes = 512;
        cfg.faults =
            FaultPlan::none(1).kill_node(Placement::new(3, 2, 0).nodes_for("c")[0]);
        let store = std::sync::Arc::new(ExpertStore::new(
            cfg,
            Some(std::sync::Arc::new(crate::util::pool::ThreadPool::new(2))),
            std::sync::Arc::clone(&metrics),
        ));
        let sharded = fast_links().with_store(std::sync::Arc::clone(&store));
        let (got, sim) = sharded.fetch_encoded(&rec).unwrap();
        assert_eq!(got, want, "striped fetch must reassemble the flat bytes");
        assert!(sim > Duration::ZERO);
        assert_eq!(sharded.net.bytes_moved(), 0, "flat link unused with a store");
        assert_eq!(store.bytes_moved(), rec.encoded_bytes);
        assert!(metrics.snapshot().failovers > 0, "dead primary must fail over");

        // Decode of the striped payload equals decode of the flat one.
        let (a, _) = flat.decode(&rec, &want, &tv).unwrap();
        let (b, _) = sharded.decode(&rec, &got, &tv).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The fused fetch→decode path: bit-identical task vectors to the
    /// staged fetch-then-decode path at every pool size, with and
    /// without store faults; the fused critical path never exceeds
    /// `fetch + decode` and the overlap accounting is exact; non-fused
    /// shapes (bitmask) fall back gracefully; no store → `None`.
    #[test]
    fn fused_fetch_decode_matches_staged_path() {
        use crate::compeft::compress::compress_params;
        use crate::compeft::format::Encoding;
        use crate::coordinator::metrics::Metrics;
        use crate::coordinator::store::{ExpertStore, StoreConfig};
        use crate::coordinator::transport::{FaultPlan, FaultSpec};

        let dir = std::env::temp_dir()
            .join(format!("compeft_loader_fused_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Big enough for several 8K-nonzero frames: density 0.3 over
        // 60K params ≈ 18K nonzeros ≈ 3 frames.
        let mut rng = Pcg::seed(41);
        let n = 60_000usize;
        let mut p = ParamSet::new();
        p.insert("w", Tensor::new(vec![n], prop::task_vector_like(&mut rng, n)));
        let c = compress_params(
            &p,
            &CompressConfig { density: 0.3, ..Default::default() },
        );
        let mk = |enc: Encoding, name: &str| -> ExpertRecord {
            let path = dir.join(format!("{name}.cpeft"));
            let bytes = format::save(&path, &c, enc).unwrap();
            ExpertRecord {
                id: name.into(),
                task: "t".into(),
                scale: "s".into(),
                method: ExpertMethod::Lora,
                format: ExpertFormat::Compeft,
                path,
                encoded_bytes: bytes,
                n_params: n,
            }
        };
        let rec = mk(Encoding::Golomb, "fused");

        let flat = fast_links();
        let (want_bytes, _) = flat.fetch_encoded(&rec).unwrap();
        let (want_tv, _) = flat.decode(&rec, &want_bytes, &p).unwrap();
        let plan = format::golomb_frame_plan(&want_bytes).unwrap().unwrap();
        assert!(plan.table.frames.len() > 1, "need a multi-frame payload");

        // Without a store the fused path declines.
        assert!(flat.fetch_decode_fused(&rec, &p).unwrap().is_none());

        let store_with = |faults: FaultPlan, workers: usize| {
            let mut cfg = StoreConfig::new(3, 2);
            cfg.time_scale = 0.0;
            cfg.stripe_bytes = 512; // several stripes per fetch
            cfg.faults = faults;
            let pool = Arc::new(ThreadPool::new(workers));
            fast_links().with_pool(Arc::clone(&pool)).with_store(Arc::new(
                ExpertStore::new(cfg, Some(pool), Arc::new(Metrics::new())),
            ))
        };

        for &workers in &prop::pool_sizes() {
            let plans: Vec<(&str, FaultPlan)> = vec![
                ("clean", FaultPlan::none(3)),
                (
                    "drop",
                    FaultPlan::new(
                        5,
                        FaultSpec {
                            drop_p: 0.4,
                            first_attempt_only: true,
                            ..Default::default()
                        },
                    ),
                ),
            ];
            for (fname, faults) in plans {
                let loader = store_with(faults, workers);
                let fused = loader
                    .fetch_decode_fused(&rec, &p)
                    .unwrap()
                    .expect("store-backed golomb container must fuse");
                assert_eq!(fused.tv, want_tv, "{fname} w={workers}: bit-identical");
                assert_eq!(fused.payload, want_bytes, "{fname} w={workers}");
                assert!(fused.fetch > Duration::ZERO);
                assert!(
                    fused.fused <= fused.fetch + fused.decode,
                    "{fname} w={workers}: fused {:?} exceeds unfused {:?}",
                    fused.fused,
                    fused.fetch + fused.decode
                );
                assert_eq!(
                    fused.overlap,
                    (fused.fetch + fused.decode) - fused.fused,
                    "{fname} w={workers}: overlap accounting must be exact"
                );
            }
        }

        // A bitmask container declines fusion but still decodes through
        // the fallback, identically to the staged path.
        let bm = mk(Encoding::Bitmask, "fallback");
        let (bm_bytes, _) = flat.fetch_encoded(&bm).unwrap();
        let (bm_tv, _) = flat.decode(&bm, &bm_bytes, &p).unwrap();
        let loader = store_with(FaultPlan::none(0), 2);
        let fused = loader.fetch_decode_fused(&bm, &p).unwrap().expect("fallback");
        assert_eq!(fused.tv, bm_tv, "fallback decode must match");
        assert_eq!(fused.overlap, Duration::ZERO, "fallback has no overlap");
        assert_eq!(fused.fused, fused.fetch + fused.decode);

        std::fs::remove_dir_all(&dir).ok();
    }

    /// Delta updates through the loader: applying a wire delta on the
    /// resident v(n) reconstructs the full re-encode of v(n+1) bit for
    /// bit, ships far fewer bytes over the link than a full push, and
    /// lands on the attached store's metrics; a corrupted delta is
    /// rejected instead of applied.
    #[test]
    fn loader_applies_delta_updates_bit_identically() {
        use crate::compeft::engine::compress_delta;
        use crate::compeft::format::Encoding;
        use crate::coordinator::metrics::Metrics;
        use crate::coordinator::store::{ExpertStore, StoreConfig};

        let v0 = sample_tv(61);
        let mut v1 = v0.clone();
        for (_, t) in v1.iter_mut() {
            let n = t.data.len();
            for k in 0..8usize {
                let i = (k * 211 + 5) % n;
                t.data[i] = -t.data[i];
            }
        }
        let cfg = CompressConfig { density: 0.1, ..Default::default() };
        let old = compress_params(&v0, &cfg);
        let new = compress_params(&v1, &cfg);
        let wire = compress_delta(&old, &new).unwrap().to_bytes(Encoding::Golomb);
        let full_bytes = format::to_bytes(&new, Encoding::Golomb).len() as u64;
        assert!((wire.len() as u64) < full_bytes);

        let metrics = Arc::new(Metrics::new());
        let mut scfg = StoreConfig::new(3, 2);
        scfg.time_scale = 0.0;
        let loader = fast_links().with_store(Arc::new(ExpertStore::new(
            scfg,
            None,
            Arc::clone(&metrics),
        )));
        let (got, _timing) = loader.apply_delta(&old, &wire, full_bytes).unwrap();
        assert_eq!(got, new, "delta apply must equal the full re-encode");

        let snap = metrics.snapshot();
        assert_eq!(snap.delta_applies, 1);
        assert_eq!(snap.delta_bytes_saved, full_bytes - wire.len() as u64);

        let mut bad = wire.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(loader.apply_delta(&old, &bad, full_bytes).is_err());
    }

    #[test]
    fn link_accounting_reflects_encoded_sizes() {
        let dir = std::env::temp_dir().join("compeft_loader_acct");
        std::fs::create_dir_all(&dir).unwrap();
        let tv = sample_tv(5);
        let npz = dir.join("t.lora.npz");
        tv.save_npz(&npz).unwrap();
        let mut reg = Registry::new();
        reg.register_original("o", "t", "s", ExpertMethod::Lora, &npz).unwrap();
        reg.register_compeft(
            "c", "t", "s", ExpertMethod::Lora, &npz,
            &CompressConfig { density: 0.05, ..Default::default() },
        )
        .unwrap();
        let loader = fast_links();
        loader.fetch_encoded(reg.get("o").unwrap()).unwrap();
        let after_orig = loader.net.bytes_moved();
        loader.fetch_encoded(reg.get("c").unwrap()).unwrap();
        let comp_bytes = loader.net.bytes_moved() - after_orig;
        assert!(comp_bytes * 4 < after_orig, "{comp_bytes} vs {after_orig}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
