//! Expert catalog: which experts exist, in which formats, at what
//! encoded sizes. Built by scanning the artifact tree (or registered
//! programmatically by benches).

use crate::compeft::compress::{compress_params, CompressConfig};
use crate::compeft::format::{self, Encoding};
use crate::tensor::ParamSet;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// How an expert checkpoint is stored on "disk"/remote.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertFormat {
    /// Dense task vector at 16-bit accounting (the paper's baseline).
    OriginalFp16,
    /// ComPEFT `.cpeft` (Golomb-coded).
    Compeft,
}

/// Adapter family of the expert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertMethod {
    Lora,
    Ia3,
    Full,
}

impl ExpertMethod {
    pub fn parse(s: &str) -> Option<ExpertMethod> {
        match s {
            "lora" => Some(ExpertMethod::Lora),
            "ia3" => Some(ExpertMethod::Ia3),
            "full" => Some(ExpertMethod::Full),
            _ => None,
        }
    }
}

/// One registered expert.
#[derive(Clone, Debug)]
pub struct ExpertRecord {
    pub id: String,
    pub task: String,
    pub scale: String,
    pub method: ExpertMethod,
    pub format: ExpertFormat,
    /// Path of the stored checkpoint (npz task vector or .cpeft).
    pub path: PathBuf,
    /// Bytes that move when this expert is fetched.
    pub encoded_bytes: u64,
    /// Dense parameter count of the task vector.
    pub n_params: usize,
}

/// The expert catalog.
#[derive(Default, Debug)]
pub struct Registry {
    experts: BTreeMap<String, ExpertRecord>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn insert(&mut self, rec: ExpertRecord) {
        self.experts.insert(rec.id.clone(), rec);
    }

    pub fn get(&self, id: &str) -> Option<&ExpertRecord> {
        self.experts.get(id)
    }

    pub fn ids(&self) -> Vec<String> {
        self.experts.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.experts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.experts.is_empty()
    }

    /// Register the original (fp16-accounted) form of a task-vector npz.
    pub fn register_original(
        &mut self,
        id: &str,
        task: &str,
        scale: &str,
        method: ExpertMethod,
        npz_path: &Path,
    ) -> Result<&ExpertRecord> {
        let tv = ParamSet::load_npz(npz_path)
            .with_context(|| format!("load {}", npz_path.display()))?;
        let rec = ExpertRecord {
            id: id.to_string(),
            task: task.to_string(),
            scale: scale.to_string(),
            method,
            format: ExpertFormat::OriginalFp16,
            path: npz_path.to_path_buf(),
            encoded_bytes: tv.bytes_fp16(),
            n_params: tv.total_elements(),
        };
        self.insert(rec);
        Ok(self.get(id).unwrap())
    }

    /// Compress a task-vector npz with ComPEFT, write the `.cpeft` next
    /// to it, and register the compressed form.
    pub fn register_compeft(
        &mut self,
        id: &str,
        task: &str,
        scale: &str,
        method: ExpertMethod,
        npz_path: &Path,
        cfg: &CompressConfig,
    ) -> Result<&ExpertRecord> {
        let tv = ParamSet::load_npz(npz_path)?;
        let compressed = compress_params(&tv, cfg);
        let out = npz_path.with_extension("cpeft");
        let bytes = format::save(&out, &compressed, Encoding::Golomb)?;
        let rec = ExpertRecord {
            id: id.to_string(),
            task: task.to_string(),
            scale: scale.to_string(),
            method,
            format: ExpertFormat::Compeft,
            path: out,
            encoded_bytes: bytes,
            n_params: tv.total_elements(),
        };
        self.insert(rec);
        Ok(self.get(id).unwrap())
    }
}

/// Scan `artifacts/experts/{scale}` for `{task}.{method}.npz` task
/// vectors; returns (task, method, path) triples.
pub fn scan_expert_npz(artifacts: &Path, scale: &str) -> Result<Vec<(String, ExpertMethod, PathBuf)>> {
    let dir = artifacts.join("experts").join(scale);
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(&dir)? {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if !name.ends_with(".npz") {
            continue;
        }
        let stem = name.trim_end_matches(".npz");
        let parts: Vec<&str> = stem.split('.').collect();
        if parts.len() < 2 {
            continue;
        }
        // {task}.{method}[.r{rank}]
        if let Some(m) = ExpertMethod::parse(parts[1]) {
            if parts.len() == 2 {
                out.push((parts[0].to_string(), m, path.clone()));
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn tv_npz(dir: &Path, name: &str) -> PathBuf {
        let mut rng = Pcg::seed(33);
        let mut p = ParamSet::new();
        p.insert("w", Tensor::new(vec![512], prop::task_vector_like(&mut rng, 512)));
        let path = dir.join(name);
        p.save_npz(&path).unwrap();
        path
    }

    #[test]
    fn register_both_formats() {
        let dir = std::env::temp_dir().join("compeft_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let npz = tv_npz(&dir, "taskA.lora.npz");
        let mut reg = Registry::new();
        reg.register_original("a/orig", "taskA", "s", ExpertMethod::Lora, &npz).unwrap();
        reg.register_compeft(
            "a/comp",
            "taskA",
            "s",
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, ..Default::default() },
        )
        .unwrap();
        let orig = reg.get("a/orig").unwrap();
        let comp = reg.get("a/comp").unwrap();
        assert_eq!(orig.encoded_bytes, 1024); // 512 * 2 bytes
        assert!(
            comp.encoded_bytes < orig.encoded_bytes / 4,
            "compressed {} vs orig {}",
            comp.encoded_bytes,
            orig.encoded_bytes
        );
        assert!(comp.path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scan_parses_names() {
        let dir = std::env::temp_dir().join("compeft_scan_test/experts/s");
        std::fs::create_dir_all(&dir).unwrap();
        tv_npz(&dir, "alpha.lora.npz");
        tv_npz(&dir, "beta.ia3.npz");
        tv_npz(&dir, "gamma.lora.r4.npz"); // rank variant: skipped by scan
        let root = std::env::temp_dir().join("compeft_scan_test");
        let found = scan_expert_npz(&root, "s").unwrap();
        assert_eq!(found.len(), 2);
        assert_eq!(found[0].0, "alpha");
        assert_eq!(found[1].1, ExpertMethod::Ia3);
        std::fs::remove_dir_all(&root).ok();
    }
}
