//! Expert catalog: which experts exist, in which formats, at what
//! encoded sizes. Built by scanning the artifact tree (or registered
//! programmatically by benches).
//!
//! Besides stored experts, the catalog records **compositions**
//! ([`CompositionRecord`]): virtual experts defined as a merge of
//! member experts (TIES, averaging, task arithmetic, or learned
//! LoraHub weights — [`MergeMethod`]). A composition has no checkpoint
//! of its own; the serving engine materializes it on demand by pulling
//! the members' `.cpeft` payloads through the host tier and merging
//! them ternary-domain (never densifying the members), then caches the
//! result in the accelerator tier like any stored expert.

use crate::compeft::compress::{compress_params, CompressConfig};
use crate::compeft::format::{self, Encoding};
use crate::merging::MergeMethod;
use crate::tensor::ParamSet;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};

/// How an expert checkpoint is stored on "disk"/remote.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertFormat {
    /// Dense task vector at 16-bit accounting (the paper's baseline).
    OriginalFp16,
    /// ComPEFT `.cpeft` (Golomb-coded).
    Compeft,
}

/// Adapter family of the expert.
///
/// `Ord` so catalog listings (and [`scan_expert_npz`]) can sort on
/// `(task, method)` deterministically.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ExpertMethod {
    Lora,
    Ia3,
    Full,
}

impl ExpertMethod {
    pub fn parse(s: &str) -> Option<ExpertMethod> {
        match s {
            "lora" => Some(ExpertMethod::Lora),
            "ia3" => Some(ExpertMethod::Ia3),
            "full" => Some(ExpertMethod::Full),
            _ => None,
        }
    }
}

/// One registered expert.
#[derive(Clone, Debug)]
pub struct ExpertRecord {
    pub id: String,
    pub task: String,
    pub scale: String,
    pub method: ExpertMethod,
    pub format: ExpertFormat,
    /// Path of the stored checkpoint (npz task vector or .cpeft).
    pub path: PathBuf,
    /// Bytes that move when this expert is fetched.
    pub encoded_bytes: u64,
    /// Dense parameter count of the task vector.
    pub n_params: usize,
}

/// A merged (virtual) expert: member expert ids + how to combine them.
///
/// Members must be `.cpeft`-stored experts of one adapter family with
/// identical parameter counts; the merge itself runs ternary-domain in
/// the loader, so registration is metadata-only.
#[derive(Clone, Debug)]
pub struct CompositionRecord {
    pub id: String,
    /// Ids of the member experts, in merge order (merge methods are
    /// order-sensitive only in float rounding, but the order is part of
    /// the record so repeated materializations are identical).
    pub members: Vec<String>,
    /// Merge method + hyper-parameters.
    pub merge: MergeMethod,
    /// Adapter family shared by every member.
    pub method: ExpertMethod,
    /// Dense parameter count (equal across members).
    pub n_params: usize,
}

/// Version chain for one expert id: newer versions are registered as
/// `"{id}@v{n}"` alias records ([`version_key`]), and `current` is the
/// version admission pins new batches to. `current` is atomic so the
/// serving engine can activate a pushed version through a shared
/// `Arc<Registry>` without a lock: in-flight batches keep the version
/// string they resolved at admission, so a flip mid-trace never mixes
/// versions inside one batch.
#[derive(Debug)]
struct VersionChain {
    /// Highest registered version (`0` = the base record under `id`).
    latest: u32,
    /// Currently admitted version; bumped by [`Registry::activate_next`].
    current: AtomicU32,
}

/// Catalog key of version `v` of expert `id` (`v ≥ 1`; version 0 is the
/// base record under the bare id).
pub fn version_key(id: &str, v: u32) -> String {
    format!("{id}@v{v}")
}

/// Split a version alias key back into `(base id, version)`; `None` for
/// bare (unversioned) ids.
pub fn split_version_key(id: &str) -> Option<(&str, u32)> {
    let (base, v) = id.rsplit_once("@v")?;
    if base.is_empty() {
        return None;
    }
    let n: u32 = v.parse().ok()?;
    Some((base, n))
}

/// The expert catalog.
#[derive(Default, Debug)]
pub struct Registry {
    experts: BTreeMap<String, ExpertRecord>,
    compositions: BTreeMap<String, CompositionRecord>,
    versions: BTreeMap<String, VersionChain>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Insert a stored-expert record, running the same id validation as
    /// the registering entry points: an id colliding with a live
    /// composition is rejected. (The raw insert used to bypass
    /// `ensure_id_free_of_compositions` entirely — serving routes
    /// stored experts before compositions, so a raw insert could
    /// silently shadow a registered merged expert, the exact hazard the
    /// checked paths guard against.) Re-inserting an existing *expert*
    /// id stays allowed and replaces the record (re-registration after
    /// recompression).
    pub fn insert(&mut self, rec: ExpertRecord) -> Result<()> {
        self.ensure_id_free_of_compositions(&rec.id)?;
        self.experts.insert(rec.id.clone(), rec);
        Ok(())
    }

    /// Serving routes stored experts before compositions, so an expert
    /// registered under an existing composition's id would silently
    /// shadow it; both checked registration paths reject that.
    fn ensure_id_free_of_compositions(&self, id: &str) -> Result<()> {
        if self.compositions.contains_key(id) {
            bail!("expert id {id:?} collides with a registered composition");
        }
        Ok(())
    }

    pub fn get(&self, id: &str) -> Option<&ExpertRecord> {
        self.experts.get(id)
    }

    pub fn ids(&self) -> Vec<String> {
        self.experts.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.experts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.experts.is_empty()
    }

    /// Register a merged expert: `id` serves the [`MergeMethod`]
    /// combination of `members`, materialized ternary-domain on demand.
    ///
    /// Validates that the id is free, every member exists as a `.cpeft`
    /// expert, members share one adapter family and parameter count,
    /// and (for [`MergeMethod::Weighted`]) the weight count matches.
    pub fn register_composition(
        &mut self,
        id: &str,
        members: &[&str],
        merge: MergeMethod,
    ) -> Result<&CompositionRecord> {
        if self.experts.contains_key(id) {
            bail!("composition id {id:?} collides with a stored expert");
        }
        if members.is_empty() {
            bail!("composition {id:?} has no members");
        }
        let mut method: Option<ExpertMethod> = None;
        let mut n_params: Option<usize> = None;
        for m in members {
            let rec = match self.experts.get(*m) {
                Some(r) => r,
                None => bail!("composition {id:?}: unknown member expert {m:?}"),
            };
            if rec.format != ExpertFormat::Compeft {
                bail!(
                    "composition {id:?}: member {m:?} is not `.cpeft`-stored — \
                     ternary-domain merging needs compressed members"
                );
            }
            match method {
                None => method = Some(rec.method),
                Some(k) if k != rec.method => bail!(
                    "composition {id:?}: members mix adapter families \
                     ({k:?} vs {:?} for {m:?})",
                    rec.method
                ),
                _ => {}
            }
            match n_params {
                None => n_params = Some(rec.n_params),
                Some(n) if n != rec.n_params => bail!(
                    "composition {id:?}: member {m:?} has {} params, \
                     others have {n}",
                    rec.n_params
                ),
                _ => {}
            }
        }
        if let MergeMethod::Weighted { weights } = &merge {
            if weights.len() != members.len() {
                bail!(
                    "composition {id:?}: {} members but {} weights",
                    members.len(),
                    weights.len()
                );
            }
        }
        if let MergeMethod::Ties { density, .. } = &merge {
            if !(*density > 0.0 && *density <= 1.0) {
                bail!(
                    "composition {id:?}: TIES density must be in (0,1], \
                     got {density}"
                );
            }
        }
        let rec = CompositionRecord {
            id: id.to_string(),
            members: members.iter().map(|m| m.to_string()).collect(),
            merge,
            method: method.expect("members non-empty"),
            n_params: n_params.expect("members non-empty"),
        };
        self.compositions.insert(id.to_string(), rec);
        Ok(self.compositions.get(id).unwrap())
    }

    /// Look up a composition record by id.
    pub fn composition(&self, id: &str) -> Option<&CompositionRecord> {
        self.compositions.get(id)
    }

    /// Ids of all registered compositions.
    pub fn composition_ids(&self) -> Vec<String> {
        self.compositions.keys().cloned().collect()
    }

    /// Register the next version of an existing expert. The record is
    /// stored under the alias key [`version_key`]`(id, n)` and does
    /// **not** start serving: admission keeps resolving the previous
    /// version until [`Registry::activate_next`] flips the pin. Returns
    /// the new version number.
    pub fn register_version(&mut self, id: &str, mut rec: ExpertRecord) -> Result<u32> {
        if id.contains("@v") {
            bail!("register versions against the base id, not alias {id:?}");
        }
        if !self.experts.contains_key(id) {
            bail!("cannot register a version of unknown expert {id:?}");
        }
        let next = self.versions.get(id).map(|c| c.latest + 1).unwrap_or(1);
        let key = version_key(id, next);
        self.ensure_id_free_of_compositions(&key)?;
        rec.id = key.clone();
        self.experts.insert(key, rec);
        match self.versions.get_mut(id) {
            Some(c) => c.latest = next,
            None => {
                self.versions.insert(
                    id.to_string(),
                    VersionChain { latest: next, current: AtomicU32::new(0) },
                );
            }
        }
        Ok(next)
    }

    /// Currently admitted version of `id` (0 = the base record).
    pub fn current_version(&self, id: &str) -> u32 {
        self.versions
            .get(id)
            .map(|c| c.current.load(Ordering::Acquire))
            .unwrap_or(0)
    }

    /// Highest registered version of `id` (0 = no versions pushed).
    pub fn latest_version(&self, id: &str) -> u32 {
        self.versions.get(id).map(|c| c.latest).unwrap_or(0)
    }

    /// Resolve the catalog key admission should pin a new batch of `id`
    /// to: the bare id until a pushed version is activated, then the
    /// [`version_key`] alias of the admitted version. In-flight batches
    /// hold on to the string this returned when *they* were admitted,
    /// which is the whole version-pinning story.
    pub fn pin(&self, id: &str) -> String {
        match self.current_version(id) {
            0 => id.to_string(),
            v => version_key(id, v),
        }
    }

    /// Flip admission to the next registered version of `id`, if one is
    /// staged beyond the current pin. Takes `&self` — the engine calls
    /// this through its shared `Arc<Registry>`; only the admitting
    /// thread activates, so a plain load/store pair suffices. Returns
    /// the newly admitted version, or `None` when already current.
    pub fn activate_next(&self, id: &str) -> Option<u32> {
        let c = self.versions.get(id)?;
        let cur = c.current.load(Ordering::Acquire);
        if cur >= c.latest {
            return None;
        }
        c.current.store(cur + 1, Ordering::Release);
        Some(cur + 1)
    }

    /// Compress a new task-vector npz as the next version of stored
    /// expert `id`: writes `{npz stem}.v{n}.cpeft` next to it and
    /// registers the alias record (staged — serving stays on the
    /// current pin until [`Registry::activate_next`]). Returns the new
    /// version number.
    pub fn register_compeft_version(
        &mut self,
        id: &str,
        npz_path: &Path,
        cfg: &CompressConfig,
    ) -> Result<u32> {
        let base = match self.experts.get(id) {
            Some(r) => r.clone(),
            None => bail!("cannot register a version of unknown expert {id:?}"),
        };
        if base.format != ExpertFormat::Compeft {
            bail!(
                "versioned updates need a `.cpeft` base; {id:?} is stored as {:?}",
                base.format
            );
        }
        let tv = ParamSet::load_npz(npz_path)
            .with_context(|| format!("load {}", npz_path.display()))?;
        if tv.total_elements() != base.n_params {
            bail!(
                "version of {id:?} has {} params, base has {}",
                tv.total_elements(),
                base.n_params
            );
        }
        let next = self.latest_version(id) + 1;
        let compressed = compress_params(&tv, cfg);
        let out = npz_path.with_extension(format!("v{next}.cpeft"));
        let bytes = format::save(&out, &compressed, Encoding::Golomb)?;
        self.register_version(
            id,
            ExpertRecord {
                id: String::new(), // overwritten with the alias key
                task: base.task,
                scale: base.scale,
                method: base.method,
                format: ExpertFormat::Compeft,
                path: out,
                encoded_bytes: bytes,
                n_params: base.n_params,
            },
        )
    }

    /// Register the original (fp16-accounted) form of a task-vector npz.
    pub fn register_original(
        &mut self,
        id: &str,
        task: &str,
        scale: &str,
        method: ExpertMethod,
        npz_path: &Path,
    ) -> Result<&ExpertRecord> {
        self.ensure_id_free_of_compositions(id)?;
        let tv = ParamSet::load_npz(npz_path)
            .with_context(|| format!("load {}", npz_path.display()))?;
        let rec = ExpertRecord {
            id: id.to_string(),
            task: task.to_string(),
            scale: scale.to_string(),
            method,
            format: ExpertFormat::OriginalFp16,
            path: npz_path.to_path_buf(),
            encoded_bytes: tv.bytes_fp16(),
            n_params: tv.total_elements(),
        };
        self.insert(rec)?;
        Ok(self.get(id).unwrap())
    }

    /// Compress a task-vector npz with ComPEFT, write the `.cpeft` next
    /// to it, and register the compressed form.
    pub fn register_compeft(
        &mut self,
        id: &str,
        task: &str,
        scale: &str,
        method: ExpertMethod,
        npz_path: &Path,
        cfg: &CompressConfig,
    ) -> Result<&ExpertRecord> {
        self.ensure_id_free_of_compositions(id)?;
        let tv = ParamSet::load_npz(npz_path)?;
        let compressed = compress_params(&tv, cfg);
        let out = npz_path.with_extension("cpeft");
        let bytes = format::save(&out, &compressed, Encoding::Golomb)?;
        let rec = ExpertRecord {
            id: id.to_string(),
            task: task.to_string(),
            scale: scale.to_string(),
            method,
            format: ExpertFormat::Compeft,
            path: out,
            encoded_bytes: bytes,
            n_params: tv.total_elements(),
        };
        self.insert(rec)?;
        Ok(self.get(id).unwrap())
    }

    /// Placement record of the catalog: which store nodes hold each
    /// stored expert under `placement`, in id order. The serving setup
    /// prints this so operators can see the shard layout; tests assert
    /// it is a pure function of the catalog + placement.
    pub fn assignments(
        &self,
        placement: &crate::coordinator::store::Placement,
    ) -> Vec<(String, Vec<crate::coordinator::store::NodeId>)> {
        self.experts
            .keys()
            .map(|id| (id.clone(), placement.nodes_for(id)))
            .collect()
    }
}

/// Scan `artifacts/experts/{scale}` for `{task}.{method}.npz` task
/// vectors; returns (task, method, path) triples sorted on
/// `(task, method)` — fully deterministic even when one task ships
/// several adapter families (sorting on task alone left the
/// intra-task order up to the directory iterator).
pub fn scan_expert_npz(artifacts: &Path, scale: &str) -> Result<Vec<(String, ExpertMethod, PathBuf)>> {
    let dir = artifacts.join("experts").join(scale);
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(&dir)? {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if !name.ends_with(".npz") {
            continue;
        }
        let stem = name.trim_end_matches(".npz");
        let parts: Vec<&str> = stem.split('.').collect();
        if parts.len() < 2 {
            continue;
        }
        // {task}.{method}[.r{rank}]
        if let Some(m) = ExpertMethod::parse(parts[1]) {
            if parts.len() == 2 {
                out.push((parts[0].to_string(), m, path.clone()));
            }
        }
    }
    out.sort_by(|a, b| (&a.0, a.1).cmp(&(&b.0, b.1)));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn tv_npz(dir: &Path, name: &str) -> PathBuf {
        let mut rng = Pcg::seed(33);
        let mut p = ParamSet::new();
        p.insert("w", Tensor::new(vec![512], prop::task_vector_like(&mut rng, 512)));
        let path = dir.join(name);
        p.save_npz(&path).unwrap();
        path
    }

    #[test]
    fn register_both_formats() {
        let dir = std::env::temp_dir().join("compeft_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let npz = tv_npz(&dir, "taskA.lora.npz");
        let mut reg = Registry::new();
        reg.register_original("a/orig", "taskA", "s", ExpertMethod::Lora, &npz).unwrap();
        reg.register_compeft(
            "a/comp",
            "taskA",
            "s",
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, ..Default::default() },
        )
        .unwrap();
        let orig = reg.get("a/orig").unwrap();
        let comp = reg.get("a/comp").unwrap();
        assert_eq!(orig.encoded_bytes, 1024); // 512 * 2 bytes
        assert!(
            comp.encoded_bytes < orig.encoded_bytes / 4,
            "compressed {} vs orig {}",
            comp.encoded_bytes,
            orig.encoded_bytes
        );
        assert!(comp.path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn composition_registration_and_validation() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_comp_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let npz = tv_npz(&dir, "taskA.lora.npz");
        let mut reg = Registry::new();
        let cfg = CompressConfig { density: 0.2, ..Default::default() };
        reg.register_compeft("e1", "a", "s", ExpertMethod::Lora, &npz, &cfg).unwrap();
        reg.register_compeft("e2", "a", "s", ExpertMethod::Lora, &npz, &cfg).unwrap();
        reg.register_original("dense", "a", "s", ExpertMethod::Lora, &npz).unwrap();

        let rec = reg
            .register_composition("m/avg", &["e1", "e2"], MergeMethod::Average)
            .unwrap();
        assert_eq!(rec.members, vec!["e1", "e2"]);
        assert_eq!(rec.method, ExpertMethod::Lora);
        assert_eq!(rec.n_params, 512);
        assert!(reg.composition("m/avg").is_some());
        assert_eq!(reg.composition_ids(), vec!["m/avg".to_string()]);

        // Weighted must match the member count; TIES density validated.
        assert!(reg
            .register_composition(
                "m/w",
                &["e1", "e2"],
                MergeMethod::Weighted { weights: vec![1.0] }
            )
            .is_err());
        assert!(reg
            .register_composition(
                "m/t",
                &["e1", "e2"],
                MergeMethod::Ties { density: 0.0, lambda: 1.0 }
            )
            .is_err());
        // Unknown member, empty members, non-cpeft member, id collision.
        assert!(reg
            .register_composition("m/x", &["nope"], MergeMethod::Average)
            .is_err());
        assert!(reg.register_composition("m/e", &[], MergeMethod::Average).is_err());
        assert!(reg
            .register_composition("m/d", &["e1", "dense"], MergeMethod::Average)
            .is_err());
        assert!(reg
            .register_composition("e1", &["e2"], MergeMethod::Average)
            .is_err());
        // Reverse collision: a stored expert may not take a live
        // composition's id (serving would shadow the merged expert).
        assert!(reg
            .register_compeft("m/avg", "a", "s", ExpertMethod::Lora, &npz, &cfg)
            .is_err());
        assert!(reg
            .register_original("m/avg", "a", "s", ExpertMethod::Lora, &npz)
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression: the raw `insert` used to bypass
    /// `ensure_id_free_of_compositions`, so it could silently shadow a
    /// registered composition (serving routes stored experts first).
    /// It must now run the same validation as the checked paths, while
    /// still allowing same-kind re-registration.
    #[test]
    fn raw_insert_cannot_shadow_a_composition() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_raw_insert_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let npz = tv_npz(&dir, "taskA.lora.npz");
        let mut reg = Registry::new();
        let cfg = CompressConfig { density: 0.2, ..Default::default() };
        reg.register_compeft("e1", "a", "s", ExpertMethod::Lora, &npz, &cfg).unwrap();
        reg.register_compeft("e2", "a", "s", ExpertMethod::Lora, &npz, &cfg).unwrap();
        reg.register_composition("m/avg", &["e1", "e2"], MergeMethod::Average).unwrap();

        let raw = |id: &str| ExpertRecord {
            id: id.to_string(),
            task: "a".into(),
            scale: "s".into(),
            method: ExpertMethod::Lora,
            format: ExpertFormat::OriginalFp16,
            path: npz.clone(),
            encoded_bytes: 1024,
            n_params: 512,
        };
        // Shadowing the live composition is rejected...
        let err = reg.insert(raw("m/avg")).unwrap_err().to_string();
        assert!(err.contains("collides"), "{err}");
        assert!(reg.get("m/avg").is_none(), "rejected insert must not land");
        assert!(reg.composition("m/avg").is_some(), "composition untouched");
        // ...while fresh ids and expert re-registration stay allowed.
        reg.insert(raw("fresh")).unwrap();
        assert!(reg.get("fresh").is_some());
        reg.insert(raw("e1")).unwrap(); // replace after recompression
        assert_eq!(reg.get("e1").unwrap().format, ExpertFormat::OriginalFp16);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Placement assignments are a deterministic record of the shard
    /// layout: id order follows the catalog, node sets follow the
    /// placement, and recomputing yields the same answer.
    #[test]
    fn assignments_record_shard_layout() {
        use crate::coordinator::store::Placement;
        let dir = std::env::temp_dir()
            .join(format!("compeft_reg_assign_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let npz = tv_npz(&dir, "taskA.lora.npz");
        let mut reg = Registry::new();
        for id in ["b", "a", "c"] {
            reg.register_original(id, "t", "s", ExpertMethod::Lora, &npz).unwrap();
        }
        let p = Placement::new(4, 2, 3);
        let got = reg.assignments(&p);
        assert_eq!(
            got.iter().map(|(id, _)| id.as_str()).collect::<Vec<_>>(),
            vec!["a", "b", "c"],
            "catalog order"
        );
        for (id, nodes) in &got {
            assert_eq!(nodes, &p.nodes_for(id));
            assert_eq!(nodes.len(), 2);
        }
        assert_eq!(got, reg.assignments(&p), "pure function");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Version chains: pushed versions stage under `id@v{n}` aliases,
    /// admission pins stay on the current version until an explicit
    /// activate, and activation works through a shared reference.
    #[test]
    fn version_chain_pins_and_activates() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_reg_versions_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let npz = tv_npz(&dir, "taskA.lora.npz");
        let mut reg = Registry::new();
        let cfg = CompressConfig { density: 0.2, ..Default::default() };
        reg.register_compeft("e", "a", "s", ExpertMethod::Lora, &npz, &cfg).unwrap();

        // No versions pushed: the pin is the bare id.
        assert_eq!(reg.pin("e"), "e");
        assert_eq!(reg.current_version("e"), 0);
        assert!(reg.activate_next("e").is_none());

        // Stage two versions; serving stays pinned to v0 until told.
        let v1 = reg.register_compeft_version("e", &npz, &cfg).unwrap();
        let v2 = reg.register_compeft_version("e", &npz, &cfg).unwrap();
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(reg.latest_version("e"), 2);
        assert_eq!(reg.pin("e"), "e", "staging must not move the pin");
        assert!(reg.get("e@v1").is_some());
        assert!(reg.get("e@v2").is_some());
        assert!(reg.get("e@v2").unwrap().path.exists());

        // Activate through a shared reference, one step at a time.
        let shared = std::sync::Arc::new(reg);
        assert_eq!(shared.activate_next("e"), Some(1));
        assert_eq!(shared.pin("e"), version_key("e", 1));
        assert_eq!(shared.activate_next("e"), Some(2));
        assert_eq!(shared.pin("e"), "e@v2");
        assert!(shared.activate_next("e").is_none(), "already current");

        // Guard rails: unknown base, alias base, non-cpeft base.
        let mut reg = std::sync::Arc::try_unwrap(shared).unwrap();
        assert!(reg.register_compeft_version("nope", &npz, &cfg).is_err());
        assert!(reg
            .register_version(
                "e@v1",
                reg.get("e").unwrap().clone(),
            )
            .is_err());
        reg.register_original("dense", "a", "s", ExpertMethod::Lora, &npz).unwrap();
        assert!(reg.register_compeft_version("dense", &npz, &cfg).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Two adapter families of one task must come back in a fixed
    /// order: the scan sorts on (task, method), not task alone.
    #[test]
    fn scan_orders_methods_within_a_task() {
        let root = std::env::temp_dir()
            .join(format!("compeft_scan_methods_{}", std::process::id()));
        let dir = root.join("experts/s");
        std::fs::create_dir_all(&dir).unwrap();
        // Same task, two methods — written ia3-first to catch an
        // iterator-order-dependent scan.
        tv_npz(&dir, "alpha.ia3.npz");
        tv_npz(&dir, "alpha.lora.npz");
        tv_npz(&dir, "beta.full.npz");
        let found = scan_expert_npz(&root, "s").unwrap();
        let keys: Vec<(String, ExpertMethod)> =
            found.iter().map(|(t, m, _)| (t.clone(), *m)).collect();
        assert_eq!(
            keys,
            vec![
                ("alpha".to_string(), ExpertMethod::Lora),
                ("alpha".to_string(), ExpertMethod::Ia3),
                ("beta".to_string(), ExpertMethod::Full),
            ],
            "(task, method) order is fixed by the enum, not the dirent order"
        );
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn scan_parses_names() {
        let dir = std::env::temp_dir().join("compeft_scan_test/experts/s");
        std::fs::create_dir_all(&dir).unwrap();
        tv_npz(&dir, "alpha.lora.npz");
        tv_npz(&dir, "beta.ia3.npz");
        tv_npz(&dir, "gamma.lora.r4.npz"); // rank variant: skipped by scan
        let root = std::env::temp_dir().join("compeft_scan_test");
        let found = scan_expert_npz(&root, "s").unwrap();
        assert_eq!(found.len(), 2);
        assert_eq!(found[0].0, "alpha");
        assert_eq!(found[1].1, ExpertMethod::Ia3);
        std::fs::remove_dir_all(&root).ok();
    }
}
