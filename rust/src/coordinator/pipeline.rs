//! Prefetch-and-stage pipeline: overlap expert fetch/decode with batch
//! execution.
//!
//! The blocking serving loop paid the full swap on the critical path:
//! net fetch → decode → PCIe upload, serially, on the engine thread —
//! even when the batcher's queues made the next expert perfectly
//! predictable. This module splits a swap into its three stages (see
//! `loader.rs`) and runs the first two *ahead of time* on background
//! threads:
//!
//! ```text
//!                 engine thread            prefetch threads
//!                 ─────────────            ────────────────
//!   batch N       execute ───────────┐     fetch(N+1) → decode(N+1)
//!                                    │     fetch(N+2) → decode(N+2)
//!   batch N+1     take(N+1) ✓ upload ┘     ...
//! ```
//!
//! * [`PrepareContext`] — runs stages 1–2 (fetch via the shared host
//!   tier, decode/merge, materialize) for a stored *or composed* expert
//!   id, producing a [`PreparedExpert`]. Thread-agnostic: the engine
//!   uses it as the blocking fallback, the prefetcher from background
//!   threads.
//! * [`StagingArea`] — byte-budgeted slot map of decoded-and-ready
//!   experts between the prefetch threads and the engine.
//! * [`Prefetcher`] — background workers that watch the batcher's
//!   [`plan`](crate::coordinator::batcher::Batcher::plan) lookahead and
//!   keep the staging slots warm.
//!
//! Within stage 1–2 there is a second, finer overlap: when the expert
//! is a remote store-backed `.cpeft` checkpoint, the prepare runs the
//! **fused** fetch→decode
//! ([`ExpertLoader::fetch_decode_fused`](crate::coordinator::loader::ExpertLoader::fetch_decode_fused))
//! — Golomb frames decode as their stripes land, so the staged cost is
//! ≈ `max(fetch, decode)` rather than their sum, and the hidden time
//! lands in the `decode_overlap_us` metric. Host-tier and archive hits
//! skip it (their fetch is free; nothing to overlap).
//!
//! Every stage is deterministic (decode and merge are bit-identical at
//! any pool size), so prefetching changes *when* work happens, never
//! what is served: predictions are identical with the prefetcher on or
//! off, at any `prefetch_depth` and any decode-worker count — enforced
//! by the equivalence suites here and in `tests/integration.rs`.

use crate::compeft::payload::Payload;
use crate::coordinator::archive::ArchiveTier;
use crate::coordinator::cache::LruTier;
use crate::coordinator::loader::ExpertLoader;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::{
    CompositionRecord, ExpertMethod, ExpertRecord, Registry,
};
use crate::tensor::ParamSet;
use crate::util::sync::{rank, OrderedMutex};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

/// Adapter-init templates for each expert method, `Arc`-shared with the
/// model bundle's host-side parameter sets so the decode stage never
/// needs the (engine-thread-only) runtime objects — and never copies
/// the base model.
#[derive(Clone)]
pub struct Templates {
    /// Base parameters (template + init for `Full` experts).
    pub base: Arc<ParamSet>,
    pub lora_init: Arc<ParamSet>,
    pub ia3_init: Arc<ParamSet>,
}

impl Templates {
    /// Template/init for one expert method (fixes names and shapes for
    /// decode, and is the init the task vector is added onto).
    pub fn for_method(&self, method: ExpertMethod) -> &ParamSet {
        match method {
            ExpertMethod::Lora => &*self.lora_init,
            ExpertMethod::Ia3 => &*self.ia3_init,
            ExpertMethod::Full => &*self.base,
        }
    }
}

/// A decoded-and-ready expert: everything a swap needs except the
/// engine-thread-only upload hop (PjRt buffers are not `Send`).
pub struct PreparedExpert {
    pub id: String,
    pub method: ExpertMethod,
    /// Fully materialized host-side parameters: adapter init + task
    /// vector (adapter families), or base + task vector (`Full`).
    pub params: ParamSet,
    /// What the fetch+decode stages would have cost on the engine
    /// critical path (simulated fetch + real decode/merge time) — the
    /// time a staging hit removes from the swap.
    pub staged_sim: Duration,
    /// Bytes the upload stage moves over PCIe: the encoded checkpoint
    /// for stored experts (decode-on-device model, paper §2.2), the
    /// dense fp16 update for merged experts (no compact wire form).
    pub upload_bytes: u64,
    /// fp16 accounting of the device-resident form (GPU-tier charge).
    pub dense_bytes: u64,
}

/// Shared inputs of the fetch+decode stages: loader (links + decode
/// pool), expert catalog, adapter templates, and the host (CPU) tier
/// for encoded bytes — everything is `Sync`, so one context serves the
/// engine thread and every prefetch thread.
///
/// The fetch stage targets whatever the loader is wired to: the flat
/// `net` link, or — when the coordinator runs a sharded store
/// ([`crate::coordinator::store::ExpertStore`]) — the striped
/// multi-replica fetch with CRC-verified failover. Either way the
/// fetched bytes are identical, so everything staged downstream is too.
pub struct PrepareContext {
    pub loader: ExpertLoader,
    pub registry: Arc<Registry>,
    pub templates: Templates,
    /// Host tier of encoded checkpoint bytes, shared across threads.
    /// Entries are zero-copy [`Payload`] views, so a tier hit hands
    /// out the payload without copying a byte under the lock — and
    /// since a view keeps its backing alive, eviction can never touch
    /// data a decode is reading. Entries are additionally pinned while
    /// a decode is in flight, keeping the bytes tier-resident (no
    /// refetch) until the decode completes.
    pub cpu: Arc<OrderedMutex<LruTier<Payload>>>,
    /// Optional local archive tier, consulted between the host tier
    /// and the remote fetch (GPU ⊃ host ⊃ archive ⊃ remote). An
    /// archive hit is a borrowed view of the resident file image:
    /// free in the link model, zero heap copies, and **not** inserted
    /// into the host tier (the bytes are already local-resident; a
    /// second copy would double-charge the host budget), so it needs
    /// no pin either — the view itself keeps the archive image alive.
    pub archive: Option<Arc<ArchiveTier>>,
}

impl PrepareContext {
    /// Run the fetch+decode stages for `id` (stored or composed),
    /// producing a [`PreparedExpert`]. Deterministic: the result is
    /// bit-identical no matter which thread runs it or how large the
    /// decode pool is.
    pub fn prepare(&self, id: &str) -> Result<PreparedExpert> {
        if let Some(rec) = self.registry.get(id) {
            self.prepare_stored(rec)
        } else if let Some(comp) = self.registry.composition(id) {
            self.prepare_composed(comp)
        } else {
            Err(anyhow!("unknown expert {id:?}"))
        }
    }

    /// Fetch an expert's encoded bytes through the cache hierarchy:
    /// host tier, then the local archive, then the remote fetch (which
    /// charges the net/store links). The payload comes back as a
    /// zero-copy [`Payload`] view — a tier hit clones the view (not
    /// the bytes), an archive hit borrows the resident file image, and
    /// a remote fetch shares the one materialized buffer. For
    /// tier-resident entries the returned [`PinGuard`] keeps the entry
    /// resident until dropped — even if the caller's decode panics
    /// (the guard unpins on unwind). Archive hits need no pin: the
    /// view itself keeps the archive image alive.
    fn fetch_via_cpu_tier<'a>(
        &'a self,
        rec: &ExpertRecord,
    ) -> Result<(Payload, Duration, Option<PinGuard<'a>>)> {
        {
            let mut cpu = self.cpu.lock().unwrap();
            if let Some(b) = cpu.get(&rec.id) {
                let bytes = b.clone();
                cpu.pin(&rec.id);
                return Ok((
                    bytes,
                    Duration::ZERO,
                    Some(PinGuard::new(&self.cpu, &rec.id)),
                ));
            }
        }
        // Archive tier: local-resident, free in the link model, and a
        // corrupt/absent member falls through to the remote path.
        if let Some(archive) = &self.archive {
            if let Some(view) = archive.get(&rec.id) {
                return Ok((view, Duration::ZERO, None));
            }
        }
        // The net transfer runs outside the tier lock so concurrent
        // prepares serialize on the link (one NIC), not on the tier.
        // Two prepares racing on the same id (an expert that is both
        // served directly and a composition member) may thus both pay
        // the fetch — ordinary link contention — but the tier insert
        // must be idempotent: replacing the entry another thread just
        // inserted would strip its pins (LruTier replacement resets the
        // pin count) and void the stays-resident-mid-decode guarantee.
        let (bytes, fetch) = self.loader.fetch_encoded(rec)?;
        let mut cpu = self.cpu.lock().unwrap();
        if !cpu.contains(&rec.id) {
            cpu.insert(&rec.id, bytes.clone(), rec.encoded_bytes.max(1));
        }
        cpu.pin(&rec.id);
        drop(cpu);
        Ok((bytes, fetch, Some(PinGuard::new(&self.cpu, &rec.id))))
    }

    fn prepare_stored(&self, rec: &ExpertRecord) -> Result<PreparedExpert> {
        if let Some(prepared) = self.prepare_stored_delta(rec)? {
            return Ok(prepared);
        }
        if let Some(prepared) = self.prepare_stored_fused(rec)? {
            return Ok(prepared);
        }
        let (bytes, fetch, pin) = self.fetch_via_cpu_tier(rec)?;
        let template = self.templates.for_method(rec.method);
        // The encoded bytes stay pinned in the host tier while this
        // decode runs: a concurrent prefetch insert cannot push them
        // out and force upcoming users of the same expert to refetch.
        let (tv, decode) = self.loader.decode(rec, bytes.as_slice(), template)?;
        drop(pin);
        let params = self.loader.materialize(rec.method, template, &tv)?;
        Ok(PreparedExpert {
            id: rec.id.clone(),
            method: rec.method,
            staged_sim: fetch + decode,
            upload_bytes: rec.encoded_bytes,
            dense_bytes: params.bytes_fp16(),
            params,
        })
    }

    /// Delta fast path for versioned experts. When `rec` is a version
    /// alias (`"id@vN"`, see
    /// [`crate::coordinator::registry::version_key`]) whose *previous*
    /// version's encoded payload is host-tier resident and a `.cpeftd`
    /// delta container sits next to the record's `.cpeft`, ship the
    /// delta instead of the full checkpoint: parse the resident v(N−1)
    /// bytes to ternary form, apply the delta in the ternary domain
    /// ([`ExpertLoader::apply_delta`] — counted as `delta_applies` /
    /// `delta_bytes_saved`), and re-encode for the host tier. The
    /// reconstruction is bit-identical to the full `.cpeft` on disk
    /// (`apply_delta` is exact set algebra and the encoder is
    /// deterministic), so everything staged downstream — host-tier
    /// bytes, dense params, predictions — is byte-for-byte what the
    /// full-fetch path produces; only the wire bytes shipped change.
    /// Returns `Ok(None)` whenever the fast path does not apply (bare
    /// id, no delta file, previous version not resident, target already
    /// host-tier resident) and the caller falls through unchanged.
    fn prepare_stored_delta(&self, rec: &ExpertRecord) -> Result<Option<PreparedExpert>> {
        use crate::coordinator::registry::{split_version_key, version_key};

        let Some((base, v)) = split_version_key(&rec.id) else {
            return Ok(None);
        };
        let delta_path = rec.path.with_extension("cpeftd");
        if !delta_path.exists() {
            return Ok(None);
        }
        if self.cpu.lock().unwrap().contains(&rec.id) {
            return Ok(None); // already resident: the tier hit is free
        }
        let prev_key = if v <= 1 { base.to_string() } else { version_key(base, v - 1) };
        let Some(prev) = self.registry.get(&prev_key) else {
            return Ok(None);
        };
        use crate::coordinator::registry::ExpertFormat;
        if rec.format != ExpertFormat::Compeft || prev.format != ExpertFormat::Compeft {
            return Ok(None); // deltas exist only in the ternary domain
        }
        // The previous version's bytes must already be local; otherwise
        // a delta saves nothing over fetching the full new version.
        let (prev_bytes, pin) = {
            let mut cpu = self.cpu.lock().unwrap();
            match cpu.get(&prev_key) {
                Some(b) => {
                    let bytes = b.clone();
                    cpu.pin(&prev_key);
                    (bytes, PinGuard::new(&self.cpu, &prev_key))
                }
                None => return Ok(None),
            }
        };
        let (prev_c, parse_prev) =
            self.loader.decode_compressed(prev, prev_bytes.as_slice())?;
        drop(pin);
        let delta_bytes = std::fs::read(&delta_path)
            .map_err(|e| anyhow!("read delta {}: {e}", delta_path.display()))?;
        // One heap materialization off disk, like the flat fetch path.
        self.loader.meter().record(1);
        let (next_c, apply) =
            self.loader.apply_delta(&prev_c, &delta_bytes, rec.encoded_bytes)?;
        // Re-encode for the host tier: deterministic encoder + exact
        // reconstruction ⇒ the same bytes a full fetch would have
        // cached, so upcoming users (and compositions) see one payload.
        let wire =
            crate::compeft::format::to_bytes(&next_c, crate::compeft::format::Encoding::Golomb);
        {
            let mut cpu = self.cpu.lock().unwrap();
            if !cpu.contains(&rec.id) {
                cpu.insert(&rec.id, Payload::from_vec(wire), rec.encoded_bytes.max(1));
            }
        }
        let template = self.templates.for_method(rec.method);
        let (tv, densify) = self.loader.densify(&next_c, template)?;
        let params = self.loader.materialize(rec.method, template, &tv)?;
        Ok(Some(PreparedExpert {
            id: rec.id.clone(),
            method: rec.method,
            staged_sim: apply.fetch + apply.decode + parse_prev + densify,
            upload_bytes: rec.encoded_bytes,
            dense_bytes: params.bytes_fp16(),
            params,
        }))
    }

    /// Fused cold path for store-backed `.cpeft` experts: stream the
    /// striped fetch and decode Golomb frames as their stripes land
    /// ([`ExpertLoader::fetch_decode_fused`]), charging the staged cost
    /// `≈ max(fetch, decode)` instead of their sum. Only attempted when
    /// the bytes are genuinely remote — a host-tier or archive hit has
    /// a free fetch, so there is nothing to overlap and the staged path
    /// (which also records the hit) must serve it. Returns `Ok(None)`
    /// whenever the fused path does not apply; the caller falls back to
    /// the staged fetch-then-decode, so predictions never depend on
    /// which path ran (the loader's fused suite proves bit-identity).
    fn prepare_stored_fused(&self, rec: &ExpertRecord) -> Result<Option<PreparedExpert>> {
        if self.cpu.lock().unwrap().contains(&rec.id) {
            return Ok(None);
        }
        if let Some(archive) = &self.archive {
            if archive.contains(&rec.id) {
                return Ok(None);
            }
        }
        let template = self.templates.for_method(rec.method);
        let Some(fused) = self.loader.fetch_decode_fused(rec, template)? else {
            return Ok(None);
        };
        // Same idempotent tier insert as the staged remote path, so
        // upcoming users of this expert hit the host tier either way.
        // No pin needed: the decode already happened.
        {
            let mut cpu = self.cpu.lock().unwrap();
            if !cpu.contains(&rec.id) {
                cpu.insert(&rec.id, fused.payload.clone(), rec.encoded_bytes.max(1));
            }
        }
        let params = self.loader.materialize(rec.method, template, &fused.tv)?;
        Ok(Some(PreparedExpert {
            id: rec.id.clone(),
            method: rec.method,
            staged_sim: fused.fused,
            upload_bytes: rec.encoded_bytes,
            dense_bytes: params.bytes_fp16(),
            params,
        }))
    }

    fn prepare_composed(&self, comp: &CompositionRecord) -> Result<PreparedExpert> {
        let mut staged_sim = Duration::ZERO;
        let mut members = Vec::with_capacity(comp.members.len());
        for m in &comp.members {
            let rec = self
                .registry
                .get(m)
                .ok_or_else(|| anyhow!("composition member {m:?} missing"))?;
            let (bytes, fetch, pin) = self.fetch_via_cpu_tier(rec)?;
            staged_sim += fetch;
            let (c, decode) = self.loader.decode_compressed(rec, bytes.as_slice())?;
            drop(pin);
            staged_sim += decode;
            members.push(c);
        }
        let refs: Vec<&_> = members.iter().collect();
        let (tv, merge) = self.loader.merge_ternary(&refs, &comp.merge)?;
        staged_sim += merge;
        // The merged update exists only host-side and has no compact
        // wire form: the device hop moves the dense fp16 update.
        let upload_bytes = tv.bytes_fp16();
        let template = self.templates.for_method(comp.method);
        let params = self.loader.materialize(comp.method, template, &tv)?;
        Ok(PreparedExpert {
            id: comp.id.clone(),
            method: comp.method,
            staged_sim,
            upload_bytes,
            dense_bytes: params.bytes_fp16(),
            params,
        })
    }
}

/// RAII pin on a host-tier entry: created with the pin already taken,
/// released on drop — including on unwind, so a panicking decode
/// cannot leak a pin and leave the entry permanently unevictable.
/// Pins are refcounted in the tier, so concurrent prepares sharing an
/// id (a stored expert that is also a composition member) each hold
/// their own pin. (The pin keeps the entry *tier-resident* — no
/// refetch for upcoming users; the decode's borrowed bytes would stay
/// valid even without it, since a [`Payload`] view keeps its backing
/// alive across eviction.)
struct PinGuard<'a> {
    cpu: &'a OrderedMutex<LruTier<Payload>>,
    id: String,
}

impl<'a> PinGuard<'a> {
    fn new(cpu: &'a OrderedMutex<LruTier<Payload>>, id: &str) -> PinGuard<'a> {
        PinGuard { cpu, id: id.to_string() }
    }
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        // Best-effort during unwind: a poisoned tier mutex (a panic
        // inside the lock, which no pipeline code does) must not turn
        // into a double panic here.
        if let Ok(mut cpu) = self.cpu.lock() {
            cpu.unpin(&self.id);
        }
    }
}

/// How a staging-slot lookup resolved.
pub enum TakeOutcome {
    /// Fully staged: fetch+decode already happened off-thread.
    Hit(PreparedExpert),
    /// Prefetch was in flight; the caller blocked for this long.
    Waited(PreparedExpert, Duration),
    /// The background prepare failed (the caller should fall back to
    /// the blocking path, which reports the error in context).
    Failed(String),
    /// Nothing staged or in flight for this id.
    Miss,
}

enum Slot {
    InFlight,
    Ready { prepared: PreparedExpert, seq: u64, charge: u64 },
    Failed(String),
}

struct StagingInner {
    /// Ordered map so every iteration (victim selection, sibling scan,
    /// retain) visits slots in one deterministic order on every run.
    slots: BTreeMap<String, Slot>,
    ready_bytes: u64,
    seq: u64,
    /// Ids whose staged entry was budget-evicted since the last plan
    /// update. Claims on them are refused until the next `retain`, so
    /// an over-tight budget degrades to at most one wasted prepare per
    /// id per plan instead of an endless background churn loop.
    suppressed: BTreeSet<String>,
}

/// Byte-budgeted hand-off buffer between the prefetch threads and the
/// engine: at most `budget_bytes` of decoded experts are held ready
/// (fp16 accounting, like the GPU tier); depositing past the budget
/// evicts the **newest** staged entry (counted as wasted prefetch) —
/// entries are staged in service order, so the oldest is the next one
/// the engine will take and must be the last to go. A single entry
/// larger than the whole budget is discarded on deposit when siblings
/// are staged (one blocking pickup beats evicting every sibling), and
/// admitted over budget when it is alone.
pub struct StagingArea {
    budget_bytes: u64,
    inner: OrderedMutex<StagingInner>,
    cv: Condvar,
}

impl StagingArea {
    pub fn new(budget_bytes: u64) -> StagingArea {
        StagingArea {
            budget_bytes: budget_bytes.max(1),
            inner: OrderedMutex::new(rank::STAGING, "pipeline.staging", StagingInner {
                slots: BTreeMap::new(),
                ready_bytes: 0,
                seq: 0,
                suppressed: BTreeSet::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Atomically claim `id` for preparation. Returns false when the id
    /// is already claimed, staged, failed-and-unconsumed, or was
    /// budget-evicted under the current plan.
    pub fn claim(&self, id: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.slots.contains_key(id) || inner.suppressed.contains(id) {
            return false;
        }
        inner.slots.insert(id.to_string(), Slot::InFlight);
        true
    }

    /// Deliver the result of a claimed preparation. Returns how many
    /// staged experts were discarded unused by this call (the deposit
    /// itself when its claim was cancelled, plus any budget evictions).
    pub fn deposit(&self, id: &str, res: Result<PreparedExpert>) -> u64 {
        let mut wasted = 0u64;
        {
            let mut inner = self.inner.lock().unwrap();
            match inner.slots.get(id) {
                Some(Slot::InFlight) => match res {
                    Ok(p) => {
                        let charge = p.dense_bytes.max(1);
                        // An entry bigger than the whole budget (e.g. a
                        // Full-method expert under a small accelerator
                        // budget) would evict every sibling for a
                        // single pickup: when siblings are staged,
                        // discard it instead — the engine's blocking
                        // fallback serves it, the siblings keep their
                        // hits, and the suppression stops workers from
                        // re-preparing it until the plan moves on. With
                        // nothing else staged it is admitted over
                        // budget (it must stay takeable).
                        let has_siblings = inner
                            .slots
                            .iter()
                            .any(|(k, s)| k != id && matches!(s, Slot::Ready { .. }));
                        if charge > self.budget_bytes && has_siblings {
                            inner.slots.remove(id);
                            inner.suppressed.insert(id.to_string());
                            wasted += 1;
                        } else {
                            inner.seq += 1;
                            let seq = inner.seq;
                            inner.ready_bytes += charge;
                            inner.slots.insert(
                                id.to_string(),
                                Slot::Ready { prepared: p, seq, charge },
                            );
                            // Budget: evict the *newest* staged entries
                            // — never the one just deposited (it must
                            // stay takeable) and preferably never the
                            // oldest, which is the next expert the
                            // engine will ask for. Victims are
                            // suppressed so workers do not immediately
                            // re-prepare them into the same full
                            // staging area.
                            while inner.ready_bytes > self.budget_bytes {
                                let victim = inner
                                    .slots
                                    .iter()
                                    .filter_map(|(k, s)| match s {
                                        Slot::Ready { seq, .. } if k != id => {
                                            Some((*seq, k.clone()))
                                        }
                                        _ => None,
                                    })
                                    .max()
                                    .map(|(_, k)| k);
                                let Some(v) = victim else { break };
                                if let Some(Slot::Ready { charge, .. }) =
                                    inner.slots.remove(&v)
                                {
                                    inner.ready_bytes -= charge;
                                    wasted += 1;
                                }
                                inner.suppressed.insert(v);
                            }
                        }
                    }
                    Err(e) => {
                        inner.slots.insert(id.to_string(), Slot::Failed(format!("{e:#}")));
                    }
                },
                // Claim cancelled (plan moved on) or duplicate work:
                // discard. Deterministic stages make the discard safe —
                // any other copy of this id is bit-identical.
                _ => {
                    if res.is_ok() {
                        wasted += 1;
                    }
                }
            }
        }
        self.cv.notify_all();
        wasted
    }

    /// Consume the slot for `id`: returns immediately on Ready/Failed/
    /// absent, blocks while a prefetch for `id` is in flight.
    pub fn take(&self, id: &str) -> TakeOutcome {
        let mut inner = self.inner.lock().unwrap();
        let mut waited: Option<Instant> = None;
        loop {
            match inner.slots.get(id) {
                None => return TakeOutcome::Miss,
                Some(Slot::InFlight) => {
                    // compeft-lint: allow(no-wall-clock) -- measures real engine block time for the wait metric
                    waited.get_or_insert_with(Instant::now);
                    inner = inner.wait(&self.cv).unwrap();
                }
                Some(_) => break,
            }
        }
        match inner.slots.remove(id) {
            Some(Slot::Ready { prepared, charge, .. }) => {
                inner.ready_bytes -= charge;
                match waited {
                    None => TakeOutcome::Hit(prepared),
                    Some(t0) => TakeOutcome::Waited(prepared, t0.elapsed()),
                }
            }
            Some(Slot::Failed(e)) => TakeOutcome::Failed(e),
            // The loop only breaks on Ready/Failed while the lock is
            // held continuously, so this arm is unreachable in practice.
            _ => TakeOutcome::Miss,
        }
    }

    /// Drop every slot whose id is not in `keep`; returns how many
    /// staged (ready) experts were discarded. In-flight claims are
    /// cancelled — their eventual deposit is discarded and counted
    /// there. A plan update also lifts budget-eviction suppressions:
    /// the new plan gets a fresh chance to stage every id.
    pub fn retain(&self, keep: &[&str]) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.suppressed.clear();
        let drop_ids: Vec<String> = inner
            .slots
            .keys()
            .filter(|k| !keep.contains(&k.as_str()))
            .cloned()
            .collect();
        let mut wasted = 0u64;
        for k in drop_ids {
            match inner.slots.remove(&k) {
                Some(Slot::Ready { charge, .. }) => {
                    inner.ready_bytes -= charge;
                    wasted += 1;
                }
                _ => {} // InFlight counted at deposit; Failed is free
            }
        }
        wasted
    }

    /// Number of decoded experts currently staged ready.
    pub fn ready_count(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .slots
            .values()
            .filter(|s| matches!(s, Slot::Ready { .. }))
            .count()
    }

    /// fp16 bytes of staged-ready experts (budget accounting).
    pub fn ready_bytes(&self) -> u64 {
        self.inner.lock().unwrap().ready_bytes
    }
}

struct PlanState {
    /// Upcoming expert ids, in service order (the batcher's plan).
    desired: Vec<String>,
    closed: bool,
}

struct PfShared {
    ctx: Arc<PrepareContext>,
    staging: StagingArea,
    metrics: Arc<Metrics>,
    plan: OrderedMutex<PlanState>,
    cv: Condvar,
}

/// Background lookahead: worker threads watch the engine's plan and run
/// the fetch+decode stages for upcoming experts into the staging area
/// while the engine thread executes batches.
pub struct Prefetcher {
    shared: Arc<PfShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the prefetch workers. `depth` bounds both the lookahead
    /// the engine publishes and the worker count (clamped to [1, 4]);
    /// `staging_budget_bytes` bounds the decoded bytes held ready.
    pub fn start(
        ctx: Arc<PrepareContext>,
        depth: usize,
        staging_budget_bytes: u64,
        metrics: Arc<Metrics>,
    ) -> Prefetcher {
        let shared = Arc::new(PfShared {
            ctx,
            staging: StagingArea::new(staging_budget_bytes),
            metrics,
            plan: OrderedMutex::new(
                rank::PREFETCH_PLAN,
                "pipeline.plan",
                PlanState { desired: Vec::new(), closed: false },
            ),
            cv: Condvar::new(),
        });
        let workers = (0..depth.clamp(1, 4))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("compeft-prefetch-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn prefetch worker")
            })
            .collect();
        Prefetcher { shared, workers }
    }

    /// Publish the engine's lookahead: the experts expected next, in
    /// service order (already filtered of GPU residents and the expert
    /// being served). Staged entries that fell out of the plan are
    /// discarded and counted as wasted prefetches.
    pub fn note_plan(&self, upcoming: Vec<String>) {
        let wasted = {
            let mut plan = self.shared.plan.lock().unwrap();
            plan.desired = upcoming;
            let keep: Vec<&str> = plan.desired.iter().map(|s| s.as_str()).collect();
            self.shared.staging.retain(&keep)
        };
        if wasted > 0 {
            self.shared.metrics.record_prefetch_wasted(wasted);
        }
        self.shared.cv.notify_all();
    }

    /// Engine-side pickup of a staged expert (blocks on an in-flight
    /// prefetch rather than duplicating its work). Also drops the id
    /// from the plan so an idle worker does not immediately re-prepare
    /// what was just consumed. Records the hit/wait/miss outcome — and
    /// the overlap time a hit saved — into the metrics sink.
    pub fn take(&self, id: &str) -> TakeOutcome {
        {
            let mut plan = self.shared.plan.lock().unwrap();
            plan.desired.retain(|d| d != id);
        }
        let out = self.shared.staging.take(id);
        match &out {
            TakeOutcome::Hit(p) => self.shared.metrics.record_prefetch_hit(p.staged_sim),
            TakeOutcome::Waited(..) => self.shared.metrics.record_prefetch_wait(),
            // A failed prefetch sends the engine down the blocking path,
            // which is a miss for overlap purposes.
            TakeOutcome::Miss | TakeOutcome::Failed(_) => {
                self.shared.metrics.record_prefetch_miss()
            }
        }
        out
    }

    /// Staging visibility for tests and reports.
    pub fn staging(&self) -> &StagingArea {
        &self.shared.staging
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        {
            let mut plan = self.shared.plan.lock().unwrap();
            plan.closed = true;
            plan.desired.clear();
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Whatever is still staged at shutdown was prepared for nothing.
        let leftover = self.shared.staging.retain(&[]);
        if leftover > 0 {
            self.shared.metrics.record_prefetch_wasted(leftover);
        }
    }
}

fn worker_loop(shared: &PfShared) {
    loop {
        // Find the first planned expert nobody has claimed yet.
        let target = {
            let mut plan = shared.plan.lock().unwrap();
            loop {
                if plan.closed {
                    return;
                }
                let next = plan
                    .desired
                    .iter()
                    .find(|id| shared.staging.claim(id))
                    .cloned();
                match next {
                    Some(id) => break id,
                    None => plan = plan.wait(&shared.cv).unwrap(),
                }
            }
        };
        // A panicking prepare must still deposit, or an engine blocked
        // in `take` on this in-flight slot would wait forever.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared.ctx.prepare(&target)
        }))
        .unwrap_or_else(|_| {
            Err(anyhow!("prefetch worker panicked preparing {target:?}"))
        });
        let wasted = shared.staging.deposit(&target, res);
        if wasted > 0 {
            shared.metrics.record_prefetch_wasted(wasted);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::CompressConfig;
    use crate::coordinator::transport::{LinkSpec, SimLink};
    use crate::merging::MergeMethod;
    use crate::tensor::Tensor;
    use crate::util::pool::ThreadPool;
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use std::path::PathBuf;

    fn sample_tv(seed: u64, n: usize) -> ParamSet {
        let mut rng = Pcg::seed(seed);
        let mut p = ParamSet::new();
        p.insert("a.lora_a", Tensor::new(vec![n], prop::task_vector_like(&mut rng, n)));
        p.insert(
            "b.lora_b",
            Tensor::new(vec![n / 2], prop::task_vector_like(&mut rng, n / 2)),
        );
        p
    }

    use crate::bench_support::zero_templates;

    /// Registry of three stored `.cpeft` experts plus one composition,
    /// with real files on disk — the mixed workload the engine serves.
    fn mixed_fixture(dir: &PathBuf) -> (Arc<Registry>, Templates) {
        std::fs::create_dir_all(dir).unwrap();
        let mut reg = Registry::new();
        let cfg = CompressConfig { density: 0.15, alpha: 1.0, ..Default::default() };
        let mut first_tv = None;
        for i in 0..3u64 {
            let tv = sample_tv(100 + i, 4096);
            let npz = dir.join(format!("e{i}.lora.npz"));
            tv.save_npz(&npz).unwrap();
            reg.register_compeft(
                &format!("e{i}"),
                "t",
                "s",
                ExpertMethod::Lora,
                &npz,
                &cfg,
            )
            .unwrap();
            first_tv.get_or_insert(tv);
        }
        reg.register_composition(
            "merged/ties",
            &["e0", "e1", "e2"],
            MergeMethod::Ties { density: 0.4, lambda: 0.9 },
        )
        .unwrap();
        let templates = zero_templates(&first_tv.unwrap());
        (Arc::new(reg), templates)
    }

    fn fresh_ctx(
        registry: Arc<Registry>,
        templates: Templates,
        workers: usize,
    ) -> Arc<PrepareContext> {
        let loader = ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
            SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
        )
        .with_pool(Arc::new(ThreadPool::new(workers)));
        Arc::new(PrepareContext {
            loader,
            registry,
            templates,
            cpu: Arc::new(OrderedMutex::new(
                rank::CPU_TIER,
                "cache.cpu_tier",
                LruTier::new("cpu", 64 << 20),
            )),
            archive: None,
        })
    }

    /// The pipeline's correctness bar, below the engine: for a mixed
    /// stored+composed workload, whatever the prefetcher stages is
    /// bit-identical to the blocking prepare, at every lookahead depth
    /// and decode-worker count.
    #[test]
    fn prefetched_experts_match_blocking_prepare() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_pipeline_eq_{}", std::process::id()));
        let (reg, templates) = mixed_fixture(&dir);
        let ids = ["e0", "merged/ties", "e1", "e2"];

        // Blocking reference, serial decode.
        let ctx_ref = fresh_ctx(Arc::clone(&reg), templates.clone(), 1);
        let reference: Vec<PreparedExpert> =
            ids.iter().map(|id| ctx_ref.prepare(id).unwrap()).collect();

        for depth in [1usize, 3] {
            for workers in crate::util::prop::pool_sizes() {
                let ctx = fresh_ctx(Arc::clone(&reg), templates.clone(), workers);
                let metrics = Arc::new(Metrics::new());
                let pf = Prefetcher::start(
                    Arc::clone(&ctx),
                    depth,
                    u64::MAX,
                    Arc::clone(&metrics),
                );
                pf.note_plan(ids.iter().map(|s| s.to_string()).collect());
                for (id, want) in ids.iter().zip(&reference) {
                    let got = match pf.take(id) {
                        TakeOutcome::Hit(p) | TakeOutcome::Waited(p, _) => p,
                        TakeOutcome::Miss => ctx.prepare(id).unwrap(),
                        TakeOutcome::Failed(e) => panic!("prefetch failed: {e}"),
                    };
                    assert_eq!(
                        got.params, want.params,
                        "depth={depth} workers={workers} id={id}"
                    );
                    assert_eq!(got.upload_bytes, want.upload_bytes);
                    assert_eq!(got.dense_bytes, want.dense_bytes);
                    assert_eq!(got.method, want.method);
                }
                drop(pf);
                let s = metrics.snapshot();
                assert_eq!(
                    s.prefetch_hits + s.prefetch_waits,
                    ids.len() as u64 - s.prefetch_misses,
                    "every take resolved"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Staging byte budget: depositing past the budget evicts the
    /// *newest* staged entry — never the one just deposited and never
    /// the oldest, which is the next expert the engine will take — and
    /// suppresses the victim from re-claim until the next plan update.
    #[test]
    fn staging_budget_evicts_newest_ready_and_suppresses_reclaim() {
        let mk = |id: &str, bytes: u64| PreparedExpert {
            id: id.to_string(),
            method: ExpertMethod::Lora,
            params: ParamSet::new(),
            staged_sim: Duration::ZERO,
            upload_bytes: bytes,
            dense_bytes: bytes,
        };
        let staging = StagingArea::new(130);
        assert!(staging.claim("a"));
        assert!(!staging.claim("a"), "double claim refused");
        assert_eq!(staging.deposit("a", Ok(mk("a", 60))), 0);
        assert!(staging.claim("b"));
        assert_eq!(staging.deposit("b", Ok(mk("b", 60))), 0, "120 fits in 130");
        assert!(staging.claim("c"));
        // 180 > 130: the newest staged entry ("b") goes, counted wasted.
        assert_eq!(staging.deposit("c", Ok(mk("c", 60))), 1);
        assert_eq!(staging.ready_count(), 2);
        assert_eq!(staging.ready_bytes(), 120);
        assert!(matches!(staging.take("b"), TakeOutcome::Miss));
        // ...and cannot be re-claimed into the same full area until the
        // plan moves on (prevents background churn under tight budgets).
        assert!(!staging.claim("b"), "budget victim is suppressed");
        match staging.take("a") {
            TakeOutcome::Hit(p) => assert_eq!(p.id, "a", "next-to-serve survives"),
            _ => panic!("a must be staged"),
        }
        assert!(matches!(staging.take("c"), TakeOutcome::Hit(_)));
        assert_eq!(staging.ready_bytes(), 0);
        staging.retain(&[]);
        assert!(staging.claim("b"), "plan update lifts the suppression");

        // An entry larger than the whole budget is still admitted when
        // nothing else is staged (it must stay takeable).
        assert!(staging.claim("big"));
        assert_eq!(staging.deposit("big", Ok(mk("big", 500))), 0);
        assert!(matches!(staging.take("big"), TakeOutcome::Hit(_)));

        // ...but with a sibling staged, the too-big entry is discarded
        // instead of evicting the sibling for one pickup.
        staging.retain(&[]);
        assert!(staging.claim("s1"));
        assert_eq!(staging.deposit("s1", Ok(mk("s1", 50))), 0);
        assert!(staging.claim("whale"));
        assert_eq!(staging.deposit("whale", Ok(mk("whale", 500))), 1);
        assert!(matches!(staging.take("whale"), TakeOutcome::Miss));
        assert!(!staging.claim("whale"), "discarded whale is suppressed");
        match staging.take("s1") {
            TakeOutcome::Hit(p) => assert_eq!(p.id, "s1", "sibling keeps its hit"),
            _ => panic!("sibling must survive a whale deposit"),
        }

        // A cancelled claim's deposit is discarded and counted.
        assert!(staging.claim("stale"));
        assert_eq!(staging.retain(&[]), 0, "in-flight cancel is counted at deposit");
        assert_eq!(staging.deposit("stale", Ok(mk("stale", 10))), 1);
        assert!(matches!(staging.take("stale"), TakeOutcome::Miss));

        // Failed prepares surface as Failed, once.
        assert!(staging.claim("broken"));
        assert_eq!(staging.deposit("broken", Err(anyhow!("boom"))), 0);
        match staging.take("broken") {
            TakeOutcome::Failed(e) => assert!(e.contains("boom")),
            _ => panic!("expected Failed"),
        }
        assert!(matches!(staging.take("broken"), TakeOutcome::Miss));
    }

    /// A plan update discards staged experts that are no longer
    /// upcoming (wasted prefetch) while keeping the ones still planned.
    #[test]
    fn plan_change_discards_stale_staged_entries() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_pipeline_retain_{}", std::process::id()));
        let (reg, templates) = mixed_fixture(&dir);
        let ctx = fresh_ctx(Arc::clone(&reg), templates, 2);
        let metrics = Arc::new(Metrics::new());
        let pf = Prefetcher::start(Arc::clone(&ctx), 2, u64::MAX, Arc::clone(&metrics));
        pf.note_plan(vec!["e0".into(), "e1".into()]);
        // Poll until both are staged; taking them here would consume
        // the slots and hide the waste this test wants to observe.
        let t0 = Instant::now();
        while pf.staging().ready_count() < 2 {
            assert!(t0.elapsed() < Duration::from_secs(20), "prefetch stalled");
            std::thread::sleep(Duration::from_millis(5));
        }
        // e1 falls out of the plan: it must be discarded and counted.
        pf.note_plan(vec!["e0".into()]);
        assert_eq!(pf.staging().ready_count(), 1);
        assert!(matches!(pf.take("e1"), TakeOutcome::Miss));
        assert!(matches!(pf.take("e0"), TakeOutcome::Hit(_)));
        drop(pf);
        assert!(metrics.snapshot().prefetch_wasted >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Store-backed pipeline equivalence: a PrepareContext whose loader
    /// fetches from the sharded store — including one failing over
    /// around a dead node — prepares experts bit-identical to the
    /// flat-link blocking path, at every pool size. This is the
    /// pipeline half of the "sharded store never changes predictions"
    /// acceptance bar (the integration fault suite extends it over the
    /// full fault sweep).
    #[test]
    fn store_backed_prefetch_matches_flat_blocking_prepare() {
        use crate::coordinator::store::{ExpertStore, Placement, StoreConfig};
        use crate::coordinator::transport::{FaultPlan, FaultSpec};

        let dir = std::env::temp_dir()
            .join(format!("compeft_pipeline_store_{}", std::process::id()));
        let (reg, templates) = mixed_fixture(&dir);
        let ids = ["e0", "merged/ties", "e1", "e2"];
        let ctx_flat = fresh_ctx(Arc::clone(&reg), templates.clone(), 1);
        let reference: Vec<PreparedExpert> =
            ids.iter().map(|id| ctx_flat.prepare(id).unwrap()).collect();

        let plans = [
            FaultPlan::none(0),
            FaultPlan::new(
                11,
                FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() },
            ),
            FaultPlan::none(2).kill_node(Placement::new(3, 2, 0).nodes_for("e0")[0]),
        ];
        for plan in plans {
            for workers in crate::util::prop::pool_sizes() {
                let pool = Arc::new(ThreadPool::new(workers));
                let metrics = Arc::new(Metrics::new());
                let mut scfg = StoreConfig::new(3, 2);
                scfg.time_scale = 0.0;
                scfg.stripe_bytes = 1024;
                scfg.faults = plan.clone();
                let store = Arc::new(ExpertStore::new(
                    scfg,
                    Some(Arc::clone(&pool)),
                    Arc::clone(&metrics),
                ));
                let ctx = Arc::new(PrepareContext {
                    loader: ExpertLoader::new(
                        SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                        SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
                    )
                    .with_pool(pool)
                    .with_store(store),
                    registry: Arc::clone(&reg),
                    templates: templates.clone(),
                    cpu: Arc::new(OrderedMutex::new(
                        rank::CPU_TIER,
                        "cache.cpu_tier",
                        LruTier::new("cpu", 64 << 20),
                    )),
                    archive: None,
                });
                let pf = Prefetcher::start(
                    Arc::clone(&ctx),
                    2,
                    u64::MAX,
                    Arc::clone(&metrics),
                );
                pf.note_plan(ids.iter().map(|s| s.to_string()).collect());
                for (id, want) in ids.iter().zip(&reference) {
                    let got = match pf.take(id) {
                        TakeOutcome::Hit(p) | TakeOutcome::Waited(p, _) => p,
                        TakeOutcome::Miss => ctx.prepare(id).unwrap(),
                        TakeOutcome::Failed(e) => panic!("prefetch failed: {e}"),
                    };
                    assert_eq!(got.params, want.params, "w={workers} id={id}");
                    assert_eq!(got.upload_bytes, want.upload_bytes, "{id}");
                    assert_eq!(got.dense_bytes, want.dense_bytes, "{id}");
                }
                drop(pf);
                if !plan.is_none() {
                    assert!(
                        metrics.snapshot().failovers > 0,
                        "fault plan must have fired through the pipeline"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The fused fetch→decode cold path at the pipeline layer: a
    /// remote store-backed `.cpeft` expert (big enough for several
    /// 8192-nonzero Golomb frames) prepares bit-identically to the
    /// flat blocking path at every pool size, and the hidden time is
    /// counted in `fused_loads`/`decode_overlap_us`. A second prepare
    /// of the same id hits the host tier — free fetch, nothing to
    /// overlap — and must not run the fused path again.
    #[test]
    fn fused_cold_prepare_matches_flat_and_records_overlap() {
        use crate::coordinator::store::{ExpertStore, StoreConfig};

        let dir = std::env::temp_dir()
            .join(format!("compeft_pipeline_fused_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Pcg::seed(77);
        let mut tv = ParamSet::new();
        tv.insert(
            "w",
            Tensor::new(vec![60_000], prop::task_vector_like(&mut rng, 60_000)),
        );
        let npz = dir.join("big.lora.npz");
        tv.save_npz(&npz).unwrap();
        let mut reg = Registry::new();
        let cfg = CompressConfig { density: 0.3, alpha: 1.0, ..Default::default() };
        reg.register_compeft("big", "t", "s", ExpertMethod::Lora, &npz, &cfg)
            .unwrap();
        let reg = Arc::new(reg);
        let templates = zero_templates(&tv);

        let ctx_flat = fresh_ctx(Arc::clone(&reg), templates.clone(), 1);
        let want = ctx_flat.prepare("big").unwrap();

        for workers in crate::util::prop::pool_sizes() {
            let pool = Arc::new(ThreadPool::new(workers));
            let metrics = Arc::new(Metrics::new());
            let mut scfg = StoreConfig::new(3, 2);
            scfg.time_scale = 0.0;
            scfg.stripe_bytes = 512;
            let store = Arc::new(ExpertStore::new(
                scfg,
                Some(Arc::clone(&pool)),
                Arc::clone(&metrics),
            ));
            let ctx = PrepareContext {
                loader: ExpertLoader::new(
                    SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                    SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
                )
                .with_pool(pool)
                .with_store(store),
                registry: Arc::clone(&reg),
                templates: templates.clone(),
                cpu: Arc::new(OrderedMutex::new(
                    rank::CPU_TIER,
                    "cache.cpu_tier",
                    LruTier::new("cpu", 64 << 20),
                )),
                archive: None,
            };
            let got = ctx.prepare("big").unwrap();
            assert_eq!(got.params, want.params, "w={workers}");
            assert_eq!(got.upload_bytes, want.upload_bytes);
            assert_eq!(got.dense_bytes, want.dense_bytes);
            assert_eq!(
                metrics.snapshot().fused_loads,
                1,
                "cold prepare ran the fused path (w={workers})"
            );
            let again = ctx.prepare("big").unwrap();
            assert_eq!(again.params, want.params);
            assert_eq!(
                metrics.snapshot().fused_loads,
                1,
                "host-tier hit must not re-run the fused path"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Archive-backed prepare: with every stored expert packed into a
    /// local archive, prepares are bit-identical to the flat remote
    /// path at every pool size, the net link never fires, nothing is
    /// double-copied into the host tier, and the copy meter stays at
    /// zero — the zero-copy acceptance bar at the pipeline layer.
    #[test]
    fn archive_backed_prepare_matches_flat_and_skips_host_tier() {
        use crate::coordinator::archive::{build_from_registry, ArchiveTier};

        let dir = std::env::temp_dir()
            .join(format!("compeft_pipeline_archive_{}", std::process::id()));
        let (reg, templates) = mixed_fixture(&dir);
        let ids = ["e0", "merged/ties", "e1", "e2"];
        let ctx_flat = fresh_ctx(Arc::clone(&reg), templates.clone(), 1);
        let reference: Vec<PreparedExpert> =
            ids.iter().map(|id| ctx_flat.prepare(id).unwrap()).collect();

        let archive_path = dir.join("experts.cpar");
        let (members, _) = build_from_registry(&reg, &archive_path).unwrap();
        assert_eq!(members, 3, "all stored experts packed");

        for workers in crate::util::prop::pool_sizes() {
            let metrics = Arc::new(Metrics::new());
            let tier = Arc::new(
                ArchiveTier::open(&archive_path, Arc::clone(&metrics)).unwrap(),
            );
            let loader = ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
            )
            .with_pool(Arc::new(ThreadPool::new(workers)))
            .with_meter(metrics.copy_meter());
            let net = loader.net.clone();
            let ctx = PrepareContext {
                loader,
                registry: Arc::clone(&reg),
                templates: templates.clone(),
                cpu: Arc::new(OrderedMutex::new(
                    rank::CPU_TIER,
                    "cache.cpu_tier",
                    LruTier::new("cpu", 64 << 20),
                )),
                archive: Some(tier),
            };
            for (id, want) in ids.iter().zip(&reference) {
                let got = ctx.prepare(id).unwrap();
                assert_eq!(got.params, want.params, "w={workers} id={id}");
                assert_eq!(got.upload_bytes, want.upload_bytes, "{id}");
                assert_eq!(got.dense_bytes, want.dense_bytes, "{id}");
            }
            assert_eq!(net.bytes_moved(), 0, "archive hits must not touch the net");
            assert_eq!(
                ctx.cpu.lock().unwrap().stats().entries,
                0,
                "archive views are not double-cached in the host tier"
            );
            let s = metrics.snapshot();
            assert!(s.archive_hits >= ids.len() as u64 - 1, "hits counted");
            assert!(s.archive_bytes_viewed > 0);
            assert_eq!(
                s.payload_copies, 0,
                "archive-resident serving performs zero encoded-byte copies"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Unknown ids fail cleanly through the context (the engine's
    /// unknown-expert branch rejects before ever reaching prepare, so
    /// this is the backstop) and a failed prefetch resolves to Failed
    /// rather than wedging the staging slot.
    #[test]
    fn unknown_expert_prepare_fails_cleanly() {
        let dir = std::env::temp_dir()
            .join(format!("compeft_pipeline_unknown_{}", std::process::id()));
        let (reg, templates) = mixed_fixture(&dir);
        let ctx = fresh_ctx(Arc::clone(&reg), templates, 1);
        assert!(ctx.prepare("nope").is_err());

        let metrics = Arc::new(Metrics::new());
        let pf = Prefetcher::start(Arc::clone(&ctx), 1, u64::MAX, Arc::clone(&metrics));
        pf.note_plan(vec!["nope".into()]);
        match pf.take("nope") {
            TakeOutcome::Failed(e) => assert!(e.contains("unknown expert"), "{e}"),
            TakeOutcome::Miss => {} // worker had not claimed yet — equally fine
            _ => panic!("an unknown expert cannot be staged"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
