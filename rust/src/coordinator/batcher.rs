//! Dynamic per-expert batching.
//!
//! Requests for the same expert are queued together and released as a
//! batch when either the batch-size target is reached or the oldest
//! request has waited past the deadline — the standard continuous-
//! batching trade-off (throughput vs tail latency) that multi-expert
//! serving systems make per adapter (S-LoRA, vLLM). The engine drains
//! one expert at a time, which maximizes reuse of the currently
//! resident expert between swaps.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued request (payload is opaque to the batcher).
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Release a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

struct Queues<T> {
    by_expert: HashMap<String, VecDeque<Pending<T>>>,
    closed: bool,
}

/// Thread-safe batcher.
pub struct Batcher<T> {
    policy: BatchPolicy,
    queues: Mutex<Queues<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            policy,
            queues: Mutex::new(Queues { by_expert: HashMap::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request for an expert.
    pub fn push(&self, expert: &str, payload: T) {
        let mut q = self.queues.lock().unwrap();
        q.by_expert
            .entry(expert.to_string())
            .or_default()
            .push_back(Pending { payload, enqueued: Instant::now() });
        self.cv.notify_all();
    }

    /// Signal shutdown: wakes waiters; remaining queued work is still
    /// drained by subsequent `next_batch` calls until empty.
    pub fn close(&self) {
        self.queues.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn queued(&self) -> usize {
        let q = self.queues.lock().unwrap();
        q.by_expert.values().map(|v| v.len()).sum()
    }

    /// Pick the next batch: prefer the expert whose head-of-line
    /// request is most overdue; if none is overdue yet, prefer
    /// `prefer_resident` (the expert currently loaded — free to serve),
    /// then the fullest queue once it hits `max_batch`.
    ///
    /// Blocks until work is ready or (closed && empty) → None.
    pub fn next_batch(&self, prefer_resident: Option<&str>) -> Option<(String, Vec<Pending<T>>)> {
        let mut guard = self.queues.lock().unwrap();
        loop {
            if let Some(key) = self.pick(&guard, prefer_resident) {
                let queue = guard.by_expert.get_mut(&key).unwrap();
                let take = queue.len().min(self.policy.max_batch);
                let batch: Vec<Pending<T>> = queue.drain(..take).collect();
                if queue.is_empty() {
                    guard.by_expert.remove(&key);
                }
                return Some((key, batch));
            }
            if guard.closed {
                if guard.by_expert.is_empty() {
                    return None;
                }
                // Closed but work remains: flush immediately.
                let key = guard.by_expert.keys().next().unwrap().clone();
                let queue = guard.by_expert.get_mut(&key).unwrap();
                let take = queue.len().min(self.policy.max_batch);
                let batch: Vec<Pending<T>> = queue.drain(..take).collect();
                if queue.is_empty() {
                    guard.by_expert.remove(&key);
                }
                return Some((key, batch));
            }
            // Sleep only until the oldest head-of-line request crosses
            // its deadline, not a fixed max_wait per wakeup: a notify
            // that arrives mid-wait (another request landing) used to
            // reset the timer, so a lone request could wait up to
            // ~2× max_wait before release.
            let wait = {
                let now = Instant::now();
                let next_deadline = guard
                    .by_expert
                    .values()
                    .filter_map(|q| q.front())
                    .map(|head| head.enqueued + self.policy.max_wait)
                    .min();
                match next_deadline {
                    Some(dl) => dl.saturating_duration_since(now),
                    None => self.policy.max_wait,
                }
            };
            let (g, _) = self
                .cv
                .wait_timeout(guard, wait.max(Duration::from_micros(200)))
                .unwrap();
            guard = g;
        }
    }

    /// Deterministic snapshot of upcoming work: expert ids in the order
    /// the scheduler will serve them, up to `n` entries. The prefetcher
    /// uses this lookahead to run the fetch+decode stages for the next
    /// experts while the engine executes the current batch. Does not
    /// mutate the queues.
    ///
    /// Ordering mirrors [`Batcher::next_batch`]'s pick: the resident
    /// expert's full batch first, then other full queues by oldest
    /// head-of-line request, then the remaining queues by oldest head —
    /// ties broken by expert id so the plan is stable across calls.
    pub fn plan(&self, n: usize, prefer_resident: Option<&str>) -> Vec<String> {
        let q = self.queues.lock().unwrap();
        let mut entries: Vec<(&String, usize, Instant)> = q
            .by_expert
            .iter()
            .filter_map(|(k, queue)| queue.front().map(|h| (k, queue.len(), h.enqueued)))
            .collect();
        let rank = |id: &String, len: usize| -> u8 {
            if prefer_resident == Some(id.as_str()) && len >= self.policy.max_batch {
                0
            } else if len >= self.policy.max_batch {
                1
            } else {
                2
            }
        };
        entries.sort_by(|a, b| {
            (rank(a.0, a.1), a.2, a.0).cmp(&(rank(b.0, b.1), b.2, b.0))
        });
        entries.into_iter().take(n).map(|(k, _, _)| k.clone()).collect()
    }

    fn pick(&self, q: &Queues<T>, prefer_resident: Option<&str>) -> Option<String> {
        let now = Instant::now();
        // 1. full batches for the resident expert (no swap, no wait).
        if let Some(res) = prefer_resident {
            if let Some(queue) = q.by_expert.get(res) {
                if queue.len() >= self.policy.max_batch {
                    return Some(res.to_string());
                }
            }
        }
        // 2. any full batch — ties broken by oldest head-of-line
        //    request (then id), so the choice is deterministic and a
        //    full queue cannot be starved indefinitely by another queue
        //    that refills faster (the old HashMap-iteration pick could
        //    land on the same "first" queue forever under sustained
        //    load).
        let mut full: Option<(&String, Instant)> = None;
        for (k, queue) in &q.by_expert {
            if queue.len() >= self.policy.max_batch {
                let head = queue.front().expect("full queue has a head").enqueued;
                if full.map_or(true, |(bk, bh)| (head, k) < (bh, bk)) {
                    full = Some((k, head));
                }
            }
        }
        if let Some((k, _)) = full {
            return Some(k.clone());
        }
        // 3. most-overdue head-of-line request (ties by id).
        let mut best: Option<(&String, Duration)> = None;
        for (k, queue) in &q.by_expert {
            if let Some(head) = queue.front() {
                let age = now.duration_since(head.enqueued);
                if age >= self.policy.max_wait
                    && best.map_or(true, |(bk, b)| age > b || (age == b && k < bk))
                {
                    best = Some((k, age));
                }
            }
        }
        if let Some((k, _)) = best {
            return Some(k.clone());
        }
        // 4. resident expert with any work (free to serve, still batches
        //    whatever is there once its head ages past max_wait — but if
        //    nothing else is pending we can serve it immediately).
        if q.by_expert.len() == 1 {
            if let Some(res) = prefer_resident {
                if q.by_expert.contains_key(res) {
                    return Some(res.to_string());
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_releases_immediately() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..3 {
            b.push("e1", i);
        }
        let (k, batch) = b.next_batch(None).unwrap();
        assert_eq!(k, "e1");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(300),
        }));
        b.push("e1", 1);
        // A second request landing just before the deadline wakes the
        // waiter but must NOT reset its timer: the wait is computed
        // from the oldest head-of-line deadline, so release happens at
        // ~max_wait, not ~2× max_wait as with the old fixed re-sleep.
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(250));
                b.push("e1", 2);
            })
        };
        let t0 = Instant::now();
        let (_, batch) = b.next_batch(None).unwrap();
        let elapsed = t0.elapsed();
        producer.join().unwrap();
        // Usually 2 (the late request rides along); 1 only if a loaded
        // runner delays the producer past the deadline — the timing
        // bounds below are the actual regression check.
        assert!(!batch.is_empty());
        assert!(elapsed >= Duration::from_millis(290), "elapsed {elapsed:?}");
        // The old fixed re-sleep released at ~550 ms (250 ms wakeup +
        // a fresh 300 ms wait); the deadline-based wait releases at
        // ~300 ms. The 450 ms ceiling leaves ~150 ms of slack for a
        // loaded CI runner on either side of the verdict.
        assert!(
            elapsed < Duration::from_millis(450),
            "a mid-wait wakeup reset the deadline: {elapsed:?}"
        );
    }

    /// Regression: pick rule 2 used to iterate a `HashMap`, so with two
    /// persistently-full queues the chosen one was arbitrary and could
    /// starve the other indefinitely. Ties now break by oldest
    /// head-of-line request, which makes sustained full-load service
    /// alternate.
    #[test]
    fn persistently_full_queues_alternate_instead_of_starving() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        b.push("a", 0);
        b.push("a", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("b", 2);
        b.push("b", 3);
        let mut order = Vec::new();
        for _ in 0..6 {
            let (k, batch) = b.next_batch(None).unwrap();
            assert_eq!(batch.len(), 2);
            // Keep the served queue persistently full: its refill is
            // newer than the other queue's waiting head.
            std::thread::sleep(Duration::from_millis(2));
            for v in 90..92 {
                b.push(&k, v);
            }
            order.push(k);
        }
        assert_eq!(order, ["a", "b", "a", "b", "a", "b"], "oldest head must win");
    }

    /// The prefetcher's lookahead: `plan` reports upcoming experts in
    /// deterministic service order without mutating the queues.
    #[test]
    fn plan_snapshots_upcoming_experts_in_service_order() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        // oldest head: "slow" (non-full), then "cold" fills, then "hot"
        // fills, then "tail" (non-full).
        b.push("slow", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("cold", 2);
        b.push("cold", 3);
        std::thread::sleep(Duration::from_millis(2));
        b.push("hot", 4);
        b.push("hot", 5);
        std::thread::sleep(Duration::from_millis(2));
        b.push("tail", 6);

        // Resident full batch first, then the other full queue (older
        // head first), then non-full queues by head age.
        assert_eq!(
            b.plan(10, Some("hot")),
            vec!["hot", "cold", "slow", "tail"],
            "resident full batch leads the plan"
        );
        // Without a resident, full queues rank by oldest head.
        assert_eq!(b.plan(10, None), vec!["cold", "hot", "slow", "tail"]);
        // Truncation, and no mutation happened above.
        assert_eq!(b.plan(2, None), vec!["cold", "hot"]);
        assert_eq!(b.queued(), 6);
        assert!(b.plan(0, None).is_empty());
    }

    #[test]
    fn resident_expert_preferred_for_full_batches() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        b.push("cold", 1);
        b.push("cold", 2);
        b.push("hot", 3);
        b.push("hot", 4);
        let (k, _) = b.next_batch(Some("hot")).unwrap();
        assert_eq!(k, "hot");
    }

    #[test]
    fn close_drains_and_terminates() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        }));
        b.push("e", 1);
        b.close();
        let (_, batch) = b.next_batch(None).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch(None).is_none());
    }

    #[test]
    fn cross_thread_flow() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }));
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..40 {
                    b.push(if i % 2 == 0 { "a" } else { "b" }, i);
                }
                b.close();
            })
        };
        let mut seen = 0;
        while let Some((_, batch)) = b.next_batch(None) {
            seen += batch.len();
        }
        producer.join().unwrap();
        assert_eq!(seen, 40);
    }
}
