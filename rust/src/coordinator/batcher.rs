//! Dynamic per-expert batching.
//!
//! Requests for the same expert are queued together and released as a
//! batch when either the batch-size target is reached or the oldest
//! request has waited past the deadline — the standard continuous-
//! batching trade-off (throughput vs tail latency) that multi-expert
//! serving systems make per adapter (S-LoRA, vLLM). The engine drains
//! one expert at a time, which maximizes reuse of the currently
//! resident expert between swaps.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued request (payload is opaque to the batcher).
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Release a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

struct Queues<T> {
    by_expert: HashMap<String, VecDeque<Pending<T>>>,
    closed: bool,
}

/// Thread-safe batcher.
pub struct Batcher<T> {
    policy: BatchPolicy,
    queues: Mutex<Queues<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            policy,
            queues: Mutex::new(Queues { by_expert: HashMap::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request for an expert.
    pub fn push(&self, expert: &str, payload: T) {
        let mut q = self.queues.lock().unwrap();
        q.by_expert
            .entry(expert.to_string())
            .or_default()
            .push_back(Pending { payload, enqueued: Instant::now() });
        self.cv.notify_all();
    }

    /// Signal shutdown: wakes waiters; remaining queued work is still
    /// drained by subsequent `next_batch` calls until empty.
    pub fn close(&self) {
        self.queues.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn queued(&self) -> usize {
        let q = self.queues.lock().unwrap();
        q.by_expert.values().map(|v| v.len()).sum()
    }

    /// Pick the next batch: prefer the expert whose head-of-line
    /// request is most overdue; if none is overdue yet, prefer
    /// `prefer_resident` (the expert currently loaded — free to serve),
    /// then the fullest queue once it hits `max_batch`.
    ///
    /// Blocks until work is ready or (closed && empty) → None.
    pub fn next_batch(&self, prefer_resident: Option<&str>) -> Option<(String, Vec<Pending<T>>)> {
        let mut guard = self.queues.lock().unwrap();
        loop {
            if let Some(key) = self.pick(&guard, prefer_resident) {
                let queue = guard.by_expert.get_mut(&key).unwrap();
                let take = queue.len().min(self.policy.max_batch);
                let batch: Vec<Pending<T>> = queue.drain(..take).collect();
                if queue.is_empty() {
                    guard.by_expert.remove(&key);
                }
                return Some((key, batch));
            }
            if guard.closed {
                if guard.by_expert.is_empty() {
                    return None;
                }
                // Closed but work remains: flush immediately.
                let key = guard.by_expert.keys().next().unwrap().clone();
                let queue = guard.by_expert.get_mut(&key).unwrap();
                let take = queue.len().min(self.policy.max_batch);
                let batch: Vec<Pending<T>> = queue.drain(..take).collect();
                if queue.is_empty() {
                    guard.by_expert.remove(&key);
                }
                return Some((key, batch));
            }
            let (g, _) = self
                .cv
                .wait_timeout(guard, self.policy.max_wait.max(Duration::from_micros(200)))
                .unwrap();
            guard = g;
        }
    }

    fn pick(&self, q: &Queues<T>, prefer_resident: Option<&str>) -> Option<String> {
        let now = Instant::now();
        // 1. full batches for the resident expert (no swap, no wait).
        if let Some(res) = prefer_resident {
            if let Some(queue) = q.by_expert.get(res) {
                if queue.len() >= self.policy.max_batch {
                    return Some(res.to_string());
                }
            }
        }
        // 2. any full batch.
        for (k, queue) in &q.by_expert {
            if queue.len() >= self.policy.max_batch {
                return Some(k.clone());
            }
        }
        // 3. most-overdue head-of-line request.
        let mut best: Option<(String, Duration)> = None;
        for (k, queue) in &q.by_expert {
            if let Some(head) = queue.front() {
                let age = now.duration_since(head.enqueued);
                if age >= self.policy.max_wait
                    && best.as_ref().map_or(true, |(_, b)| age > *b)
                {
                    best = Some((k.clone(), age));
                }
            }
        }
        if let Some((k, _)) = best {
            return Some(k);
        }
        // 4. resident expert with any work (free to serve, still batches
        //    whatever is there once its head ages past max_wait — but if
        //    nothing else is pending we can serve it immediately).
        if q.by_expert.len() == 1 {
            if let Some(res) = prefer_resident {
                if q.by_expert.contains_key(res) {
                    return Some(res.to_string());
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_releases_immediately() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..3 {
            b.push("e1", i);
        }
        let (k, batch) = b.next_batch(None).unwrap();
        assert_eq!(k, "e1");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        b.push("e1", 1);
        let t0 = Instant::now();
        let (_, batch) = b.next_batch(None).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn resident_expert_preferred_for_full_batches() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        b.push("cold", 1);
        b.push("cold", 2);
        b.push("hot", 3);
        b.push("hot", 4);
        let (k, _) = b.next_batch(Some("hot")).unwrap();
        assert_eq!(k, "hot");
    }

    #[test]
    fn close_drains_and_terminates() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        }));
        b.push("e", 1);
        b.close();
        let (_, batch) = b.next_batch(None).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch(None).is_none());
    }

    #[test]
    fn cross_thread_flow() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }));
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..40 {
                    b.push(if i % 2 == 0 { "a" } else { "b" }, i);
                }
                b.close();
            })
        };
        let mut seen = 0;
        while let Some((_, batch)) = b.next_batch(None) {
            seen += batch.len();
        }
        producer.join().unwrap();
        assert_eq!(seen, 40);
    }
}
