//! Dynamic per-expert batching with weighted-fair tenant scheduling.
//!
//! Requests for the same expert are queued together and released as a
//! batch when either the batch-size target is reached or the oldest
//! request has waited past the deadline — the standard continuous-
//! batching trade-off (throughput vs tail latency) that multi-expert
//! serving systems make per adapter (S-LoRA, vLLM). The engine drains
//! one expert at a time, which maximizes reuse of the currently
//! resident expert between swaps.
//!
//! Each request carries a **tenant** tag; candidate queues at the same
//! pick rank are ordered by their head request's tenant *virtual time*
//! (start-time weighted fair queueing: `served / weight`), so a tenant
//! with weight `w` gets a `w`-proportional share of service under
//! contention. With a single tenant — or equal weights and balanced
//! traffic — every virtual time ties and the scheduler reduces to the
//! pre-WFQ (head age, expert id) order.
//!
//! All time-dependent decisions flow through an explicit `now` so the
//! load harness ([`crate::workload::sim`]) can drive the real scheduler
//! on a virtual clock: same pushes + same clock ⇒ same batches, at any
//! worker count.

use crate::util::sync::{rank, OrderedMutex};
use std::collections::{HashMap, VecDeque};
use std::sync::Condvar;
use std::time::{Duration, Instant};

/// One queued request (payload is opaque to the batcher).
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
    /// Tenant for weighted-fair scheduling (0 = default tenant).
    pub tenant: u32,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Release a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Virtual-time resolution: served-count units per weight unit.
const VT_SCALE: u64 = 1 << 20;

#[derive(Clone, Copy)]
struct TenantState {
    weight: u64,
    served: u64,
}

struct Queues<T> {
    /// Every iteration over this map reduces through a total order —
    /// sum (`queued`), min over (enqueued, id) (`next_deadline`,
    /// `flush_key`), or an explicit sort/min with an id tie-break
    /// (`plan`, `pick` rules 2–3) — so map order never leaks.
    // compeft-lint: allow(no-map-order) -- iterations reduce via total-order tie-breaks, see field doc
    by_expert: HashMap<String, VecDeque<Pending<T>>>,
    closed: bool,
    /// WFQ bookkeeping, keyed by tenant. Absent tenants have weight 1
    /// and zero service.
    // compeft-lint: allow(no-map-order) -- keyed access only, never iterated
    tenants: HashMap<u32, TenantState>,
}

impl<T> Queues<T> {
    /// WFQ virtual time of a tenant: service received divided by
    /// weight, in integer `VT_SCALE` units (deterministic, no floats).
    fn vtime(&self, tenant: u32) -> u64 {
        match self.tenants.get(&tenant) {
            Some(t) => t.served.saturating_mul(VT_SCALE) / t.weight.max(1),
            None => 0,
        }
    }

    fn charge(&mut self, tenant: u32, n: u64) {
        let e = self
            .tenants
            .entry(tenant)
            .or_insert(TenantState { weight: 1, served: 0 });
        e.served += n;
    }
}

/// Thread-safe batcher.
pub struct Batcher<T> {
    policy: BatchPolicy,
    queues: OrderedMutex<Queues<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            policy,
            queues: OrderedMutex::new(rank::BATCHER_QUEUES, "batcher.queues", Queues {
                by_expert: HashMap::new(), // compeft-lint: allow(no-map-order) -- see field doc
                closed: false,
                tenants: HashMap::new(), // compeft-lint: allow(no-map-order) -- see field doc
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request for an expert (default tenant, wall clock).
    pub fn push(&self, expert: &str, payload: T) {
        // compeft-lint: allow(no-wall-clock) -- engine-facing arrival stamp; sim paths inject `now` via push_at
        self.push_at(expert, 0, payload, Instant::now());
    }

    /// Enqueue a request for an expert with an explicit tenant and
    /// arrival time. The harness passes virtual-clock instants; arrival
    /// times within one expert queue must be non-decreasing for the
    /// head-of-line deadline logic to hold (FIFO per queue).
    pub fn push_at(&self, expert: &str, tenant: u32, payload: T, now: Instant) {
        let mut q = self.queues.lock().unwrap();
        q.by_expert
            .entry(expert.to_string())
            .or_default()
            .push_back(Pending { payload, enqueued: now, tenant });
        self.cv.notify_all();
    }

    /// Set a tenant's weighted-fair-scheduling weight (default 1;
    /// clamped to ≥ 1). Service already received is kept, so weights
    /// are best set before traffic starts.
    pub fn set_tenant_weight(&self, tenant: u32, weight: u64) {
        let mut q = self.queues.lock().unwrap();
        let e = q
            .tenants
            .entry(tenant)
            .or_insert(TenantState { weight: 1, served: 0 });
        e.weight = weight.max(1);
    }

    /// Signal shutdown: wakes waiters; remaining queued work is still
    /// drained by subsequent `next_batch` calls until empty.
    pub fn close(&self) {
        self.queues.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn queued(&self) -> usize {
        let q = self.queues.lock().unwrap();
        q.by_expert.values().map(|v| v.len()).sum()
    }

    /// Earliest instant at which some head-of-line request crosses the
    /// `max_wait` deadline (None when idle). The virtual-clock driver
    /// advances its clock to this point when no batch is ready.
    pub fn next_deadline(&self) -> Option<Instant> {
        let q = self.queues.lock().unwrap();
        q.by_expert
            .values()
            .filter_map(|queue| queue.front())
            .map(|head| head.enqueued + self.policy.max_wait)
            .min()
    }

    /// Pick the next batch: prefer the expert whose head-of-line
    /// request is most overdue; if none is overdue yet, prefer
    /// `prefer_resident` (the expert currently loaded — free to serve),
    /// then the fullest queue once it hits `max_batch`.
    ///
    /// Blocks until work is ready or (closed && empty) → None.
    pub fn next_batch(&self, prefer_resident: Option<&str>) -> Option<(String, Vec<Pending<T>>)> {
        let mut guard = self.queues.lock().unwrap();
        loop {
            // compeft-lint: allow(no-wall-clock) -- blocking engine loop runs on the wall clock; the sim drives try_next_batch
            if let Some(key) = self.pick(&guard, prefer_resident, Instant::now()) {
                return Some(self.drain(&mut guard, &key));
            }
            if guard.closed {
                if guard.by_expert.is_empty() {
                    return None;
                }
                // Closed but work remains: flush immediately.
                let key = Self::flush_key(&guard);
                return Some(self.drain(&mut guard, &key));
            }
            // Sleep only until the oldest head-of-line request crosses
            // its deadline, not a fixed max_wait per wakeup: a notify
            // that arrives mid-wait (another request landing) used to
            // reset the timer, so a lone request could wait up to
            // ~2× max_wait before release.
            let wait = {
                // compeft-lint: allow(no-wall-clock) -- deadline sleep for the blocking engine loop, wall time by design
                let now = Instant::now();
                let next_deadline = guard
                    .by_expert
                    .values()
                    .filter_map(|q| q.front())
                    .map(|head| head.enqueued + self.policy.max_wait)
                    .min();
                match next_deadline {
                    Some(dl) => dl.saturating_duration_since(now),
                    None => self.policy.max_wait,
                }
            };
            let (g, _) = guard
                .wait_timeout(&self.cv, wait.max(Duration::from_micros(200)))
                .unwrap();
            guard = g;
        }
    }

    /// Non-blocking pick at an explicit instant: the virtual-clock
    /// driver's entry point. Returns a batch if one is releasable at
    /// `now` (or the batcher is closed with work remaining), else None
    /// — the caller advances its clock to [`Batcher::next_deadline`]
    /// and retries.
    pub fn try_next_batch(
        &self,
        prefer_resident: Option<&str>,
        now: Instant,
    ) -> Option<(String, Vec<Pending<T>>)> {
        let mut guard = self.queues.lock().unwrap();
        if let Some(key) = self.pick(&guard, prefer_resident, now) {
            return Some(self.drain(&mut guard, &key));
        }
        if guard.closed && !guard.by_expert.is_empty() {
            let key = Self::flush_key(&guard);
            return Some(self.drain(&mut guard, &key));
        }
        None
    }

    /// Remove up to `max_batch` requests from `key`'s queue and charge
    /// the served tenants' virtual clocks.
    fn drain(&self, q: &mut Queues<T>, key: &str) -> (String, Vec<Pending<T>>) {
        let queue = q.by_expert.get_mut(key).expect("picked key exists");
        let take = queue.len().min(self.policy.max_batch);
        let batch: Vec<Pending<T>> = queue.drain(..take).collect();
        if queue.is_empty() {
            q.by_expert.remove(key);
        }
        for p in &batch {
            q.charge(p.tenant, 1);
        }
        (key.to_string(), batch)
    }

    /// Deterministic drain order for the post-close flush: oldest head
    /// first, ties by id (never HashMap iteration order).
    fn flush_key(q: &Queues<T>) -> String {
        q.by_expert
            .iter()
            .filter_map(|(k, queue)| queue.front().map(|h| (h.enqueued, k)))
            .min()
            .map(|(_, k)| k.clone())
            .expect("flush on non-empty queues")
    }

    /// Deterministic snapshot of upcoming work: expert ids in the order
    /// the scheduler will serve them, up to `n` entries. The prefetcher
    /// uses this lookahead to run the fetch+decode stages for the next
    /// experts while the engine executes the current batch. Does not
    /// mutate the queues.
    ///
    /// Ordering mirrors [`Batcher::next_batch`]'s pick: the resident
    /// expert's full batch first, then other full queues, then the
    /// remaining queues — within a rank by (tenant virtual time, oldest
    /// head-of-line request, expert id) so the plan is stable across
    /// calls.
    pub fn plan(&self, n: usize, prefer_resident: Option<&str>) -> Vec<String> {
        let q = self.queues.lock().unwrap();
        let mut entries: Vec<(&String, usize, u64, Instant)> = q
            .by_expert
            .iter()
            .filter_map(|(k, queue)| {
                queue.front().map(|h| (k, queue.len(), q.vtime(h.tenant), h.enqueued))
            })
            .collect();
        let rank = |id: &String, len: usize| -> u8 {
            if prefer_resident == Some(id.as_str()) && len >= self.policy.max_batch {
                0
            } else if len >= self.policy.max_batch {
                1
            } else {
                2
            }
        };
        entries.sort_by(|a, b| {
            (rank(a.0, a.1), a.2, a.3, a.0).cmp(&(rank(b.0, b.1), b.2, b.3, b.0))
        });
        entries.into_iter().take(n).map(|(k, _, _, _)| k.clone()).collect()
    }

    fn pick(&self, q: &Queues<T>, prefer_resident: Option<&str>, now: Instant) -> Option<String> {
        // 1. full batches for the resident expert (no swap, no wait).
        if let Some(res) = prefer_resident {
            if let Some(queue) = q.by_expert.get(res) {
                if queue.len() >= self.policy.max_batch {
                    return Some(res.to_string());
                }
            }
        }
        // 2. any full batch — ordered by the head request's tenant
        //    virtual time (weighted-fair share), then oldest head, then
        //    id. The trailing keys keep the choice deterministic and
        //    starvation-free (the old HashMap-iteration pick could land
        //    on the same "first" queue forever under sustained load);
        //    the leading vtime makes sustained contention split service
        //    by tenant weight.
        let mut full: Option<(&String, u64, Instant)> = None;
        for (k, queue) in &q.by_expert {
            if queue.len() >= self.policy.max_batch {
                let head = queue.front().expect("full queue has a head");
                let key = (q.vtime(head.tenant), head.enqueued);
                if full.map_or(true, |(bk, bv, bh)| (key, k) < ((bv, bh), bk)) {
                    full = Some((k, key.0, key.1));
                }
            }
        }
        if let Some((k, _, _)) = full {
            return Some(k.clone());
        }
        // 3. overdue head-of-line requests: lowest tenant virtual time
        //    first (fair share), then most-overdue, then id.
        let mut best: Option<(&String, u64, Duration)> = None;
        for (k, queue) in &q.by_expert {
            if let Some(head) = queue.front() {
                let age = now.saturating_duration_since(head.enqueued);
                if age < self.policy.max_wait {
                    continue;
                }
                let vt = q.vtime(head.tenant);
                let better = match best {
                    None => true,
                    Some((bk, bvt, bage)) => {
                        (vt, std::cmp::Reverse(age), k) < (bvt, std::cmp::Reverse(bage), bk)
                    }
                };
                if better {
                    best = Some((k, vt, age));
                }
            }
        }
        if let Some((k, _, _)) = best {
            return Some(k.clone());
        }
        // 4. resident expert with any work (free to serve, still batches
        //    whatever is there once its head ages past max_wait — but if
        //    nothing else is pending we can serve it immediately).
        if q.by_expert.len() == 1 {
            if let Some(res) = prefer_resident {
                if q.by_expert.contains_key(res) {
                    return Some(res.to_string());
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_releases_immediately() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..3 {
            b.push("e1", i);
        }
        let (k, batch) = b.next_batch(None).unwrap();
        assert_eq!(k, "e1");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(300),
        }));
        b.push("e1", 1);
        // A second request landing just before the deadline wakes the
        // waiter but must NOT reset its timer: the wait is computed
        // from the oldest head-of-line deadline, so release happens at
        // ~max_wait, not ~2× max_wait as with the old fixed re-sleep.
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(250));
                b.push("e1", 2);
            })
        };
        let t0 = Instant::now();
        let (_, batch) = b.next_batch(None).unwrap();
        let elapsed = t0.elapsed();
        producer.join().unwrap();
        // Usually 2 (the late request rides along); 1 only if a loaded
        // runner delays the producer past the deadline — the timing
        // bounds below are the actual regression check.
        assert!(!batch.is_empty());
        assert!(elapsed >= Duration::from_millis(290), "elapsed {elapsed:?}");
        // The old fixed re-sleep released at ~550 ms (250 ms wakeup +
        // a fresh 300 ms wait); the deadline-based wait releases at
        // ~300 ms. The 450 ms ceiling leaves ~150 ms of slack for a
        // loaded CI runner on either side of the verdict.
        assert!(
            elapsed < Duration::from_millis(450),
            "a mid-wait wakeup reset the deadline: {elapsed:?}"
        );
    }

    /// Regression: pick rule 2 used to iterate a `HashMap`, so with two
    /// persistently-full queues the chosen one was arbitrary and could
    /// starve the other indefinitely. Ties now break by oldest
    /// head-of-line request, which makes sustained full-load service
    /// alternate. (Both queues carry the default tenant, so the WFQ
    /// virtual times stay tied and age decides — the pre-WFQ order.)
    #[test]
    fn persistently_full_queues_alternate_instead_of_starving() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        b.push("a", 0);
        b.push("a", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("b", 2);
        b.push("b", 3);
        let mut order = Vec::new();
        for _ in 0..6 {
            let (k, batch) = b.next_batch(None).unwrap();
            assert_eq!(batch.len(), 2);
            // Keep the served queue persistently full: its refill is
            // newer than the other queue's waiting head.
            std::thread::sleep(Duration::from_millis(2));
            for v in 90..92 {
                b.push(&k, v);
            }
            order.push(k);
        }
        assert_eq!(order, ["a", "b", "a", "b", "a", "b"], "oldest head must win");
    }

    /// The prefetcher's lookahead: `plan` reports upcoming experts in
    /// deterministic service order without mutating the queues.
    #[test]
    fn plan_snapshots_upcoming_experts_in_service_order() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        // oldest head: "slow" (non-full), then "cold" fills, then "hot"
        // fills, then "tail" (non-full).
        b.push("slow", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("cold", 2);
        b.push("cold", 3);
        std::thread::sleep(Duration::from_millis(2));
        b.push("hot", 4);
        b.push("hot", 5);
        std::thread::sleep(Duration::from_millis(2));
        b.push("tail", 6);

        // Resident full batch first, then the other full queue (older
        // head first), then non-full queues by head age.
        assert_eq!(
            b.plan(10, Some("hot")),
            vec!["hot", "cold", "slow", "tail"],
            "resident full batch leads the plan"
        );
        // Without a resident, full queues rank by oldest head.
        assert_eq!(b.plan(10, None), vec!["cold", "hot", "slow", "tail"]);
        // Truncation, and no mutation happened above.
        assert_eq!(b.plan(2, None), vec!["cold", "hot"]);
        assert_eq!(b.queued(), 6);
        assert!(b.plan(0, None).is_empty());
    }

    #[test]
    fn resident_expert_preferred_for_full_batches() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        b.push("cold", 1);
        b.push("cold", 2);
        b.push("hot", 3);
        b.push("hot", 4);
        let (k, _) = b.next_batch(Some("hot")).unwrap();
        assert_eq!(k, "hot");
    }

    #[test]
    fn close_drains_and_terminates() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        }));
        b.push("e", 1);
        b.close();
        let (_, batch) = b.next_batch(None).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch(None).is_none());
    }

    #[test]
    fn cross_thread_flow() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }));
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..40 {
                    b.push(if i % 2 == 0 { "a" } else { "b" }, i);
                }
                b.close();
            })
        };
        let mut seen = 0;
        while let Some((_, batch)) = b.next_batch(None) {
            seen += batch.len();
        }
        producer.join().unwrap();
        assert_eq!(seen, 40);
    }

    /// Weighted-fair scheduling: two persistently backlogged tenants on
    /// separate experts with weights 1 and 3 receive service in a ~1:3
    /// ratio, with no wall-clock involved (virtual clock throughout).
    #[test]
    fn wfq_splits_service_by_tenant_weight() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_micros(1),
        });
        b.set_tenant_weight(1, 1);
        b.set_tenant_weight(2, 3);
        let t0 = Instant::now();
        for i in 0..400u64 {
            b.push_at("a", 1, i as u32, t0 + Duration::from_micros(i));
            b.push_at("b", 2, i as u32, t0 + Duration::from_micros(i));
        }
        // Everything is overdue at `now`: rule 3 (WFQ-first) governs.
        let now = t0 + Duration::from_secs(1);
        let (mut served_a, mut served_b) = (0u64, 0u64);
        for _ in 0..200 {
            let (k, batch) = b.try_next_batch(None, now).unwrap();
            assert_eq!(batch.len(), 1);
            match k.as_str() {
                "a" => served_a += 1,
                "b" => served_b += 1,
                other => panic!("unexpected expert {other}"),
            }
        }
        assert_eq!(served_a + served_b, 200);
        // 1:3 split up to integer rounding of the virtual clock.
        assert!(
            (served_b as i64 - 3 * served_a as i64).abs() <= 4,
            "weight-1 tenant got {served_a}, weight-3 tenant got {served_b}"
        );
    }

    /// The explicit-clock API is a pure function of (pushes, clock):
    /// replaying the same arrivals against the same instants yields the
    /// same batch sequence, and `next_deadline` reports the oldest
    /// head's release point.
    #[test]
    fn try_next_batch_is_deterministic_on_a_virtual_clock() {
        let run = || {
            let b: Batcher<u64> = Batcher::new(BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(5),
            });
            let t0 = Instant::now();
            let experts = ["x", "y", "x", "z", "y", "x", "z", "z"];
            for (i, e) in experts.iter().enumerate() {
                b.push_at(e, (i % 3) as u32, i as u64, t0 + Duration::from_micros(100 * i as u64));
            }
            assert_eq!(
                b.next_deadline().unwrap(),
                t0 + Duration::from_millis(5),
                "deadline tracks the oldest head"
            );
            let mut order: Vec<(String, Vec<u64>)> = Vec::new();
            let mut now = t0;
            while b.queued() > 0 {
                match b.try_next_batch(None, now) {
                    Some((k, batch)) => order
                        .push((k, batch.into_iter().map(|p| p.payload).collect())),
                    None => now = b.next_deadline().unwrap(),
                }
            }
            order
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same pushes + same clock must replay identically");
        let total: usize = a.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 8);
    }

    /// After close, try_next_batch flushes deterministically (oldest
    /// head first) instead of following HashMap iteration order.
    #[test]
    fn closed_flush_is_deterministic() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        });
        let t0 = Instant::now();
        b.push_at("late", 0, 1, t0 + Duration::from_millis(2));
        b.push_at("early", 0, 2, t0);
        b.push_at("mid", 0, 3, t0 + Duration::from_millis(1));
        b.close();
        let order: Vec<String> = std::iter::from_fn(|| {
            b.try_next_batch(None, t0).map(|(k, _)| k)
        })
        .collect();
        assert_eq!(order, ["early", "mid", "late"]);
    }
}
