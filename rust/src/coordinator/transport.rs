//! Simulated transfer links (paper §3.4 / Table 5 substrate).
//!
//! The paper measures wall-clock download (internet → local) and load
//! (CPU → GPU) times for original vs ComPEFT checkpoints. This image
//! has neither a network nor a GPU, so links are modeled as
//! latency + bytes/bandwidth pipes with *real sleeps* over the *real
//! encoded artifact bytes* — the original/compressed time ratio, which
//! is the paper's claim, is preserved exactly (DESIGN.md §3.5).
//!
//! A link serializes its transfers (one NIC / one PCIe lane): a
//! transfer begun while another is in flight queues behind it, which is
//! precisely the contention that makes expert swapping a bottleneck in
//! concurrent multi-expert serving (§1).
//!
//! Links are `Clone + Send + Sync` over shared state, and the prefetch
//! pipeline relies on that: background fetch threads and the engine
//! thread issue transfers on the *same* link, and both the wall-clock
//! queue (scaled sleeps) and the simulated queue (unscaled service
//! times on the sim clock, see [`SimLink::transfer`]) keep their FIFO
//! semantics under that concurrency — a prefetch does not get a free
//! ride past the NIC, it queues like any other transfer.

use crate::util::rng::Pcg;
use crate::util::sync::{rank, OrderedMutex};
use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Static description of a link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bytes per second.
    pub bandwidth: f64,
    /// One-way latency per transfer.
    pub latency: Duration,
}

impl LinkSpec {
    /// Internet download path (cloud checkpoint store → local disk).
    pub fn internet() -> LinkSpec {
        LinkSpec { bandwidth: 800e6, latency: Duration::from_millis(40) }
    }

    /// Host-to-accelerator path (PCIe 3.0 x16-ish).
    pub fn pcie() -> LinkSpec {
        LinkSpec { bandwidth: 12e9, latency: Duration::from_micros(10) }
    }

    /// Local NVMe read.
    pub fn disk() -> LinkSpec {
        LinkSpec { bandwidth: 2.5e9, latency: Duration::from_micros(80) }
    }

    /// Pure model: how long a transfer of `bytes` takes on an idle link.
    pub fn duration_for(&self, bytes: u64) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth)
    }
}

/// What happened to one faulted transfer attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Delivered normally.
    None,
    /// Delivered, but the service time grew by the given amount
    /// (congestion, retransmits).
    Delay(Duration),
    /// The transfer failed outright (node unreachable, connection
    /// reset). Only the connection latency was paid; no payload moved.
    Drop,
    /// The payload was delivered but corrupted in flight — the caller's
    /// integrity check (per-stripe CRC in the expert store) must catch
    /// it and re-fetch from another replica.
    Corrupt,
}

/// Fault probabilities and magnitudes of a [`FaultPlan`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSpec {
    /// Probability a transfer is delayed by `delay`.
    pub delay_p: f64,
    /// Extra service time of a delayed transfer.
    pub delay: Duration,
    /// Probability a transfer is dropped.
    pub drop_p: f64,
    /// Probability a transfer is corrupted in flight.
    pub corrupt_p: f64,
    /// When set, faults hit only `attempt == 0` of each stripe — every
    /// failover is then guaranteed to succeed, which is how the
    /// "drop-primary" / "corrupt-one-stripe" test plans keep ≥ 1
    /// surviving replica per stripe by construction.
    pub first_attempt_only: bool,
}

/// Deterministic, seeded fault injection for keyed transfers.
///
/// Faults are decided by a pure function of
/// `(seed, node, key, stripe, attempt)` — **not** by a per-link
/// transfer counter — so the fault sequence is independent of thread
/// interleaving: the same seed produces the same failover sequence and
/// counters at any worker count, which is what makes the fault suites
/// deterministic across pool sizes.
///
/// Unkeyed [`SimLink::transfer`] calls are never faulted; only the
/// sharded expert store issues keyed transfers.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
    /// Nodes that drop every transfer (the "kill-one-node" plan).
    dead_nodes: BTreeSet<usize>,
}

impl FaultPlan {
    /// A plan that injects nothing (the production default).
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan::new(seed, FaultSpec::default())
    }

    pub fn new(seed: u64, spec: FaultSpec) -> FaultPlan {
        FaultPlan { seed, spec, dead_nodes: BTreeSet::new() }
    }

    /// Mark a node dead: every transfer it serves is dropped.
    pub fn kill_node(mut self, node: usize) -> FaultPlan {
        self.dead_nodes.insert(node);
        self
    }

    /// True when this plan can never inject a fault.
    pub fn is_none(&self) -> bool {
        self.dead_nodes.is_empty()
            && self.spec.delay_p <= 0.0
            && self.spec.drop_p <= 0.0
            && self.spec.corrupt_p <= 0.0
    }

    /// Decide the fault for one transfer attempt. Pure: depends only on
    /// the plan and the `(node, key, stripe, attempt)` coordinates.
    pub fn decide(&self, node: usize, key: &str, stripe: u32, attempt: u32) -> Fault {
        if self.dead_nodes.contains(&node) {
            return Fault::Drop;
        }
        if self.spec.first_attempt_only && attempt > 0 {
            return Fault::None;
        }
        // The shared seeded FNV-1a key-fold (util::rng::fnv1a_64, also
        // the store placement's fold) xor'd with the coordinates, then
        // drawn through a Pcg stream: deterministic across platforms,
        // well mixed across neighboring stripes/attempts.
        let mut h = crate::util::rng::fnv1a_64(self.seed, key.as_bytes());
        h ^= ((node as u64) << 48) ^ ((stripe as u64) << 16) ^ attempt as u64;
        let u = Pcg::new(h, self.seed.rotate_left(17) | 1).next_f64();
        let FaultSpec { delay_p, drop_p, corrupt_p, delay, .. } = self.spec;
        if u < drop_p {
            Fault::Drop
        } else if u < drop_p + corrupt_p {
            Fault::Corrupt
        } else if u < drop_p + corrupt_p + delay_p {
            Fault::Delay(delay)
        } else {
            Fault::None
        }
    }
}

/// Result of a keyed (fault-injectable) transfer.
#[derive(Clone, Copy, Debug)]
pub struct FaultedTransfer {
    /// Simulated transfer time, including queueing and any injected
    /// delay. A dropped transfer still pays the connection latency.
    pub sim: Duration,
    /// What the fault plan did to this attempt.
    pub fault: Fault,
}

struct LinkState {
    /// Wall-clock instant the link drains in the *scaled* domain —
    /// governs how long callers actually sleep.
    busy_until: Option<Instant>,
    /// Simulated instant (seconds since `origin`, unscaled) the link
    /// drains — governs the simulated queueing reported to metrics.
    sim_free_at: f64,
    bytes_moved: u64,
    transfers: u64,
}

/// A shared, contended link.
#[derive(Clone)]
pub struct SimLink {
    pub name: &'static str,
    pub spec: LinkSpec,
    /// Multiplier on simulated time actually slept (1.0 = real time;
    /// benches may compress time, metrics always report simulated time).
    time_scale: f64,
    /// Epoch anchoring the simulated clock.
    origin: Instant,
    /// Fault injection for keyed transfers: the plan plus this link's
    /// node id in the store topology. Unkeyed transfers are unaffected.
    faults: Option<Arc<(FaultPlan, usize)>>,
    state: Arc<OrderedMutex<LinkState>>,
}

impl SimLink {
    pub fn new(name: &'static str, spec: LinkSpec) -> SimLink {
        SimLink {
            name,
            spec,
            time_scale: 1.0,
            origin: Instant::now(),
            faults: None,
            state: Arc::new(OrderedMutex::new(rank::LINK_STATE, "transport.link", LinkState {
                busy_until: None,
                sim_free_at: 0.0,
                bytes_moved: 0,
                transfers: 0,
            })),
        }
    }

    /// Attach a fault plan. `node` is this link's node id in the store
    /// topology — the coordinate the plan's decisions are keyed on.
    pub fn with_faults(mut self, plan: FaultPlan, node: usize) -> SimLink {
        self.faults = Some(Arc::new((plan, node)));
        self
    }

    /// Compress wall-clock sleeps by `scale` (metrics stay in simulated
    /// time). `scale = 0.0` disables sleeping entirely (pure model);
    /// simulated queueing is still tracked from unscaled service times,
    /// so contended transfers report bounded, physically meaningful
    /// queue waits at every scale.
    pub fn with_time_scale(mut self, scale: f64) -> SimLink {
        self.time_scale = scale.max(0.0);
        self
    }

    /// Transfer `bytes`; blocks for the simulated duration (scaled) and
    /// returns the *simulated* transfer time including queueing.
    ///
    /// Two clocks are kept deliberately separate. The **wall** queue
    /// (`busy_until`) lives in the scaled domain and only decides how
    /// long to sleep. The **simulated** queue (`sim_free_at`) is
    /// computed from *unscaled* service times: each transfer arrives at
    /// `sim_now` (wall time since the link's epoch mapped through the
    /// scale; at `scale = 0` wall time counts 1:1 as simulated idle
    /// time) and pushes the free-horizon out by its unscaled service
    /// time. Deriving simulated queueing by rescaling wall waits — the
    /// old implementation — divides `Instant` jitter by the scale,
    /// which at `scale = 0` amplified nanoseconds of noise into ~1e12×
    /// phantom queueing under contention.
    pub fn transfer(&self, bytes: u64) -> Duration {
        self.transfer_service(bytes, self.spec.duration_for(bytes))
    }

    /// Keyed transfer: like [`SimLink::transfer`], but subject to the
    /// attached [`FaultPlan`] (no plan → never faulted). The key
    /// coordinates `(key, stripe, attempt)` — not a transfer counter —
    /// select the fault, so concurrency cannot change the outcome.
    ///
    /// A [`Fault::Drop`] pays only the connection latency and moves no
    /// payload bytes; [`Fault::Delay`] stretches the service time;
    /// [`Fault::Corrupt`] transfers normally (the caller's integrity
    /// check is what detects the damage).
    pub fn transfer_keyed(
        &self,
        bytes: u64,
        key: &str,
        stripe: u32,
        attempt: u32,
    ) -> FaultedTransfer {
        let fault = match &self.faults {
            Some(f) => f.0.decide(f.1, key, stripe, attempt),
            None => Fault::None,
        };
        let sim = match fault {
            Fault::Drop => self.transfer_service(0, self.spec.latency),
            Fault::Delay(d) => {
                self.transfer_service(bytes, self.spec.duration_for(bytes) + d)
            }
            Fault::None | Fault::Corrupt => self.transfer(bytes),
        };
        FaultedTransfer { sim, fault }
    }

    /// The queueing core shared by every transfer flavor: occupy the
    /// link for `service` (both clocks), account `bytes`, sleep the
    /// scaled wall wait, return the simulated time including queueing.
    fn transfer_service(&self, bytes: u64, service: Duration) -> Duration {
        let now = Instant::now();
        let scale = self.time_scale;
        let (wall_wait, queue_sim) = {
            let mut st = self.state.lock().unwrap();
            // Wall queue position (scaled domain).
            let start = match st.busy_until {
                Some(b) if b > now => b,
                _ => now,
            };
            st.busy_until = Some(start + service.mul_f64(scale));
            // Simulated queue position (unscaled service times).
            let elapsed = now.duration_since(self.origin).as_secs_f64();
            let sim_now = if scale > 0.0 { elapsed / scale } else { elapsed };
            let queue_sim = (st.sim_free_at - sim_now).max(0.0);
            st.sim_free_at = sim_now + queue_sim + service.as_secs_f64();
            st.bytes_moved += bytes;
            st.transfers += 1;
            (start.saturating_duration_since(now), queue_sim)
        };
        let sleep = wall_wait + service.mul_f64(scale);
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        Duration::from_secs_f64(queue_sim) + service
    }

    pub fn bytes_moved(&self) -> u64 {
        self.state.lock().unwrap().bytes_moved
    }

    pub fn transfers(&self) -> u64 {
        self.state.lock().unwrap().transfers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_model_is_latency_plus_bw() {
        let spec = LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(10) };
        let d = spec.duration_for(1_000_000);
        assert!((d.as_secs_f64() - 1.01).abs() < 1e-9);
    }

    #[test]
    fn transfer_sleeps_and_accounts() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 10e6, latency: Duration::from_millis(1) },
        );
        let t0 = Instant::now();
        let sim = link.transfer(100_000); // 1ms + 10ms
        let wall = t0.elapsed();
        assert!(sim >= Duration::from_millis(10));
        assert!(wall >= Duration::from_millis(10), "wall={wall:?}");
        assert_eq!(link.bytes_moved(), 100_000);
        assert_eq!(link.transfers(), 1);
    }

    #[test]
    fn time_scale_compresses_wall_clock() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(100) },
        )
        .with_time_scale(0.01);
        let t0 = Instant::now();
        let sim = link.transfer(1_000_000); // sim ≈ 1.1s
        let wall = t0.elapsed();
        assert!(sim >= Duration::from_secs_f64(1.0));
        assert!(wall < Duration::from_millis(300), "wall={wall:?}");
    }

    #[test]
    fn contended_zero_scale_reports_bounded_queueing() {
        // time_scale = 0 is the pure model used by tests and benches:
        // no sleeping, but simulated queueing must still come out as
        // roughly the sum of the unscaled service times ahead — not the
        // ~1e12× explosion the old wall-rescaling produced.
        const THREADS: usize = 4;
        let service = Duration::from_millis(100); // latency-dominated
        let link = Arc::new(
            SimLink::new("t", LinkSpec { bandwidth: 1e9, latency: service })
                .with_time_scale(0.0),
        );
        let t0 = Instant::now();
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let l = Arc::clone(&link);
                std::thread::spawn(move || l.transfer(1000))
            })
            .collect();
        let sims: Vec<Duration> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let wall = t0.elapsed();

        // No sleeping at scale 0: the whole burst is near-instant.
        assert!(wall < Duration::from_millis(250), "wall={wall:?}");
        let max = sims.iter().max().unwrap();
        let min = sims.iter().min().unwrap();
        // Every transfer pays at least its own service time...
        assert!(*min >= service, "min={min:?}");
        // ...and the most-queued one pays at most the whole burst (plus
        // scheduling slack), far from the old pathological blow-up.
        let burst = service * THREADS as u32;
        assert!(
            *max <= burst + Duration::from_millis(150),
            "max={max:?} vs burst bound {burst:?}"
        );
        // Queueing was actually observed: the burst contended.
        assert!(*max > *min, "expected unequal queue positions, all={sims:?}");
        assert_eq!(link.transfers(), THREADS as u64);
    }

    #[test]
    fn spaced_transfers_at_zero_scale_do_not_queue() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e9, latency: Duration::from_millis(5) },
        )
        .with_time_scale(0.0);
        let a = link.transfer(1000);
        // Real wall time passes; the simulated link has long drained.
        std::thread::sleep(Duration::from_millis(20));
        let b = link.transfer(1000);
        let service = link.spec.duration_for(1000);
        assert_eq!(a, service);
        assert_eq!(b, service, "idle link must report pure service time");
    }

    /// The prefetch pipeline's usage pattern: background threads and
    /// the "engine" interleave transfers on one shared link across an
    /// extended burst. At any scale the accounting must stay exact and
    /// every simulated time bounded by the whole burst's service time —
    /// the PR 2 sim-clock/wall-clock separation must survive sustained
    /// multi-thread traffic, not just a single contended burst.
    #[test]
    fn interleaved_prefetch_and_engine_transfers_keep_queue_semantics() {
        const PREFETCH_THREADS: usize = 3;
        const PER_THREAD: usize = 5;
        let service = Duration::from_millis(10);
        let link = Arc::new(
            SimLink::new("net", LinkSpec { bandwidth: 1e9, latency: service })
                .with_time_scale(0.0),
        );
        let handles: Vec<_> = (0..PREFETCH_THREADS)
            .map(|_| {
                let l = Arc::clone(&link);
                std::thread::spawn(move || {
                    (0..PER_THREAD).map(|_| l.transfer(1_000)).collect::<Vec<_>>()
                })
            })
            .collect();
        // The "engine" transfers from this thread, interleaved.
        let mut engine_sims = Vec::new();
        for _ in 0..PER_THREAD {
            engine_sims.push(link.transfer(1_000));
        }
        let mut all: Vec<Duration> = engine_sims;
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let n = (PREFETCH_THREADS + 1) * PER_THREAD;
        assert_eq!(link.transfers(), n as u64);
        assert_eq!(link.bytes_moved(), n as u64 * 1_000);
        let per = link.spec.duration_for(1_000);
        for (i, sim) in all.iter().enumerate() {
            assert!(*sim >= per, "transfer {i}: {sim:?} below service time");
            assert!(
                *sim <= per * n as u32 + Duration::from_millis(100),
                "transfer {i}: {sim:?} exceeds the whole burst's service"
            );
        }
    }

    /// Fault decisions are a pure function of (seed, node, key, stripe,
    /// attempt): two plans with the same seed agree everywhere, the
    /// decision never depends on call order, and different seeds
    /// produce different sequences.
    #[test]
    fn fault_plan_is_deterministic_and_seeded() {
        let spec = FaultSpec {
            delay_p: 0.2,
            delay: Duration::from_millis(5),
            drop_p: 0.2,
            corrupt_p: 0.2,
            first_attempt_only: false,
        };
        let a = FaultPlan::new(42, spec);
        let b = FaultPlan::new(42, spec);
        let c = FaultPlan::new(43, spec);
        let mut seen = [0usize; 4];
        let mut differs_from_c = 0;
        for node in 0..3usize {
            for stripe in 0..40u32 {
                for attempt in 0..2u32 {
                    let fa = a.decide(node, "expert/x", stripe, attempt);
                    assert_eq!(fa, b.decide(node, "expert/x", stripe, attempt));
                    // Re-asking (any interleaving) never changes the answer.
                    assert_eq!(fa, a.decide(node, "expert/x", stripe, attempt));
                    if fa != c.decide(node, "expert/x", stripe, attempt) {
                        differs_from_c += 1;
                    }
                    seen[match fa {
                        Fault::None => 0,
                        Fault::Delay(_) => 1,
                        Fault::Drop => 2,
                        Fault::Corrupt => 3,
                    }] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&n| n > 0), "all fault kinds occur: {seen:?}");
        assert!(differs_from_c > 0, "a different seed must change the plan");

        // Dead nodes drop everything; first_attempt_only spares retries.
        let killed = FaultPlan::none(7).kill_node(1);
        assert_eq!(killed.decide(1, "e", 0, 0), Fault::Drop);
        assert_eq!(killed.decide(1, "e", 9, 3), Fault::Drop);
        assert_eq!(killed.decide(0, "e", 0, 0), Fault::None);
        let primary_only = FaultPlan::new(
            3,
            FaultSpec { drop_p: 1.0, first_attempt_only: true, ..Default::default() },
        );
        assert_eq!(primary_only.decide(0, "e", 5, 0), Fault::Drop);
        assert_eq!(primary_only.decide(0, "e", 5, 1), Fault::None);
        assert!(FaultPlan::none(0).is_none());
        assert!(!killed.is_none());
        assert!(!primary_only.is_none());
    }

    /// Keyed transfers apply the plan's timing semantics: a drop pays
    /// only latency and moves no bytes, a delay stretches the service
    /// time, an unfaulted keyed transfer equals a plain transfer.
    #[test]
    fn transfer_keyed_applies_fault_timing() {
        let spec = LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(10) };
        // drop_p = 1: every keyed transfer on this link is dropped.
        let dropper = SimLink::new("t", spec)
            .with_time_scale(0.0)
            .with_faults(
                FaultPlan::new(1, FaultSpec { drop_p: 1.0, ..Default::default() }),
                0,
            );
        let out = dropper.transfer_keyed(1_000_000, "e", 0, 0);
        assert_eq!(out.fault, Fault::Drop);
        assert_eq!(out.sim, spec.latency, "drop pays connection latency only");
        assert_eq!(dropper.bytes_moved(), 0, "no payload moved on a drop");
        assert_eq!(dropper.transfers(), 1);

        // delay_p = 1: service time grows by exactly the configured delay.
        let delay = Duration::from_millis(7);
        let delayer = SimLink::new("t", spec)
            .with_time_scale(0.0)
            .with_faults(
                FaultPlan::new(1, FaultSpec { delay_p: 1.0, delay, ..Default::default() }),
                0,
            );
        let out = delayer.transfer_keyed(1_000_000, "e", 0, 0);
        assert_eq!(out.fault, Fault::Delay(delay));
        assert_eq!(out.sim, spec.duration_for(1_000_000) + delay);
        assert_eq!(delayer.bytes_moved(), 1_000_000);

        // No plan attached: keyed == plain, never faulted.
        let clean = SimLink::new("t", spec).with_time_scale(0.0);
        let out = clean.transfer_keyed(1_000_000, "e", 0, 0);
        assert_eq!(out.fault, Fault::None);
        assert_eq!(out.sim, spec.duration_for(1_000_000));
    }

    #[test]
    fn contention_serializes() {
        let link = Arc::new(SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e9, latency: Duration::from_millis(20) },
        ));
        let l2 = Arc::clone(&link);
        let h = std::thread::spawn(move || l2.transfer(1000));
        let a = link.transfer(1000);
        let b = h.join().unwrap();
        // One of the two waited behind the other: total sim time of the
        // later one exceeds the idle-link service time.
        let max = a.max(b);
        assert!(max >= Duration::from_millis(39), "max={max:?}");
    }
}
