//! Simulated transfer links (paper §3.4 / Table 5 substrate).
//!
//! The paper measures wall-clock download (internet → local) and load
//! (CPU → GPU) times for original vs ComPEFT checkpoints. This image
//! has neither a network nor a GPU, so links are modeled as
//! latency + bytes/bandwidth pipes with *real sleeps* over the *real
//! encoded artifact bytes* — the original/compressed time ratio, which
//! is the paper's claim, is preserved exactly (DESIGN.md §3.5).
//!
//! A link serializes its transfers (one NIC / one PCIe lane): a
//! transfer begun while another is in flight queues behind it, which is
//! precisely the contention that makes expert swapping a bottleneck in
//! concurrent multi-expert serving (§1).
//!
//! Links are `Clone + Send + Sync` over shared state, and the prefetch
//! pipeline relies on that: background fetch threads and the engine
//! thread issue transfers on the *same* link, and both the wall-clock
//! queue (scaled sleeps) and the simulated queue (unscaled service
//! times on the sim clock, see [`SimLink::transfer`]) keep their FIFO
//! semantics under that concurrency — a prefetch does not get a free
//! ride past the NIC, it queues like any other transfer.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Static description of a link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bytes per second.
    pub bandwidth: f64,
    /// One-way latency per transfer.
    pub latency: Duration,
}

impl LinkSpec {
    /// Internet download path (cloud checkpoint store → local disk).
    pub fn internet() -> LinkSpec {
        LinkSpec { bandwidth: 800e6, latency: Duration::from_millis(40) }
    }

    /// Host-to-accelerator path (PCIe 3.0 x16-ish).
    pub fn pcie() -> LinkSpec {
        LinkSpec { bandwidth: 12e9, latency: Duration::from_micros(10) }
    }

    /// Local NVMe read.
    pub fn disk() -> LinkSpec {
        LinkSpec { bandwidth: 2.5e9, latency: Duration::from_micros(80) }
    }

    /// Pure model: how long a transfer of `bytes` takes on an idle link.
    pub fn duration_for(&self, bytes: u64) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth)
    }
}

struct LinkState {
    /// Wall-clock instant the link drains in the *scaled* domain —
    /// governs how long callers actually sleep.
    busy_until: Option<Instant>,
    /// Simulated instant (seconds since `origin`, unscaled) the link
    /// drains — governs the simulated queueing reported to metrics.
    sim_free_at: f64,
    bytes_moved: u64,
    transfers: u64,
}

/// A shared, contended link.
#[derive(Clone)]
pub struct SimLink {
    pub name: &'static str,
    pub spec: LinkSpec,
    /// Multiplier on simulated time actually slept (1.0 = real time;
    /// benches may compress time, metrics always report simulated time).
    time_scale: f64,
    /// Epoch anchoring the simulated clock.
    origin: Instant,
    state: Arc<Mutex<LinkState>>,
}

impl SimLink {
    pub fn new(name: &'static str, spec: LinkSpec) -> SimLink {
        SimLink {
            name,
            spec,
            time_scale: 1.0,
            origin: Instant::now(),
            state: Arc::new(Mutex::new(LinkState {
                busy_until: None,
                sim_free_at: 0.0,
                bytes_moved: 0,
                transfers: 0,
            })),
        }
    }

    /// Compress wall-clock sleeps by `scale` (metrics stay in simulated
    /// time). `scale = 0.0` disables sleeping entirely (pure model);
    /// simulated queueing is still tracked from unscaled service times,
    /// so contended transfers report bounded, physically meaningful
    /// queue waits at every scale.
    pub fn with_time_scale(mut self, scale: f64) -> SimLink {
        self.time_scale = scale.max(0.0);
        self
    }

    /// Transfer `bytes`; blocks for the simulated duration (scaled) and
    /// returns the *simulated* transfer time including queueing.
    ///
    /// Two clocks are kept deliberately separate. The **wall** queue
    /// (`busy_until`) lives in the scaled domain and only decides how
    /// long to sleep. The **simulated** queue (`sim_free_at`) is
    /// computed from *unscaled* service times: each transfer arrives at
    /// `sim_now` (wall time since the link's epoch mapped through the
    /// scale; at `scale = 0` wall time counts 1:1 as simulated idle
    /// time) and pushes the free-horizon out by its unscaled service
    /// time. Deriving simulated queueing by rescaling wall waits — the
    /// old implementation — divides `Instant` jitter by the scale,
    /// which at `scale = 0` amplified nanoseconds of noise into ~1e12×
    /// phantom queueing under contention.
    pub fn transfer(&self, bytes: u64) -> Duration {
        let now = Instant::now();
        let service = self.spec.duration_for(bytes);
        let scale = self.time_scale;
        let (wall_wait, queue_sim) = {
            let mut st = self.state.lock().unwrap();
            // Wall queue position (scaled domain).
            let start = match st.busy_until {
                Some(b) if b > now => b,
                _ => now,
            };
            st.busy_until = Some(start + service.mul_f64(scale));
            // Simulated queue position (unscaled service times).
            let elapsed = now.duration_since(self.origin).as_secs_f64();
            let sim_now = if scale > 0.0 { elapsed / scale } else { elapsed };
            let queue_sim = (st.sim_free_at - sim_now).max(0.0);
            st.sim_free_at = sim_now + queue_sim + service.as_secs_f64();
            st.bytes_moved += bytes;
            st.transfers += 1;
            (start.saturating_duration_since(now), queue_sim)
        };
        let sleep = wall_wait + service.mul_f64(scale);
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        Duration::from_secs_f64(queue_sim) + service
    }

    pub fn bytes_moved(&self) -> u64 {
        self.state.lock().unwrap().bytes_moved
    }

    pub fn transfers(&self) -> u64 {
        self.state.lock().unwrap().transfers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_model_is_latency_plus_bw() {
        let spec = LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(10) };
        let d = spec.duration_for(1_000_000);
        assert!((d.as_secs_f64() - 1.01).abs() < 1e-9);
    }

    #[test]
    fn transfer_sleeps_and_accounts() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 10e6, latency: Duration::from_millis(1) },
        );
        let t0 = Instant::now();
        let sim = link.transfer(100_000); // 1ms + 10ms
        let wall = t0.elapsed();
        assert!(sim >= Duration::from_millis(10));
        assert!(wall >= Duration::from_millis(10), "wall={wall:?}");
        assert_eq!(link.bytes_moved(), 100_000);
        assert_eq!(link.transfers(), 1);
    }

    #[test]
    fn time_scale_compresses_wall_clock() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(100) },
        )
        .with_time_scale(0.01);
        let t0 = Instant::now();
        let sim = link.transfer(1_000_000); // sim ≈ 1.1s
        let wall = t0.elapsed();
        assert!(sim >= Duration::from_secs_f64(1.0));
        assert!(wall < Duration::from_millis(300), "wall={wall:?}");
    }

    #[test]
    fn contended_zero_scale_reports_bounded_queueing() {
        // time_scale = 0 is the pure model used by tests and benches:
        // no sleeping, but simulated queueing must still come out as
        // roughly the sum of the unscaled service times ahead — not the
        // ~1e12× explosion the old wall-rescaling produced.
        const THREADS: usize = 4;
        let service = Duration::from_millis(100); // latency-dominated
        let link = Arc::new(
            SimLink::new("t", LinkSpec { bandwidth: 1e9, latency: service })
                .with_time_scale(0.0),
        );
        let t0 = Instant::now();
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let l = Arc::clone(&link);
                std::thread::spawn(move || l.transfer(1000))
            })
            .collect();
        let sims: Vec<Duration> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let wall = t0.elapsed();

        // No sleeping at scale 0: the whole burst is near-instant.
        assert!(wall < Duration::from_millis(250), "wall={wall:?}");
        let max = sims.iter().max().unwrap();
        let min = sims.iter().min().unwrap();
        // Every transfer pays at least its own service time...
        assert!(*min >= service, "min={min:?}");
        // ...and the most-queued one pays at most the whole burst (plus
        // scheduling slack), far from the old pathological blow-up.
        let burst = service * THREADS as u32;
        assert!(
            *max <= burst + Duration::from_millis(150),
            "max={max:?} vs burst bound {burst:?}"
        );
        // Queueing was actually observed: the burst contended.
        assert!(*max > *min, "expected unequal queue positions, all={sims:?}");
        assert_eq!(link.transfers(), THREADS as u64);
    }

    #[test]
    fn spaced_transfers_at_zero_scale_do_not_queue() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e9, latency: Duration::from_millis(5) },
        )
        .with_time_scale(0.0);
        let a = link.transfer(1000);
        // Real wall time passes; the simulated link has long drained.
        std::thread::sleep(Duration::from_millis(20));
        let b = link.transfer(1000);
        let service = link.spec.duration_for(1000);
        assert_eq!(a, service);
        assert_eq!(b, service, "idle link must report pure service time");
    }

    /// The prefetch pipeline's usage pattern: background threads and
    /// the "engine" interleave transfers on one shared link across an
    /// extended burst. At any scale the accounting must stay exact and
    /// every simulated time bounded by the whole burst's service time —
    /// the PR 2 sim-clock/wall-clock separation must survive sustained
    /// multi-thread traffic, not just a single contended burst.
    #[test]
    fn interleaved_prefetch_and_engine_transfers_keep_queue_semantics() {
        const PREFETCH_THREADS: usize = 3;
        const PER_THREAD: usize = 5;
        let service = Duration::from_millis(10);
        let link = Arc::new(
            SimLink::new("net", LinkSpec { bandwidth: 1e9, latency: service })
                .with_time_scale(0.0),
        );
        let handles: Vec<_> = (0..PREFETCH_THREADS)
            .map(|_| {
                let l = Arc::clone(&link);
                std::thread::spawn(move || {
                    (0..PER_THREAD).map(|_| l.transfer(1_000)).collect::<Vec<_>>()
                })
            })
            .collect();
        // The "engine" transfers from this thread, interleaved.
        let mut engine_sims = Vec::new();
        for _ in 0..PER_THREAD {
            engine_sims.push(link.transfer(1_000));
        }
        let mut all: Vec<Duration> = engine_sims;
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let n = (PREFETCH_THREADS + 1) * PER_THREAD;
        assert_eq!(link.transfers(), n as u64);
        assert_eq!(link.bytes_moved(), n as u64 * 1_000);
        let per = link.spec.duration_for(1_000);
        for (i, sim) in all.iter().enumerate() {
            assert!(*sim >= per, "transfer {i}: {sim:?} below service time");
            assert!(
                *sim <= per * n as u32 + Duration::from_millis(100),
                "transfer {i}: {sim:?} exceeds the whole burst's service"
            );
        }
    }

    #[test]
    fn contention_serializes() {
        let link = Arc::new(SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e9, latency: Duration::from_millis(20) },
        ));
        let l2 = Arc::clone(&link);
        let h = std::thread::spawn(move || l2.transfer(1000));
        let a = link.transfer(1000);
        let b = h.join().unwrap();
        // One of the two waited behind the other: total sim time of the
        // later one exceeds the idle-link service time.
        let max = a.max(b);
        assert!(max >= Duration::from_millis(39), "max={max:?}");
    }
}
