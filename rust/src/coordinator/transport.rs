//! Simulated transfer links (paper §3.4 / Table 5 substrate).
//!
//! The paper measures wall-clock download (internet → local) and load
//! (CPU → GPU) times for original vs ComPEFT checkpoints. This image
//! has neither a network nor a GPU, so links are modeled as
//! latency + bytes/bandwidth pipes with *real sleeps* over the *real
//! encoded artifact bytes* — the original/compressed time ratio, which
//! is the paper's claim, is preserved exactly (DESIGN.md §3.5).
//!
//! A link serializes its transfers (one NIC / one PCIe lane): a
//! transfer begun while another is in flight queues behind it, which is
//! precisely the contention that makes expert swapping a bottleneck in
//! concurrent multi-expert serving (§1).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Static description of a link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Bytes per second.
    pub bandwidth: f64,
    /// One-way latency per transfer.
    pub latency: Duration,
}

impl LinkSpec {
    /// Internet download path (cloud checkpoint store → local disk).
    pub fn internet() -> LinkSpec {
        LinkSpec { bandwidth: 800e6, latency: Duration::from_millis(40) }
    }

    /// Host-to-accelerator path (PCIe 3.0 x16-ish).
    pub fn pcie() -> LinkSpec {
        LinkSpec { bandwidth: 12e9, latency: Duration::from_micros(10) }
    }

    /// Local NVMe read.
    pub fn disk() -> LinkSpec {
        LinkSpec { bandwidth: 2.5e9, latency: Duration::from_micros(80) }
    }

    /// Pure model: how long a transfer of `bytes` takes on an idle link.
    pub fn duration_for(&self, bytes: u64) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth)
    }
}

struct LinkState {
    busy_until: Option<Instant>,
    bytes_moved: u64,
    transfers: u64,
}

/// A shared, contended link.
#[derive(Clone)]
pub struct SimLink {
    pub name: &'static str,
    pub spec: LinkSpec,
    /// Multiplier on simulated time actually slept (1.0 = real time;
    /// benches may compress time, metrics always report simulated time).
    time_scale: f64,
    state: Arc<Mutex<LinkState>>,
}

impl SimLink {
    pub fn new(name: &'static str, spec: LinkSpec) -> SimLink {
        SimLink {
            name,
            spec,
            time_scale: 1.0,
            state: Arc::new(Mutex::new(LinkState {
                busy_until: None,
                bytes_moved: 0,
                transfers: 0,
            })),
        }
    }

    /// Compress wall-clock sleeps by `scale` (metrics stay in simulated
    /// time). `scale = 0.0` disables sleeping entirely (pure model).
    pub fn with_time_scale(mut self, scale: f64) -> SimLink {
        self.time_scale = scale;
        self
    }

    /// Transfer `bytes`; blocks for the simulated duration (scaled) and
    /// returns the *simulated* transfer time including queueing.
    pub fn transfer(&self, bytes: u64) -> Duration {
        let now = Instant::now();
        let service = self.spec.duration_for(bytes);
        let (queue_wait, _done) = {
            let mut st = self.state.lock().unwrap();
            let start = match st.busy_until {
                Some(b) if b > now => b,
                _ => now,
            };
            let done = start + service.mul_f64(self.time_scale.max(1e-12));
            st.busy_until = Some(done);
            st.bytes_moved += bytes;
            st.transfers += 1;
            (start.saturating_duration_since(now), done)
        };
        let sleep = queue_wait + service.mul_f64(self.time_scale);
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        // Simulated time: queueing (rescaled back) + service.
        Duration::from_secs_f64(
            queue_wait.as_secs_f64() / self.time_scale.max(1e-12),
        ) + service
    }

    pub fn bytes_moved(&self) -> u64 {
        self.state.lock().unwrap().bytes_moved
    }

    pub fn transfers(&self) -> u64 {
        self.state.lock().unwrap().transfers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_model_is_latency_plus_bw() {
        let spec = LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(10) };
        let d = spec.duration_for(1_000_000);
        assert!((d.as_secs_f64() - 1.01).abs() < 1e-9);
    }

    #[test]
    fn transfer_sleeps_and_accounts() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 10e6, latency: Duration::from_millis(1) },
        );
        let t0 = Instant::now();
        let sim = link.transfer(100_000); // 1ms + 10ms
        let wall = t0.elapsed();
        assert!(sim >= Duration::from_millis(10));
        assert!(wall >= Duration::from_millis(10), "wall={wall:?}");
        assert_eq!(link.bytes_moved(), 100_000);
        assert_eq!(link.transfers(), 1);
    }

    #[test]
    fn time_scale_compresses_wall_clock() {
        let link = SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e6, latency: Duration::from_millis(100) },
        )
        .with_time_scale(0.01);
        let t0 = Instant::now();
        let sim = link.transfer(1_000_000); // sim ≈ 1.1s
        let wall = t0.elapsed();
        assert!(sim >= Duration::from_secs_f64(1.0));
        assert!(wall < Duration::from_millis(300), "wall={wall:?}");
    }

    #[test]
    fn contention_serializes() {
        let link = Arc::new(SimLink::new(
            "t",
            LinkSpec { bandwidth: 1e9, latency: Duration::from_millis(20) },
        ));
        let l2 = Arc::clone(&link);
        let h = std::thread::spawn(move || l2.transfer(1000));
        let a = link.transfer(1000);
        let b = h.join().unwrap();
        // One of the two waited behind the other: total sim time of the
        // later one exceeds the idle-link service time.
        let max = a.max(b);
        assert!(max >= Duration::from_millis(39), "max={max:?}");
    }
}
