//! Byte-budgeted LRU tiers for expert residency.
//!
//! The serving hierarchy (paper §1): a few experts fit in accelerator
//! memory ("GPU" tier), more fit in host RAM ("CPU" tier, encoded),
//! everything lives on disk/remote. The engine promotes an expert up
//! the hierarchy on demand and evicts least-recently-used experts when
//! a tier's byte budget is exceeded — smaller (ComPEFT) experts ⇒ more
//! experts per tier ⇒ fewer evictions and cheaper refills, which is the
//! mechanism behind the paper's latency claims.

use std::collections::HashMap;

/// An LRU map with a byte budget.
#[derive(Debug)]
pub struct LruTier<V> {
    name: &'static str,
    capacity_bytes: u64,
    used_bytes: u64,
    clock: u64,
    entries: HashMap<String, (V, u64, u64)>, // value, bytes, last_use
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<V> LruTier<V> {
    pub fn new(name: &'static str, capacity_bytes: u64) -> LruTier<V> {
        LruTier {
            name,
            capacity_bytes,
            used_bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn contains(&self, id: &str) -> bool {
        self.entries.contains_key(id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Look up and touch.
    pub fn get(&mut self, id: &str) -> Option<&V> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(id) {
            Some((v, _, last)) => {
                *last = clock;
                self.hits += 1;
                Some(&*v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert, evicting LRU entries as needed. Returns evicted
    /// (id, value, bytes) tuples (for demotion to a lower tier). When
    /// `id` was already resident, its displaced value is returned
    /// first, ahead of any LRU evictions.
    pub fn insert(&mut self, id: &str, value: V, bytes: u64) -> Vec<(String, V, u64)> {
        let mut evicted = Vec::new();
        // Displace any existing copy first — and *return* it: silently
        // dropping it meant a re-registered expert's prior resident
        // never demoted to the lower tier, unlike every other entry
        // this insert pushes out.
        if let Some((old, old_bytes, _)) = self.entries.remove(id) {
            self.used_bytes -= old_bytes;
            evicted.push((id.to_string(), old, old_bytes));
        }
        while self.used_bytes + bytes > self.capacity_bytes && !self.entries.is_empty() {
            // Find LRU.
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, (_, _, last))| *last)
                .map(|(k, _)| k.clone())
                .unwrap();
            let (v, b, _) = self.entries.remove(&victim).unwrap();
            self.used_bytes -= b;
            self.evictions += 1;
            evicted.push((victim, v, b));
        }
        self.clock += 1;
        self.entries.insert(id.to_string(), (value, bytes, self.clock));
        self.used_bytes += bytes;
        evicted
    }

    /// Remove a specific entry.
    pub fn remove(&mut self, id: &str) -> Option<(V, u64)> {
        self.entries.remove(id).map(|(v, b, _)| {
            self.used_bytes -= b;
            (v, b)
        })
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            name: self.name,
            entries: self.entries.len(),
            used_bytes: self.used_bytes,
            capacity_bytes: self.capacity_bytes,
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
        }
    }
}

/// Snapshot of a tier's counters.
#[derive(Clone, Copy, Debug)]
pub struct TierStats {
    pub name: &'static str,
    pub entries: usize,
    pub used_bytes: u64,
    pub capacity_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl TierStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_oldest_by_bytes() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        assert!(t.insert("a", 1, 40).is_empty());
        assert!(t.insert("b", 2, 40).is_empty());
        t.get("a"); // b is now LRU
        let ev = t.insert("c", 3, 40);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].0, "b");
        assert!(t.contains("a") && t.contains("c"));
        assert_eq!(t.used_bytes(), 80);
    }

    #[test]
    fn oversized_insert_evicts_everything_then_admits() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 50);
        t.insert("a", 1, 30);
        let ev = t.insert("big", 2, 100);
        assert_eq!(ev.len(), 1);
        assert!(t.contains("big")); // admitted even though over budget (singleton)
    }

    #[test]
    fn reinsert_replaces_without_leak() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        t.insert("a", 1, 40);
        let displaced = t.insert("a", 2, 60);
        assert_eq!(t.used_bytes(), 60);
        assert_eq!(t.len(), 1);
        // The displaced value comes back for demotion instead of being
        // silently dropped.
        assert_eq!(displaced, vec![("a".to_string(), 1, 40)]);
        assert_eq!(t.get("a"), Some(&2));
    }

    /// Regression for the demotion leak: replacing an id must hand the
    /// old value back alongside (and ahead of) LRU evictions, so the
    /// caller can demote it like any other displaced resident.
    #[test]
    fn reinsert_returns_old_value_before_lru_evictions() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        t.insert("a", 1, 50);
        t.insert("b", 2, 50);
        t.get("a"); // b is LRU
        // Replacing "a" with a bigger entry displaces old "a" AND
        // evicts "b" to make room.
        let out = t.insert("a", 3, 90);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], ("a".to_string(), 1, 50), "replaced value first");
        assert_eq!(out[1], ("b".to_string(), 2, 50), "then LRU evictions");
        assert_eq!(t.len(), 1);
        assert_eq!(t.used_bytes(), 90);
        assert_eq!(t.get("a"), Some(&3));
        // Eviction counters track only true LRU evictions.
        assert_eq!(t.stats().evictions, 1);
    }

    #[test]
    fn stats_track_hits_misses() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        t.insert("a", 1, 10);
        t.get("a");
        t.get("zz");
        let s = t.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn smaller_entries_mean_more_residents() {
        // The paper's core serving argument, as a cache property: at a
        // fixed byte budget, 16x-smaller experts ⇒ 16x more resident.
        let mut orig: LruTier<()> = LruTier::new("gpu", 1600);
        let mut comp: LruTier<()> = LruTier::new("gpu", 1600);
        for i in 0..32 {
            orig.insert(&format!("e{i}"), (), 400); // 4 fit
            comp.insert(&format!("e{i}"), (), 25); // 32 fit
        }
        assert_eq!(orig.len(), 4);
        assert_eq!(comp.len(), 32);
    }
}
