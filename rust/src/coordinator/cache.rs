//! Byte-budgeted LRU tiers for expert residency.
//!
//! The serving hierarchy (paper §1): a few experts fit in accelerator
//! memory ("GPU" tier), more fit in host RAM ("CPU" tier, encoded),
//! everything lives on disk/remote. The engine promotes an expert up
//! the hierarchy on demand and evicts least-recently-used experts when
//! a tier's byte budget is exceeded — smaller (ComPEFT) experts ⇒ more
//! experts per tier ⇒ fewer evictions and cheaper refills, which is the
//! mechanism behind the paper's latency claims.
//!
//! Entries can be **pinned** ([`LruTier::pin`]): the prefetch pipeline
//! pins an expert's encoded bytes in the host tier while a background
//! decode is in flight, so concurrent inserts cannot evict the payload
//! mid-decode. Pinned entries are passed over by the eviction scan; if
//! only pinned entries remain, an insert is admitted over budget.

use std::collections::HashMap;

#[derive(Debug)]
struct Entry<V> {
    value: V,
    bytes: u64,
    last_use: u64,
    /// Pin count. Entries with `pins > 0` are exempt from LRU eviction
    /// — the prefetch pipeline pins an expert's encoded bytes while a
    /// background decode is in flight so a concurrent insert cannot
    /// evict the payload out from under it. A count (not a flag):
    /// several concurrent prepares may pin the same id (e.g. a stored
    /// expert and a composition sharing it as a member), and one
    /// finishing must not unpin the others.
    pins: u32,
}

/// An LRU map with a byte budget.
#[derive(Debug)]
pub struct LruTier<V> {
    name: &'static str,
    capacity_bytes: u64,
    used_bytes: u64,
    clock: u64,
    /// Eviction scans pick `min_by_key` over `last_use`, a strictly
    /// increasing logical clock that is unique per entry — the victim
    /// is the same whatever order the map iterates.
    // compeft-lint: allow(no-map-order) -- eviction min_by_key over the unique last_use clock is order-free
    entries: HashMap<String, Entry<V>>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<V> LruTier<V> {
    pub fn new(name: &'static str, capacity_bytes: u64) -> LruTier<V> {
        LruTier {
            name,
            capacity_bytes,
            used_bytes: 0,
            clock: 0,
            entries: HashMap::new(), // compeft-lint: allow(no-map-order) -- see field doc
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn contains(&self, id: &str) -> bool {
        self.entries.contains_key(id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Look up and touch.
    pub fn get(&mut self, id: &str) -> Option<&V> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(id) {
            Some(e) => {
                e.last_use = clock;
                self.hits += 1;
                Some(&e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Pin an entry (incrementing its pin count): pinned entries are
    /// never chosen for LRU eviction (an over-budget insert admits over
    /// budget rather than evict a pinned entry). Returns false when the
    /// id is not resident.
    pub fn pin(&mut self, id: &str) -> bool {
        match self.entries.get_mut(id) {
            Some(e) => {
                e.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Drop one pin; the entry becomes evictable again when the last
    /// pin is released. Returns false when the id is not resident.
    pub fn unpin(&mut self, id: &str) -> bool {
        match self.entries.get_mut(id) {
            Some(e) => {
                e.pins = e.pins.saturating_sub(1);
                true
            }
            None => false,
        }
    }

    /// Number of currently pinned entries.
    pub fn pinned_count(&self) -> usize {
        self.entries.values().filter(|e| e.pins > 0).count()
    }

    /// Insert, evicting LRU entries as needed. Returns evicted
    /// (id, value, bytes) tuples (for demotion to a lower tier). When
    /// `id` was already resident, its displaced value is returned
    /// first, ahead of any LRU evictions. Pinned entries are skipped by
    /// the eviction scan; when only pinned entries remain, the insert
    /// is admitted over budget (mirroring the singleton case) so an
    /// in-flight prefetch can never lose its source bytes.
    pub fn insert(&mut self, id: &str, value: V, bytes: u64) -> Vec<(String, V, u64)> {
        let mut evicted = Vec::new();
        // Displace any existing copy first — and *return* it: silently
        // dropping it meant a re-registered expert's prior resident
        // never demoted to the lower tier, unlike every other entry
        // this insert pushes out.
        if let Some(old) = self.entries.remove(id) {
            self.used_bytes -= old.bytes;
            evicted.push((id.to_string(), old.value, old.bytes));
        }
        while self.used_bytes + bytes > self.capacity_bytes && !self.entries.is_empty() {
            // Find the LRU entry among unpinned candidates.
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else {
                break; // everything left is pinned: admit over budget
            };
            let e = self.entries.remove(&victim).unwrap();
            self.used_bytes -= e.bytes;
            self.evictions += 1;
            evicted.push((victim, e.value, e.bytes));
        }
        self.clock += 1;
        self.entries.insert(
            id.to_string(),
            Entry { value, bytes, last_use: self.clock, pins: 0 },
        );
        self.used_bytes += bytes;
        evicted
    }

    /// Remove a specific entry.
    pub fn remove(&mut self, id: &str) -> Option<(V, u64)> {
        self.entries.remove(id).map(|e| {
            self.used_bytes -= e.bytes;
            (e.value, e.bytes)
        })
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            name: self.name,
            entries: self.entries.len(),
            used_bytes: self.used_bytes,
            capacity_bytes: self.capacity_bytes,
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
        }
    }
}

/// Snapshot of a tier's counters.
#[derive(Clone, Copy, Debug)]
pub struct TierStats {
    pub name: &'static str,
    pub entries: usize,
    pub used_bytes: u64,
    pub capacity_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl TierStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_oldest_by_bytes() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        assert!(t.insert("a", 1, 40).is_empty());
        assert!(t.insert("b", 2, 40).is_empty());
        t.get("a"); // b is now LRU
        let ev = t.insert("c", 3, 40);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].0, "b");
        assert!(t.contains("a") && t.contains("c"));
        assert_eq!(t.used_bytes(), 80);
    }

    #[test]
    fn oversized_insert_evicts_everything_then_admits() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 50);
        t.insert("a", 1, 30);
        let ev = t.insert("big", 2, 100);
        assert_eq!(ev.len(), 1);
        assert!(t.contains("big")); // admitted even though over budget (singleton)
    }

    #[test]
    fn reinsert_replaces_without_leak() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        t.insert("a", 1, 40);
        let displaced = t.insert("a", 2, 60);
        assert_eq!(t.used_bytes(), 60);
        assert_eq!(t.len(), 1);
        // The displaced value comes back for demotion instead of being
        // silently dropped.
        assert_eq!(displaced, vec![("a".to_string(), 1, 40)]);
        assert_eq!(t.get("a"), Some(&2));
    }

    /// Regression for the demotion leak: replacing an id must hand the
    /// old value back alongside (and ahead of) LRU evictions, so the
    /// caller can demote it like any other displaced resident.
    #[test]
    fn reinsert_returns_old_value_before_lru_evictions() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        t.insert("a", 1, 50);
        t.insert("b", 2, 50);
        t.get("a"); // b is LRU
        // Replacing "a" with a bigger entry displaces old "a" AND
        // evicts "b" to make room.
        let out = t.insert("a", 3, 90);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], ("a".to_string(), 1, 50), "replaced value first");
        assert_eq!(out[1], ("b".to_string(), 2, 50), "then LRU evictions");
        assert_eq!(t.len(), 1);
        assert_eq!(t.used_bytes(), 90);
        assert_eq!(t.get("a"), Some(&3));
        // Eviction counters track only true LRU evictions.
        assert_eq!(t.stats().evictions, 1);
    }

    /// Pinning contract for the prefetch pipeline: a pinned entry
    /// survives inserts that would otherwise evict it (the tier admits
    /// over budget instead), and unpinning restores evictability.
    #[test]
    fn pinned_entries_survive_over_budget_insert() {
        let mut t: LruTier<i32> = LruTier::new("cpu", 100);
        t.insert("decoding", 1, 60);
        assert!(t.pin("decoding"));
        assert_eq!(t.pinned_count(), 1);
        assert!(!t.pin("absent"), "pin of a missing id reports false");

        // The insert needs 60 bytes freed, but the only candidate is
        // pinned: nothing is evicted and the tier runs over budget.
        let ev = t.insert("newcomer", 2, 60);
        assert!(ev.is_empty(), "pinned entry must not be evicted");
        assert!(t.contains("decoding") && t.contains("newcomer"));
        assert_eq!(t.used_bytes(), 120);
        assert_eq!(t.stats().evictions, 0);

        // With an unpinned sibling present, eviction passes over the
        // pinned entry even when it is the least recently used.
        t.get("newcomer"); // "decoding" is now strictly LRU
        let ev = t.insert("third", 3, 40);
        assert_eq!(ev.len(), 1, "the unpinned sibling goes; pinned stays");
        assert_eq!(ev[0].0, "newcomer");
        assert!(t.contains("decoding"));

        // Unpin: the entry becomes a normal LRU citizen again.
        assert!(t.unpin("decoding"));
        assert_eq!(t.pinned_count(), 0);
        let ev = t.insert("fourth", 4, 90);
        assert!(
            ev.iter().any(|(id, _, _)| id == "decoding"),
            "unpinned entry is evictable again: {ev:?}"
        );
    }

    #[test]
    fn replacing_a_pinned_id_clears_the_pin() {
        let mut t: LruTier<i32> = LruTier::new("cpu", 100);
        t.insert("a", 1, 40);
        t.pin("a");
        let displaced = t.insert("a", 2, 40);
        assert_eq!(displaced, vec![("a".to_string(), 1, 40)]);
        assert_eq!(t.pinned_count(), 0, "fresh insert starts unpinned");
    }

    /// Pins are a count, not a flag: two concurrent prepares pinning
    /// the same id (a stored expert also serving as a composition
    /// member) must both release before the entry is evictable.
    #[test]
    fn pins_are_refcounted() {
        let mut t: LruTier<i32> = LruTier::new("cpu", 100);
        t.insert("shared", 1, 60);
        t.pin("shared");
        t.pin("shared");
        t.unpin("shared"); // first prepare finished; second still running
        assert_eq!(t.pinned_count(), 1);
        let ev = t.insert("other", 2, 60);
        assert!(ev.is_empty(), "entry with a live pin must survive");
        assert!(t.contains("shared"));
        t.unpin("shared"); // last pin released
        assert_eq!(t.pinned_count(), 0);
        let ev = t.insert("third", 3, 60);
        assert!(
            ev.iter().any(|(id, _, _)| id == "shared"),
            "fully unpinned entry is evictable: {ev:?}"
        );
        // Underflow guard: spurious extra unpin stays at zero.
        t.insert("z", 9, 1);
        t.unpin("z");
        t.unpin("z");
        assert_eq!(t.pinned_count(), 0);
    }

    #[test]
    fn stats_track_hits_misses() {
        let mut t: LruTier<i32> = LruTier::new("gpu", 100);
        t.insert("a", 1, 10);
        t.get("a");
        t.get("zz");
        let s = t.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    /// The host tier stores zero-copy [`Payload`] views: a `get` hands
    /// out a clone of the view (refcount bump, same bytes), so an entry
    /// evicted while a decode still borrows it cannot invalidate the
    /// in-flight bytes — the view keeps the backing alive past both
    /// LRU eviction and explicit removal. This is the safety net under
    /// the pipeline's `PinGuard` (the pin only guarantees *residency*,
    /// not validity).
    #[test]
    fn evicted_payload_views_stay_valid_for_borrowers() {
        use crate::compeft::payload::Payload;

        let mut t: LruTier<Payload> = LruTier::new("cpu", 100);
        let original: Vec<u8> = (0..60u8).collect();
        t.insert("decoding", Payload::from_vec(original.clone()), 60);

        // A prepare grabs the view (as `fetch_via_cpu_tier` does) and
        // starts "decoding" from it...
        let borrowed = t.get("decoding").unwrap().clone();
        assert_eq!(
            borrowed.as_slice().as_ptr(),
            t.get("decoding").unwrap().as_slice().as_ptr(),
            "tier hit is a view of the resident bytes, not a copy"
        );

        // ...then a burst of inserts evicts the entry mid-decode.
        let ev = t.insert("newcomer", Payload::from_vec(vec![9u8; 70]), 70);
        assert!(
            ev.iter().any(|(id, _, _)| id == "decoding"),
            "unpinned entry was evicted: {ev:?}"
        );
        drop(ev); // the tier's handle on the bytes is gone for good
        assert!(!t.contains("decoding"));

        // The borrowed view still reads the original bytes in place.
        assert_eq!(borrowed, original);
        let tail = borrowed.slice(50, 10).unwrap();
        assert_eq!(&*tail, &original[50..]);

        // Same story for explicit removal while borrowed.
        let b2 = t.get("newcomer").unwrap().clone();
        t.remove("newcomer").unwrap();
        assert_eq!(b2, vec![9u8; 70]);
    }

    #[test]
    fn smaller_entries_mean_more_residents() {
        // The paper's core serving argument, as a cache property: at a
        // fixed byte budget, 16x-smaller experts ⇒ 16x more resident.
        let mut orig: LruTier<()> = LruTier::new("gpu", 1600);
        let mut comp: LruTier<()> = LruTier::new("gpu", 1600);
        for i in 0..32 {
            orig.insert(&format!("e{i}"), (), 400); // 4 fit
            comp.insert(&format!("e{i}"), (), 25); // 32 fit
        }
        assert_eq!(orig.len(), 4);
        assert_eq!(comp.len(), 32);
    }
}
