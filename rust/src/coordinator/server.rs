//! The serving engine: request loop, expert residency, batched
//! execution through the PJRT runtime.
//!
//! Architecture (single accelerator, matching the paper's serving
//! story): client threads submit requests tagged with an expert id; the
//! [`Batcher`] groups them per expert; one **engine thread** owns the
//! [`ModelBundle`] (device buffers are not `Send`) and drains batches,
//! swapping experts through the tiered cache + simulated links when the
//! target expert is not GPU-resident.

use crate::coordinator::batcher::{BatchPolicy, Batcher, Pending};
use crate::coordinator::cache::{LruTier, TierStats};
use crate::coordinator::loader::ExpertLoader;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot, RequestTiming};
use crate::coordinator::registry::{ExpertMethod, ExpertRecord, Registry};
use crate::coordinator::transport::{LinkSpec, SimLink};
use crate::eval::ANSWER_BASE;
use crate::runtime::{AdapterKind, ModelBundle, Runtime};

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serving batch size must match an exported executable batch.
pub const SERVE_BATCH: usize = 8;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifacts: PathBuf,
    pub scale: String,
    pub policy: BatchPolicy,
    /// Byte budget of the accelerator tier (decoded adapter bytes).
    pub gpu_capacity_bytes: u64,
    /// Byte budget of the host tier (encoded checkpoint bytes).
    pub cpu_capacity_bytes: u64,
    pub net: LinkSpec,
    pub pcie: LinkSpec,
    /// Wall-clock compression for simulated links (1.0 = real time).
    pub time_scale: f64,
}

impl CoordinatorConfig {
    pub fn new(artifacts: PathBuf, scale: &str) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts,
            scale: scale.to_string(),
            policy: BatchPolicy::default(),
            gpu_capacity_bytes: 2 << 20,
            cpu_capacity_bytes: 64 << 20,
            net: LinkSpec::internet(),
            pcie: LinkSpec::pcie(),
            time_scale: 1.0,
        }
    }
}

/// A single inference request (one example).
struct ClientRequest {
    tokens: Vec<i32>,
    n_classes: usize,
    resp: mpsc::Sender<Prediction>,
}

/// Response: predicted class + latency breakdown.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub class: usize,
    pub timing: RequestTiming,
}

/// Final engine accounting returned at shutdown.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub gpu: TierStats,
    pub cpu: TierStats,
    pub net_bytes: u64,
    pub pcie_bytes: u64,
    pub batches: u64,
}

/// Public handle: submit requests, read metrics, shut down.
pub struct Coordinator {
    batcher: Arc<Batcher<ClientRequest>>,
    metrics: Arc<Metrics>,
    /// Kept for external byte accounting while the engine runs.
    pub net: SimLink,
    pub pcie: SimLink,
    engine: Option<std::thread::JoinHandle<Result<EngineReport>>>,
}

impl Coordinator {
    /// Start the engine. Blocks until the model bundle is loaded and
    /// executables for the serve batch are compiled.
    pub fn start(cfg: CoordinatorConfig, registry: Registry) -> Result<Coordinator> {
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let metrics = Arc::new(Metrics::new());
        let net = SimLink::new("net", cfg.net).with_time_scale(cfg.time_scale);
        let pcie = SimLink::new("pcie", cfg.pcie).with_time_scale(cfg.time_scale);

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let engine = {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let net = net.clone();
            let pcie = pcie.clone();
            std::thread::Builder::new()
                .name("compeft-engine".into())
                .spawn(move || {
                    engine_main(cfg, registry, batcher, metrics, net, pcie, ready_tx)
                })?
        };
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => {
                let err = engine
                    .join()
                    .map_err(|_| anyhow::anyhow!("engine panicked"))?
                    .err()
                    .unwrap_or_else(|| anyhow::anyhow!("engine exited during startup"));
                return Err(err);
            }
        }
        Ok(Coordinator { batcher, metrics, net, pcie, engine: Some(engine) })
    }

    /// Submit one request; returns the response receiver.
    pub fn submit(
        &self,
        expert: &str,
        tokens: Vec<i32>,
        n_classes: usize,
    ) -> mpsc::Receiver<Prediction> {
        let (tx, rx) = mpsc::channel();
        self.batcher.push(expert, ClientRequest { tokens, n_classes, resp: tx });
        rx
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn queued(&self) -> usize {
        self.batcher.queued()
    }

    /// Drain remaining work and stop the engine.
    pub fn shutdown(mut self) -> Result<EngineReport> {
        self.batcher.close();
        let handle = self.engine.take().expect("engine running");
        handle.join().map_err(|_| anyhow::anyhow!("engine panicked"))?
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.engine.take() {
            self.batcher.close();
            let _ = h.join();
        }
    }
}

/// GPU-resident expert: decoded adapter + uploaded device buffers.
struct Resident {
    kind: AdapterKind,
    adapter_bufs: Vec<xla::PjRtBuffer>,
    /// For full-FT experts: full replacement parameter buffers.
    full_bufs: Option<Vec<xla::PjRtBuffer>>,
    dense_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn engine_main(
    cfg: CoordinatorConfig,
    registry: Registry,
    batcher: Arc<Batcher<ClientRequest>>,
    metrics: Arc<Metrics>,
    net: SimLink,
    pcie: SimLink,
    ready_tx: mpsc::Sender<Result<()>>,
) -> Result<EngineReport> {
    // --- startup: load model, precompile serve executables ---
    let setup = (|| -> Result<(Runtime, ModelBundle)> {
        let rt = Runtime::cpu()?;
        let bundle = ModelBundle::load(&rt, &cfg.artifacts, &cfg.scale)?;
        bundle.executable(AdapterKind::Base, SERVE_BATCH)?;
        bundle.executable(AdapterKind::Lora, SERVE_BATCH)?;
        bundle.executable(AdapterKind::Ia3, SERVE_BATCH)?;
        Ok((rt, bundle))
    })();
    let (_rt, bundle) = match setup {
        Ok(x) => {
            let _ = ready_tx.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return Err(anyhow::anyhow!("engine startup failed"));
        }
    };

    let loader = ExpertLoader::new(net.clone(), pcie.clone());
    let mut gpu: LruTier<Resident> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
    let mut cpu: LruTier<Vec<u8>> = LruTier::new("cpu", cfg.cpu_capacity_bytes);
    let mut resident_hint: Option<String> = None;
    let seq = bundle.meta.seq_len;

    // --- request loop ---
    while let Some((expert_id, batch)) = batcher.next_batch(resident_hint.as_deref()) {
        let rec = match registry.get(&expert_id) {
            Some(r) => r.clone(),
            None => {
                // Unknown expert: drop requests (metrics still count them).
                for p in batch {
                    drop(p.payload.resp);
                }
                continue;
            }
        };

        // Ensure residency.
        let t_swap = Instant::now();
        let mut swapped = false;
        let mut sim_swap = Duration::ZERO;
        if gpu.get(&expert_id).is_none() {
            swapped = true;
            match load_expert(&bundle, &loader, &rec, &mut cpu) {
                Ok((resident, sim)) => {
                    sim_swap = sim;
                    gpu.insert(&expert_id, resident, rec.encoded_bytes.max(1));
                }
                Err(e) => {
                    eprintln!("[engine] load {expert_id} failed: {e:#}");
                    for p in batch {
                        drop(p.payload.resp);
                    }
                    continue;
                }
            }
        }
        let swap_wall = t_swap.elapsed();
        let swap_total = sim_swap.max(swap_wall);
        resident_hint = Some(expert_id.clone());
        let resident = gpu.get(&expert_id).expect("just inserted");

        // Execute in SERVE_BATCH chunks.
        metrics.record_batch(batch.len(), swapped);
        let t_exec = Instant::now();
        let mut chunk_tokens = vec![0i32; SERVE_BATCH * seq];
        let mut responses: Vec<(usize, &Pending<ClientRequest>)> = Vec::new();
        let mut classes: Vec<usize> = Vec::with_capacity(batch.len());
        let mut exec_err = false;
        let mut i = 0;
        while i < batch.len() {
            let take = (batch.len() - i).min(SERVE_BATCH);
            for (j, p) in batch[i..i + take].iter().enumerate() {
                chunk_tokens[j * seq..(j + 1) * seq].copy_from_slice(&p.payload.tokens);
            }
            for v in chunk_tokens[take * seq..].iter_mut() {
                *v = 0;
            }
            let logits = bundle.run_batch(
                resident.kind,
                SERVE_BATCH,
                &resident.adapter_bufs,
                resident.full_bufs.as_deref(),
                &chunk_tokens,
            );
            match logits {
                Ok(l) => {
                    for (j, p) in batch[i..i + take].iter().enumerate() {
                        let row = &l[j * bundle.meta.vocab..(j + 1) * bundle.meta.vocab];
                        let c = p.payload.n_classes;
                        let mut best = 0usize;
                        let mut best_v = f32::NEG_INFINITY;
                        for (k, &v) in
                            row[ANSWER_BASE..ANSWER_BASE + c].iter().enumerate()
                        {
                            if v > best_v {
                                best_v = v;
                                best = k;
                            }
                        }
                        classes.push(best);
                        responses.push((classes.len() - 1, p));
                    }
                }
                Err(e) => {
                    eprintln!("[engine] exec failed: {e:#}");
                    exec_err = true;
                    break;
                }
            }
            i += take;
        }
        let exec = t_exec.elapsed();
        if exec_err {
            continue;
        }

        let now = Instant::now();
        for (ci, p) in responses {
            let timing = RequestTiming {
                queue: p.enqueued.elapsed().saturating_sub(swap_wall + exec),
                swap: swap_total,
                exec,
                total: now.duration_since(p.enqueued) + (swap_total - swap_wall),
                swapped,
            };
            metrics.record_request(&timing);
            let _ = p.payload.resp.send(Prediction { class: classes[ci], timing });
        }
    }

    Ok(EngineReport {
        gpu: gpu.stats(),
        cpu: cpu.stats(),
        net_bytes: net.bytes_moved(),
        pcie_bytes: pcie.bytes_moved(),
        batches: metrics.snapshot().batches,
    })
}

/// Pull an expert to the GPU tier; returns (resident, simulated time).
fn load_expert(
    bundle: &ModelBundle,
    loader: &ExpertLoader,
    rec: &ExpertRecord,
    cpu: &mut LruTier<Vec<u8>>,
) -> Result<(Resident, Duration)> {
    let mut sim = Duration::ZERO;
    // Host tier: encoded bytes.
    let encoded: Vec<u8> = match cpu.get(&rec.id) {
        Some(b) => b.clone(),
        None => {
            let (bytes, fetch) = loader.fetch_encoded(rec)?;
            sim += fetch;
            cpu.insert(&rec.id, bytes.clone(), rec.encoded_bytes.max(1));
            bytes
        }
    };
    // Decode against the matching template.
    let (kind, template) = match rec.method {
        ExpertMethod::Lora => (AdapterKind::Lora, &bundle.lora_init),
        ExpertMethod::Ia3 => (AdapterKind::Ia3, &bundle.ia3_init),
        ExpertMethod::Full => (AdapterKind::Base, &bundle.base),
    };
    let (tv, decode) = loader.decode(rec, &encoded, template)?;
    sim += decode;
    // Host → device (encoded bytes move; decode-on-device model, §2.2).
    sim += loader.upload_cost(rec);

    let resident = match rec.method {
        ExpertMethod::Full => {
            let mut params = bundle.base.clone();
            params.add_assign(&tv).context("apply full tv")?;
            let bufs = bundle.upload_full_params(&params)?;
            Resident {
                kind,
                adapter_bufs: Vec::new(),
                full_bufs: Some(bufs),
                dense_bytes: params.bytes_fp16(),
            }
        }
        _ => {
            let adapter = loader.materialize(rec.method, template, &tv)?;
            let bufs = bundle.upload_adapter(kind, &adapter)?;
            Resident {
                kind,
                adapter_bufs: bufs,
                full_bufs: None,
                dense_bytes: adapter.bytes_fp16(),
            }
        }
    };
    let _ = resident.dense_bytes;
    Ok((resident, sim))
}
