//! The serving engine: request loop, expert residency, batched
//! execution through the PJRT runtime.
//!
//! Architecture (single accelerator, matching the paper's serving
//! story): client threads submit requests tagged with an expert id; the
//! [`Batcher`] groups them per expert; one **engine thread** owns the
//! [`ModelBundle`] (device buffers are not `Send`) and drains batches,
//! swapping experts through the tiered cache + simulated links when the
//! target expert is not GPU-resident.
//!
//! An expert id may also name a **composition**
//! ([`CompositionRecord`]): a merged expert the engine materializes on
//! demand by pulling the members' `.cpeft` payloads through the host
//! tier and merging them ternary-domain (`load_composed`) — the merged
//! adapter then lives in the accelerator LRU tier as a first-class
//! resident, indistinguishable from a stored expert.

use crate::coordinator::batcher::{BatchPolicy, Batcher, Pending};
use crate::coordinator::cache::{LruTier, TierStats};
use crate::coordinator::loader::ExpertLoader;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot, RequestTiming};
use crate::coordinator::registry::{
    CompositionRecord, ExpertMethod, ExpertRecord, Registry,
};
use crate::coordinator::transport::{LinkSpec, SimLink};
use crate::eval::ANSWER_BASE;
use crate::runtime::{AdapterKind, ModelBundle, Runtime};

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serving batch size must match an exported executable batch.
pub const SERVE_BATCH: usize = 8;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifacts: PathBuf,
    pub scale: String,
    pub policy: BatchPolicy,
    /// Byte budget of the accelerator tier (decoded adapter bytes).
    pub gpu_capacity_bytes: u64,
    /// Byte budget of the host tier (encoded checkpoint bytes).
    pub cpu_capacity_bytes: u64,
    pub net: LinkSpec,
    pub pcie: LinkSpec,
    /// Wall-clock compression for simulated links (1.0 = real time).
    pub time_scale: f64,
    /// Workers in the engine-owned decode pool (`.cpeft` frame decode,
    /// dense materialization, adapter add). Outputs are bit-identical at
    /// any count; this only tunes swap-in latency. Defaults to the
    /// machine's available parallelism.
    pub decode_workers: usize,
}

impl CoordinatorConfig {
    pub fn new(artifacts: PathBuf, scale: &str) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts,
            scale: scale.to_string(),
            policy: BatchPolicy::default(),
            gpu_capacity_bytes: 2 << 20,
            cpu_capacity_bytes: 64 << 20,
            net: LinkSpec::internet(),
            pcie: LinkSpec::pcie(),
            time_scale: 1.0,
            decode_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }
}

/// A single inference request (one example).
struct ClientRequest {
    tokens: Vec<i32>,
    n_classes: usize,
    resp: mpsc::Sender<Prediction>,
}

/// Response: predicted class + latency breakdown.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub class: usize,
    pub timing: RequestTiming,
}

/// Final engine accounting returned at shutdown.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub gpu: TierStats,
    pub cpu: TierStats,
    pub net_bytes: u64,
    pub pcie_bytes: u64,
    pub batches: u64,
}

/// Public handle: submit requests, read metrics, shut down.
pub struct Coordinator {
    batcher: Arc<Batcher<ClientRequest>>,
    metrics: Arc<Metrics>,
    /// Sequence length every request's token vector must match
    /// (fixed by the loaded model bundle).
    seq_len: usize,
    /// Kept for external byte accounting while the engine runs.
    pub net: SimLink,
    pub pcie: SimLink,
    engine: Option<std::thread::JoinHandle<Result<EngineReport>>>,
}

impl Coordinator {
    /// Start the engine. Blocks until the model bundle is loaded and
    /// executables for the serve batch are compiled.
    pub fn start(cfg: CoordinatorConfig, registry: Registry) -> Result<Coordinator> {
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let metrics = Arc::new(Metrics::new());
        let net = SimLink::new("net", cfg.net).with_time_scale(cfg.time_scale);
        let pcie = SimLink::new("pcie", cfg.pcie).with_time_scale(cfg.time_scale);

        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let engine = {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let net = net.clone();
            let pcie = pcie.clone();
            std::thread::Builder::new()
                .name("compeft-engine".into())
                .spawn(move || {
                    engine_main(cfg, registry, batcher, metrics, net, pcie, ready_tx)
                })?
        };
        let seq_len = match ready_rx.recv() {
            Ok(Ok(seq)) => seq,
            Ok(Err(e)) => return Err(e),
            Err(_) => {
                let err = engine
                    .join()
                    .map_err(|_| anyhow::anyhow!("engine panicked"))?
                    .err()
                    .unwrap_or_else(|| anyhow::anyhow!("engine exited during startup"));
                return Err(err);
            }
        };
        Ok(Coordinator { batcher, metrics, seq_len, net, pcie, engine: Some(engine) })
    }

    /// Sequence length the loaded model expects per request.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Submit one request; returns the response receiver.
    ///
    /// A token vector whose length does not match [`Coordinator::seq_len`]
    /// is rejected here, before it reaches the engine thread: the
    /// returned receiver's sender is already dropped, so `recv()` fails
    /// with a disconnect error. (Previously such a request panicked the
    /// engine's batch packing and took the coordinator down for every
    /// client.)
    pub fn submit(
        &self,
        expert: &str,
        tokens: Vec<i32>,
        n_classes: usize,
    ) -> mpsc::Receiver<Prediction> {
        let (tx, rx) = mpsc::channel();
        if tokens.len() != self.seq_len {
            // Dropping `tx` makes the receiver report the rejection.
            return rx;
        }
        self.batcher.push(expert, ClientRequest { tokens, n_classes, resp: tx });
        rx
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn queued(&self) -> usize {
        self.batcher.queued()
    }

    /// Drain remaining work and stop the engine.
    pub fn shutdown(mut self) -> Result<EngineReport> {
        self.batcher.close();
        let handle = self.engine.take().expect("engine running");
        handle.join().map_err(|_| anyhow::anyhow!("engine panicked"))?
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.engine.take() {
            self.batcher.close();
            let _ = h.join();
        }
    }
}

/// GPU-resident expert: decoded adapter + uploaded device buffers.
struct Resident {
    kind: AdapterKind,
    adapter_bufs: Vec<xla::PjRtBuffer>,
    /// For full-FT experts: full replacement parameter buffers.
    full_bufs: Option<Vec<xla::PjRtBuffer>>,
    dense_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn engine_main(
    cfg: CoordinatorConfig,
    registry: Registry,
    batcher: Arc<Batcher<ClientRequest>>,
    metrics: Arc<Metrics>,
    net: SimLink,
    pcie: SimLink,
    ready_tx: mpsc::Sender<Result<usize>>,
) -> Result<EngineReport> {
    // --- startup: load model, precompile serve executables ---
    let setup = (|| -> Result<(Runtime, ModelBundle)> {
        let rt = Runtime::cpu()?;
        let bundle = ModelBundle::load(&rt, &cfg.artifacts, &cfg.scale)?;
        bundle.executable(AdapterKind::Base, SERVE_BATCH)?;
        bundle.executable(AdapterKind::Lora, SERVE_BATCH)?;
        bundle.executable(AdapterKind::Ia3, SERVE_BATCH)?;
        Ok((rt, bundle))
    })();
    let (_rt, bundle) = match setup {
        Ok(x) => {
            let _ = ready_tx.send(Ok(x.1.meta.seq_len));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return Err(anyhow::anyhow!("engine startup failed"));
        }
    };

    // Decode pool: parallel .cpeft frame decode + materialization on
    // GPU-tier misses. Owned by the engine thread; results are
    // bit-identical at any worker count.
    let pool = Arc::new(crate::util::pool::ThreadPool::new(cfg.decode_workers.max(1)));
    let loader = ExpertLoader::new(net.clone(), pcie.clone()).with_pool(pool);
    let mut gpu: LruTier<Resident> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
    let mut cpu: LruTier<Vec<u8>> = LruTier::new("cpu", cfg.cpu_capacity_bytes);
    let mut resident_hint: Option<String> = None;
    let seq = bundle.meta.seq_len;

    // --- request loop ---
    while let Some((expert_id, batch)) = batcher.next_batch(resident_hint.as_deref()) {
        // Route: a stored expert, or a registered composition (a merged
        // expert materialized on demand from its members).
        enum Target {
            Stored(ExpertRecord),
            Composed(CompositionRecord),
        }
        let target = if let Some(r) = registry.get(&expert_id) {
            Target::Stored(r.clone())
        } else if let Some(c) = registry.composition(&expert_id) {
            Target::Composed(c.clone())
        } else {
            // Unknown expert: drop requests (metrics still count them).
            for p in batch {
                drop(p.payload.resp);
            }
            continue;
        };

        // Ensure residency.
        let t_swap = Instant::now();
        let mut swapped = false;
        let mut sim_swap = Duration::ZERO;
        if gpu.get(&expert_id).is_none() {
            swapped = true;
            let loaded = match &target {
                Target::Stored(rec) => load_expert(&bundle, &loader, rec, &mut cpu),
                Target::Composed(comp) => {
                    load_composed(&bundle, &loader, &registry, comp, &mut cpu)
                }
            };
            match loaded {
                Ok((resident, sim)) => {
                    sim_swap = sim;
                    // The GPU tier budgets *decoded* adapter bytes
                    // (`gpu_capacity_bytes` docs): charge what actually
                    // sits in device memory, not the 8–50x smaller
                    // encoded form — charging encoded bytes admitted
                    // ~26 "residents" into a 2 MiB budget that holds
                    // one dense adapter.
                    let charge = resident.dense_bytes.max(1);
                    gpu.insert(&expert_id, resident, charge);
                }
                Err(e) => {
                    eprintln!("[engine] load {expert_id} failed: {e:#}");
                    for p in batch {
                        drop(p.payload.resp);
                    }
                    continue;
                }
            }
        }
        let swap_wall = t_swap.elapsed();
        let swap_total = sim_swap.max(swap_wall);
        resident_hint = Some(expert_id.clone());
        let resident = gpu.get(&expert_id).expect("just inserted");

        // Execute in SERVE_BATCH chunks.
        metrics.record_batch(batch.len(), swapped);
        let t_exec = Instant::now();
        let mut chunk_tokens = vec![0i32; SERVE_BATCH * seq];
        let mut responses: Vec<(usize, &Pending<ClientRequest>)> = Vec::new();
        let mut classes: Vec<usize> = Vec::with_capacity(batch.len());
        let mut exec_err = false;
        let mut i = 0;
        while i < batch.len() {
            let take = (batch.len() - i).min(SERVE_BATCH);
            for (j, p) in batch[i..i + take].iter().enumerate() {
                pack_row(&mut chunk_tokens[j * seq..(j + 1) * seq], &p.payload.tokens);
            }
            for v in chunk_tokens[take * seq..].iter_mut() {
                *v = 0;
            }
            let logits = bundle.run_batch(
                resident.kind,
                SERVE_BATCH,
                &resident.adapter_bufs,
                resident.full_bufs.as_deref(),
                &chunk_tokens,
            );
            match logits {
                Ok(l) => {
                    for (j, p) in batch[i..i + take].iter().enumerate() {
                        let row = &l[j * bundle.meta.vocab..(j + 1) * bundle.meta.vocab];
                        let c = p.payload.n_classes;
                        let mut best = 0usize;
                        let mut best_v = f32::NEG_INFINITY;
                        for (k, &v) in
                            row[ANSWER_BASE..ANSWER_BASE + c].iter().enumerate()
                        {
                            if v > best_v {
                                best_v = v;
                                best = k;
                            }
                        }
                        classes.push(best);
                        responses.push((classes.len() - 1, p));
                    }
                }
                Err(e) => {
                    eprintln!("[engine] exec failed: {e:#}");
                    exec_err = true;
                    break;
                }
            }
            i += take;
        }
        let exec = t_exec.elapsed();
        if exec_err {
            continue;
        }

        let now = Instant::now();
        for (ci, p) in responses {
            let timing = RequestTiming {
                queue: p.enqueued.elapsed().saturating_sub(swap_wall + exec),
                swap: swap_total,
                exec,
                total: now.duration_since(p.enqueued) + (swap_total - swap_wall),
                swapped,
            };
            metrics.record_request(&timing);
            let _ = p.payload.resp.send(Prediction { class: classes[ci], timing });
        }
    }

    Ok(EngineReport {
        gpu: gpu.stats(),
        cpu: cpu.stats(),
        net_bytes: net.bytes_moved(),
        pcie_bytes: pcie.bytes_moved(),
        batches: metrics.snapshot().batches,
    })
}

/// Copy one request's tokens into a `seq_len`-sized row of the batch
/// buffer, truncating or zero-padding a mis-sized vector instead of
/// panicking. [`Coordinator::submit`] rejects mis-sized requests before
/// they reach the engine, so this is defense in depth: the engine
/// thread serves every client and must not be killable by one request's
/// shape (the old `copy_from_slice` panicked on any length mismatch).
fn pack_row(dst: &mut [i32], tokens: &[i32]) {
    let n = tokens.len().min(dst.len());
    dst[..n].copy_from_slice(&tokens[..n]);
    for v in dst[n..].iter_mut() {
        *v = 0;
    }
}

/// Fetch an expert's encoded bytes through the host (CPU) tier,
/// charging the net link only on a miss.
fn fetch_via_cpu_tier(
    loader: &ExpertLoader,
    rec: &ExpertRecord,
    cpu: &mut LruTier<Vec<u8>>,
    sim: &mut Duration,
) -> Result<Vec<u8>> {
    if let Some(b) = cpu.get(&rec.id) {
        return Ok(b.clone());
    }
    let (bytes, fetch) = loader.fetch_encoded(rec)?;
    *sim += fetch;
    cpu.insert(&rec.id, bytes.clone(), rec.encoded_bytes.max(1));
    Ok(bytes)
}

/// Runtime kind + adapter init template for an expert method.
fn kind_and_template(
    bundle: &ModelBundle,
    method: ExpertMethod,
) -> (AdapterKind, &crate::tensor::ParamSet) {
    match method {
        ExpertMethod::Lora => (AdapterKind::Lora, &bundle.lora_init),
        ExpertMethod::Ia3 => (AdapterKind::Ia3, &bundle.ia3_init),
        ExpertMethod::Full => (AdapterKind::Base, &bundle.base),
    }
}

/// Materialize a decoded task vector into a GPU-tier resident (adapter
/// or full-parameter buffers) — shared by stored and merged experts.
fn build_resident(
    bundle: &ModelBundle,
    loader: &ExpertLoader,
    method: ExpertMethod,
    tv: &crate::tensor::ParamSet,
) -> Result<Resident> {
    let (kind, template) = kind_and_template(bundle, method);
    Ok(match method {
        ExpertMethod::Full => {
            let params = loader
                .materialize(method, &bundle.base, tv)
                .context("apply full tv")?;
            let bufs = bundle.upload_full_params(&params)?;
            Resident {
                kind,
                adapter_bufs: Vec::new(),
                full_bufs: Some(bufs),
                dense_bytes: params.bytes_fp16(),
            }
        }
        _ => {
            let adapter = loader.materialize(method, template, tv)?;
            let bufs = bundle.upload_adapter(kind, &adapter)?;
            Resident {
                kind,
                adapter_bufs: bufs,
                full_bufs: None,
                dense_bytes: adapter.bytes_fp16(),
            }
        }
    })
}

/// Pull an expert to the GPU tier; returns (resident, simulated time).
fn load_expert(
    bundle: &ModelBundle,
    loader: &ExpertLoader,
    rec: &ExpertRecord,
    cpu: &mut LruTier<Vec<u8>>,
) -> Result<(Resident, Duration)> {
    let mut sim = Duration::ZERO;
    // Host tier: encoded bytes.
    let encoded = fetch_via_cpu_tier(loader, rec, cpu, &mut sim)?;
    // Decode against the matching template.
    let (_, template) = kind_and_template(bundle, rec.method);
    let (tv, decode) = loader.decode(rec, &encoded, template)?;
    sim += decode;
    // Host → device (encoded bytes move; decode-on-device model, §2.2).
    sim += loader.upload_cost(rec);
    let resident = build_resident(bundle, loader, rec.method, &tv)?;
    Ok((resident, sim))
}

/// Materialize a merged expert on demand: pull every member's `.cpeft`
/// payload through the host tier, decode to the ternary domain (never
/// densifying members), merge per the composition record, and build a
/// first-class GPU-tier resident. Members benefit from — and populate —
/// the host tier exactly like directly-served experts, so a merged
/// expert whose members are already cached costs no net traffic.
fn load_composed(
    bundle: &ModelBundle,
    loader: &ExpertLoader,
    registry: &Registry,
    comp: &CompositionRecord,
    cpu: &mut LruTier<Vec<u8>>,
) -> Result<(Resident, Duration)> {
    let mut sim = Duration::ZERO;
    let mut members = Vec::with_capacity(comp.members.len());
    for m in &comp.members {
        let rec = registry
            .get(m)
            .ok_or_else(|| anyhow::anyhow!("composition member {m:?} missing"))?;
        let encoded = fetch_via_cpu_tier(loader, rec, cpu, &mut sim)?;
        let (c, decode) = loader.decode_compressed(rec, &encoded)?;
        sim += decode;
        members.push(c);
    }
    let refs: Vec<&_> = members.iter().collect();
    let (tv, merge) = loader.merge_ternary(&refs, &comp.merge)?;
    sim += merge;
    // The merged update exists only host-side and has no compact wire
    // form: the device hop moves the dense fp16 adapter.
    sim += loader.pcie.transfer(tv.bytes_fp16());
    let resident = build_resident(bundle, loader, comp.method, &tv)?;
    Ok((resident, sim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::compeft::golomb;
    use crate::coordinator::cache::LruTier;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn pack_row_pads_truncates_and_copies_exact() {
        let mut row = [9i32; 6];
        pack_row(&mut row, &[1, 2, 3]);
        assert_eq!(row, [1, 2, 3, 0, 0, 0], "short request zero-pads");
        let mut row = [9i32; 4];
        pack_row(&mut row, &[1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(row, [1, 2, 3, 4], "long request truncates");
        let mut row = [9i32; 3];
        pack_row(&mut row, &[5, 6, 7]);
        assert_eq!(row, [5, 6, 7]);
        let mut empty: [i32; 0] = [];
        pack_row(&mut empty, &[1, 2]);
    }

    /// Regression for the residency-accounting bug: the GPU tier budget
    /// is documented as *decoded* adapter bytes, but residents were
    /// charged at their `encoded_bytes` — so the default 2 MiB budget
    /// "held" dozens of experts whose dense device buffers were each
    /// about the size of the whole budget.
    #[test]
    fn gpu_tier_budgets_dense_adapter_bytes() {
        let cfg = CoordinatorConfig::new(PathBuf::from("/nonexistent"), "s");
        let d = 1usize << 20; // a 1M-param LoRA adapter
        let mut rng = Pcg::seed(17);
        let tau = prop::task_vector_like(&mut rng, d);
        let tern = compress_vector(
            &tau,
            &CompressConfig { density: 0.05, alpha: 1.0, ..Default::default() },
        );
        let dense_bytes = d as u64 * 2; // fp16 device accounting
        let encoded_bytes = golomb::encoded_size_bytes(&tern);
        assert!(encoded_bytes * 8 < dense_bytes, "fixture must be compressible");

        // Dense charging (what the engine does now): the default 2 MiB
        // accelerator budget holds exactly one adapter of this size.
        let mut gpu: LruTier<()> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
        for i in 0..4 {
            gpu.insert(&format!("e{i}"), (), dense_bytes.max(1));
        }
        assert_eq!(gpu.len(), 1, "dense charging: ~1 resident at 2 MiB");
        assert_eq!(gpu.stats().evictions, 3);

        // Encoded charging (the bug): dozens of phantom residents whose
        // actual device footprint overflows the budget many times over.
        let mut wrong: LruTier<()> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
        for i in 0..64 {
            wrong.insert(&format!("e{i}"), (), encoded_bytes.max(1));
        }
        assert!(
            wrong.len() >= 8,
            "encoded charging admitted only {} residents — fixture too large?",
            wrong.len()
        );
        assert!(
            wrong.len() as u64 * dense_bytes > cfg.gpu_capacity_bytes * 8,
            "the phantom residents' dense footprint must dwarf the budget"
        );
    }
}
