//! The serving engine: request loop, expert residency, batched
//! execution through the PJRT runtime.
//!
//! Architecture (single accelerator, matching the paper's serving
//! story): client threads submit requests tagged with an expert id; the
//! [`Batcher`] groups them per expert; one **engine thread** owns the
//! [`ModelBundle`] (device buffers are not `Send`) and drains batches,
//! swapping experts through the tiered cache + simulated links when the
//! target expert is not GPU-resident.
//!
//! Swaps run as a **staged pipeline with lookahead prefetch**
//! ([`crate::coordinator::pipeline`]): the batcher's queue plan tells
//! background threads which experts come next, their fetch+decode
//! stages run while the engine executes the current batch, and a cold
//! swap pays only the engine-thread upload hop on a staging hit.
//! `CoordinatorConfig::prefetch_depth` sets the lookahead (0 disables
//! it); predictions are bit-identical either way.
//!
//! An expert id may also name a **composition**: a merged expert
//! materialized on demand by pulling the members' `.cpeft` payloads
//! through the host tier and merging them ternary-domain
//! ([`PrepareContext::prepare`]) — the merged adapter then lives in the
//! accelerator LRU tier as a first-class resident, indistinguishable
//! from a stored expert, and prefetches like one.

use crate::coordinator::admission::{self, AdmissionConfig};
use crate::coordinator::batcher::{BatchPolicy, Batcher, Pending};
use crate::coordinator::cache::{LruTier, TierStats};
use crate::coordinator::loader::ExpertLoader;
use crate::coordinator::metrics::{
    Metrics, MetricsSnapshot, RejectCounts, RejectReason, RequestTiming,
};
use crate::coordinator::pipeline::{
    PrepareContext, PreparedExpert, Prefetcher, TakeOutcome, Templates,
};
use crate::coordinator::registry::{ExpertMethod, Registry};
use crate::coordinator::store::{
    ExpertStore, MigrationReport, RebalanceConfig, Rebalancer, StoreConfig,
};
use crate::coordinator::transport::{FaultPlan, FaultSpec, LinkSpec, SimLink};
use crate::eval::ANSWER_BASE;
use crate::runtime::{AdapterKind, ModelBundle, Runtime};
use crate::util::sync::{rank, OrderedMutex};

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serving batch size must match an exported executable batch.
pub const SERVE_BATCH: usize = 8;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifacts: PathBuf,
    pub scale: String,
    pub policy: BatchPolicy,
    /// Byte budget of the accelerator tier (decoded adapter bytes).
    pub gpu_capacity_bytes: u64,
    /// Byte budget of the host tier (encoded checkpoint bytes).
    pub cpu_capacity_bytes: u64,
    pub net: LinkSpec,
    pub pcie: LinkSpec,
    /// Wall-clock compression for simulated links (1.0 = real time).
    pub time_scale: f64,
    /// Workers in the engine-owned decode pool (`.cpeft` frame decode,
    /// dense materialization, adapter add). Outputs are bit-identical at
    /// any count; this only tunes swap-in latency. Defaults to the
    /// machine's available parallelism.
    pub decode_workers: usize,
    /// Lookahead of the prefetch pipeline: how many upcoming experts
    /// (from the batcher's queue plan) have their fetch+decode stages
    /// run on background threads while the engine executes the current
    /// batch. `0` disables prefetching (the pre-pipeline blocking
    /// behavior). Served predictions are bit-identical at any depth and
    /// any worker count; this only tunes how much cold-swap latency is
    /// hidden behind execution.
    pub prefetch_depth: usize,
    /// Nodes in the sharded expert store. `0` = flat single-link store
    /// (the pre-store behavior). With nodes, fetches run as striped
    /// multi-replica transfers with CRC-verified failover — predictions
    /// stay bit-identical at any node count, replication factor, and
    /// fault seed (given ≥ 1 surviving replica per stripe).
    pub store_nodes: usize,
    /// Replicas per expert in the sharded store (clamped to ≥ 1 and to
    /// the node count at placement time).
    pub replication: usize,
    /// Seed of the store's deterministic fault plan: same seed → same
    /// fault/failover sequence and counters, at any worker count.
    pub fault_seed: u64,
    /// Fault probabilities injected into the store links (all-zero by
    /// default: a healthy store).
    pub store_faults: FaultSpec,
    /// Admission control at [`Coordinator::submit`]: bounded-queue
    /// backpressure and deadline-aware shedding. The default admits
    /// everything (the pre-admission behavior).
    pub admission: AdmissionConfig,
    /// Popularity-aware adaptive replication: when the sharded store is
    /// on, the engine feeds per-expert fetch counts into a
    /// [`Rebalancer`] and runs one bounded-churn round every
    /// [`CoordinatorConfig::rebalance_every`] batches — hot experts
    /// widen their replica sets, cold ones narrow back toward the base
    /// replication. Rounds are keyed to the batch counter, so the
    /// rebalance schedule is deterministic in the workload, not in wall
    /// time. Served bytes are bit-identical with this on or off; only
    /// placement (and therefore simulated fetch latency) changes.
    pub rebalance: bool,
    /// Batches between adaptive-replication rounds (ignored unless
    /// [`CoordinatorConfig::rebalance`] is set).
    pub rebalance_every: u64,
    /// Tuning of the adaptive-replication controller (EWMA decay,
    /// per-round migration byte budget, replica cap, churn slack).
    pub rebalance_cfg: RebalanceConfig,
    /// Optional local `.cpeft` archive
    /// ([`crate::coordinator::archive`]): when set, the engine opens it
    /// as a third cache level between the host tier and the remote
    /// store (GPU ⊃ host ⊃ archive ⊃ remote). Archive-resident experts
    /// are served as zero-copy views of the resident file image — no
    /// net/store transfer, no heap copy of the encoded bytes. A
    /// missing, truncated, or corrupt archive degrades to the remote
    /// path at startup (counted as a store fault), never a crash.
    pub archive: Option<PathBuf>,
}

impl CoordinatorConfig {
    pub fn new(artifacts: PathBuf, scale: &str) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts,
            scale: scale.to_string(),
            policy: BatchPolicy::default(),
            gpu_capacity_bytes: 2 << 20,
            cpu_capacity_bytes: 64 << 20,
            net: LinkSpec::internet(),
            pcie: LinkSpec::pcie(),
            time_scale: 1.0,
            decode_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            prefetch_depth: 2,
            store_nodes: 0,
            replication: 1,
            fault_seed: 0,
            store_faults: FaultSpec::default(),
            admission: AdmissionConfig::default(),
            rebalance: false,
            rebalance_every: 8,
            rebalance_cfg: RebalanceConfig::default(),
            archive: None,
        }
    }
}

/// A single inference request (one example).
struct ClientRequest {
    tokens: Vec<i32>,
    n_classes: usize,
    resp: mpsc::Sender<Prediction>,
}

/// Response: predicted class + latency breakdown.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub class: usize,
    pub timing: RequestTiming,
}

/// Final engine accounting returned at shutdown.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub gpu: TierStats,
    pub cpu: TierStats,
    pub net_bytes: u64,
    pub pcie_bytes: u64,
    pub batches: u64,
    /// Requests dropped without a reply (sum of `rejected_by`).
    pub rejected: u64,
    /// The same drops split by reason: admission-control shedding and
    /// backpressure vs client errors vs server faults.
    pub rejected_by: RejectCounts,
    /// Cold swaps served entirely from the prefetch staging slot.
    pub prefetch_hits: u64,
    /// Cold swaps that waited on an in-flight prefetch.
    pub prefetch_waits: u64,
    /// Cold swaps nothing was staged for (full blocking path).
    pub prefetch_misses: u64,
    /// Staged experts discarded unused.
    pub prefetch_wasted: u64,
    /// Simulated fetch+decode time hidden behind batch execution.
    pub overlap_saved: Duration,
    /// Cold-swap time hidden by the fused fetch→decode path (frames
    /// decoded as their stripes land): `fetch + decode − fused`.
    pub decode_overlap: Duration,
    /// Cold swaps that ran the fused fetch→decode path.
    pub fused_loads: u64,
    /// Extra stripe fetch attempts beyond the first (sharded store).
    pub stripe_retries: u64,
    /// Stripes served by a replica other than their first choice.
    pub failovers: u64,
    /// Stripe payloads received corrupt and re-fetched elsewhere.
    pub corrupt_payloads: u64,
    /// Fetches served as zero-copy views of the local archive.
    pub archive_hits: u64,
    /// Encoded bytes those archive hits viewed in place.
    pub archive_bytes_viewed: u64,
    /// Adaptive-replication rounds that changed placement.
    pub rebalances: u64,
    /// Replicas widened onto extra nodes by those rounds.
    pub replicas_added: u64,
    /// Replicas narrowed back off nodes by those rounds.
    pub replicas_dropped: u64,
    /// Bytes migrated by rebalance rounds plus node add/drain ops.
    pub migrated_bytes: u64,
    /// Expert updates applied as ternary deltas instead of full pushes.
    pub delta_applies: u64,
    /// Wire bytes those delta applies saved vs full re-pushes.
    pub delta_bytes_saved: u64,
    /// Heap copies of encoded payload bytes made by the fetch path
    /// (file/remote materializations + fallback reassembly concats).
    /// Archive-resident serving keeps this at zero.
    pub payload_copies: u64,
}

/// Public handle: submit requests, read metrics, administer the store
/// (rebalance/drain/add run live against the serving engine), shut
/// down.
pub struct Coordinator {
    batcher: Arc<Batcher<ClientRequest>>,
    metrics: Arc<Metrics>,
    admission: AdmissionConfig,
    /// Shared with the engine thread: admission resolves each request's
    /// version pin here ([`Registry::pin`]) before it enters a queue.
    registry: Arc<Registry>,
    /// Shared with the engine thread when `store_nodes > 0`: node
    /// add/drain are live admin operations on this handle, concurrent
    /// with the engine's fetches (placement-epoch swap inside).
    store: Option<Arc<ExpertStore>>,
    /// Sequence length every request's token vector must match
    /// (fixed by the loaded model bundle).
    seq_len: usize,
    /// Kept for external byte accounting while the engine runs.
    pub net: SimLink,
    pub pcie: SimLink,
    engine: Option<std::thread::JoinHandle<Result<EngineReport>>>,
}

impl Coordinator {
    /// Start the engine. Blocks until the model bundle is loaded and
    /// executables for the serve batch are compiled.
    pub fn start(cfg: CoordinatorConfig, registry: Registry) -> Result<Coordinator> {
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let metrics = Arc::new(Metrics::new());
        let admission = cfg.admission;
        let registry = Arc::new(registry);
        let net = SimLink::new("net", cfg.net).with_time_scale(cfg.time_scale);
        let pcie = SimLink::new("pcie", cfg.pcie).with_time_scale(cfg.time_scale);
        // Decode pool: parallel .cpeft frame decode + materialization on
        // GPU-tier misses. Shared between the engine thread (blocking
        // fallback) and the prefetch threads; results are bit-identical
        // at any worker count.
        let pool = Arc::new(crate::util::pool::ThreadPool::new(cfg.decode_workers.max(1)));
        // Sharded store: striped multi-replica fetch over per-node links
        // (stripes run on the shared decode pool), replacing the flat
        // net link. Bytes — and therefore predictions — are identical
        // either way; only latency, fault tolerance, and the failover
        // counters change. Built here (not on the engine thread) so the
        // public handle can run live node add/drain against it.
        let store = if cfg.store_nodes > 0 {
            let mut scfg = StoreConfig::new(cfg.store_nodes, cfg.replication);
            scfg.link = cfg.net;
            scfg.time_scale = cfg.time_scale;
            scfg.faults = FaultPlan::new(cfg.fault_seed, cfg.store_faults);
            Some(Arc::new(ExpertStore::new(
                scfg,
                Some(Arc::clone(&pool)),
                Arc::clone(&metrics),
            )))
        } else {
            None
        };

        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let engine = {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let registry = Arc::clone(&registry);
            let store = store.clone();
            let net = net.clone();
            let pcie = pcie.clone();
            std::thread::Builder::new()
                .name("compeft-engine".into())
                .spawn(move || {
                    engine_main(
                        cfg, registry, batcher, metrics, pool, store, net, pcie,
                        ready_tx,
                    )
                })?
        };
        let seq_len = match ready_rx.recv() {
            Ok(Ok(seq)) => seq,
            Ok(Err(e)) => return Err(e),
            Err(_) => {
                let err = engine
                    .join()
                    .map_err(|_| anyhow::anyhow!("engine panicked"))?
                    .err()
                    .unwrap_or_else(|| anyhow::anyhow!("engine exited during startup"));
                return Err(err);
            }
        };
        Ok(Coordinator {
            batcher,
            metrics,
            admission,
            registry,
            store,
            seq_len,
            net,
            pcie,
            engine: Some(engine),
        })
    }

    /// The shared expert catalog (version pins, activation state).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Flip admission of `id` to its next staged version
    /// ([`Registry::activate_next`]). Batches admitted before the flip
    /// keep the version they were pinned to; batches admitted after it
    /// resolve to the new one — no batch ever mixes versions.
    pub fn activate_version(&self, id: &str) -> Option<u32> {
        self.registry.activate_next(id)
    }

    /// Live-drain a store node: its replicas migrate onto the surviving
    /// nodes in the background, in-flight fetches finish against the
    /// old placement, and a single placement-epoch swap cuts new
    /// fetches over. Errors without a sharded store or for an unknown /
    /// last-remaining node.
    pub fn drain_store_node(&self, node: usize) -> Result<MigrationReport> {
        match &self.store {
            Some(s) => s.drain_node(node),
            None => Err(anyhow::anyhow!("no sharded store to drain from")),
        }
    }

    /// Live-add a store node (it starts cold and takes over the
    /// assignments the widened placement hashes onto it). Errors
    /// without a sharded store.
    pub fn add_store_node(&self) -> Result<MigrationReport> {
        match &self.store {
            Some(s) => Ok(s.add_node()),
            None => Err(anyhow::anyhow!("no sharded store to add a node to")),
        }
    }

    /// The sharded store, when the engine runs with one.
    pub fn store(&self) -> Option<&Arc<ExpertStore>> {
        self.store.as_ref()
    }

    /// Sequence length the loaded model expects per request.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Submit one request; returns the response receiver.
    ///
    /// A token vector whose length does not match [`Coordinator::seq_len`]
    /// is rejected here, before it reaches the engine thread: the
    /// returned receiver's sender is already dropped, so `recv()` fails
    /// with a disconnect error. (Previously such a request panicked the
    /// engine's batch packing and took the coordinator down for every
    /// client.)
    pub fn submit(
        &self,
        expert: &str,
        tokens: Vec<i32>,
        n_classes: usize,
    ) -> mpsc::Receiver<Prediction> {
        self.submit_with(expert, 0, None, tokens, n_classes)
    }

    /// [`Coordinator::submit`] with a tenant id (weighted-fair service in
    /// the batcher) and an optional latency budget.
    ///
    /// Admission control runs here, at the door: malformed requests,
    /// bounded-queue backpressure, and deadline-aware shedding all drop
    /// the sender before the request touches the engine — the receiver
    /// reports a disconnect and the drop is counted under its
    /// [`RejectReason`]. A shed request never consumes a fetch, a decode,
    /// or a batch slot.
    pub fn submit_with(
        &self,
        expert: &str,
        tenant: u32,
        deadline: Option<Duration>,
        tokens: Vec<i32>,
        n_classes: usize,
    ) -> mpsc::Receiver<Prediction> {
        let (tx, rx) = mpsc::channel();
        if tokens.len() != self.seq_len {
            // Dropping `tx` makes the receiver report the rejection.
            self.metrics.record_rejected(RejectReason::Malformed, 1);
            return rx;
        }
        let deadline_us = deadline.map(|d| d.as_micros() as u64);
        let verdict = admission::admit(&self.admission, self.batcher.queued(), deadline_us);
        if let Some(reason) = verdict.reject_reason() {
            self.metrics.record_rejected(reason, 1);
            return rx;
        }
        // Version pin at admission: resolve the expert's current version
        // *now*, so a concurrent [`Coordinator::activate_version`] can
        // never retarget a request that has already been admitted — the
        // whole batch it joins serves the version it was pinned to.
        let pinned = self.registry.pin(expert);
        self.batcher.push_at(
            &pinned,
            tenant,
            ClientRequest { tokens, n_classes, resp: tx },
            Instant::now(),
        );
        rx
    }

    /// Set a tenant's weighted-fair service weight (default 1).
    pub fn set_tenant_weight(&self, tenant: u32, weight: u64) {
        self.batcher.set_tenant_weight(tenant, weight);
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn queued(&self) -> usize {
        self.batcher.queued()
    }

    /// Drain remaining work and stop the engine.
    pub fn shutdown(mut self) -> Result<EngineReport> {
        self.batcher.close();
        let handle = self.engine.take().expect("engine running");
        handle.join().map_err(|_| anyhow::anyhow!("engine panicked"))?
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(h) = self.engine.take() {
            self.batcher.close();
            let _ = h.join();
        }
    }
}

/// GPU-resident expert: decoded adapter + uploaded device buffers.
struct Resident {
    kind: AdapterKind,
    adapter_bufs: Vec<xla::PjRtBuffer>,
    /// For full-FT experts: full replacement parameter buffers.
    full_bufs: Option<Vec<xla::PjRtBuffer>>,
    dense_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn engine_main(
    cfg: CoordinatorConfig,
    registry: Arc<Registry>,
    batcher: Arc<Batcher<ClientRequest>>,
    metrics: Arc<Metrics>,
    pool: Arc<crate::util::pool::ThreadPool>,
    store: Option<Arc<ExpertStore>>,
    net: SimLink,
    pcie: SimLink,
    ready_tx: mpsc::Sender<Result<usize>>,
) -> Result<EngineReport> {
    // --- startup: load model, precompile serve executables ---
    let setup = (|| -> Result<(Runtime, ModelBundle)> {
        let rt = Runtime::cpu()?;
        let bundle = ModelBundle::load(&rt, &cfg.artifacts, &cfg.scale)?;
        bundle.executable(AdapterKind::Base, SERVE_BATCH)?;
        bundle.executable(AdapterKind::Lora, SERVE_BATCH)?;
        bundle.executable(AdapterKind::Ia3, SERVE_BATCH)?;
        Ok((rt, bundle))
    })();
    let (_rt, bundle) = match setup {
        Ok(x) => {
            let _ = ready_tx.send(Ok(x.1.meta.seq_len));
            x
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return Err(anyhow::anyhow!("engine startup failed"));
        }
    };

    let mut loader = ExpertLoader::new(net.clone(), pcie.clone())
        .with_pool(pool)
        .with_meter(metrics.copy_meter());
    if let Some(store) = &store {
        loader = loader.with_store(Arc::clone(store));
    }
    // Local archive tier: zero-copy views of the resident file image,
    // consulted between the host tier and the remote fetch. A dead
    // archive (missing file, truncated index, corrupt CRC) is a
    // degraded start, not a failed one: log it, count it like a failed
    // replica, and serve everything through the remote path.
    let archive = cfg.archive.as_ref().and_then(|path| {
        match crate::coordinator::archive::ArchiveTier::open(path, Arc::clone(&metrics)) {
            Ok(tier) => Some(Arc::new(tier)),
            Err(e) => {
                eprintln!(
                    "[engine] archive {} unusable, serving via remote store: {e:#}",
                    path.display()
                );
                metrics.record_store_faults(0, 1, 0);
                None
            }
        }
    });
    // Host tier of encoded bytes, shared with the prefetch threads
    // (entries pinned while a background decode is in flight).
    let cpu = Arc::new(OrderedMutex::new(
        rank::CPU_TIER,
        "cache.cpu_tier",
        LruTier::new("cpu", cfg.cpu_capacity_bytes),
    ));
    let ctx = Arc::new(PrepareContext {
        loader: loader.clone(),
        registry: Arc::clone(&registry),
        // Shared Arcs, not copies: the prefetch threads read the same
        // host-side parameter sets the bundle owns.
        templates: Templates {
            base: Arc::clone(&bundle.base),
            lora_init: Arc::clone(&bundle.lora_init),
            ia3_init: Arc::clone(&bundle.ia3_init),
        },
        cpu: Arc::clone(&cpu),
        archive,
    });
    let prefetcher = if cfg.prefetch_depth > 0 {
        Some(Prefetcher::start(
            Arc::clone(&ctx),
            cfg.prefetch_depth,
            // The staging slots hold decoded (dense) experts host-side;
            // budget them at one accelerator tier per lookahead slot so
            // a full-depth plan can be staged without the newest
            // deposit evicting the next expert to be served.
            cfg.gpu_capacity_bytes.saturating_mul(cfg.prefetch_depth as u64),
            Arc::clone(&metrics),
        ))
    } else {
        None
    };
    let mut gpu: LruTier<Resident> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
    let mut resident_hint: Option<String> = None;
    let seq = bundle.meta.seq_len;
    // Adaptive replication: one rebalancer for the engine's lifetime so
    // the popularity EWMA carries across rounds. Cadence is keyed to the
    // batch counter, not wall time, so a given trace always rebalances
    // at the same points regardless of host speed.
    let mut rebalancer = if cfg.rebalance && store.is_some() {
        Some(Rebalancer::new(cfg.rebalance_cfg))
    } else {
        None
    };
    let mut batches_seen: u64 = 0;

    // --- request loop ---
    while let Some((expert_id, batch)) = batcher.next_batch(resident_hint.as_deref()) {
        if registry.get(&expert_id).is_none() && registry.composition(&expert_id).is_none()
        {
            // Unknown expert: drop the requests and count the drops.
            metrics.record_rejected(RejectReason::UnknownExpert, batch.len() as u64);
            for p in batch {
                drop(p.payload.resp);
            }
            continue;
        }

        // Ensure residency. Stages 1–2 (fetch+decode) come from the
        // prefetch staging slot when the lookahead saw this expert
        // coming — the batch then pays only the upload hop — with the
        // blocking prepare as fallback.
        let t_swap = Instant::now();
        let mut swapped = false;
        let mut sim_swap = Duration::ZERO;
        if gpu.get(&expert_id).is_none() {
            swapped = true;
            let prepared: Result<PreparedExpert> =
                match prefetcher.as_ref().map(|pf| pf.take(&expert_id)) {
                    // Fully staged: the fetch+decode sim time was paid
                    // off the critical path; the batch pays only the
                    // upload hop below.
                    Some(TakeOutcome::Hit(p)) => Ok(p),
                    // In flight when the engine arrived: the overlap
                    // was only partial, and how much of the staged cost
                    // was already hidden cannot be split between the
                    // sim and wall clocks — charge the whole staged
                    // cost like a miss (conservative: prefetch-on
                    // latency is never flattered by partial overlaps;
                    // the wait itself is inside `t_swap`'s window).
                    Some(TakeOutcome::Waited(p, _)) => {
                        sim_swap += p.staged_sim;
                        Ok(p)
                    }
                    // Miss / failed prefetch / prefetch disabled: run
                    // the stages here and charge them to the batch,
                    // exactly like the pre-pipeline engine.
                    Some(TakeOutcome::Failed(_)) | Some(TakeOutcome::Miss) | None => {
                        match ctx.prepare(&expert_id) {
                            Ok(p) => {
                                sim_swap += p.staged_sim;
                                Ok(p)
                            }
                            Err(e) => Err(e),
                        }
                    }
                };
            // Stage 3: engine-thread-only upload (PjRt buffers are not
            // `Send`).
            match prepared.and_then(|p| upload_prepared(&bundle, &loader, &p)) {
                Ok((resident, upload_sim)) => {
                    sim_swap += upload_sim;
                    // The GPU tier budgets *decoded* adapter bytes
                    // (`gpu_capacity_bytes` docs): charge what actually
                    // sits in device memory, not the 8–50x smaller
                    // encoded form.
                    let charge = resident.dense_bytes.max(1);
                    gpu.insert(&expert_id, resident, charge);
                }
                Err(e) => {
                    eprintln!("[engine] load {expert_id} failed: {e:#}");
                    metrics.record_rejected(RejectReason::LoadFailure, batch.len() as u64);
                    for p in batch {
                        drop(p.payload.resp);
                    }
                    continue;
                }
            }
        }
        let swap_wall = t_swap.elapsed();
        let swap_total = sim_swap.max(swap_wall);
        resident_hint = Some(expert_id.clone());

        // Publish the lookahead *before* executing, so the prefetch
        // threads overlap the next experts' fetch+decode with this
        // batch's execution. GPU residents and the expert being served
        // are excluded — prefetching them would be pure waste.
        if let Some(pf) = &prefetcher {
            let upcoming: Vec<String> = batcher
                .plan(cfg.prefetch_depth + 2, Some(&expert_id))
                .into_iter()
                .filter(|id| *id != expert_id && !gpu.contains(id))
                .take(cfg.prefetch_depth)
                .collect();
            pf.note_plan(upcoming);
        }
        let resident = gpu.get(&expert_id).expect("just inserted");

        // Execute in SERVE_BATCH chunks.
        metrics.record_batch(batch.len(), swapped);
        batches_seen += 1;
        if let (Some(rb), Some(store)) = (rebalancer.as_mut(), store.as_ref()) {
            if batches_seen % cfg.rebalance_every.max(1) == 0 {
                store.rebalance(rb);
            }
        }
        let t_exec = Instant::now();
        let mut chunk_tokens = vec![0i32; SERVE_BATCH * seq];
        let mut responses: Vec<(usize, &Pending<ClientRequest>)> = Vec::new();
        let mut classes: Vec<usize> = Vec::with_capacity(batch.len());
        let mut exec_err = false;
        let mut i = 0;
        while i < batch.len() {
            let take = (batch.len() - i).min(SERVE_BATCH);
            for (j, p) in batch[i..i + take].iter().enumerate() {
                pack_row(&mut chunk_tokens[j * seq..(j + 1) * seq], &p.payload.tokens);
            }
            for v in chunk_tokens[take * seq..].iter_mut() {
                *v = 0;
            }
            let logits = bundle.run_batch(
                resident.kind,
                SERVE_BATCH,
                &resident.adapter_bufs,
                resident.full_bufs.as_deref(),
                &chunk_tokens,
            );
            match logits {
                Ok(l) => {
                    for (j, p) in batch[i..i + take].iter().enumerate() {
                        let row = &l[j * bundle.meta.vocab..(j + 1) * bundle.meta.vocab];
                        let c = p.payload.n_classes;
                        let mut best = 0usize;
                        let mut best_v = f32::NEG_INFINITY;
                        for (k, &v) in
                            row[ANSWER_BASE..ANSWER_BASE + c].iter().enumerate()
                        {
                            if v > best_v {
                                best_v = v;
                                best = k;
                            }
                        }
                        classes.push(best);
                        responses.push((classes.len() - 1, p));
                    }
                }
                Err(e) => {
                    eprintln!("[engine] exec failed: {e:#}");
                    exec_err = true;
                    break;
                }
            }
            i += take;
        }
        let exec = t_exec.elapsed();

        // Reply to every chunk that completed — including ahead of an
        // exec error, whose already-computed responses used to be
        // silently dropped along with the failed chunk's.
        let answered = responses.len();
        flush_responses(&metrics, responses, &classes, swap_wall, swap_total, exec, swapped);
        if exec_err {
            metrics.record_rejected(RejectReason::ExecError, (batch.len() - answered) as u64);
            continue;
        }
    }

    // Stop the prefetch threads before the final snapshot so in-flight
    // deposits and shutdown discards are all accounted.
    drop(prefetcher);
    let snap = metrics.snapshot();
    Ok(EngineReport {
        gpu: gpu.stats(),
        cpu: cpu.lock().unwrap().stats(),
        // With a sharded store, fetch bytes move over its node links.
        net_bytes: store
            .as_ref()
            .map(|s| s.bytes_moved())
            .unwrap_or_else(|| net.bytes_moved()),
        pcie_bytes: pcie.bytes_moved(),
        batches: snap.batches,
        rejected: snap.rejected,
        rejected_by: snap.rejected_by,
        prefetch_hits: snap.prefetch_hits,
        prefetch_waits: snap.prefetch_waits,
        prefetch_misses: snap.prefetch_misses,
        prefetch_wasted: snap.prefetch_wasted,
        overlap_saved: Duration::from_micros(snap.overlap_saved_us),
        decode_overlap: Duration::from_micros(snap.decode_overlap_us),
        fused_loads: snap.fused_loads,
        stripe_retries: snap.stripe_retries,
        failovers: snap.failovers,
        corrupt_payloads: snap.corrupt_payloads,
        archive_hits: snap.archive_hits,
        archive_bytes_viewed: snap.archive_bytes_viewed,
        rebalances: snap.rebalances,
        replicas_added: snap.replicas_added,
        replicas_dropped: snap.replicas_dropped,
        migrated_bytes: snap.migrated_bytes,
        delta_applies: snap.delta_applies,
        delta_bytes_saved: snap.delta_bytes_saved,
        payload_copies: snap.payload_copies,
    })
}

/// Copy one request's tokens into a `seq_len`-sized row of the batch
/// buffer, truncating or zero-padding a mis-sized vector instead of
/// panicking. [`Coordinator::submit`] rejects mis-sized requests before
/// they reach the engine, so this is defense in depth: the engine
/// thread serves every client and must not be killable by one request's
/// shape (the old `copy_from_slice` panicked on any length mismatch).
fn pack_row(dst: &mut [i32], tokens: &[i32]) {
    let n = tokens.len().min(dst.len());
    dst[..n].copy_from_slice(&tokens[..n]);
    for v in dst[n..].iter_mut() {
        *v = 0;
    }
}

/// Runtime forward variant for an expert method.
fn adapter_kind(method: ExpertMethod) -> AdapterKind {
    match method {
        ExpertMethod::Lora => AdapterKind::Lora,
        ExpertMethod::Ia3 => AdapterKind::Ia3,
        ExpertMethod::Full => AdapterKind::Base,
    }
}

/// Stage 3 of a swap — the engine-thread-only upload hop: move the
/// prepared expert's bytes over PCIe (encoded bytes for stored experts,
/// dense fp16 for merged ones; see [`PreparedExpert::upload_bytes`])
/// and create the device buffers. Returns the GPU-tier resident and the
/// simulated transfer time.
fn upload_prepared(
    bundle: &ModelBundle,
    loader: &ExpertLoader,
    p: &PreparedExpert,
) -> Result<(Resident, Duration)> {
    let sim = loader.pcie.transfer(p.upload_bytes);
    let kind = adapter_kind(p.method);
    let resident = match p.method {
        ExpertMethod::Full => Resident {
            kind,
            adapter_bufs: Vec::new(),
            full_bufs: Some(
                bundle.upload_full_params(&p.params).context("upload full params")?,
            ),
            dense_bytes: p.dense_bytes,
        },
        _ => Resident {
            kind,
            adapter_bufs: bundle.upload_adapter(kind, &p.params)?,
            full_bufs: None,
            dense_bytes: p.dense_bytes,
        },
    };
    Ok((resident, sim))
}

/// Reply to every request whose logits were computed: record timing and
/// send the prediction. Extracted from the exec loop so the exec-error
/// path flushes the chunks that *did* complete before abandoning the
/// rest (their already-computed responses used to be dropped without a
/// reply alongside the failed chunk's).
fn flush_responses(
    metrics: &Metrics,
    responses: Vec<(usize, &Pending<ClientRequest>)>,
    classes: &[usize],
    swap_wall: Duration,
    swap_total: Duration,
    exec: Duration,
    swapped: bool,
) {
    let now = Instant::now();
    for (ci, p) in responses {
        let timing = RequestTiming {
            queue: p.enqueued.elapsed().saturating_sub(swap_wall + exec),
            swap: swap_total,
            exec,
            total: now.duration_since(p.enqueued) + (swap_total - swap_wall),
            swapped,
        };
        metrics.record_request(&timing);
        let _ = p.payload.resp.send(Prediction { class: classes[ci], timing });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compeft::compress::{compress_vector, CompressConfig};
    use crate::compeft::golomb;
    use crate::coordinator::cache::LruTier;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    /// The response-flush helper replies to exactly the chunks whose
    /// logits were computed and records their timings; requests beyond
    /// the flushed set (an exec error mid-batch) see a dropped sender
    /// and a `rejected` count, not silence with a leaked reply.
    #[test]
    fn flush_responses_replies_to_completed_chunks_only() {
        let metrics = Metrics::new();
        let mk = |tokens: Vec<i32>| {
            let (tx, rx) = mpsc::channel();
            (
                Pending {
                    payload: ClientRequest { tokens, n_classes: 2, resp: tx },
                    enqueued: Instant::now(),
                    tenant: 0,
                },
                rx,
            )
        };
        let (p0, r0) = mk(vec![1]);
        let (p1, r1) = mk(vec![2]);
        let (p2, r2) = mk(vec![3]);
        let batch = vec![p0, p1, p2];
        // Two chunks completed before the (simulated) exec error.
        let classes = vec![1usize, 0];
        let responses: Vec<(usize, &Pending<ClientRequest>)> =
            vec![(0, &batch[0]), (1, &batch[1])];
        flush_responses(
            &metrics,
            responses,
            &classes,
            Duration::ZERO,
            Duration::from_millis(1),
            Duration::from_micros(10),
            true,
        );
        assert_eq!(r0.recv().unwrap().class, 1);
        assert_eq!(r1.recv().unwrap().class, 0);
        // The engine's exec-error path: count the unanswered remainder,
        // then drop the batch (disconnecting their senders).
        metrics.record_rejected(RejectReason::ExecError, (batch.len() - classes.len()) as u64);
        drop(batch);
        assert!(r2.recv().is_err(), "unanswered request sees a disconnect");
        let s = metrics.snapshot();
        assert_eq!(s.requests, 2, "only completed chunks are recorded");
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn pack_row_pads_truncates_and_copies_exact() {
        let mut row = [9i32; 6];
        pack_row(&mut row, &[1, 2, 3]);
        assert_eq!(row, [1, 2, 3, 0, 0, 0], "short request zero-pads");
        let mut row = [9i32; 4];
        pack_row(&mut row, &[1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(row, [1, 2, 3, 4], "long request truncates");
        let mut row = [9i32; 3];
        pack_row(&mut row, &[5, 6, 7]);
        assert_eq!(row, [5, 6, 7]);
        let mut empty: [i32; 0] = [];
        pack_row(&mut empty, &[1, 2]);
    }

    /// Regression for the residency-accounting bug: the GPU tier budget
    /// is documented as *decoded* adapter bytes, but residents were
    /// charged at their `encoded_bytes` — so the default 2 MiB budget
    /// "held" dozens of experts whose dense device buffers were each
    /// about the size of the whole budget.
    #[test]
    fn gpu_tier_budgets_dense_adapter_bytes() {
        let cfg = CoordinatorConfig::new(PathBuf::from("/nonexistent"), "s");
        let d = 1usize << 20; // a 1M-param LoRA adapter
        let mut rng = Pcg::seed(17);
        let tau = prop::task_vector_like(&mut rng, d);
        let tern = compress_vector(
            &tau,
            &CompressConfig { density: 0.05, alpha: 1.0, ..Default::default() },
        );
        let dense_bytes = d as u64 * 2; // fp16 device accounting
        let encoded_bytes = golomb::encoded_size_bytes(&tern);
        assert!(encoded_bytes * 8 < dense_bytes, "fixture must be compressible");

        // Dense charging (what the engine does now): the default 2 MiB
        // accelerator budget holds exactly one adapter of this size.
        let mut gpu: LruTier<()> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
        for i in 0..4 {
            gpu.insert(&format!("e{i}"), (), dense_bytes.max(1));
        }
        assert_eq!(gpu.len(), 1, "dense charging: ~1 resident at 2 MiB");
        assert_eq!(gpu.stats().evictions, 3);

        // Encoded charging (the bug): dozens of phantom residents whose
        // actual device footprint overflows the budget many times over.
        let mut wrong: LruTier<()> = LruTier::new("gpu", cfg.gpu_capacity_bytes);
        for i in 0..64 {
            wrong.insert(&format!("e{i}"), (), encoded_bytes.max(1));
        }
        assert!(
            wrong.len() >= 8,
            "encoded charging admitted only {} residents — fixture too large?",
            wrong.len()
        );
        assert!(
            wrong.len() as u64 * dense_bytes > cfg.gpu_capacity_bytes * 8,
            "the phantom residents' dense footprint must dwarf the budget"
        );
    }
}
